"""Shared benchmark scaffolding: scaled-down paper workload + CSV helpers.

The paper drives 136M (Wiki) / 402M (Meme) tokens through a 100–200MB
table; we run the same *shape* of experiment at 1/128 scale (1–2M zipf
tokens, 1MB table) so the full suite completes in minutes on one CPU core.
All comparisons are within-suite, so the paper's *trends/ratios* are the
reproduction target (EXPERIMENTS.md §Paper), not absolute times.
"""
from __future__ import annotations

import sys
import time
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.core import TableGeometry, make_table  # noqa: E402
from repro.core import DEVICES as DEVICES  # noqa: E402  (re-export)

# 64 blocks × 32 pages × 64 entries = 131,072 entries ≈ 1MB of 8B pairs
GEOM = TableGeometry(num_blocks=16, pages_per_block=128, entries_per_page=64)

WIKI_TOKENS = 1_000_000     # unique/total ≈ 7% (paper Wiki: 7.1%)
MEME_TOKENS = 2_000_000     # unique/total ≈ 4% (paper Meme: 4.2%)

# --smoke (CI bench-smoke job): shrink workloads by this factor so the
# reduced suite finishes in a couple of minutes on one CPU core. Trends
# stay within-suite comparable; absolute numbers are not the target.
SMOKE_SCALE = 1


def set_smoke(scale: int = 16) -> None:
    global SMOKE_SCALE
    SMOKE_SCALE = max(int(scale), 1)


def smoke() -> bool:
    return SMOKE_SCALE > 1


# --slow: opt-in long-running sweeps (the paper's remaining fig4 axes on
# device: change-segment-size and RAM-buffer-size grids). Off by default
# so the CI bench-smoke job stays minutes-long.
SLOW = False


def set_slow() -> None:
    global SLOW
    SLOW = True


def slow_mode() -> bool:
    return SLOW


def corpus(name: str, n_tokens: int | None = None) -> np.ndarray:
    rng = np.random.default_rng(42 if name == "wiki" else 1337)
    n = (n_tokens or (WIKI_TOKENS if name == "wiki" else MEME_TOKENS)
         ) // SMOKE_SCALE
    a = 1.35 if name == "wiki" else 1.45
    return (rng.zipf(a, size=max(n, 1)) % (1 << 22)).astype(np.int64)


def build_table(scheme: str, ram_pct: float, cs_pct: float):
    return make_table(scheme, GEOM, ram_buffer_pct=ram_pct,
                      change_segment_pct=cs_pct)


def run_inserts(table, tokens: np.ndarray, chunk: int = 16384) -> float:
    t0 = time.time()
    table.insert_batch(tokens, chunk=chunk)
    table.finalize()
    return time.time() - t0


def run_interleaved_queries(table, tokens: np.ndarray, n_queries: int,
                            warm_frac: float = 0.25, seed: int = 0):
    """Paper §3.3: warm-start inserts, then interleave queries with the
    remaining inserts."""
    rng = np.random.default_rng(seed)
    warm = int(len(tokens) * warm_frac)
    table.insert_batch(tokens[:warm])
    rest = tokens[warm:]
    q_keys = rng.choice(tokens, size=n_queries)
    found = 0
    step = max(len(rest) // n_queries, 1)
    qi = 0
    for i in range(0, len(rest), 16384):
        table.insert_batch(rest[i:i + 16384])
        want = min(n_queries, (i + 16384) // step)
        while qi < want:
            if table.query(int(q_keys[qi])) != 0:
                found += 1
            qi += 1
    while qi < n_queries:
        if table.query(int(q_keys[qi])) != 0:
            found += 1
        qi += 1
    return found


def emit(rows, file=None):
    out = file or sys.stdout
    for name, us, derived in rows:
        print(f"{name},{us:.3f},{derived}", file=out, flush=True)


def _parse_derived(derived: str) -> dict:
    """Split a ``k=v;k=v;flag`` derived column into a JSON-able dict."""
    out: dict = {}
    for part in str(derived).split(";"):
        part = part.strip()
        if not part:
            continue
        if "=" in part:
            k, v = part.split("=", 1)
            try:
                out[k] = int(v)
            except ValueError:
                try:
                    out[k] = float(v)
                except ValueError:
                    out[k] = v
        else:
            out[part] = True
    return out


def rows_to_json(rows, meta: dict | None = None) -> dict:
    """Machine-readable twin of the CSV rows (``run.py --json``)."""
    return {
        "meta": meta or {},
        "rows": [{"name": name, "us_per_call": round(float(us), 3),
                  "derived": _parse_derived(derived),
                  "derived_raw": str(derived)}
                 for name, us, derived in rows],
    }


# acceptance floors per device-suite prefix: (derived field, floor)
# pairs — a row is gated on every listed field it carries. The floors
# are the PR acceptance ratios (ISSUE 2: fig3dev batched ≥10× per-key;
# ISSUE 3: fig4dev engine-buffered ≥5× per-call; ISSUE 5: fig4dev async
# ingest ≥1× the synchronous engine) — ``run.py --baseline`` fails the
# run if any current row drops below a floor.
ACCEPTANCE_FLOORS = {
    "fig3dev": (("speedup_vs_per_key", 10.0),
                # ISSUE 8: 100%-miss batches ride the Bloom fast path...
                ("miss_speedup_vs_filterless", 5.0),
                # ...and 0%-miss batches pay at most 2× for the pre-pass
                ("present_speedup_vs_filterless", 0.5)),
    "fig4dev": (("speedup_vs_per_call", 5.0),
                ("speedup_vs_sync", 1.0)),
    # ISSUE 9: continuous batching ≥2× the serial serve() loop on the
    # same trace, token-identical outputs, and ≥25% of prompt tokens
    # served from the paged prefix cache on the repeated-prefix trace
    "fig7dev": (("speedup_vs_serial", 2.0),
                ("identical_outputs", 1.0),
                ("cache_hit_rate", 0.25)),
    # ISSUE 10: the 2-process multihost rows (fields only they carry, so
    # the single-host fig6dev ladder is not gated by them): owner-aligned
    # waves stay carry-free on every host, every host actually hid
    # collective drain time behind ingest, and per-update efficiency vs
    # the single-host 1-shard baseline stays above a collapse-catching
    # floor. Both processes share one physical CPU (gloo over virtual
    # devices measures software overhead, not multi-chip bandwidth):
    # measured ≈2.3× in smoke, ≈0.5× at full load — 0.2 flags a
    # serialization regression without gating on machine noise.
    "fig6dev": (("carry_free", 1.0),
                ("overlap_us", 1.0),
                ("mh_weak_efficiency", 0.2)),
}


def compare_to_baseline(rows, baseline_path: str) -> bool:
    """Regression gate for the trajectory benchmarks (CI bench-smoke).

    Checks every current row covered by :data:`ACCEPTANCE_FLOORS`
    against its floor, printing the committed baseline's value (e.g.
    ``BENCH_PR3.json``) for reference. Returns False — and the caller
    exits nonzero — if any speedup regressed below its floor, or if a
    gated suite went missing: every suite that carries gated fields *in
    the committed baseline* must contribute at least one checked row to
    this run (ISSUE 10) — a renamed suite/field, or a multihost pair
    that silently failed to spawn, must not let the gate pass vacuously.
    (Running a ``--only`` subset against a full baseline therefore
    fails; gate subset runs against a matching baseline, or not at all.)
    """
    import json

    with open(baseline_path) as f:
        base = {r["name"]: r for r in json.load(f)["rows"]}
    # suites the gate *expects*: gated fields present in the baseline
    expected = {s for s, floors in ACCEPTANCE_FLOORS.items()
                for r in base.values()
                if r["name"].split("/")[0] == s
                and any(f in r.get("derived", {}) for f, _ in floors)}
    checked_by_suite: dict = {}
    failures = []
    for name, _us, derived in rows:
        suite = name.split("/")[0]
        if suite not in ACCEPTANCE_FLOORS:
            continue
        d = _parse_derived(derived)
        for field, floor in ACCEPTANCE_FLOORS[suite]:
            if field not in d:
                continue
            checked_by_suite[suite] = checked_by_suite.get(suite, 0) + 1
            cur = float(d[field])
            ref = base.get(name, {}).get("derived", {}).get(field)
            note = f"baseline={ref}" if ref is not None else "baseline=n/a"
            line = f"{name}: {field}={cur:.1f} floor={floor} {note}"
            if cur < floor:
                failures.append(line)
            else:
                print(f"# baseline-ok {line}", file=sys.stderr, flush=True)
    for line in failures:
        print(f"# REGRESSION {line}", file=sys.stderr, flush=True)
    missing = expected - set(checked_by_suite)
    for suite in sorted(missing):
        print(f"# REGRESSION baseline gate: suite {suite} carries "
              "acceptance fields in the baseline but contributed no "
              "checked rows to this run (gate fails closed)",
              file=sys.stderr, flush=True)
    if not checked_by_suite:
        print("# REGRESSION baseline gate matched no rows: acceptance "
              "suites/fields missing from this run (gate fails closed)",
              file=sys.stderr, flush=True)
        return False
    return not failures and not missing
