"""Benchmark suite entry point — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
  fig3*   — paper Figure 3 (query times)           bench_query_times
  fig4*   — paper Figure 4 + §3.5 naive (I/O cost) bench_io_costs
  fig5*   — paper Figure 5 (cleans)                bench_cleans
  table2* — paper Table 2 (op mix)                 bench_block_page_ops
  kernel* — Pallas flash-hash microbench           bench_kernels
  roofline* — dry-run-derived roofline terms       bench_roofline

Run: ``PYTHONPATH=src python -m benchmarks.run [--only fig3,...]``
"""
from __future__ import annotations

import argparse
import sys
import time

from . import (bench_block_page_ops, bench_cleans, bench_io_costs,
               bench_kernels, bench_query_times, bench_roofline)
from .common import emit

SUITES = {
    "fig3": bench_query_times,
    "fig4": bench_io_costs,
    "fig5": bench_cleans,
    "table2": bench_block_page_ops,
    "kernel": bench_kernels,
    "roofline": bench_roofline,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated suite names (default: all)")
    args = ap.parse_args()
    names = list(SUITES) if not args.only else args.only.split(",")
    rows = []
    print("name,us_per_call,derived")
    for name in names:
        t0 = time.time()
        suite_rows = []
        SUITES[name].run(suite_rows)
        emit(suite_rows)
        rows.extend(suite_rows)
        print(f"# suite {name}: {len(suite_rows)} rows in "
              f"{time.time() - t0:.1f}s", file=sys.stderr, flush=True)


if __name__ == "__main__":
    main()
