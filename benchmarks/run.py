"""Benchmark suite entry point — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
  fig3*   — paper Figure 3 (query times)           bench_query_times
  fig3dev — per-key vs batched device query engine bench_query_times
  fig4*   — paper Figure 4 + §3.5 naive (I/O cost) bench_io_costs
  fig5*   — paper Figure 5 (cleans)                bench_cleans
  fig6dev — sharded FlashStore weak scaling        bench_weak_scaling
  fig7dev — continuous-batching serving traffic    bench_serving
  table2* — paper Table 2 (op mix)                 bench_block_page_ops
  kernel* — Pallas flash-hash microbench           bench_kernels
  roofline* — dry-run-derived roofline terms       bench_roofline

Run: ``PYTHONPATH=src python -m benchmarks.run [--only fig3,...]
[--smoke] [--json PATH]``

``--json PATH`` additionally writes the rows as machine-readable JSON
(name, us_per_call, parsed derived fields) — the artifact CI's
bench-smoke job uploads, and the format of the committed
``BENCH_PR*.json`` trajectory files. ``--smoke`` shrinks the workloads
for a minutes-long CI run. ``--baseline PATH`` compares the device
acceptance rows (fig3dev batched speedup, fig4dev engine-buffered
speedup) against their floors, printing the committed trajectory file's
values for reference, and exits nonzero on a regression — the CI
bench-smoke gate. ``--slow`` opts into the long-running fig4dev
change-segment-size and RAM-buffer-size sweeps (the paper's remaining
Figure-4 axes on device).
"""
from __future__ import annotations

import argparse
import json
import platform
import sys
import time

from . import (bench_block_page_ops, bench_cleans, bench_io_costs,
               bench_kernels, bench_query_times, bench_roofline,
               bench_serving, bench_weak_scaling)
from .common import (compare_to_baseline, emit, rows_to_json, set_slow,
                     set_smoke)

SUITES = {
    "fig3": bench_query_times,
    "fig4": bench_io_costs,
    "fig5": bench_cleans,
    "fig6": bench_weak_scaling,
    "fig7": bench_serving,
    "table2": bench_block_page_ops,
    "kernel": bench_kernels,
    "roofline": bench_roofline,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated suite names (default: all)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows as machine-readable JSON")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced workloads (CI bench-smoke job)")
    ap.add_argument("--slow", action="store_true",
                    help="include long-running sweeps (fig4dev change-"
                         "segment-size and RAM-buffer-size grids)")
    ap.add_argument("--baseline", default=None, metavar="PATH",
                    help="compare acceptance rows against this committed "
                         "BENCH_PR*.json; exit 1 if any speedup falls "
                         "below its floor")
    args = ap.parse_args()
    if args.smoke:
        set_smoke()
    if args.slow:
        set_slow()
    names = list(SUITES) if not args.only else args.only.split(",")
    rows = []
    suite_secs = {}
    print("name,us_per_call,derived")
    try:
        for name in names:
            t0 = time.time()
            suite_rows = []
            SUITES[name].run(suite_rows)
            emit(suite_rows)
            rows.extend(suite_rows)
            suite_secs[name] = round(time.time() - t0, 1)
            print(f"# suite {name}: {len(suite_rows)} rows in "
                  f"{suite_secs[name]}s", file=sys.stderr, flush=True)
    finally:
        # write whatever completed even if a suite raised, so the CI
        # artifact always carries the rows gathered up to the failure
        if args.json:
            from .common import SMOKE_SCALE as scale  # set_smoke may run
            payload = rows_to_json(rows, meta={
                "suites": names,
                "suite_seconds": suite_secs,
                "smoke_scale": scale,
                "python": platform.python_version(),
                "platform": platform.platform(),
            })
            with open(args.json, "w") as f:
                json.dump(payload, f, indent=1)
                f.write("\n")
            print(f"# wrote {len(rows)} rows to {args.json}",
                  file=sys.stderr, flush=True)
    if args.baseline:
        if not compare_to_baseline(rows, args.baseline):
            sys.exit(1)


if __name__ == "__main__":
    main()
