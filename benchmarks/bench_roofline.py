"""Deliverable (g): roofline terms per (arch × shape) from dry-run artifacts.

Hardware model (TPU v5e per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI. All artifact quantities are per-device (the SPMD
partition program), so:

    compute term    = dot_flops / 197e12                [s]
    memory term     = hbm_bytes / 819e9                 [s]
    collective term = collective_operand_bytes / 50e9   [s]

Dominant term = bottleneck. Step time under perfect overlap = max(terms);
MFU-proxy ("roofline fraction") = MODEL_FLOPS_per_chip / (197e12 ×
max(terms)), with MODEL_FLOPS = 6·N(active)·D for training (fwd+bwd) and
2·N(active)·D for inference shapes.
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

ART = Path(__file__).resolve().parent.parent / "artifacts" / "dryrun"

SHAPE_TOKENS = {
    "train_4k": 4096 * 256,
    "prefill_32k": 32768 * 32,
    "decode_32k": 128,        # one token per sequence
    "long_500k": 1,
}


def model_flops(rec) -> float:
    d_tokens = SHAPE_TOKENS[rec["shape"]]
    n = rec["active_params"]
    mult = 6.0 if rec["kind"] == "train" else 2.0
    return mult * n * d_tokens


def analyze(rec) -> dict:
    chips = rec["chips"]
    t_comp = rec["flops"] / PEAK_FLOPS
    # optimized variant: attention realized by the fused Pallas flash
    # kernel → S×S tiles never reach HBM (bytes_accessed_flashproj)
    mem_key = ("bytes_accessed_flashproj"
               if rec.get("variant") == "opt"
               and "bytes_accessed_flashproj" in rec else "bytes_accessed")
    t_mem = rec[mem_key] / HBM_BW
    t_coll = rec["collectives"]["total_bytes"] / LINK_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    t_star = max(terms.values())
    mf = model_flops(rec) / chips
    mfu = mf / (PEAK_FLOPS * max(t_star, 1e-30))
    # decode shapes are inherently memory-bound: report how close the
    # traffic is to the params-read lower bound instead
    min_bytes = 2.0 * rec["active_params"] / chips
    mem_eff = min_bytes / max(rec[mem_key], 1e-30)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "variant": rec.get("variant", "baseline"),
        "mem_efficiency": mem_eff,
        "compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll,
        "dominant": dominant, "step_s_overlap": t_star,
        "model_flops_per_chip": mf,
        "useful_flops_ratio": mf / max(rec["flops"], 1e-30),
        "roofline_fraction": mfu,
    }


def load_records(mesh: str = "16_16", variant: str = "baseline"):
    d = ART if variant == "baseline" else ART.parent / "dryrun_opt"
    recs = []
    for p in sorted(d.glob(f"*__{mesh}.json")):
        recs.append(json.loads(p.read_text()))
    return recs


def run(rows):
    for variant in ("baseline", "opt"):
        recs = load_records("16_16", variant)
        for rec in recs:
            a = analyze(rec)
            rows.append((
                f"roofline/{variant}/{a['arch']}/{a['shape']}",
                a["step_s_overlap"] * 1e6,
                f"dom={a['dominant']};comp_s={a['compute_s']:.4e};"
                f"mem_s={a['memory_s']:.4e};coll_s={a['collective_s']:.4e};"
                f"mfu={a['roofline_fraction']:.3f};"
                f"useful={a['useful_flops_ratio']:.2f};"
                f"mem_eff={a['mem_efficiency']:.3f}"))
    if not rows:
        rows.append(("roofline/missing", 0.0,
                     "run `python -m repro.launch.dryrun --all` first"))
    return rows


def table(variant: str = "baseline") -> str:
    """Markdown table for EXPERIMENTS.md."""
    recs = load_records("16_16", variant)
    lines = ["| arch | shape | compute s | memory s | collective s | "
             "dominant | MFU-proxy | useful/HLO |",
             "|---|---|---|---|---|---|---|---|"]
    for rec in recs:
        a = analyze(rec)
        lines.append(
            f"| {a['arch']} | {a['shape']} | {a['compute_s']:.4e} | "
            f"{a['memory_s']:.4e} | {a['collective_s']:.4e} | "
            f"{a['dominant']} | {a['roofline_fraction']:.3f} | "
            f"{a['useful_flops_ratio']:.2f} |")
    return "\n".join(lines)


if __name__ == "__main__":
    if len(sys.argv) > 1 and sys.argv[1] == "--table":
        print(table(sys.argv[2] if len(sys.argv) > 2 else "baseline"))
    else:
        rows = []
        run(rows)
        from .common import emit
        emit(rows)
