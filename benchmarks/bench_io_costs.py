"""Paper Figure 4 (+§3.5 naive baseline): total I/O cost of insert-only
workloads per scheme × SSD configuration × dataset."""
from __future__ import annotations

from .common import DEVICES, build_table, corpus, emit, run_inserts


def run(rows, include_naive: bool = True):
    for dataset in ("wiki", "meme"):
        tokens = corpus(dataset)
        base_times = {}
        for scheme in ("MB", "MDB", "MDB-L"):
            t = build_table(scheme, 5.0, 12.5)
            run_inserts(t, tokens)
            for dev_name, dev in DEVICES.items():
                io_s = t.ledger.time_us(dev) / 1e6
                base_times[(scheme, dev_name)] = io_s
                rows.append((f"fig4/{dataset}/{scheme}/{dev_name}",
                             io_s * 1e6,
                             f"io_s={io_s:.3f};cleans={t.ledger.cleans};"
                             f"block_ops={t.ledger.block_ops};"
                             f"page_ops={t.ledger.page_ops}"))
        if include_naive:
            t = build_table("naive", 0.0, 0.0)
            run_inserts(t, tokens)
            for dev_name, dev in DEVICES.items():
                io_s = t.ledger.time_us(dev) / 1e6
                best = min(base_times[(s, dev_name)]
                           for s in ("MB", "MDB", "MDB-L"))
                rows.append((f"fig4naive/{dataset}/naive/{dev_name}",
                             io_s * 1e6,
                             f"io_s={io_s:.3f};cleans={t.ledger.cleans};"
                             f"slowdown_vs_best={io_s / max(best, 1e-9):.0f}x"))
    return rows


if __name__ == "__main__":
    rows = []
    run(rows)
    emit(rows)
