"""Paper Figure 4 (+§3.5 naive baseline): total I/O cost of insert-only
workloads per scheme × SSD configuration × dataset.

fig4dev (beyond paper): the same insert/update axis on the *device*
table, in three write regimes — one jitted (un-donated) ``update`` per
raw micro-batch (the pre-PR3 writer path), the batched write engine
(host H_R dedup, threshold flushes, EMPTY-padded fixed-shape donated
dispatches — the PR-3 acceptance rows), and the engine draining through
the async double-buffered dispatcher vs its synchronous twin (DESIGN.md
§9 — the PR-5 acceptance rows, ``fig4dev_async``).
"""
from __future__ import annotations

import time

import numpy as np

from . import common as _common
from .common import (DEVICES, build_table, corpus, emit, run_inserts,
                     slow_mode, smoke)

N_DEV_UPDATES = 200_000     # the ISSUE-3 acceptance stream
DEV_BATCH = 128             # per-call micro-batch (one ingest document)
N_SWEEP_UPDATES = 100_000   # per grid point of the --slow sweeps


def fig4dev(rows):
    """Per-call vs engine-buffered vs async device updates — the ISSUE-3
    and ISSUE-5 acceptance rows.

    A 200k-update skewed (zipf) stream against the on-device table (all
    three schemes), written (a) with one un-donated jitted ``update`` per
    128-token micro-batch — exactly the old writer discipline — and (b)
    through ``BatchedWriteEngine`` (same arrival pattern, H_R-buffered,
    synchronous drains). The derived columns record the throughput
    ratio, that both final tables hold identical counts
    (``contents_equal``), and that replaying the engine's recorded
    dispatch chunks through direct per-call updates reproduces the
    engine state bit-identically — wear counters included
    (``replay_bitident``).
    """
    import jax
    import jax.numpy as jnp

    from repro.core import table_jax as tj
    from repro.core.query_engine import BatchedQueryEngine
    from repro.core.write_engine import BatchedWriteEngine

    # fixed: the full 200k acceptance workload even under --smoke
    # (mirrors fig3dev) — a shrunk stream never fills the change segment,
    # so fixed per-run costs dominate and the speedup loses meaning.
    # --smoke instead restricts the schemes (MB's per-call run is the
    # long one; MDB-L covers the gate in seconds).
    toks = corpus("wiki", N_DEV_UPDATES * _common.SMOKE_SCALE)
    n = toks.size
    schemes = ("MDB-L",) if smoke() else ("MB", "MDB", "MDB-L")
    chunk, threshold = 4096, 8192
    for scheme in schemes:
        cfg = tj.FlashTableConfig(q_log2=15, r_log2=9, scheme=scheme)
        # warm the compile caches outside the timed regions: the per-call
        # (DEV_BATCH,) tokens program (and the tail batch's shape, when
        # the stream is not a DEV_BATCH multiple) + flush, and the
        # engine's (chunk,) deltas program, all on throwaway states
        warm = tj.update_copying(cfg, tj.init(cfg),
                                 jnp.asarray(toks[:DEV_BATCH], jnp.int32))
        tail = n % DEV_BATCH
        if tail:
            warm = tj.update_copying(cfg, warm,
                                     jnp.asarray(toks[:tail], jnp.int32))
        tj.flush(cfg, warm)
        weng = BatchedWriteEngine(cfg, chunk=chunk, flush_threshold=1)
        weng.update(np.arange(8))
        weng.merge()
        # (a) unbuffered per-call: one un-donated update per micro-batch
        st_a = tj.init(cfg)
        t0 = time.time()
        for i in range(0, n, DEV_BATCH):
            st_a = tj.update_copying(
                cfg, st_a, jnp.asarray(toks[i:i + DEV_BATCH], jnp.int32))
        st_a = tj.flush(cfg, st_a)
        jax.block_until_ready(st_a)
        per_call = time.time() - t0
        # (b) engine-buffered: same arrival pattern through H_R
        rec = []
        eng = BatchedWriteEngine(cfg, chunk=chunk, flush_threshold=threshold,
                                 record=rec)
        t0 = time.time()
        for i in range(0, n, DEV_BATCH):
            eng.update(toks[i:i + DEV_BATCH])
        eng.merge()
        jax.block_until_ready(eng.state)
        buffered = time.time() - t0
        # identical final contents: every touched key answers the same
        uniq = np.unique(toks)
        qa = BatchedQueryEngine(cfg, hot_capacity=0).query_batch(st_a, uniq)
        qb = BatchedQueryEngine(cfg, hot_capacity=0).query_batch(eng.state,
                                                                 uniq)
        assert (qa == qb).all(), f"{scheme}: buffered contents diverged"
        # bit-identity (incl. TableStats wear): direct per-call dispatch
        # of the engine's recorded chunks reproduces the engine state
        st_c = tj.init(cfg)
        for pk, pd in rec:
            st_c = tj.update_copying(cfg, st_c, jnp.asarray(pk, jnp.int32),
                                     jnp.asarray(pd, jnp.int32))
        st_c = tj.flush(cfg, st_c)
        for a, b in zip(jax.tree.leaves(st_c), jax.tree.leaves(eng.state)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        speedup = per_call / max(buffered, 1e-9)
        w = eng.stats
        calls = -(-n // DEV_BATCH)
        rows.append((f"fig4dev/{scheme}/per_call_{n}",
                     per_call / n * 1e6,
                     f"updates={n};batch={DEV_BATCH};calls={calls};"
                     f"path=update_per_call;"
                     f"tile_stores={int(st_a.stats.tile_stores)};"
                     f"staged={int(st_a.stats.staged_entries)};"
                     f"dropped={int(st_a.stats.dropped)}"))
        rows.append((f"fig4dev/{scheme}/buffered_{n}",
                     buffered / n * 1e6,
                     f"updates={n};path=write_engine;"
                     f"speedup_vs_per_call={speedup:.1f};"
                     f"flushes={w.flushes};dispatches={w.dispatches};"
                     f"deduped={w.deduped};"
                     f"dispatched={w.dispatched_entries};"
                     f"tile_stores={int(eng.state.stats.tile_stores)};"
                     f"dropped={int(eng.state.stats.dropped)};"
                     f"contents_equal=1;replay_bitident=1"))


def fig4dev_async(rows):
    """Sync vs async double-buffered ingest — the ISSUE-5 acceptance rows.

    The 200k-update zipf stream through ``BatchedWriteEngine`` at an H_R
    of 4096 entries (several mid-stream threshold drains — the regime
    double buffering exists for), draining synchronously vs through the
    async dispatcher (DESIGN.md §9). Both engines honor the store's
    durable-drain contract (a completed drain is device-complete, not
    queued): the sync engine pays that latency inline, stalling ingest;
    the async engine hides it on the drain worker while H_R keeps
    filling.

    Timed on the *ingest phase* (the update loop — the end-of-stream
    durability merge is checkpoint cost and cannot overlap ingest by
    definition), best-of-3 interleaved reps per engine. The async row's
    ``speedup_vs_sync`` is the ISSUE-5 acceptance floor (≥1×), and the
    full-run ``stall_us`` must come out strictly below the sync engine's
    (``stall_reduced``, asserted), with both final tables identical
    (``contents_equal``). Under ``--smoke`` only MB runs — the
    merge-per-drain scheme with the largest drain latency to hide; the
    full run records all three schemes.
    """
    import jax

    from repro.core import table_jax as tj
    from repro.core.query_engine import BatchedQueryEngine
    from repro.core.store import FlushDispatcher
    from repro.core.write_engine import BatchedWriteEngine

    toks = corpus("wiki", N_DEV_UPDATES * _common.SMOKE_SCALE)
    n = toks.size
    chunk = threshold = 4096
    schemes = ("MB",) if smoke() else ("MB", "MDB", "MDB-L")
    for scheme in schemes:
        cfg = tj.FlashTableConfig(q_log2=15, r_log2=9, scheme=scheme)
        warm = BatchedWriteEngine(cfg, chunk=chunk, flush_threshold=1)
        warm.update(np.arange(8))
        warm.merge()
        best = {"sync": None, "async": None}
        for _rep in range(3):               # interleaved: noise hits both
            for mode, enabled in (("sync", False), ("async", True)):
                eng = BatchedWriteEngine(
                    cfg, chunk=chunk, flush_threshold=threshold,
                    dispatcher=FlushDispatcher(enabled=enabled))
                t0 = time.time()
                for i in range(0, n, DEV_BATCH):
                    eng.update(toks[i:i + DEV_BATCH])
                ingest = time.time() - t0
                eng.merge(wait=True)
                jax.block_until_ready(eng.state)
                eng.dispatcher.close()
                if best[mode] is None or ingest < best[mode][0]:
                    best[mode] = (ingest, eng)
        sync_s, seng = best["sync"]
        async_s, aeng = best["async"]
        uniq = np.unique(toks)
        qs = BatchedQueryEngine(cfg, hot_capacity=0).query_batch(seng.state,
                                                                 uniq)
        qc = BatchedQueryEngine(cfg, hot_capacity=0).query_batch(aeng.state,
                                                                 uniq)
        assert (qs == qc).all(), f"{scheme}: async contents diverged"
        # ISSUE-5 acceptance: hiding drains behind ingest must strictly
        # reduce the measured ingest stall
        assert aeng.stats.stall_us < seng.stats.stall_us, (
            f"{scheme}: async stall {aeng.stats.stall_us}us did not "
            f"improve on sync {seng.stats.stall_us}us")
        speedup_async = sync_s / max(async_s, 1e-9)
        ws, wa = seng.stats, aeng.stats
        rows.append((f"fig4dev/{scheme}/sync_ingest_{n}",
                     sync_s / n * 1e6,
                     f"updates={n};path=write_engine_sync;reps=3;"
                     f"flush_threshold={threshold};"
                     f"flushes={ws.flushes};dispatches={ws.dispatches};"
                     f"stall_us={ws.stall_us};overlap_us={ws.overlap_us};"
                     f"tile_stores={int(seng.state.stats.tile_stores)};"
                     f"dropped={int(seng.state.stats.dropped)}"))
        rows.append((f"fig4dev/{scheme}/async_{n}",
                     async_s / n * 1e6,
                     f"updates={n};path=write_engine_async;reps=3;"
                     f"flush_threshold={threshold};"
                     f"speedup_vs_sync={speedup_async:.2f};"
                     f"flushes={wa.flushes};dispatches={wa.dispatches};"
                     f"stall_us={wa.stall_us};overlap_us={wa.overlap_us};"
                     f"tile_stores={int(aeng.state.stats.tile_stores)};"
                     f"dropped={int(aeng.state.stats.dropped)};"
                     f"contents_equal=1;stall_reduced=1"))


def fig4dev_sweeps(rows):
    """Paper Figure 4's remaining axes on the *device* table (--slow):
    the change-segment-size sweep (MDB-L ``log_capacity`` — the paper's
    x-axis in Fig 4 right) and the RAM-buffer-size sweep (H_R
    ``flush_threshold`` — Fig 4 left), each at a fixed zipf stream
    through the FlashStore facade. Expected trends: a larger change
    segment amortizes merges (fewer tile rewrites); a larger H_R absorbs
    more duplicates before dispatch (fewer dispatched entries)."""
    import time as _time

    import jax

    from repro.core import table_jax as tj
    from repro.core.store import FlashStore

    toks = corpus("wiki", N_SWEEP_UPDATES * _common.SMOKE_SCALE)
    n = toks.size

    def drive(store):
        t0 = _time.time()
        for i in range(0, n, DEV_BATCH):
            store.update(toks[i:i + DEV_BATCH])
        store.flush()
        jax.block_until_ready(store.state)
        return _time.time() - t0

    # (a) change-segment size: log_capacity from 1/8 to 2× the default
    for cap_log2 in (11, 12, 13, 14, 15):
        cfg = tj.FlashTableConfig(q_log2=15, r_log2=9, scheme="MDB-L",
                                  log_capacity=1 << cap_log2)
        store = FlashStore.open(cfg, backend="device", chunk=4096,
                                flush_threshold=8192)
        secs = drive(store)
        w, s = store.wear(), store.stats()
        rows.append((f"fig4dev_sweep/cs/MDB-L/log2_{cap_log2}",
                     secs / n * 1e6,
                     f"updates={n};log_capacity={1 << cap_log2};"
                     f"tile_stores={w['tile_stores']};"
                     f"merges={w['merges']};staged={w['staged_entries']};"
                     f"dispatched={s['write_dispatched_entries']};"
                     f"dropped={w['dropped']}"))
        store.close()
    # (b) RAM-buffer size: H_R flush threshold from 1k to 64k entries
    for thr_log2 in (10, 12, 14, 16):
        cfg = tj.FlashTableConfig(q_log2=15, r_log2=9, scheme="MDB-L")
        store = FlashStore.open(cfg, backend="device", chunk=4096,
                                flush_threshold=1 << thr_log2)
        secs = drive(store)
        w, s = store.wear(), store.stats()
        rows.append((f"fig4dev_sweep/hr/MDB-L/log2_{thr_log2}",
                     secs / n * 1e6,
                     f"updates={n};flush_threshold={1 << thr_log2};"
                     f"tile_stores={w['tile_stores']};"
                     f"flushes={s['write_flushes']};"
                     f"deduped={s['write_deduped']};"
                     f"dispatched={s['write_dispatched_entries']};"
                     f"dropped={w['dropped']}"))
        store.close()


def run(rows, include_naive: bool = True):
    for dataset in ("wiki", "meme"):
        tokens = corpus(dataset)
        base_times = {}
        for scheme in ("MB", "MDB", "MDB-L"):
            t = build_table(scheme, 5.0, 12.5)
            run_inserts(t, tokens)
            for dev_name, dev in DEVICES.items():
                io_s = t.ledger.time_us(dev) / 1e6
                base_times[(scheme, dev_name)] = io_s
                rows.append((f"fig4/{dataset}/{scheme}/{dev_name}",
                             io_s * 1e6,
                             f"io_s={io_s:.3f};cleans={t.ledger.cleans};"
                             f"block_ops={t.ledger.block_ops};"
                             f"page_ops={t.ledger.page_ops}"))
        if include_naive:
            t = build_table("naive", 0.0, 0.0)
            run_inserts(t, tokens)
            for dev_name, dev in DEVICES.items():
                io_s = t.ledger.time_us(dev) / 1e6
                best = min(base_times[(s, dev_name)]
                           for s in ("MB", "MDB", "MDB-L"))
                rows.append((f"fig4naive/{dataset}/naive/{dev_name}",
                             io_s * 1e6,
                             f"io_s={io_s:.3f};cleans={t.ledger.cleans};"
                             f"slowdown_vs_best={io_s / max(best, 1e-9):.0f}x"))
    fig4dev(rows)
    fig4dev_async(rows)
    if slow_mode():
        fig4dev_sweeps(rows)
    return rows


if __name__ == "__main__":
    rows = []
    run(rows)
    emit(rows)
