"""Paper Table 2: block/page-level operations, merges and stages for each
scheme over (change-segment %) × (RAM buffer %), Wiki workload."""
from __future__ import annotations

from .common import build_table, corpus, emit, run_inserts


def run(rows):
    tokens = corpus("wiki")
    for cs in (50.0, 25.0, 12.5):
        for ram in (1.0, 2.0, 5.0, 10.0):
            for scheme in ("MB", "MDB", "MDB-L"):
                t = build_table(scheme, ram, cs)
                run_inserts(t, tokens)
                led = t.ledger
                frac = led.block_op_fraction() * 100
                rows.append((
                    f"table2/{scheme}/cs={cs}/ram={ram}",
                    float(led.block_ops),
                    f"block={led.block_ops};page={led.page_ops};"
                    f"block_frac={frac:.2f}%;merges={led.merges};"
                    f"stages={led.stages}"))
    return rows


if __name__ == "__main__":
    rows = []
    run(rows)
    emit(rows)
