"""Paper Figure 3: average query time — plus the batched-regime rows.

(a) vs change-segment size (RAM fixed 5%), (b) vs RAM buffer size (CS fixed
12.5%), (c) across SSD configurations (RAM 5%, CS 12.5%) — update-intensive
interleaved workload per §3.4.

fig3dev (beyond paper): the same query axis on the *device* table, in
both serving regimes — one jitted lookup per key (the pre-engine path)
vs the batched query engine (dedup + fixed-shape chunks + one
change-segment scan per chunk) — so Figure 3 reflects per-key and
batched serving side by side.
"""
from __future__ import annotations

import time

import numpy as np

from .common import (DEVICES, build_table, corpus, emit,
                     run_interleaved_queries, smoke)

N_QUERIES = 4000


def _n_queries() -> int:
    """Sim-figure query count; reduced under --smoke (fig3dev keeps the
    full 4000-key acceptance workload regardless)."""
    return max(N_QUERIES // 16, 250) if smoke() else N_QUERIES


def _avg_query_ms(table, dev) -> float:
    return table.qstats.avg_time_ms(dev)


def fig3a(tokens, rows, dataset):
    dev = DEVICES["MLC-1"]
    for cs in (50.0, 25.0, 12.5):
        for scheme in ("MB", "MDB", "MDB-L"):
            t = build_table(scheme, 5.0, cs)
            run_interleaved_queries(t, tokens, _n_queries())
            ms = _avg_query_ms(t, dev)
            rows.append((f"fig3a/{dataset}/{scheme}/cs={cs}", ms * 1000,
                         f"avg_query_ms={ms:.4f}"))


def fig3b(tokens, rows, dataset):
    dev = DEVICES["MLC-1"]
    for ram in (1.0, 2.0, 5.0, 10.0):
        for scheme in ("MB", "MDB", "MDB-L"):
            t = build_table(scheme, ram, 12.5)
            run_interleaved_queries(t, tokens, _n_queries())
            ms = _avg_query_ms(t, dev)
            rows.append((f"fig3b/{dataset}/{scheme}/ram={ram}", ms * 1000,
                         f"avg_query_ms={ms:.4f}"))


def fig3c(tokens, rows, dataset):
    for dev_name, dev in DEVICES.items():
        for scheme in ("MB", "MDB", "MDB-L"):
            t = build_table(scheme, 5.0, 12.5)
            run_interleaved_queries(t, tokens, _n_queries())
            ms = _avg_query_ms(t, dev)
            rows.append((f"fig3c/{dataset}/{scheme}/{dev_name}", ms * 1000,
                         f"avg_query_ms={ms:.4f}"))


def fig3dev(rows):
    """Per-key vs batched device queries — the PR-2 acceptance rows.

    A 4000-key query workload against the on-device table (all three
    schemes), answered (a) with one jitted ``lookup`` per key — exactly
    the pre-engine per-key loop — and (b) through the store's batched
    query engine in a single ``query_batch`` call. The derived column on
    the batched row records the throughput ratio.
    """
    import jax.numpy as jnp

    from repro.core import table_jax as tj
    from repro.core.store import FlashStore

    n_q = 4000  # fixed: the acceptance workload, even under --smoke
    rng = np.random.default_rng(7)
    toks = corpus("wiki", 320_000)  # /smoke_scale inside corpus()
    schemes = ("MDB-L",) if smoke() else ("MB", "MDB", "MDB-L")
    for scheme in schemes:
        t = FlashStore.open(tj.FlashTableConfig(q_log2=15, r_log2=9,
                                                scheme=scheme),
                            backend="device")
        t.update(toks)
        t.flush()
        uniq = np.unique(toks)
        q_keys = rng.choice(uniq, size=n_q, replace=uniq.size < n_q)
        # (a) per-key: one jitted lookup per key, batch shape (1,)
        warm = jnp.asarray([int(q_keys[0])], jnp.int32)
        int(tj.lookup(t.cfg, t.state, warm)[0][0])     # compile Q=1
        t0 = time.time()
        hits = 0
        for k in q_keys:
            cnt, _ = tj.lookup(t.cfg, t.state,
                               jnp.asarray([int(k)], jnp.int32))
            hits += int(cnt[0]) != 0
        per_key = time.time() - t0
        # (b) batched: one store call, cold hot-key cache (warm the
        # compiled chunk shape on keys outside the workload so nothing
        # is served from cache in the timed run)
        t.query_batch(np.arange(1 << 23, (1 << 23) + 8))
        t._b.query_engine.invalidate()
        t0 = time.time()
        out = t.query_batch(q_keys)
        batched = time.time() - t0
        assert int((out != 0).sum()) == hits           # identical answers
        speedup = per_key / max(batched, 1e-9)
        rows.append((f"fig3dev/{scheme}/per_key_{n_q}",
                     per_key / n_q * 1e6,
                     f"queries={n_q};path=lookup_per_key;found={hits}"))
        rows.append((f"fig3dev/{scheme}/batched_{n_q}",
                     batched / n_q * 1e6,
                     f"queries={n_q};path=query_batch;"
                     f"speedup_vs_per_key={speedup:.1f}"))
        t.close()


def run(rows):
    for dataset in ("wiki", "meme"):
        tokens = corpus(dataset)
        fig3a(tokens, rows, dataset)
        fig3b(tokens, rows, dataset)
        if dataset == "wiki":
            fig3c(tokens, rows, dataset)
    fig3dev(rows)
    return rows


if __name__ == "__main__":
    rows = []
    run(rows)
    emit(rows)
