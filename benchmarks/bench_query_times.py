"""Paper Figure 3: average query time.

(a) vs change-segment size (RAM fixed 5%), (b) vs RAM buffer size (CS fixed
12.5%), (c) across SSD configurations (RAM 5%, CS 12.5%) — update-intensive
interleaved workload per §3.4.
"""
from __future__ import annotations

from .common import DEVICES, build_table, corpus, emit, run_interleaved_queries

N_QUERIES = 4000


def _avg_query_ms(table, dev) -> float:
    return table.qstats.avg_time_ms(dev)


def fig3a(tokens, rows, dataset):
    dev = DEVICES["MLC-1"]
    for cs in (50.0, 25.0, 12.5):
        for scheme in ("MB", "MDB", "MDB-L"):
            t = build_table(scheme, 5.0, cs)
            run_interleaved_queries(t, tokens, N_QUERIES)
            ms = _avg_query_ms(t, dev)
            rows.append((f"fig3a/{dataset}/{scheme}/cs={cs}", ms * 1000,
                         f"avg_query_ms={ms:.4f}"))


def fig3b(tokens, rows, dataset):
    dev = DEVICES["MLC-1"]
    for ram in (1.0, 2.0, 5.0, 10.0):
        for scheme in ("MB", "MDB", "MDB-L"):
            t = build_table(scheme, ram, 12.5)
            run_interleaved_queries(t, tokens, N_QUERIES)
            ms = _avg_query_ms(t, dev)
            rows.append((f"fig3b/{dataset}/{scheme}/ram={ram}", ms * 1000,
                         f"avg_query_ms={ms:.4f}"))


def fig3c(tokens, rows, dataset):
    for dev_name, dev in DEVICES.items():
        for scheme in ("MB", "MDB", "MDB-L"):
            t = build_table(scheme, 5.0, 12.5)
            run_interleaved_queries(t, tokens, N_QUERIES)
            ms = _avg_query_ms(t, dev)
            rows.append((f"fig3c/{dataset}/{scheme}/{dev_name}", ms * 1000,
                         f"avg_query_ms={ms:.4f}"))


def run(rows):
    for dataset in ("wiki", "meme"):
        tokens = corpus(dataset)
        fig3a(tokens, rows, dataset)
        fig3b(tokens, rows, dataset)
        if dataset == "wiki":
            fig3c(tokens, rows, dataset)
    return rows


if __name__ == "__main__":
    rows = []
    run(rows)
    emit(rows)
