"""Paper Figure 3: average query time — plus the batched-regime rows.

(a) vs change-segment size (RAM fixed 5%), (b) vs RAM buffer size (CS fixed
12.5%), (c) across SSD configurations (RAM 5%, CS 12.5%) — update-intensive
interleaved workload per §3.4.

fig3dev (beyond paper): the same query axis on the *device* table, in
both serving regimes — one jitted lookup per key (the pre-engine path)
vs the batched query engine (dedup + fixed-shape chunks + one
change-segment scan per chunk) — so Figure 3 reflects per-key and
batched serving side by side.
"""
from __future__ import annotations

import time

import numpy as np

from .common import (DEVICES, build_table, corpus, emit,
                     run_interleaved_queries, smoke)

N_QUERIES = 4000


def _n_queries() -> int:
    """Sim-figure query count; reduced under --smoke (fig3dev keeps the
    full 4000-key acceptance workload regardless)."""
    return max(N_QUERIES // 16, 250) if smoke() else N_QUERIES


def _avg_query_ms(table, dev) -> float:
    return table.qstats.avg_time_ms(dev)


def fig3a(tokens, rows, dataset):
    dev = DEVICES["MLC-1"]
    for cs in (50.0, 25.0, 12.5):
        for scheme in ("MB", "MDB", "MDB-L"):
            t = build_table(scheme, 5.0, cs)
            run_interleaved_queries(t, tokens, _n_queries())
            ms = _avg_query_ms(t, dev)
            rows.append((f"fig3a/{dataset}/{scheme}/cs={cs}", ms * 1000,
                         f"avg_query_ms={ms:.4f}"))


def fig3b(tokens, rows, dataset):
    dev = DEVICES["MLC-1"]
    for ram in (1.0, 2.0, 5.0, 10.0):
        for scheme in ("MB", "MDB", "MDB-L"):
            t = build_table(scheme, ram, 12.5)
            run_interleaved_queries(t, tokens, _n_queries())
            ms = _avg_query_ms(t, dev)
            rows.append((f"fig3b/{dataset}/{scheme}/ram={ram}", ms * 1000,
                         f"avg_query_ms={ms:.4f}"))


def fig3c(tokens, rows, dataset):
    for dev_name, dev in DEVICES.items():
        for scheme in ("MB", "MDB", "MDB-L"):
            t = build_table(scheme, 5.0, 12.5)
            run_interleaved_queries(t, tokens, _n_queries())
            ms = _avg_query_ms(t, dev)
            rows.append((f"fig3c/{dataset}/{scheme}/{dev_name}", ms * 1000,
                         f"avg_query_ms={ms:.4f}"))


def fig3dev(rows):
    """Per-key vs batched device queries — the PR-2 acceptance rows.

    A 4000-key query workload against the on-device table (all three
    schemes), answered (a) with one jitted ``lookup`` per key — exactly
    the pre-engine per-key loop — and (b) through the store's batched
    query engine in a single ``query_batch`` call. The derived column on
    the batched row records the throughput ratio.
    """
    import jax.numpy as jnp

    from repro.core import table_jax as tj
    from repro.core.store import FlashStore

    n_q = 4000  # fixed: the acceptance workload, even under --smoke
    rng = np.random.default_rng(7)
    toks = corpus("wiki", 320_000)  # /smoke_scale inside corpus()
    schemes = ("MDB-L",) if smoke() else ("MB", "MDB", "MDB-L")
    for scheme in schemes:
        t = FlashStore.open(tj.FlashTableConfig(q_log2=15, r_log2=9,
                                                scheme=scheme),
                            backend="device")
        t.update(toks)
        t.flush()
        uniq = np.unique(toks)
        q_keys = rng.choice(uniq, size=n_q, replace=uniq.size < n_q)
        # (a) per-key: one jitted lookup per key, batch shape (1,)
        warm = jnp.asarray([int(q_keys[0])], jnp.int32)
        int(tj.lookup(t.cfg, t.state, warm)[0][0])     # compile Q=1
        t0 = time.time()
        hits = 0
        for k in q_keys:
            cnt, _ = tj.lookup(t.cfg, t.state,
                               jnp.asarray([int(k)], jnp.int32))
            hits += int(cnt[0]) != 0
        per_key = time.time() - t0
        # (b) batched: one store call, cold hot-key cache. Warm with
        # *present* keys: absent ones would be ruled out by the Bloom
        # pre-pass and never compile the lookup program, leaving its
        # compile inside the timed run. invalidate() below re-colds the
        # cache so nothing is served from it in the timed call.
        t.query_batch(uniq[:8])
        t._b.query_engine.invalidate()
        t0 = time.time()
        out = t.query_batch(q_keys)
        batched = time.time() - t0
        assert int((out != 0).sum()) == hits           # identical answers
        speedup = per_key / max(batched, 1e-9)
        rows.append((f"fig3dev/{scheme}/per_key_{n_q}",
                     per_key / n_q * 1e6,
                     f"queries={n_q};path=lookup_per_key;found={hits}"))
        rows.append((f"fig3dev/{scheme}/batched_{n_q}",
                     batched / n_q * 1e6,
                     f"queries={n_q};path=query_batch;"
                     f"speedup_vs_per_key={speedup:.1f}"))
        t.close()


def miss_heavy(rows):
    """fig3dev ``miss_heavy/*`` rows — the ISSUE-8 acceptance axis.

    Zipf present/absent query mixes at 0/50/90/100% miss rates against
    two otherwise-identical device stores, blocked-Bloom filters on vs
    off (``cfg.filters`` gates consultation only; both maintain the
    same state). The derived columns are the fail-closed gates:
    ``miss_speedup_vs_filterless`` on the 100%-miss filters-on row
    (floor ≥5×: a batch of absent keys skips nearly every lookup
    dispatch) and ``present_speedup_vs_filterless`` on the 0%-miss row
    (floor ≥0.5×: the filter pre-pass must stay noise-level when every
    key is resident). A final probe row asserts the zero-traffic
    contract in-bench: a batch of filter-ruled-out keys dispatches no
    lookup and loads no tile.
    """
    from repro.core import table_jax as tj
    from repro.core.store import FlashStore

    n_q = 16384  # fixed: the acceptance workload, even under --smoke
    rng = np.random.default_rng(17)
    toks = corpus("wiki", 320_000)
    # corpus keys are % 2**22 — this pool can never collide with them
    absent_pool = np.unique(rng.integers(1 << 23, 1 << 30, size=4 * n_q))
    schemes = ("MDB-L",) if smoke() else ("MB", "MDB", "MDB-L")
    rates = (0, 100) if smoke() else (0, 50, 90, 100)
    for scheme in schemes:
        stores = {}
        for tag in ("on", "off"):
            st = FlashStore.open(
                tj.FlashTableConfig(q_log2=16, r_log2=10, scheme=scheme,
                                    filters=(tag == "on")),
                backend="device")
            st.update(toks)
            st.flush()
            assert st.wear()["dropped"] == 0
            # warm the compiled chunk shapes with a present/absent mix:
            # present keys force the lookup program to compile (absent
            # ones alone would be Bloom-filtered before any dispatch),
            # absent ones ([2^22, 2^23): outside corpus and absent_pool)
            # warm the filter path; invalidate() re-colds the cache
            # before every timed rep
            st.query_batch(np.concatenate(
                [toks[:8], np.arange(1 << 22, (1 << 22) + 8)]))
            stores[tag] = st
        base_us = {}
        for pct in rates:
            n_miss = n_q * pct // 100
            q_keys = np.concatenate([
                rng.choice(toks, size=n_q - n_miss),       # zipf-weighted
                rng.choice(absent_pool, size=n_miss, replace=False)])
            rng.shuffle(q_keys)
            answers = {}
            for tag in ("off", "on"):   # off first: its time seeds the ratio
                st = stores[tag]
                best = float("inf")
                for _ in range(3):
                    st._b.query_engine.invalidate()        # cold cache
                    t0 = time.time()
                    answers[tag] = st.query_batch(q_keys)
                    best = min(best, time.time() - t0)
                base_us[(tag, pct)] = best
                s = st.stats()
                extra = ""
                if tag == "on":
                    extra = (f";filter_negatives="
                             f"{s['query_filter_negatives']}")
                    if pct == 100:
                        extra += (f";miss_speedup_vs_filterless="
                                  f"{base_us[('off', pct)] / max(best, 1e-9):.1f}")
                    elif pct == 0:
                        extra += (f";present_speedup_vs_filterless="
                                  f"{base_us[('off', pct)] / max(best, 1e-9):.2f}")
                rows.append((f"fig3dev/miss_heavy/{scheme}/miss={pct}/"
                             f"filters={tag}",
                             best / n_q * 1e6,
                             f"queries={n_q};miss_pct={pct};"
                             f"tile_loads={s['query_tile_loads']}{extra}"))
            np.testing.assert_array_equal(answers["on"], answers["off"])
        # zero-traffic contract: keys the filter itself rules out cost
        # no dispatch and no tile — asserted, not just reported
        st = stores["on"]
        filt = st._b.query_engine._filter
        import jax.numpy as jnp
        cands = absent_pool[:2048]
        may = np.asarray(filt(st.state, jnp.asarray(cands, jnp.int32)))
        negs = cands[~may.astype(bool)][:1024]
        st._b.query_engine.invalidate()
        before = st.stats()
        assert int(st.query_batch(negs).sum()) == 0
        after = st.stats()
        d_tiles = after["query_tile_loads"] - before["query_tile_loads"]
        d_disp = (after["query_device_dispatches"]
                  - before["query_device_dispatches"])
        assert d_tiles == 0 and d_disp == 0, (d_tiles, d_disp)
        rows.append((f"fig3dev/miss_heavy/{scheme}/true_negative_probe",
                     0.0,
                     f"queries={negs.size};tile_loads_delta={d_tiles};"
                     f"dispatches_delta={d_disp}"))
        for st in stores.values():
            st.close()


def run(rows):
    for dataset in ("wiki", "meme"):
        tokens = corpus(dataset)
        fig3a(tokens, rows, dataset)
        fig3b(tokens, rows, dataset)
        if dataset == "wiki":
            fig3c(tokens, rows, dataset)
    fig3dev(rows)
    miss_heavy(rows)
    return rows


if __name__ == "__main__":
    rows = []
    run(rows)
    emit(rows)
