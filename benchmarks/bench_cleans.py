"""Paper Figure 5: clean (erase) counts vs RAM buffer size (a) and vs
change-segment size (b) — simulator ledger; plus the on-device twin
(``table_jax`` tile_stores) so the sim-vs-device scheme comparison covers
the full MB / MDB / MDB-L landscape."""
from __future__ import annotations

from .common import build_table, corpus, emit, run_inserts

DEVICE_SCHEMES = ("MB", "MDB", "MDB-L")


def run_device(rows, n_tokens: int = 1 << 15, chunk: int = 1 << 10):
    """Device cleans analogue: tile_stores per scheme on a zipf stream."""
    import jax.numpy as jnp

    from repro.core import table_jax as tj

    toks = corpus("wiki", n_tokens) % (1 << 20)
    for scheme in DEVICE_SCHEMES:
        cfg = tj.FlashTableConfig(q_log2=12, r_log2=8, scheme=scheme,
                                  log_capacity=1 << 12, cs_partitions=4,
                                  max_updates_per_block=1 << 8,
                                  overflow_capacity=1 << 12)
        st = tj.init(cfg)
        for i in range(0, len(toks), chunk):
            st = tj.update(cfg, st, jnp.asarray(toks[i:i + chunk],
                                                jnp.int32))
        st = tj.flush(cfg, st)
        s = st.stats
        rows.append((f"fig5dev/wiki/{scheme}/tile_stores",
                     float(int(s.tile_stores)),
                     f"merges={int(s.merges)};staged={int(s.staged_entries)};"
                     f"dropped={int(s.dropped)};carried={int(s.carried)}"))
    return rows


def run(rows):
    for dataset in ("wiki", "meme"):
        tokens = corpus(dataset)
        for ram in (1.0, 2.0, 5.0, 10.0):
            for scheme in ("MB", "MDB", "MDB-L"):
                t = build_table(scheme, ram, 12.5)
                run_inserts(t, tokens)
                rows.append((f"fig5a/{dataset}/{scheme}/ram={ram}",
                             float(t.ledger.cleans),
                             f"cleans={t.ledger.cleans}"))
        if dataset == "wiki":
            for cs in (50.0, 25.0, 12.5):
                for scheme in ("MB", "MDB", "MDB-L"):
                    t = build_table(scheme, 5.0, cs)
                    run_inserts(t, tokens)
                    rows.append((f"fig5b/{dataset}/{scheme}/cs={cs}",
                                 float(t.ledger.cleans),
                                 f"cleans={t.ledger.cleans}"))
    run_device(rows)
    return rows


if __name__ == "__main__":
    rows = []
    run(rows)
    emit(rows)
