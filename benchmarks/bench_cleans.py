"""Paper Figure 5: clean (erase) counts vs RAM buffer size (a) and vs
change-segment size (b)."""
from __future__ import annotations

from .common import build_table, corpus, emit, run_inserts


def run(rows):
    for dataset in ("wiki", "meme"):
        tokens = corpus(dataset)
        for ram in (1.0, 2.0, 5.0, 10.0):
            for scheme in ("MB", "MDB", "MDB-L"):
                t = build_table(scheme, ram, 12.5)
                run_inserts(t, tokens)
                rows.append((f"fig5a/{dataset}/{scheme}/ram={ram}",
                             float(t.ledger.cleans),
                             f"cleans={t.ledger.cleans}"))
        if dataset == "wiki":
            for cs in (50.0, 25.0, 12.5):
                for scheme in ("MB", "MDB", "MDB-L"):
                    t = build_table(scheme, 5.0, cs)
                    run_inserts(t, tokens)
                    rows.append((f"fig5b/{dataset}/{scheme}/cs={cs}",
                                 float(t.ledger.cleans),
                                 f"cleans={t.ledger.cleans}"))
    return rows


if __name__ == "__main__":
    rows = []
    run(rows)
    emit(rows)
