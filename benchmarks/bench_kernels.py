"""Flash-hash kernel microbench (beyond paper): merge/query throughput of
the device table vs the jnp reference path, CPU interpret mode.

Wall-times here are CPU-interpret numbers (no TPU in this container) — the
derived column carries the structural quantities that matter for the TPU
roofline: VMEM tile residency, bytes per merge, updates per tile.
"""
from __future__ import annotations

import time

import numpy as np

from .common import emit

import jax.numpy as jnp  # noqa: E402

from repro.core.hashing import Pow2Hash, filter_words_for  # noqa: E402
from repro.kernels.flash_hash import ops, ref  # noqa: E402


def _bench(fn, *args, iters=3):
    fn(*args)  # compile/warm
    t0 = time.time()
    for _ in range(iters):
        r = fn(*args)
    for leaf in (r if isinstance(r, tuple) else (r,)):
        leaf.block_until_ready()
    return (time.time() - t0) / iters


def run(rows):
    pair = Pow2Hash(q_log2=16, r_log2=10)
    n_b, r = pair.num_slots, pair.r
    rng = np.random.default_rng(0)
    tk = jnp.full((n_b, r), ref.EMPTY, jnp.int32)
    tc = jnp.zeros((n_b, r), jnp.int32)
    toks = jnp.asarray(rng.integers(0, 1 << 20, size=1 << 14), jnp.int32)
    keys, cnts = ops.accumulate(toks)
    uk, uc, *_ = ops.bucket_updates(pair, keys, cnts, 512)
    tf = jnp.zeros((n_b, filter_words_for(r)), jnp.uint32)

    t_acc = _bench(ops.accumulate, toks)
    rows.append(("kernel/accumulate_16k", t_acc * 1e6,
                 "tokens=16384;dedup=sort+segsum"))
    t_ref = _bench(lambda: ref.merge_ref(pair, tk, tc, uk, uc))
    t_k = _bench(lambda: ops.merge(pair, tk, tc, tf, uk, uc))
    tile_bytes = r * 8  # keys+counts int32
    upd_bytes = 512 * 8
    rows.append(("kernel/merge_ref_jnp", t_ref * 1e6,
                 f"blocks={n_b};tile_B={tile_bytes};upd_B={upd_bytes}"))
    rows.append(("kernel/merge_pallas_interpret", t_k * 1e6,
                 f"blocks={n_b};vmem_per_tile_B={tile_bytes + upd_bytes};"
                 f"hbm_per_merge_B={n_b * (2 * tile_bytes + upd_bytes)}"))
    # dirty-block merge: grid over only n_d dirty tiles (the MDB / MDB-L
    # partial-merge path) — HBM traffic scales with the dirty fraction.
    for n_d in (1, n_b // 8, n_b):
        dirty = jnp.arange(n_d, dtype=jnp.int32)
        duk, duc = uk[:n_d], uc[:n_d]
        t_d = _bench(lambda: ops.merge_dirty(pair, tk, tc, tf, dirty,
                                             duk, duc))
        rows.append((f"kernel/merge_dirty_{n_d}of{n_b}", t_d * 1e6,
                     f"dirty={n_d};blocks={n_b};"
                     f"hbm_per_merge_B={n_d * (2 * tile_bytes + upd_bytes)}"))
    mk, mc, *_ = ops.merge(pair, tk, tc, tf, uk, uc)
    q = jnp.asarray(rng.integers(0, 1 << 20, size=2048), jnp.int32)
    t_q = _bench(lambda: ops.query_sorted(pair, mk, mc, q))
    rows.append(("kernel/query_2048_pallas_interpret", t_q * 1e6,
                 "queries=2048;tile_reuse=sorted"))
    t_qr = _bench(lambda: ref.query_ref(pair, mk, mc, q))
    rows.append(("kernel/query_2048_ref_jnp", t_qr * 1e6, "oracle"))
    return rows


if __name__ == "__main__":
    rows = []
    run(rows)
    emit(rows)
