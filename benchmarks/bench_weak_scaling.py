"""fig6dev (beyond paper): weak scaling of the sharded FlashStore.

The ROADMAP "distributed sharded table at scale" benchmark: the PR-4
facade fronts :mod:`repro.core.distributed` with per-shard H_R
partitions, shard-local flush thresholds and consolidated cross-shard
lookups; this suite measures whether throughput holds as the mesh grows
1 → 8 shards at **fixed per-shard load** (weak scaling, 8 virtual CPU
devices).

The multi-device XLA view must exist before jax initializes, so the
measurement runs in a subprocess (``weak_scaling_main.py``, mirroring
``tests/helpers/dist_*_main.py``) and this module parses its
``ROW|name|us|derived`` lines into suite rows. Note the virtual devices
share one physical CPU: ``weak_efficiency`` reflects the *software*
overhead of sharding (collective + per-shard bookkeeping), not real
multi-chip bandwidth.
"""
from __future__ import annotations

import subprocess
import sys
from pathlib import Path

from .common import emit, smoke

HELPER = Path(__file__).resolve().parent / "weak_scaling_main.py"


def run(rows):
    cmd = [sys.executable, str(HELPER)] + (["--smoke"] if smoke() else [])
    r = subprocess.run(cmd, capture_output=True, text=True, timeout=1800)
    if r.returncode != 0:
        raise RuntimeError(
            f"weak-scaling helper failed:\n{r.stdout[-2000:]}"
            f"\n{r.stderr[-4000:]}")
    parsed = 0
    for line in r.stdout.splitlines():
        if not line.startswith("ROW|"):
            continue
        _tag, name, us, derived = line.split("|", 3)
        rows.append((name, float(us), derived))
        parsed += 1
    if parsed == 0:
        raise RuntimeError(f"no ROW lines from helper:\n{r.stdout[-2000:]}")
    return rows


if __name__ == "__main__":
    rows = []
    run(rows)
    emit(rows)
