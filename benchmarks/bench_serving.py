"""fig7dev (beyond paper): serving at traffic — continuous batching over
the paged prefix-KV block pool.

The ROADMAP north star is "serve heavy traffic from millions of users";
this suite is the first traffic-level measurement: a Zipf user
population (``serving/trace.py``) replayed through worker feeder threads
into the continuous-batching scheduler, with prefix-KV blocks paged
through the counting-flash-hash :class:`PrefixKVCache` (sim-backend
refcounts so the suite runs on one CPU core like the rest of the bench).

Rows (all on the tiny fp32 llama config so argmax ties cannot flip):

  fig7dev/serial               seed ``ServeEngine.serve`` loop — the
                               baseline the acceptance floor is against
  fig7dev/continuous_batching  same trace through the scheduler;
                               ``speedup_vs_serial`` (floor ≥2×) and
                               ``identical_outputs`` (floor =1: every
                               request's tokens equal the serial loop's)
  fig7dev/repeated_prefix      hot replay on a warmed cache;
                               ``cache_hit_rate`` (token-level, floor
                               ≥0.25) plus p50/p99 latency and the
                               accounted flash wear of the refcount table

``us_per_call`` is microseconds per *request*.
"""
from __future__ import annotations

import dataclasses
import time

from .common import emit, smoke


def _build():
    import jax

    from repro.configs import get_config
    from repro.models import model as M

    cfg = dataclasses.replace(get_config("llama32_3b", tiny=True),
                              dtype="float32")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _trace(cfg, n_req, seed=9):
    from repro.serving import make_trace
    return make_trace(num_requests=n_req, num_users=4, zipf_s=1.2,
                      prefix_blocks=2, block_tokens=16,
                      suffix_tokens=(4, 12), max_new_tokens=16,
                      vocab_size=cfg.vocab_size, seed=seed)


def _sched(cfg, params):
    from repro.serving import ContinuousBatchingScheduler, PrefixKVCache
    cache = PrefixKVCache(block_tokens=16, capacity_blocks=128,
                          backend="sim")
    return ContinuousBatchingScheduler(cfg, params, prefix_cache=cache,
                                       max_slots=8, max_context=96)


def run(rows):
    from repro.serving import Request, SchedRequest, ServeEngine, replay_trace

    cfg, params = _build()
    n_req = 8 if smoke() else 24
    trace = _trace(cfg, n_req)
    gen_tokens = sum(t.max_new_tokens for t in trace)

    # -- serial baseline: the seed per-request loop, warmed up ---------------
    eng = ServeEngine(cfg, params)
    eng.generate(Request(prompt=[1, 2, 3], max_new_tokens=2))
    t0 = time.time()
    serial = eng.serve([Request(prompt=list(t.prompt),
                                max_new_tokens=t.max_new_tokens)
                        for t in trace])
    serial_s = time.time() - t0
    rows.append((
        "fig7dev/serial", serial_s / n_req * 1e6,
        f"requests={n_req};tok_s={gen_tokens / serial_s:.1f}"))

    # -- continuous batching on the identical trace --------------------------
    sched = _sched(cfg, params)
    sched.run([SchedRequest(prompt=[3, 2, 1] * 6, max_new_tokens=2),
               SchedRequest(prompt=[4, 5] * 9, max_new_tokens=2)])  # warmup
    rep = replay_trace(sched, trace, workers=2)
    by_id = {r.request_id: r for r in sched.completed}
    identical = int(all(by_id[i].output == s.output
                        for i, s in enumerate(serial)))
    rows.append((
        "fig7dev/continuous_batching", rep.wall_s / n_req * 1e6,
        f"requests={n_req};tok_s={rep.tokens_per_s:.1f};"
        f"speedup_vs_serial={serial_s / rep.wall_s:.2f};"
        f"identical_outputs={identical};"
        f"p50_ms={rep.p50_latency_s * 1e3:.1f};"
        f"p99_ms={rep.p99_latency_s * 1e3:.1f};"
        f"slots=8;workers=2"))

    # -- repeated-prefix hot replay: cache hit rate + accounted wear ---------
    sched2 = _sched(cfg, params)
    warm = _trace(cfg, max(n_req // 2, 4))   # same users/prefixes, seed 9
    replay_trace(sched2, warm, workers=1)
    hot = _trace(cfg, n_req)
    rep2 = replay_trace(sched2, hot, workers=2)
    stats = sched2.cache.stats()
    rows.append((
        "fig7dev/repeated_prefix", rep2.wall_s / n_req * 1e6,
        f"requests={n_req};tok_s={rep2.tokens_per_s:.1f};"
        f"cache_hit_rate={rep2.hit_rate:.3f};"
        f"p50_ms={rep2.p50_latency_s * 1e3:.1f};"
        f"p99_ms={rep2.p99_latency_s * 1e3:.1f};"
        f"wear={rep2.wear};resident_blocks={stats['resident']};"
        f"pool_high_water={stats['pool_high_water']}"))
    return rows


if __name__ == "__main__":
    rows = []
    run(rows)
    emit(rows)
