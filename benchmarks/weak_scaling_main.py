"""Subprocess helper for the fig6dev weak-scaling benchmark.

Forces an 8-virtual-device XLA view *before* importing jax (the parent
benchmark process must keep its single-device view), then drives
``FlashStore(backend="sharded")`` at 1 → 8 shards with **fixed per-shard
load** (weak scaling): per-shard update stream, per-shard table geometry
and a key space that grows with the mesh. Ideal weak scaling holds
us/update constant as shards grow.

After the single-host ladder it re-runs the 8-shard point as a **2-process
multihost mesh** (ISSUE 10): two ``--mh-worker`` children of this same
script, 4 virtual devices each, joined via ``jax.distributed.initialize``
over a localhost coordinator with gloo CPU collectives. Each host ingests
its half of the same stream and hides the collective drains behind local
ingest (``drain(wait=False)``); the per-host rows carry the
``overlap_us``/``stall_us`` ledgers, ``carry_free`` (owner-aligned waves
never carry) and ``mh_weak_efficiency`` vs the single-host shards_1
baseline — the fields the fig6dev acceptance floors gate on.

Prints one ``ROW|name|us_per_call|derived`` line per shard count / host;
``benchmarks.bench_weak_scaling`` parses them into suite rows.
"""
import os
import sys

_MH_WORKER = "--mh-worker" in sys.argv
os.environ["XLA_FLAGS"] = (
    f"--xla_force_host_platform_device_count={4 if _MH_WORKER else 8} "
    + os.environ.get("XLA_FLAGS", ""))
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import socket
import subprocess
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import numpy as np

from repro.core import table_jax as tj
from repro.core.distributed import ShardedTableConfig
from repro.core.store import FlashStore

PER_SHARD_UPDATES = 100_000
PER_SHARD_KEYS = 1 << 14
BATCH = 4096
N_QUERIES = 4096
MH_PROCS = 2
MH_DRAIN_EVERY = 2     # global batches between hidden collective drains


def _cfg(n: int) -> ShardedTableConfig:
    return ShardedTableConfig(
        local=tj.FlashTableConfig(q_log2=13, r_log2=9, scheme="MDB-L",
                                  log_capacity=1 << 13,
                                  max_updates_per_block=1 << 8,
                                  overflow_capacity=1 << 10),
        num_shards=n, bucket_cap=1 << 10)


def _stream(n: int, n_updates: int, rng: np.random.Generator) -> np.ndarray:
    # key space scales with the mesh: per-shard unique load stays fixed
    return (rng.zipf(1.35, size=n * n_updates)
            % (n * PER_SHARD_KEYS)).astype(np.int64)


def bench_shards(n: int, n_updates: int, rng: np.random.Generator):
    store = FlashStore.open(_cfg(n), backend="sharded", shard_chunk=1024,
                            flush_threshold=2048)
    total = n * n_updates
    toks = _stream(n, n_updates, rng)
    # warm the compiled update/lookup programs outside the timed region
    store.update(np.arange(BATCH, dtype=np.int64))
    store._b.drain()
    store.query(np.arange(N_QUERIES, dtype=np.int64))
    t0 = time.time()
    for i in range(0, total, BATCH):
        store.update(toks[i:i + BATCH])
    store.flush()
    jax.block_until_ready(store.state)
    upd_secs = time.time() - t0
    q = rng.choice(toks, size=N_QUERIES).astype(np.int64)
    t0 = time.time()
    store.query_batch(q)
    q_secs = time.time() - t0
    s = store.stats()
    store.close()
    return upd_secs, q_secs, total, s


# ---------------------------------------------------------------------------
# multihost: the 8-shard point as a 2-process mesh (ISSUE 10)
# ---------------------------------------------------------------------------
def run_mh_worker(pid: int, port: int, base_us: float,
                  n_updates: int) -> None:
    try:
        jax.config.update("jax_cpu_collectives_implementation", "gloo")
    except Exception:
        pass          # newer jax: gloo is already the CPU default
    jax.distributed.initialize(coordinator_address=f"127.0.0.1:{port}",
                               num_processes=MH_PROCS, process_id=pid)
    assert jax.device_count() == 8 and jax.local_device_count() == 4
    rng = np.random.default_rng(7)
    store = FlashStore.open(_cfg(8), backend="sharded", shard_chunk=1024)
    total = 8 * n_updates
    toks = _stream(8, n_updates, rng)     # every host derives the same
    # warm compile + first collective outside the timed region
    store.update(np.arange(BATCH, dtype=np.int64))
    store.drain(wait=True)
    store.query(np.arange(N_QUERIES, dtype=np.int64))
    t0 = time.time()
    for b, i in enumerate(range(0, total, BATCH)):
        if b % MH_PROCS == pid:           # my half of the global stream
            store.update(toks[i:i + BATCH])
        if b % MH_DRAIN_EVERY == MH_DRAIN_EVERY - 1:
            store.drain(wait=False)       # collective hidden behind ingest
    store.flush(wait=True)
    upd_secs = time.time() - t0
    q = rng.choice(toks, size=N_QUERIES).astype(np.int64)
    t0 = time.time()
    store.query_batch(q)
    q_secs = time.time() - t0
    s = store.stats()
    store.close()
    us = upd_secs / total * 1e6           # both hosts cover the window
    derived = (f"procs={MH_PROCS};host={pid};shards=8;"
               f"per_shard_updates={n_updates};total_updates={total};"
               f"secs={upd_secs:.2f};"
               f"mh_weak_efficiency={base_us / us:.2f};"
               f"query_us_per_key={q_secs / N_QUERIES * 1e6:.2f};"
               f"overlap_us={s['write_overlap_us']};"
               f"stall_us={s['write_stall_us']};"
               f"flushes={s['write_flushes']};"
               f"collectives={s['write_dispatches']};"
               f"deduped={s['write_deduped']};"
               f"carried={s['write_carried']};"
               f"carry_free={1 if s['write_carried'] == 0 else 0};"
               f"dropped={s['dropped']}")
    print(f"ROW|fig6dev/multihost/MDB-L/host_{pid}|{us:.3f}|{derived}",
          flush=True)


def spawn_mh_pair(base_us: float, smoke: bool) -> None:
    s = socket.socket()
    s.bind(("127.0.0.1", 0))
    port = s.getsockname()[1]
    s.close()
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)            # workers pin their own 4-dev view
    procs = [subprocess.Popen(
        [sys.executable, str(Path(__file__).resolve()), "--mh-worker",
         "--pid", str(p), "--port", str(port), "--base-us", str(base_us)]
        + (["--smoke"] if smoke else []),
        env=env, stdout=subprocess.PIPE, stderr=subprocess.STDOUT,
        text=True) for p in range(MH_PROCS)]
    for p, proc in enumerate(procs):
        out, _ = proc.communicate(timeout=1800)
        if proc.returncode != 0:
            raise RuntimeError(f"mh worker {p} rc={proc.returncode}\n"
                               f"{out[-4000:]}")
        for line in out.splitlines():     # relay the per-host ROW lines
            if line.startswith("ROW|"):
                print(line, flush=True)


def _arg(flag: str, default=None):
    return (sys.argv[sys.argv.index(flag) + 1]
            if flag in sys.argv else default)


def main() -> None:
    smoke = "--smoke" in sys.argv
    n_updates = PER_SHARD_UPDATES // (16 if smoke else 1)
    if _MH_WORKER:
        run_mh_worker(int(_arg("--pid")), int(_arg("--port")),
                      float(_arg("--base-us")), n_updates)
        return
    assert jax.device_count() == 8, jax.devices()
    base_us = None
    for n in (1, 2, 4, 8):
        rng = np.random.default_rng(7)
        upd_secs, q_secs, total, s = bench_shards(n, n_updates, rng)
        us = upd_secs / total * 1e6
        if base_us is None:
            base_us = us
        eff = base_us / us
        derived = (f"shards={n};per_shard_updates={n_updates};"
                   f"total_updates={total};secs={upd_secs:.2f};"
                   f"weak_efficiency={eff:.2f};"
                   f"query_us_per_key={q_secs / N_QUERIES * 1e6:.2f};"
                   f"flushes={s['write_flushes']};"
                   f"collectives={s['write_dispatches']};"
                   f"auto_flushes={s['write_auto_flushes']};"
                   f"piggybacked={s['write_piggybacked']};"
                   f"deduped={s['write_deduped']};"
                   f"tile_stores={s['tile_stores']};"
                   f"carried={s['write_carried']};dropped={s['dropped']}")
        print(f"ROW|fig6dev/sharded/MDB-L/shards_{n}|{us:.3f}|{derived}",
              flush=True)
    spawn_mh_pair(base_us, smoke)


if __name__ == "__main__":
    main()
