"""Subprocess helper for the fig6dev weak-scaling benchmark.

Forces an 8-virtual-device XLA view *before* importing jax (the parent
benchmark process must keep its single-device view), then drives
``FlashStore(backend="sharded")`` at 1 → 8 shards with **fixed per-shard
load** (weak scaling): per-shard update stream, per-shard table geometry
and a key space that grows with the mesh. Ideal weak scaling holds
us/update constant as shards grow.

Prints one ``ROW|name|us_per_call|derived`` line per shard count;
``benchmarks.bench_weak_scaling`` parses them into suite rows.
"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import numpy as np

from repro.core import table_jax as tj
from repro.core.distributed import ShardedTableConfig
from repro.core.store import FlashStore

PER_SHARD_UPDATES = 100_000
PER_SHARD_KEYS = 1 << 14
BATCH = 4096
N_QUERIES = 4096


def bench_shards(n: int, n_updates: int, rng: np.random.Generator):
    cfg = ShardedTableConfig(
        local=tj.FlashTableConfig(q_log2=13, r_log2=9, scheme="MDB-L",
                                  log_capacity=1 << 13,
                                  max_updates_per_block=1 << 8,
                                  overflow_capacity=1 << 10),
        num_shards=n, bucket_cap=1 << 10)
    store = FlashStore.open(cfg, backend="sharded", shard_chunk=1024,
                            flush_threshold=2048)
    total = n * n_updates
    # key space scales with the mesh: per-shard unique load stays fixed
    toks = (rng.zipf(1.35, size=total) % (n * PER_SHARD_KEYS)).astype(
        np.int64)
    # warm the compiled update/lookup programs outside the timed region
    store.update(np.arange(BATCH, dtype=np.int64))
    store._b.drain()
    store.query(np.arange(N_QUERIES, dtype=np.int64))
    t0 = time.time()
    for i in range(0, total, BATCH):
        store.update(toks[i:i + BATCH])
    store.flush()
    jax.block_until_ready(store.state)
    upd_secs = time.time() - t0
    q = rng.choice(toks, size=N_QUERIES).astype(np.int64)
    t0 = time.time()
    store.query_batch(q)
    q_secs = time.time() - t0
    s = store.stats()
    store.close()
    return upd_secs, q_secs, total, s


def main() -> None:
    smoke = "--smoke" in sys.argv
    n_updates = PER_SHARD_UPDATES // (16 if smoke else 1)
    assert jax.device_count() == 8, jax.devices()
    base_us = None
    for n in (1, 2, 4, 8):
        rng = np.random.default_rng(7)
        upd_secs, q_secs, total, s = bench_shards(n, n_updates, rng)
        us = upd_secs / total * 1e6
        if base_us is None:
            base_us = us
        eff = base_us / us
        derived = (f"shards={n};per_shard_updates={n_updates};"
                   f"total_updates={total};secs={upd_secs:.2f};"
                   f"weak_efficiency={eff:.2f};"
                   f"query_us_per_key={q_secs / N_QUERIES * 1e6:.2f};"
                   f"flushes={s['write_flushes']};"
                   f"collectives={s['write_dispatches']};"
                   f"auto_flushes={s['write_auto_flushes']};"
                   f"piggybacked={s['write_piggybacked']};"
                   f"deduped={s['write_deduped']};"
                   f"tile_stores={s['tile_stores']};"
                   f"carried={s['write_carried']};dropped={s['dropped']}")
        print(f"ROW|fig6dev/sharded/MDB-L/shards_{n}|{us:.3f}|{derived}",
              flush=True)


if __name__ == "__main__":
    main()
