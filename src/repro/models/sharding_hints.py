"""Activation-sharding hints, mesh-agnostic.

Model code stays runnable without any mesh (CPU tests), but when a step is
traced under a hint context (set by launch/steps via ``use_hints``),
``hint(x, axes...)`` lowers to ``with_sharding_constraint`` — used where
GSPMD's propagation makes bad choices (MoE routing/dispatch is the big
one: without constraints it replicates the top-k over all tokens).
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Any, Dict

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_CTX: contextvars.ContextVar = contextvars.ContextVar(
    "repro_shard_hints", default=None)


@contextlib.contextmanager
def use_hints(mesh: Mesh, rules: Dict[str, Any],
              param_rules: Dict[str, Any] = None):
    """``param_rules``: when set, ``param_hint`` re-constrains per-layer
    params inside the scanned group body — with TP-only rules this forces
    GSPMD to all-gather FSDP-sharded weights per layer (85MB/layer for
    nemotron) instead of all-reducing activations (1.2GB/layer), the
    pattern it otherwise picks (§Perf nemotron iteration 2)."""
    token = _CTX.set((mesh, rules, param_rules))
    try:
        yield
    finally:
        _CTX.reset(token)


def _spec(mesh, rules, logical_axes):
    parts = []
    used = set()
    for ax in logical_axes:
        r = rules.get(ax) if ax is not None else None
        if r is None:
            parts.append(None)
            continue
        r = r if isinstance(r, tuple) else (r,)
        r = tuple(a for a in r if a not in used)
        used.update(r)
        parts.append(None if not r else (r[0] if len(r) == 1 else r))
    return NamedSharding(mesh, P(*parts))


def hint(x, *logical_axes):
    """Constrain ``x``'s sharding by logical dim names (None = unsharded).
    No-op outside a hint context."""
    ctx = _CTX.get()
    if ctx is None:
        return x
    mesh, rules = ctx[0], ctx[1]
    return jax.lax.with_sharding_constraint(
        x, _spec(mesh, rules, logical_axes))


def param_hint_tree(params, axes_tree, is_leaf=None):
    """Re-constrain a (sliced, per-layer) param subtree with the context's
    ``param_rules``. No-op unless the context carries param rules."""
    ctx = _CTX.get()
    if ctx is None or len(ctx) < 3 or ctx[2] is None:
        return params
    mesh, _, prules = ctx
    import jax as _jax

    def apply(p, axes):
        return _jax.lax.with_sharding_constraint(
            p, _spec(mesh, prules, axes))

    return _jax.tree.map(
        lambda axes, p: apply(p, axes), axes_tree, params,
        is_leaf=is_leaf)
