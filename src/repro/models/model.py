"""Model assembly: pattern-driven decoder stacks with scan-over-groups.

A model is ``embed → scan(groups) → final_norm → lm_head``. Each *group*
is the unrolled ``layer_pattern`` (attn/mla/ssm mixer + dense/moe/none FFN
per slot); group params are stacked on a leading ``layers`` axis and the
stack is ``lax.scan``'d (rematerialized per group in training), so HLO size
is independent of depth.

Three execution modes share one layer definition:
* ``forward_train``  — full-sequence causal, returns loss-ready logits;
* ``prefill``        — full-sequence + returns per-layer caches;
* ``decode_step``    — one token against stacked caches.

Modality stubs (DESIGN.md §5): ``vlm`` replaces the first ``num_patches``
positions with precomputed patch embeddings; ``audio`` consumes precomputed
codec-frame embeddings the same way. Both keep the backbone shape-identical
to a text LM, as the brief requires.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple

import jax
import jax.numpy as jnp

from . import attention as attn
from . import moe as moe_mod
from . import ssm as ssm_mod
from .config import ModelConfig
from .layers import (axes_embed, axes_ffn, axes_rmsnorm, embed_tokens,
                     ffn_apply, init_embed, init_ffn, init_rmsnorm,
                     lm_logits, rmsnorm)
from .sharding_hints import param_hint_tree

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def _init_slot(key, cfg: ModelConfig, kind: str, ffn_kind: str, dtype):
    ks = jax.random.split(key, 4)
    p: Params = {"mixer_ln": init_rmsnorm(ks[0], cfg.d_model, dtype)}
    if kind == "attn":
        if cfg.attn_type == "mla":
            p["mixer"] = attn.init_mla(ks[1], cfg, dtype)
        else:
            p["mixer"] = attn.init_gqa(ks[1], cfg, dtype)
    elif kind == "ssm":
        p["mixer"] = ssm_mod.init_ssm(ks[1], cfg, dtype)
    else:
        raise ValueError(kind)
    if ffn_kind != "none":
        p["ffn_ln"] = init_rmsnorm(ks[2], cfg.d_model, dtype)
        p["ffn"] = (init_ffn(ks[3], cfg, dtype) if ffn_kind == "dense"
                    else moe_mod.init_moe(ks[3], cfg, dtype))
    return p


def _axes_slot(cfg: ModelConfig, kind: str, ffn_kind: str):
    p: Params = {"mixer_ln": axes_rmsnorm()}
    if kind == "attn":
        p["mixer"] = (attn.axes_mla() if cfg.attn_type == "mla"
                      else attn.axes_gqa())
    else:
        p["mixer"] = ssm_mod.axes_ssm()
    if ffn_kind != "none":
        p["ffn_ln"] = axes_rmsnorm()
        p["ffn"] = (axes_ffn(cfg) if ffn_kind == "dense"
                    else moe_mod.axes_moe(cfg))
    return p


def init_params(key, cfg: ModelConfig) -> Params:
    """Concrete init. For the dry-run use ``abstract_params`` (no alloc)."""
    dtype = jnp.dtype(cfg.dtype)
    k_embed, k_final, *k_slots = jax.random.split(key, 2 + cfg.group_size)

    groups = []
    for slot, (kind, ffn_kind) in enumerate(zip(cfg.layer_pattern,
                                                cfg.ffn_pattern)):
        slot_keys = jax.random.split(k_slots[slot], cfg.num_groups)
        groups.append(jax.vmap(
            lambda k: _init_slot(k, cfg, kind, ffn_kind, dtype))(slot_keys))
    return {
        "embed": init_embed(k_embed, cfg, dtype),
        "groups": groups,
        "final_norm": init_rmsnorm(k_final, cfg.d_model, dtype),
    }


def abstract_params(cfg: ModelConfig) -> Params:
    """ShapeDtypeStruct pytree (AOT lowering input; zero allocation)."""
    return jax.eval_shape(
        functools.partial(init_params, cfg=cfg),
        jax.random.key(0))


def is_axes_leaf(t) -> bool:
    """Leaf = plain tuple of logical axis names (str | None)."""
    return (isinstance(t, tuple) and type(t) is tuple
            and all(isinstance(x, (str, type(None))) for x in t))


def param_axes(cfg: ModelConfig) -> Params:
    """Logical-axis pytree matching ``init_params`` structure; scanned
    leaves get a leading ``layers`` axis."""
    groups = []
    for kind, ffn_kind in zip(cfg.layer_pattern, cfg.ffn_pattern):
        slot = _axes_slot(cfg, kind, ffn_kind)
        slot = jax.tree.map(lambda t: ("layers",) + tuple(t), slot,
                            is_leaf=is_axes_leaf)
        groups.append(slot)
    return {
        "embed": axes_embed(cfg),
        "groups": groups,
        "final_norm": axes_rmsnorm(),
    }


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------
class SlotCacheSpec(NamedTuple):
    kind: str


def init_caches(cfg: ModelConfig, batch: int, s_max: int, dtype):
    """Stacked (num_groups, ...) caches per slot."""
    caches = []
    for kind in cfg.layer_pattern:
        if kind == "attn":
            if cfg.attn_type == "mla":
                c = attn.init_mla_cache(cfg, batch, s_max, dtype)
            else:
                c = attn.init_kv_cache(cfg, batch, s_max, dtype)
        else:
            c = ssm_mod.init_ssm_cache(cfg, batch, dtype)
        caches.append(jax.tree.map(
            lambda x: jnp.broadcast_to(x[None],
                                       (cfg.num_groups,) + x.shape), c))
    return caches


def abstract_caches(cfg: ModelConfig, batch: int, s_max: int, dtype):
    return jax.eval_shape(
        lambda: init_caches(cfg, batch, s_max, dtype))


def pad_caches(cfg: ModelConfig, caches, new_len: int):
    """Grow attention caches' sequence dim to ``new_len`` (prefill produces
    exactly-seq_len caches; serving pads to prefill+max_new_tokens)."""
    out = []
    for kind, c in zip(cfg.layer_pattern, caches):
        if kind == "attn":
            def grow(x):
                pad = new_len - x.shape[2]
                if pad <= 0:
                    return x
                widths = [(0, 0)] * x.ndim
                widths[2] = (0, pad)
                return jnp.pad(x, widths)
            c = jax.tree.map(grow, c)
        out.append(c)
    return out


def cache_axes(cfg: ModelConfig):
    caches = []
    for kind in cfg.layer_pattern:
        if kind == "attn":
            c = (attn.mla_cache_axes() if cfg.attn_type == "mla"
                 else attn.kv_cache_axes())
        else:
            c = ssm_mod.ssm_cache_axes()
        caches.append(jax.tree.map(lambda t: ("layers",) + tuple(t), c,
                                   is_leaf=is_axes_leaf))
    return caches


# ---------------------------------------------------------------------------
# group body
# ---------------------------------------------------------------------------
def _apply_ffn(p, cfg: ModelConfig, ffn_kind: str, x):
    if ffn_kind == "none":
        return x, 0.0, None
    h = rmsnorm(p["ffn_ln"], x, cfg.norm_eps)
    if ffn_kind == "dense":
        return x + ffn_apply(p["ffn"], cfg, h), 0.0, None
    y, aux, counts = moe_mod.moe_apply(p["ffn"], cfg, h)
    return x + y, aux, counts


def _reshard_group(cfg: ModelConfig, group_params):
    """Per-layer param re-gather point (see sharding_hints.use_hints)."""
    axes = [_axes_slot(cfg, k, f)
            for k, f in zip(cfg.layer_pattern, cfg.ffn_pattern)]
    return param_hint_tree(group_params, axes, is_leaf=is_axes_leaf)


def _group_train(cfg: ModelConfig, x, positions, group_params):
    from .sharding_hints import hint
    group_params = _reshard_group(cfg, group_params)
    x = hint(x, "batch", None, None)   # pin the residual stream layout
    aux_total = jnp.float32(0.0)
    counts_total = (jnp.zeros((cfg.num_experts,), jnp.int32)
                    if cfg.num_experts else None)
    for slot, (kind, ffn_kind) in enumerate(zip(cfg.layer_pattern,
                                                cfg.ffn_pattern)):
        p = group_params[slot]
        h = rmsnorm(p["mixer_ln"], x, cfg.norm_eps)
        if kind == "attn":
            mix = (attn.mla_full if cfg.attn_type == "mla"
                   else attn.gqa_full)(p["mixer"], cfg, h, positions)
        else:
            mix = ssm_mod.ssd_full(p["mixer"], cfg, h)
        x = x + mix
        x, aux, counts = _apply_ffn(p, cfg, ffn_kind, x)
        aux_total = aux_total + aux
        if counts is not None:
            counts_total = counts_total + counts
    return x, aux_total, counts_total


def _group_decode(cfg: ModelConfig, x, index, group_params, group_caches):
    new_caches = []
    for slot, (kind, ffn_kind) in enumerate(zip(cfg.layer_pattern,
                                                cfg.ffn_pattern)):
        p = group_params[slot]
        c = group_caches[slot]
        h = rmsnorm(p["mixer_ln"], x, cfg.norm_eps)
        if kind == "attn":
            if cfg.attn_type == "mla":
                mix, c = attn.mla_decode(p["mixer"], cfg, h, c, index)
            else:
                mix, c = attn.gqa_decode(p["mixer"], cfg, h, c, index)
        else:
            mix, c = ssm_mod.ssd_decode(p["mixer"], cfg, h, c)
        x = x + mix
        x, _, _ = _apply_ffn(p, cfg, ffn_kind, x)
        new_caches.append(c)
    return x, new_caches


# ---------------------------------------------------------------------------
# forward passes
# ---------------------------------------------------------------------------
def _embed_inputs(params, cfg: ModelConfig, batch):
    x = embed_tokens(params["embed"], batch["tokens"])
    if cfg.frontend in ("vision_stub", "audio_stub"):
        pe = batch["frontend_embeds"].astype(x.dtype)  # (b, P, d)
        npatch = pe.shape[1]
        x = jnp.concatenate([pe, x[:, npatch:, :]], axis=1)
    return x


def forward_train(params: Params, cfg: ModelConfig, batch,
                  remat: bool = True):
    """batch: tokens (b,s) [+ frontend_embeds] → (logits fp32, aux, counts)."""
    x = _embed_inputs(params, cfg, batch)
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)[None, :]

    def body(x, group_params):
        y, aux, counts = _group_train(cfg, x, positions, group_params)
        return y, (aux, counts)

    if remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    x, (auxs, counts) = jax.lax.scan(body, x, params["groups"])
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = lm_logits(params["embed"], cfg, x)
    aux = jnp.sum(auxs)
    total_counts = counts.sum(0) if counts is not None else None
    return logits, aux, total_counts


def loss_fn(params: Params, cfg: ModelConfig, batch,
            aux_coef: float = 0.01, remat: bool = True):
    """Next-token CE over positions with label >= 0 (+ MoE aux loss)."""
    logits, aux, counts = forward_train(params, cfg, batch, remat)
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    safe = jnp.maximum(labels, 0)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
    denom = jnp.maximum(mask.sum(), 1.0)
    ce = (nll * mask).sum() / denom
    metrics = {"ce": ce, "aux": aux, "tokens": mask.sum()}
    if counts is not None:
        metrics["expert_counts"] = counts
    return ce + aux_coef * aux, metrics


def prefill(params: Params, cfg: ModelConfig, batch):
    """Full-sequence forward that also materializes decode caches.

    Implemented as forward_train (caches are rebuilt from k/v projections
    per layer); returns last-position logits + caches sized to seq_len.
    """
    x = _embed_inputs(params, cfg, batch)
    b, s, _ = x.shape
    positions = jnp.arange(s, dtype=jnp.int32)[None, :]
    dtype = x.dtype

    def body(x, group_params):
        new_caches = []
        for slot, (kind, ffn_kind) in enumerate(zip(cfg.layer_pattern,
                                                    cfg.ffn_pattern)):
            p = group_params[slot]
            h = rmsnorm(p["mixer_ln"], x, cfg.norm_eps)
            if kind == "attn":
                if cfg.attn_type == "mla":
                    mix, cache = _mla_prefill(p["mixer"], cfg, h, positions)
                else:
                    mix, cache = _gqa_prefill(p["mixer"], cfg, h, positions)
            else:
                mix, cache = _ssm_prefill(p["mixer"], cfg, h)
            x = x + mix
            x, _, _ = _apply_ffn(p, cfg, ffn_kind, x)
            new_caches.append(cache)
        return x, tuple(new_caches)

    x, caches = jax.lax.scan(body, x, params["groups"])
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = lm_logits(params["embed"], cfg, x[:, -1:, :])
    return logits, list(caches)


def _gqa_prefill(p, cfg, x, positions):
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    k = attn.apply_rope(k, positions, cfg.rope_theta)
    out = attn.gqa_full(p, cfg, x, positions)
    return out, attn.KVCache(k=k, v=v)


def _mla_prefill(p, cfg, x, positions):
    kr = cfg.kv_lora_rank
    kvl = jnp.einsum("bsd,dr->bsr", x, p["wkv_down"],
                     preferred_element_type=jnp.float32).astype(x.dtype)
    latent, k_rope = kvl[..., :kr], kvl[..., kr:]
    latent = rmsnorm(p["kv_norm"], latent, cfg.norm_eps)
    k_rope = attn.apply_rope(k_rope[..., None, :], positions,
                             cfg.rope_theta)[:, :, 0, :]
    out = attn.mla_full(p, cfg, x, positions)
    return out, attn.MLACache(latent=latent, k_rope=k_rope)


def _ssm_prefill(p, cfg, x):
    return ssm_mod.ssd_full(p, cfg, x, return_cache=True)


def decode_step(params: Params, cfg: ModelConfig, tokens, caches, index):
    """tokens: (b, 1) → (logits (b,1,V) fp32, new caches)."""
    x = embed_tokens(params["embed"], tokens)

    def body(x, xs):
        group_params, group_caches = xs
        y, new_caches = _group_decode(cfg, x, index, group_params,
                                      group_caches)
        return y, tuple(new_caches)

    x, new_caches = jax.lax.scan(body, x, (params["groups"], tuple(caches)))
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = lm_logits(params["embed"], cfg, x)
    return logits, list(new_caches)


# ---------------------------------------------------------------------------
# packed-slot serving entry points (continuous batching; DESIGN.md §13)
# ---------------------------------------------------------------------------
def _group_decode_packed(cfg: ModelConfig, x, indices, group_params,
                         group_caches):
    new_caches = []
    for slot, (kind, ffn_kind) in enumerate(zip(cfg.layer_pattern,
                                                cfg.ffn_pattern)):
        p = group_params[slot]
        c = group_caches[slot]
        h = rmsnorm(p["mixer_ln"], x, cfg.norm_eps)
        if kind == "attn":
            if cfg.attn_type == "mla":
                mix, c = attn.mla_decode_packed(p["mixer"], cfg, h, c,
                                                indices)
            else:
                mix, c = attn.gqa_decode_packed(p["mixer"], cfg, h, c,
                                                indices)
        else:
            # SSM decode is recurrent — position-free, packed by nature
            mix, c = ssm_mod.ssd_decode(p["mixer"], cfg, h, c)
        x = x + mix
        x, _, _ = _apply_ffn(p, cfg, ffn_kind, x)
        new_caches.append(c)
    return x, new_caches


def decode_step_packed(params: Params, cfg: ModelConfig, tokens, caches,
                       indices):
    """Continuous-batching decode: tokens (b, 1), ``indices`` (b,) int32 —
    one step over a packed slot table where every row sits at its own
    sequence position (requests join/leave mid-flight). Returns
    (logits (b,1,V) fp32, new caches)."""
    x = embed_tokens(params["embed"], tokens)

    def body(x, xs):
        group_params, group_caches = xs
        y, new_caches = _group_decode_packed(cfg, x, indices, group_params,
                                             group_caches)
        return y, tuple(new_caches)

    x, new_caches = jax.lax.scan(body, x, (params["groups"], tuple(caches)))
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = lm_logits(params["embed"], cfg, x)
    return logits, list(new_caches)


def prefill_chunk(params: Params, cfg: ModelConfig, tokens, caches, start):
    """Chunked prefill: process ``tokens`` (b, c) occupying absolute
    positions start..start+c against existing caches (earlier chunks /
    reused prefix blocks already hold rows < start). Returns
    (logits (b,c,V) fp32, new caches). Attention-only stacks — SSM
    recurrent state cannot be entered mid-sequence; hybrid archs take
    the whole-prompt prefill path instead (DESIGN.md §13)."""
    if any(k == "ssm" for k in cfg.layer_pattern):
        raise ValueError("prefill_chunk requires a pure-attention stack; "
                         f"{cfg.name} has layer_pattern={cfg.layer_pattern}")
    x = embed_tokens(params["embed"], tokens)

    def body(x, xs):
        group_params, group_caches = xs
        new_caches = []
        for slot, (kind, ffn_kind) in enumerate(zip(cfg.layer_pattern,
                                                    cfg.ffn_pattern)):
            p = group_params[slot]
            c = group_caches[slot]
            h = rmsnorm(p["mixer_ln"], x, cfg.norm_eps)
            if cfg.attn_type == "mla":
                mix, c = attn.mla_chunk_append(p["mixer"], cfg, h, c, start)
            else:
                mix, c = attn.gqa_chunk_append(p["mixer"], cfg, h, c, start)
            x = x + mix
            x, _, _ = _apply_ffn(p, cfg, ffn_kind, x)
            new_caches.append(c)
        return x, tuple(new_caches)

    x, new_caches = jax.lax.scan(body, x, (params["groups"], tuple(caches)))
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = lm_logits(params["embed"], cfg, x)
    return logits, list(new_caches)
