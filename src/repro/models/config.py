"""Model configuration schema for the 10 assigned architectures.

One :class:`ModelConfig` describes a decoder-only LM backbone composed of a
repeating *group* of layers (``layer_pattern``), each layer being an
``attn``/``mla``/``ssm`` token mixer followed by a ``dense``/``moe``/``none``
channel mixer (``ffn_pattern``). Homogeneous models use a group of size 1;
Jamba's 1:7 attn:mamba interleave with MoE-every-other-layer uses a group of
8. The layer stack is ``lax.scan``'d over groups so compile time and HLO
size are O(group), not O(depth).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense|moe|ssm|hybrid|vlm|audio
    num_layers: int
    d_model: int
    vocab_size: int
    # ---- attention ----
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0
    rope_theta: float = 10_000.0
    attn_type: str = "gqa"           # gqa|mla (per-layer kinds come from
                                     # layer_pattern; this picks the variant)
    # ---- MLA (MiniCPM3 / DeepSeek-style) ----
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    # ---- FFN ----
    d_ff: int = 0
    ffn_act: str = "swiglu"          # swiglu|gelu|squared_relu
    # ---- MoE ----
    num_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    # ---- SSM (Mamba-2 / SSD) ----
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_chunk: int = 128
    conv_width: int = 4
    # ---- layer layout ----
    layer_pattern: Tuple[str, ...] = ("attn",)     # attn|ssm per group slot
    ffn_pattern: Tuple[str, ...] = ("dense",)      # dense|moe|none per slot
    # ---- embeddings / head ----
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    # ---- modality frontend stubs ----
    frontend: str = "none"           # none|vision_stub|audio_stub
    num_patches: int = 0             # vision stub: prefix length of embeds
    # ---- misc ----
    dtype: str = "bfloat16"
    sliding_window: int = 0          # 0 = full attention
    subquadratic: bool = False       # may run long_500k decode
    # ---- beyond-paper perf options (EXPERIMENTS.md §Perf) ----
    attn_impl: str = "dense"         # dense | chunked (online-softmax tiles)
    attn_q_chunk: int = 256
    attn_kv_chunk: int = 128
    opt_conv_split: bool = False     # SSM: per-stream convs (no concat AG)
    opt_bf16_grads: bool = False     # bf16 cotangents across MoE a2a

    def __post_init__(self):
        g = len(self.layer_pattern)
        if self.num_layers % g != 0:
            raise ValueError(f"{self.name}: num_layers {self.num_layers} "
                             f"not a multiple of group size {g}")
        if len(self.ffn_pattern) != g:
            raise ValueError(f"{self.name}: ffn_pattern length must equal "
                             f"layer_pattern length")

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded to a multiple of 256 so the vocab dim shards
        evenly over any TP degree ≤256 (MaxText/Megatron convention).
        Logits beyond ``vocab_size`` are masked to -inf in ``lm_logits``."""
        return -(-self.vocab_size // 256) * 256

    @property
    def num_groups(self) -> int:
        return self.num_layers // len(self.layer_pattern)

    @property
    def group_size(self) -> int:
        return len(self.layer_pattern)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim if self.ssm_headdim else 0

    @property
    def qk_head_dim(self) -> int:
        if self.attn_type == "mla":
            return self.qk_nope_dim + self.qk_rope_dim
        return self.head_dim

    def param_count(self) -> int:
        """Analytic parameter count (embeddings included once if tied)."""
        D, F, V = self.d_model, self.d_ff, self.vocab_size
        total = V * D if self.tie_embeddings else 2 * V * D
        total += D  # final norm
        for kind, ffn in zip(self.layer_pattern, self.ffn_pattern):
            n = self.num_groups
            if kind == "attn":
                if self.attn_type == "mla":
                    qk = self.qk_nope_dim + self.qk_rope_dim
                    total += n * (D * self.q_lora_rank
                                  + self.q_lora_rank * self.num_heads * qk
                                  + D * (self.kv_lora_rank + self.qk_rope_dim)
                                  + self.kv_lora_rank * self.num_heads
                                  * (self.qk_nope_dim + self.v_head_dim)
                                  + self.num_heads * self.v_head_dim * D
                                  + self.q_lora_rank + self.kv_lora_rank + D)
                else:
                    hd = self.head_dim
                    total += n * (D * self.num_heads * hd
                                  + 2 * D * self.num_kv_heads * hd
                                  + self.num_heads * hd * D + D)
            elif kind == "ssm":
                di, ds, nh = self.d_inner, self.ssm_state, self.ssm_heads
                total += n * (D * (2 * di + 2 * ds + nh)
                              + self.conv_width * (di + 2 * ds)
                              + 3 * nh + di + di * D + D)
            if ffn == "dense":
                mats = 3 if self.ffn_act == "swiglu" else 2
                total += self.num_groups * (mats * D * F + D)
            elif ffn == "moe":
                mats = 3 if self.ffn_act == "swiglu" else 2
                total += self.num_groups * (self.num_experts * mats * D * F
                                            + D * self.num_experts + D)
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed experts)."""
        if self.num_experts == 0:
            return self.param_count()
        total = self.param_count()
        mats = 3 if self.ffn_act == "swiglu" else 2
        for kind, ffn in zip(self.layer_pattern, self.ffn_pattern):
            if ffn == "moe":
                dead = (self.num_experts - self.experts_per_token)
                total -= self.num_groups * dead * mats * self.d_model * self.d_ff
        return total


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


def shapes_for(cfg: ModelConfig):
    """The shape cells an architecture runs (long_500k only if
    sub-quadratic; see DESIGN.md §Arch-applicability)."""
    out = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if cfg.subquadratic:
        out.append(LONG_500K)
    return out
