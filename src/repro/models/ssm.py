"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060) token mixer.

Training/prefill uses the chunked SSD algorithm: quadratic attention-like
math *within* chunks (MXU-friendly batched matmuls) + a linear recurrence
*across* chunks (``lax.scan`` over chunk states). Decode is the pure
recurrent update: O(d_state * d_inner) per token, constant in context
length — which is why mamba2/jamba are the `long_500k` architectures.

The fused ``in_proj`` of the reference implementation is split into
per-component projections (z, x, B, C, dt) so each can carry its own
logical sharding axis (TP shards the d_inner/head dims; B/C/dt are small
and replicated). Mathematically identical; noted in DESIGN.md.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import (_dense_init, bf16_grad_boundary, gated_rmsnorm, init_rmsnorm)
from .sharding_hints import hint


def init_ssm(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    di = cfg.d_inner
    ds = cfg.ssm_state
    nh = cfg.ssm_heads
    cw = cfg.conv_width
    ks = jax.random.split(key, 8)
    return {
        "wz": _dense_init(ks[0], (d, di), dtype),
        "wx": _dense_init(ks[1], (d, di), dtype),
        "wb": _dense_init(ks[2], (d, ds), dtype),
        "wc": _dense_init(ks[3], (d, ds), dtype),
        "wdt": _dense_init(ks[4], (d, nh), dtype),
        # causal depthwise conv over the concatenated (x, B, C) stream
        "conv_w": (jax.random.normal(ks[5], (cw, di + 2 * ds), jnp.float32)
                   * 0.02).astype(dtype),
        "conv_b": jnp.zeros((di + 2 * ds,), dtype),
        "a_log": jnp.log(jnp.arange(1, nh + 1, dtype=jnp.float32)),
        "d_skip": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "out_norm": init_rmsnorm(ks[6], di, dtype),
        "w_out": _dense_init(ks[7], (di, d), dtype),
    }


def axes_ssm():
    return {"wz": ("embed", "inner"), "wx": ("embed", "inner"),
            "wb": ("embed", None), "wc": ("embed", None),
            "wdt": ("embed", None),
            "conv_w": (None, "conv_chan"), "conv_b": ("conv_chan",),
            "a_log": (None,), "d_skip": (None,), "dt_bias": (None,),
            "out_norm": {"scale": ("inner",)},
            "w_out": ("inner", "embed")}


def _segsum(x):
    """x: (..., l) → (..., l, l) lower-tri segment sums: out[i,j]=Σ_{j<k≤i}."""
    l = x.shape[-1]
    cs = jnp.cumsum(x, -1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((l, l), bool), 0)
    return jnp.where(mask, out, -jnp.inf)


def _conv_full(params, xbc):
    """Causal depthwise conv1d; xbc: (b, l, c)."""
    cw = params["conv_w"].shape[0]
    pad = jnp.pad(xbc, ((0, 0), (cw - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1], :]
              * params["conv_w"][i][None, None, :] for i in range(cw))
    return jax.nn.silu((out + params["conv_b"][None, None, :]
                        ).astype(jnp.float32)).astype(xbc.dtype)


def ssd_full(params, cfg: ModelConfig, u, return_cache: bool = False):
    """u: (b, l, d) → (b, l, d). l must be a multiple of ssm_chunk.
    With ``return_cache``, also returns the SSMCache (terminal recurrent
    state + conv tail) so decode can continue from the prefill."""
    b, l, _ = u.shape
    nh, hd, ds = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state
    cs = min(cfg.ssm_chunk, l)
    assert l % cs == 0, f"seq {l} not a multiple of chunk {cs}"
    nc = l // cs
    dt_ = u.dtype
    if cfg.opt_bf16_grads:
        u = bf16_grad_boundary(u)

    u = hint(u, "batch", None, None)
    z = jnp.einsum("bld,di->bli", u, params["wz"],
                   preferred_element_type=jnp.float32).astype(dt_)
    x = jnp.einsum("bld,di->bli", u, params["wx"],
                   preferred_element_type=jnp.float32).astype(dt_)
    # pin activation shardings: x/z split over TP ("inner"); the small
    # B/C/dt streams replicated over TP — without these, GSPMD shards the
    # replicated-weight projections over TP and pays a full-residual
    # all-reduce per layer to undo it (§Perf mamba2 iteration 2: 276GB/dev
    # of f32[16,4096,2560] ARs traced to the bld,dn->bln dots).
    z = hint(z, "batch", None, "inner")
    x = hint(x, "batch", None, "inner")
    bmat = jnp.einsum("bld,dn->bln", u, params["wb"],
                      preferred_element_type=jnp.float32).astype(dt_)
    cmat = jnp.einsum("bld,dn->bln", u, params["wc"],
                      preferred_element_type=jnp.float32).astype(dt_)
    dt_raw = jnp.einsum("bld,dh->blh", u, params["wdt"],
                        preferred_element_type=jnp.float32)
    bmat = hint(bmat, "batch", None, None)
    cmat = hint(cmat, "batch", None, None)
    dt_raw = hint(dt_raw, "batch", None, None)
    xbc_raw = jnp.concatenate([x, bmat, cmat], -1)
    if cfg.opt_conv_split:
        # §Perf: per-stream convs on weight slices — x stays inner-sharded,
        # B/C stay replicated; avoids the concat that forces an all-gather
        # of the sharded x stream every layer. Mathematically identical.
        di = cfg.d_inner
        px = {"conv_w": params["conv_w"][:, :di],
              "conv_b": params["conv_b"][:di]}
        pb = {"conv_w": params["conv_w"][:, di:di + ds],
              "conv_b": params["conv_b"][di:di + ds]}
        pc = {"conv_w": params["conv_w"][:, di + ds:],
              "conv_b": params["conv_b"][di + ds:]}
        x = _conv_full(px, x)
        bmat = _conv_full(pb, bmat)
        cmat = _conv_full(pc, cmat)
    else:
        xbc = _conv_full(params, xbc_raw)
        x, bmat, cmat = jnp.split(xbc, [cfg.d_inner, cfg.d_inner + ds],
                                  axis=-1)

    dt = jax.nn.softplus(dt_raw + params["dt_bias"])          # (b,l,h) fp32
    a = -jnp.exp(params["a_log"])                             # (h,)
    x = x.reshape(b, l, nh, hd)
    # chunked views
    xc = x.reshape(b, nc, cs, nh, hd)
    bc = bmat.reshape(b, nc, cs, ds)
    cc = cmat.reshape(b, nc, cs, ds)
    dtc = dt.reshape(b, nc, cs, nh)
    da = dtc * a[None, None, None, :]                         # (b,nc,cs,h)
    da_cum = jnp.cumsum(da, axis=2)

    # 1) intra-chunk (diagonal blocks)
    li = jnp.exp(_segsum(da.transpose(0, 1, 3, 2)))           # (b,nc,h,cs,cs)
    xdt = (xc * dtc[..., None]).astype(dt_)
    y_diag = jnp.einsum("bcin,bcjn,bchij,bcjhp->bcihp",
                        cc, bc, li.astype(dt_), xdt,
                        preferred_element_type=jnp.float32).astype(dt_)

    # 2) per-chunk terminal states
    decay_st = jnp.exp(da_cum[:, :, -1:, :] - da_cum)         # (b,nc,cs,h)
    states = jnp.einsum("bcin,bcih,bcihp->bchpn",
                        bc, decay_st.astype(dt_), xdt,
                        preferred_element_type=jnp.float32)   # fp32 states

    # 3) inter-chunk recurrence (scan over chunks)
    chunk_decay = jnp.exp(da_cum[:, :, -1, :])                # (b,nc,h)

    def step(carry, inp):
        st, dec = inp
        new = carry * dec[..., None, None] + st
        return new, carry

    init = jnp.zeros((b, nh, hd, ds), jnp.float32)
    final_state, prev_states = jax.lax.scan(
        step, init, (states.transpose(1, 0, 2, 3, 4),
                     chunk_decay.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)        # (b,nc,h,p,n)

    # 4) state → output contribution
    state_decay = jnp.exp(da_cum)                             # (b,nc,cs,h)
    y_off = jnp.einsum("bcin,bchpn,bcih->bcihp",
                       cc, prev_states.astype(dt_),
                       state_decay.astype(dt_),
                       preferred_element_type=jnp.float32).astype(dt_)

    y = (y_diag + y_off).reshape(b, l, nh, hd)
    y = y + (params["d_skip"][None, None, :, None] * x).astype(dt_)
    y = y.reshape(b, l, cfg.d_inner)
    y = hint(y, "batch", None, "inner")
    y = gated_rmsnorm(params["out_norm"], y, z, cfg.norm_eps)
    pet = None if cfg.opt_bf16_grads else jnp.float32
    out = jnp.einsum("bli,id->bld", y, params["w_out"],
                     preferred_element_type=pet).astype(dt_)
    if return_cache:
        cw = cfg.conv_width
        cache = SSMCache(conv=xbc_raw[:, l - (cw - 1):, :],
                         state=final_state)
        return out, cache
    return out


class SSMCache(NamedTuple):
    conv: jax.Array   # (b, conv_width-1, d_inner + 2*d_state)
    state: jax.Array  # (b, nh, headdim, d_state) fp32


def init_ssm_cache(cfg: ModelConfig, batch, dtype) -> SSMCache:
    return SSMCache(
        conv=jnp.zeros((batch, cfg.conv_width - 1,
                        cfg.d_inner + 2 * cfg.ssm_state), dtype),
        state=jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_headdim,
                         cfg.ssm_state), jnp.float32))


def ssm_cache_axes() -> SSMCache:
    return SSMCache(conv=("batch", None, "conv_chan"),
                    state=("batch", "ssm_heads", None, None))


def ssd_decode(params, cfg: ModelConfig, u, cache: SSMCache
               ) -> Tuple[jax.Array, SSMCache]:
    """u: (b, 1, d) one token; recurrent state update."""
    b = u.shape[0]
    nh, hd, ds = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state
    dt_ = u.dtype
    z = jnp.einsum("bld,di->bli", u, params["wz"],
                   preferred_element_type=jnp.float32).astype(dt_)
    x = jnp.einsum("bld,di->bli", u, params["wx"],
                   preferred_element_type=jnp.float32).astype(dt_)
    bmat = jnp.einsum("bld,dn->bln", u, params["wb"],
                      preferred_element_type=jnp.float32).astype(dt_)
    cmat = jnp.einsum("bld,dn->bln", u, params["wc"],
                      preferred_element_type=jnp.float32).astype(dt_)
    dt_raw = jnp.einsum("bld,dh->blh", u, params["wdt"],
                        preferred_element_type=jnp.float32)
    xbc = jnp.concatenate([x, bmat, cmat], -1)[:, 0, :]       # (b,c)
    conv = jnp.concatenate([cache.conv, xbc[:, None, :]], 1)  # (b,cw,c)
    cw = cfg.conv_width
    out = sum(conv[:, i, :] * params["conv_w"][i][None, :] for i in range(cw))
    out = jax.nn.silu((out + params["conv_b"][None, :]
                       ).astype(jnp.float32)).astype(dt_)
    x, bmat, cmat = jnp.split(out, [cfg.d_inner, cfg.d_inner + ds], axis=-1)

    dt = jax.nn.softplus(dt_raw[:, 0] + params["dt_bias"])    # (b,h)
    a = -jnp.exp(params["a_log"])
    da = jnp.exp(dt * a[None, :])                             # (b,h)
    xh = x.reshape(b, nh, hd).astype(jnp.float32)
    dbx = (dt[..., None, None] * xh[..., :, None]
           * bmat.astype(jnp.float32)[:, None, None, :])      # (b,h,p,n)
    state = cache.state * da[..., None, None] + dbx
    y = jnp.einsum("bhpn,bn->bhp", state,
                   cmat.astype(jnp.float32))                  # fp32
    y = y + params["d_skip"][None, :, None] * xh
    y = y.reshape(b, 1, cfg.d_inner).astype(dt_)
    y = gated_rmsnorm(params["out_norm"], y, z, cfg.norm_eps)
    out = jnp.einsum("bli,id->bld", y, params["w_out"],
                     preferred_element_type=jnp.float32).astype(dt_)
    return out, SSMCache(conv=conv[:, 1:, :], state=state)
