from .config import ModelConfig, ShapeConfig, SHAPES, shapes_for  # noqa: F401
from . import model  # noqa: F401
