"""Shared layer primitives: norms, RoPE, embeddings, dense FFN variants.

Params are plain dict pytrees. Every ``init_*`` has a sibling ``axes_*``
returning an identically-structured pytree of *logical axis name* tuples
consumed by the sharding rules engine (launch/sharding.py).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from .config import ModelConfig


@jax.custom_vjp
def bf16_grad_boundary(x):
    """Identity fwd; bf16 cotangent (halves backward TP all-reduce bytes —
    §Perf). Placed where residual-stream grads cross reduction points."""
    return x


def _bgb_fwd(x):
    return x, None


def _bgb_bwd(_, g):
    return (g.astype(jnp.bfloat16),)


bf16_grad_boundary.defvjp(_bgb_fwd, _bgb_bwd)


def _dense_init(key, shape, dtype, in_axis: int = 0):
    fan_in = shape[in_axis] if isinstance(in_axis, int) else 1
    scale = 1.0 / jnp.sqrt(jnp.maximum(fan_in, 1)).astype(jnp.float32)
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------
def init_rmsnorm(key, d, dtype):
    del key
    return {"scale": jnp.ones((d,), dtype)}


def axes_rmsnorm():
    return {"scale": ("embed",)}


def rmsnorm(params, x, eps: float):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dt)


def gated_rmsnorm(params, x, gate, eps: float):
    """Mamba-2 output norm: RMSNorm(x * silu(gate))."""
    return rmsnorm(params, x * jax.nn.silu(gate.astype(jnp.float32)
                                           ).astype(x.dtype), eps)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope_angles(positions, dim: int, theta: float):
    """positions: (...,) int32 → (..., dim//2) angles."""
    freqs = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    return positions[..., None].astype(jnp.float32) * freqs


def apply_rope(x, positions, theta: float):
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    d = x.shape[-1]
    ang = rope_angles(positions, d, theta)          # (..., seq, d/2)
    cos = jnp.cos(ang)[..., None, :]                # (..., seq, 1, d/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding + LM head
# ---------------------------------------------------------------------------
def init_embed(key, cfg: ModelConfig, dtype):
    k1, k2 = jax.random.split(key)
    v = cfg.padded_vocab
    p = {"tokens": (jax.random.normal(k1, (v, cfg.d_model),
                                      jnp.float32) * 0.02).astype(dtype)}
    if not cfg.tie_embeddings:
        p["head"] = _dense_init(k2, (cfg.d_model, v), dtype)
    return p


def axes_embed(cfg: ModelConfig):
    p = {"tokens": ("vocab", "embed")}
    if not cfg.tie_embeddings:
        p["head"] = ("embed", "vocab")
    return p


def embed_tokens(params, tokens):
    return params["tokens"][tokens]


def lm_logits(params, cfg: ModelConfig, x):
    """x: (..., d) → (..., padded_vocab) fp32 logits; padding lanes masked."""
    if cfg.tie_embeddings:
        w = params["tokens"].T
    else:
        w = params["head"]
    logits = jnp.einsum("...d,dv->...v", x, w,
                        preferred_element_type=jnp.float32)
    if cfg.padded_vocab != cfg.vocab_size:
        lane = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                        logits.ndim - 1)
        logits = jnp.where(lane < cfg.vocab_size, logits, -1e30)
    return logits


# ---------------------------------------------------------------------------
# Dense FFN (swiglu / gelu / squared_relu)
# ---------------------------------------------------------------------------
def init_ffn(key, cfg: ModelConfig, dtype):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.ffn_act == "swiglu":
        return {"w_gate": _dense_init(ks[0], (d, f), dtype),
                "w_up": _dense_init(ks[1], (d, f), dtype),
                "w_down": _dense_init(ks[2], (f, d), dtype)}
    return {"w_in": _dense_init(ks[0], (d, f), dtype),
            "w_down": _dense_init(ks[1], (f, d), dtype)}


def axes_ffn(cfg: ModelConfig):
    if cfg.ffn_act == "swiglu":
        return {"w_gate": ("embed", "ffn"), "w_up": ("embed", "ffn"),
                "w_down": ("ffn", "embed")}
    return {"w_in": ("embed", "ffn"), "w_down": ("ffn", "embed")}


def ffn_apply(params, cfg: ModelConfig, x):
    dt = x.dtype
    if cfg.opt_bf16_grads:
        x = bf16_grad_boundary(x)
    if cfg.ffn_act == "swiglu":
        g = jnp.einsum("...d,df->...f", x, params["w_gate"],
                       preferred_element_type=jnp.float32)
        u = jnp.einsum("...d,df->...f", x, params["w_up"],
                       preferred_element_type=jnp.float32)
        h = (jax.nn.silu(g) * u).astype(dt)
    else:
        h = jnp.einsum("...d,df->...f", x, params["w_in"],
                       preferred_element_type=jnp.float32)
        if cfg.ffn_act == "gelu":
            h = jax.nn.gelu(h).astype(dt)
        elif cfg.ffn_act == "squared_relu":   # Nemotron-4 (Primer)
            h = jnp.square(jax.nn.relu(h)).astype(dt)
        else:
            raise ValueError(cfg.ffn_act)
    pet = None if cfg.opt_bf16_grads else jnp.float32
    return jnp.einsum("...f,fd->...d", h, params["w_down"],
                      preferred_element_type=pet).astype(dt)
