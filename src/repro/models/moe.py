"""Mixture-of-Experts channel mixer: top-k routing with sort-based dispatch.

Dispatch is the "sparse" sort/scatter formulation (not the GShard one-hot
einsum, whose (T, E, C) dispatch tensor is quadratically wasteful): token→
expert assignments are argsorted by expert, packed into per-expert capacity
buffers, batch-matmul'd per expert, and combined back weighted by router
probs. Expert weights carry the ``experts`` logical axis → EP over the
`model` mesh axis; the token shuffle lowers to an all-to-all under GSPMD.

Load accounting: per-expert assignment counts are returned so the trainer
can (a) apply the standard aux load-balancing loss and (b) feed the counts
into the flash-hash counting table for corpus-level expert statistics
(counting semantics — DESIGN.md §5).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import _dense_init
from .sharding_hints import hint


@jax.custom_vjp
def _bf16_grad_boundary(x):
    """Identity fwd; casts the cotangent to bf16 and back — halves the
    bytes of the expert⇄token all-to-all in the backward pass (§Perf)."""
    return x


def _bfb_fwd(x):
    return x, None


def _bfb_bwd(_, g):
    return (g.astype(jnp.bfloat16).astype(g.dtype),)


_bf16_grad_boundary.defvjp(_bfb_fwd, _bfb_bwd)


def init_moe(key, cfg: ModelConfig, dtype):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 4)
    p = {"router": _dense_init(ks[0], (d, e), jnp.float32)}
    if cfg.ffn_act == "swiglu":
        p["w_gate"] = _dense_init(ks[1], (e, d, f), dtype, in_axis=1)
        p["w_up"] = _dense_init(ks[2], (e, d, f), dtype, in_axis=1)
    else:
        p["w_in"] = _dense_init(ks[1], (e, d, f), dtype, in_axis=1)
    p["w_down"] = _dense_init(ks[3], (e, f, d), dtype, in_axis=1)
    return p


def axes_moe(cfg: ModelConfig):
    p = {"router": ("embed", None)}
    if cfg.ffn_act == "swiglu":
        p["w_gate"] = ("experts", "embed", "ffn")
        p["w_up"] = ("experts", "embed", "ffn")
    else:
        p["w_in"] = ("experts", "embed", "ffn")
    p["w_down"] = ("experts", "ffn", "embed")
    return p


def _topk(probs, k: int):
    """Iterative-argmax top-k over the last axis. Unlike lax.top_k (a
    TopK custom call, which GSPMD cannot partition → full token gather),
    this is plain max/one-hot ops that shard row-parallel."""
    p = probs
    vals, idxs = [], []
    for _ in range(k):
        i = jnp.argmax(p, axis=-1)
        v = jnp.max(p, axis=-1)
        vals.append(v)
        idxs.append(i.astype(jnp.int32))
        p = p - jax.nn.one_hot(i, p.shape[-1], dtype=p.dtype) * 2.0
    return jnp.stack(vals, -1), jnp.stack(idxs, -1)


def moe_apply(params, cfg: ModelConfig, x) -> Tuple[jax.Array, jax.Array,
                                                    jax.Array]:
    """x: (b, s, d) → (y, aux_loss, expert_counts (E,)).

    GShard-style *grouped* dispatch: each batch row is a dispatch group, so
    routing, sort and position assignment are local to the row (b is the
    data-parallel dim → zero cross-device traffic until the expert
    buffers), and the only collectives are the two token⇄expert
    all-to-alls induced by the ``experts``-axis sharding hints.
    """
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    x = hint(x, "batch", None, None)
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    logits = hint(logits, "batch", None, None)   # keep top-k token-local
    probs_all = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = _topk(probs_all, k)                   # (b, s, k)
    top_p = top_p / jnp.clip(top_p.sum(-1, keepdims=True), 1e-9)

    # ---- aux load-balance loss (Switch) + counting-table stats ----
    count_frac = jnp.zeros((e,), jnp.float32).at[top_i.reshape(-1)].add(
        1.0, mode="drop")
    aux = e * jnp.mean(probs_all.mean((0, 1)) * (count_frac / (b * s * k)))

    # ---- grouped dispatch (group = batch row; capacity per group) ----
    # Gather-only formulation: GSPMD partitions batched gathers on the
    # group dim, while the scatter formulation replicates the full global
    # dispatch tensors on every device (32GB/device at 256×4096 — observed
    # in the granite dry-run HLO).
    cap = max(int(cfg.capacity_factor * s * k / e), 1)
    fe = top_i.reshape(b, s * k)                          # (b, sk)
    fp = top_p.reshape(b, s * k)
    ftok = jnp.broadcast_to(
        jnp.arange(s, dtype=jnp.int32)[:, None], (s, k)).reshape(s * k)
    ftok = jnp.broadcast_to(ftok[None], (b, s * k))
    order = jnp.argsort(fe, axis=-1, stable=True)         # per-row sort
    se = jnp.take_along_axis(fe, order, -1)
    stok = jnp.take_along_axis(ftok, order, -1)
    # position of each assignment within its expert, per row
    first = jnp.concatenate(
        [jnp.ones((b, 1), bool), se[:, 1:] != se[:, :-1]], -1)
    runpos = jnp.arange(s * k, dtype=jnp.int32)[None, :]
    run_start = jnp.where(first, runpos, 0)
    run_start = jax.lax.cummax(run_start, axis=1)
    pos = runpos - run_start
    keep = pos < cap                                      # capacity drop
    # expert run starts per row: start[b, e'] = first sorted index of e'
    erange = jnp.arange(e + 1, dtype=jnp.int32)
    start = jax.vmap(lambda row_se: jnp.searchsorted(
        row_se, erange, side="left"))(se).astype(jnp.int32)  # (b, e+1)
    # slot (e', c) ← sorted index j = start[e'] + c if within the run
    cidx = jnp.arange(cap, dtype=jnp.int32)
    j = start[:, :e, None] + cidx[None, None, :]          # (b, e, cap)
    slot_valid = (j < start[:, 1:, None]) & (cidx[None, None, :] < cap)
    j_flat = jnp.clip(j, 0, s * k - 1).reshape(b, e * cap)
    tok_for_slot = jnp.take_along_axis(stok, j_flat, -1)  # (b, e*cap)
    buf = jnp.take_along_axis(x, tok_for_slot[..., None], axis=1)
    buf = jnp.where(slot_valid.reshape(b, e * cap)[..., None], buf, 0)
    buf = buf.reshape(b, e, cap, d)
    if cfg.opt_bf16_grads:
        buf = _bf16_grad_boundary(buf)
    buf = hint(buf, "batch", "experts", None, None)  # token→expert a2a
    # ---- per-expert FFN (batched over the experts axis → EP) ----
    if cfg.ffn_act == "swiglu":
        # NOTE: no preferred_element_type here — 4-D batched bf16→f32
        # dots are unsupported by the CPU thunk executor; the MXU
        # accumulates bf16 dots in fp32 internally regardless.
        g = jnp.einsum("becd,edf->becf", buf, params["w_gate"])
        u = jnp.einsum("becd,edf->becf", buf, params["w_up"])
        h = (jax.nn.silu(g.astype(jnp.float32)) *
             u.astype(jnp.float32)).astype(x.dtype)
    else:
        h = jnp.einsum("becd,edf->becf", buf, params["w_in"])
        if cfg.ffn_act == "squared_relu":
            h = jnp.square(jax.nn.relu(h.astype(jnp.float32))).astype(x.dtype)
        else:
            h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    out_buf = jnp.einsum("becf,efd->becd", h,
                         params["w_down"]).astype(x.dtype)
    if cfg.opt_bf16_grads:
        out_buf = _bf16_grad_boundary(out_buf)
    out_buf = hint(out_buf, "batch", "experts", None, None)
    # ---- combine (gather-only): token t's k contributions live at sorted
    # positions inv[t*k + i]; read them back from the flat slot buffer ----
    inv = jnp.argsort(order, axis=-1, stable=True)        # (b, sk)
    slot_of_sorted = jnp.where(keep, se * cap + pos, e * cap)  # OOB → pad
    slot_of_assign = jnp.take_along_axis(slot_of_sorted, inv, -1)
    flat = out_buf.reshape(b, e * cap, d)
    flat = hint(flat, "batch", None, None)                # expert→token a2a
    safe_slot = jnp.clip(slot_of_assign, 0, e * cap - 1)
    contrib = jnp.take_along_axis(flat, safe_slot[..., None], axis=1)
    ok = (slot_of_assign < e * cap)
    w = (fp * ok).astype(contrib.dtype)                   # (b, sk)
    y = (contrib * w[..., None]).reshape(b, s, k, d).sum(axis=2)
    y = hint(y, "batch", None, None)
    return y, aux, count_frac.astype(jnp.int32)
