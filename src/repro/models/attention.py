"""Attention token mixers: GQA (+RoPE, optional sliding window) and MLA
(MiniCPM3/DeepSeek latent attention, with absorbed-projection decode).

Modes:
* ``full``   — training / prefill over the whole sequence (causal).
* ``decode`` — one new token against a KV cache; GQA caches (k, v); MLA
  caches the compressed latent + shared rope-key (that's its point — the
  cache line is ``kv_lora + rope_dim`` per token, not ``2*H*hd``).
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import (_dense_init, apply_rope, bf16_grad_boundary, init_rmsnorm, rmsnorm)


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------
def init_gqa(key, cfg: ModelConfig, dtype):
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": _dense_init(ks[0], (d, h, hd), dtype),
        "wk": _dense_init(ks[1], (d, kv, hd), dtype),
        "wv": _dense_init(ks[2], (d, kv, hd), dtype),
        "wo": _dense_init(ks[3], (h, hd, d), dtype),
    }


def axes_gqa():
    return {"wq": ("embed", "heads", "head_dim"),
            "wk": ("embed", "kv_heads", "head_dim"),
            "wv": ("embed", "kv_heads", "head_dim"),
            "wo": ("heads", "head_dim", "embed")}


def _causal_mask(q_len, kv_len, q_offset, window: int = 0):
    """(q_len, kv_len) additive mask; window>0 = sliding-window attention."""
    qpos = jnp.arange(q_len)[:, None] + q_offset
    kpos = jnp.arange(kv_len)[None, :]
    ok = kpos <= qpos
    if window:
        ok &= kpos > qpos - window
    return jnp.where(ok, 0.0, -1e30).astype(jnp.float32)


def _sdpa(q, k, v, mask):
    """q: (b,s,h,dq) k: (b,t,kv,dq) v: (b,t,kv,dv); GQA via reshape.
    fp32 softmax; dq may differ from dv (MLA)."""
    b, s, h, dq = q.shape
    kvh = k.shape[2]
    dv = v.shape[3]
    g = h // kvh
    q = q.reshape(b, s, kvh, g, dq)
    scores = jnp.einsum("bskgd,btkd->bkgst", q, k,
                        preferred_element_type=jnp.float32)
    scores = scores * (1.0 / jnp.sqrt(dq).astype(jnp.float32))
    scores = scores + mask  # (s,t) broadcast over (b,k,g)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, s, h, dv).astype(v.dtype)


def _sdpa_chunked(q, k, v, cfg: ModelConfig):
    """Online-softmax (flash-style) causal attention: scan over KV chunks
    inside a scan over Q chunks; only (qc × kc) score tiles materialize —
    sized to stay VMEM-resident on TPU (beyond-paper §Perf lever: kills the
    O(S²) fp32 score traffic of the dense path; same FLOPs)."""
    b, s, h, dq = q.shape
    kvh = k.shape[2]
    dv = v.shape[3]
    g = h // kvh
    qc = min(cfg.attn_q_chunk, s)
    kc = min(cfg.attn_kv_chunk, s)
    assert s % qc == 0 and s % kc == 0
    nq, nk = s // qc, s // kc
    scale = 1.0 / jnp.sqrt(dq).astype(jnp.float32)
    qr = q.reshape(b, nq, qc, kvh, g, dq)
    kr = k.reshape(b, nk, kc, kvh, dq)
    vr = v.reshape(b, nk, kc, kvh, dv)

    def one_q_chunk(_, qi):
        q_tile = qr[:, qi]                        # (b, qc, kvh, g, dq)
        q_pos = qi * qc + jnp.arange(qc)

        def inner(carry, ki):
            m, l, acc = carry
            k_tile = kr[:, ki]                    # (b, kc, kvh, dq)
            v_tile = vr[:, ki]
            scores = jnp.einsum("bqkgd,btkd->bkgqt", q_tile, k_tile,
                                preferred_element_type=jnp.float32) * scale
            k_pos = ki * kc + jnp.arange(kc)
            ok = k_pos[None, :] <= q_pos[:, None]
            if cfg.sliding_window:
                ok &= k_pos[None, :] > q_pos[:, None] - cfg.sliding_window
            scores = jnp.where(ok[None, None, None], scores, -1e30)
            m_new = jnp.maximum(m, scores.max(-1))
            p = jnp.exp(scores - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bkgqt,btkd->bkgqd", p.astype(v_tile.dtype), v_tile,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc), None

        m0 = jnp.full((b, kvh, g, qc), -1e30, jnp.float32)
        l0 = jnp.zeros((b, kvh, g, qc), jnp.float32)
        a0 = jnp.zeros((b, kvh, g, qc, dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(inner, (m0, l0, a0),
                                      jnp.arange(nk))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        # (b, kvh, g, qc, dv) → (b, qc, h, dv)
        out = out.transpose(0, 3, 1, 2, 4).reshape(b, qc, h, dv)
        return None, out.astype(v.dtype)

    _, chunks = jax.lax.scan(one_q_chunk, None, jnp.arange(nq))
    return chunks.transpose(1, 0, 2, 3, 4).reshape(b, s, h, dv)


def gqa_full(params, cfg: ModelConfig, x, positions):
    """x: (b, s, d) → (b, s, d); causal full-sequence attention."""
    if cfg.opt_bf16_grads:
        x = bf16_grad_boundary(x)
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    if cfg.attn_impl == "chunked":
        o = _sdpa_chunked(q, k, v, cfg)
    else:
        mask = _causal_mask(x.shape[1], x.shape[1], 0, cfg.sliding_window)
        o = _sdpa(q, k, v, mask)
    pet = None if cfg.opt_bf16_grads else jnp.float32
    return jnp.einsum("bshk,hkd->bsd", o, params["wo"],
                      preferred_element_type=pet).astype(x.dtype)


class KVCache(NamedTuple):
    k: jax.Array  # (b, s_max, kv, hd)
    v: jax.Array  # (b, s_max, kv, hd)


def init_kv_cache(cfg: ModelConfig, batch, s_max, dtype) -> KVCache:
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    return KVCache(k=jnp.zeros((batch, s_max, kv, hd), dtype),
                   v=jnp.zeros((batch, s_max, kv, hd), dtype))


def kv_cache_axes() -> KVCache:
    return KVCache(k=("batch", "seq", "kv_heads", "head_dim"),
                   v=("batch", "seq", "kv_heads", "head_dim"))


def gqa_decode(params, cfg: ModelConfig, x, cache: KVCache, index
               ) -> Tuple[jax.Array, KVCache]:
    """x: (b, 1, d); index: () int32 — position being written."""
    b = x.shape[0]
    pos = jnp.full((b, 1), index, jnp.int32)
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)
    ck = jax.lax.dynamic_update_slice(cache.k, k, (0, index, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache.v, v, (0, index, 0, 0))
    s_max = ck.shape[1]
    kpos = jnp.arange(s_max)[None, :]
    mask = jnp.where(kpos <= index, 0.0, -1e30).astype(jnp.float32)
    o = _sdpa(q, ck, cv, mask)
    out = jnp.einsum("bshk,hkd->bsd", o, params["wo"],
                     preferred_element_type=jnp.float32).astype(x.dtype)
    return out, KVCache(ck, cv)


def gqa_decode_packed(params, cfg: ModelConfig, x, cache: KVCache, indices
                      ) -> Tuple[jax.Array, KVCache]:
    """Packed-slot decode: x (b, 1, d), ``indices`` (b,) int32 — each row
    writes/attends at its own position (continuous batching: slots are
    mid-flight at different depths). Rows beyond their request park on a
    scratch index; their writes land on never-attended rows."""
    pos = indices[:, None]                                   # (b, 1)
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)
    row_write = jax.vmap(
        lambda c, r, i: jax.lax.dynamic_update_slice(c, r, (i, 0, 0)))
    ck = row_write(cache.k, k, indices)
    cv = row_write(cache.v, v, indices)
    s_max = ck.shape[1]
    kpos = jnp.arange(s_max)[None, :]                        # (1, t)
    mask = jnp.where(kpos <= indices[:, None], 0.0, -1e30
                     ).astype(jnp.float32)[:, None, None, None, :]
    o = _sdpa(q, ck, cv, mask)                               # mask (b,1,1,1,t)
    out = jnp.einsum("bshk,hkd->bsd", o, params["wo"],
                     preferred_element_type=jnp.float32).astype(x.dtype)
    return out, KVCache(ck, cv)


def gqa_chunk_append(params, cfg: ModelConfig, x, cache: KVCache, start
                     ) -> Tuple[jax.Array, KVCache]:
    """Chunked prefill: x (b, c, d) at absolute positions start..start+c;
    KV is appended into the cache rows [start, start+c) and the chunk
    queries attend causally against the whole cache. All batch rows share
    ``start`` (the scheduler runs one slot's chunk at a time)."""
    b, c, _ = x.shape
    pos = start + jnp.arange(c, dtype=jnp.int32)[None, :]    # (1, c)
    pos = jnp.broadcast_to(pos, (b, c))
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)
    ck = jax.lax.dynamic_update_slice(cache.k, k, (0, start, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache.v, v, (0, start, 0, 0))
    s_max = ck.shape[1]
    qpos = start + jnp.arange(c)[:, None]                    # (c, 1)
    kpos = jnp.arange(s_max)[None, :]                        # (1, t)
    mask = jnp.where(kpos <= qpos, 0.0, -1e30).astype(jnp.float32)
    o = _sdpa(q, ck, cv, mask)
    out = jnp.einsum("bshk,hkd->bsd", o, params["wo"],
                     preferred_element_type=jnp.float32).astype(x.dtype)
    return out, KVCache(ck, cv)


# ---------------------------------------------------------------------------
# MLA (multi-head latent attention)
# ---------------------------------------------------------------------------
def init_mla(key, cfg: ModelConfig, dtype):
    d, h = cfg.d_model, cfg.num_heads
    qr, kr = cfg.q_lora_rank, cfg.kv_lora_rank
    nope, rope, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    ks = jax.random.split(key, 8)
    return {
        "wq_down": _dense_init(ks[0], (d, qr), dtype),
        "q_norm": init_rmsnorm(ks[1], qr, dtype),
        "wq_up": _dense_init(ks[2], (qr, h, nope + rope), dtype, in_axis=0),
        "wkv_down": _dense_init(ks[3], (d, kr + rope), dtype),
        "kv_norm": init_rmsnorm(ks[4], kr, dtype),
        "wk_up": _dense_init(ks[5], (kr, h, nope), dtype, in_axis=0),
        "wv_up": _dense_init(ks[6], (kr, h, vd), dtype, in_axis=0),
        "wo": _dense_init(ks[7], (h, vd, d), dtype),
    }


def axes_mla():
    return {"wq_down": ("embed", "q_lora"),
            "q_norm": {"scale": ("q_lora",)},
            "wq_up": ("q_lora", "heads", "head_dim"),
            "wkv_down": ("embed", "kv_lora_rope"),
            "kv_norm": {"scale": ("kv_lora",)},
            "wk_up": ("kv_lora", "heads", "head_dim"),
            "wv_up": ("kv_lora", "heads", "head_dim"),
            "wo": ("heads", "head_dim", "embed")}


def _mla_qkv_full(params, cfg: ModelConfig, x, positions):
    nope, rope = cfg.qk_nope_dim, cfg.qk_rope_dim
    kr = cfg.kv_lora_rank
    dt = x.dtype
    ql = jnp.einsum("bsd,dr->bsr", x, params["wq_down"],
                    preferred_element_type=jnp.float32).astype(dt)
    ql = rmsnorm(params["q_norm"], ql, cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", ql, params["wq_up"],
                   preferred_element_type=jnp.float32).astype(dt)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    kvl = jnp.einsum("bsd,dr->bsr", x, params["wkv_down"],
                     preferred_element_type=jnp.float32).astype(dt)
    latent, k_rope = kvl[..., :kr], kvl[..., kr:]
    latent = rmsnorm(params["kv_norm"], latent, cfg.norm_eps)
    k_rope = apply_rope(k_rope[..., None, :], positions, cfg.rope_theta)
    return q_nope, q_rope, latent, k_rope


def mla_full(params, cfg: ModelConfig, x, positions):
    """Training/prefill MLA: expand latent to per-head K/V (standard path)."""
    b, s, _ = x.shape
    h, nope, vd = cfg.num_heads, cfg.qk_nope_dim, cfg.v_head_dim
    q_nope, q_rope, latent, k_rope = _mla_qkv_full(params, cfg, x, positions)
    k_nope = jnp.einsum("bsr,rhk->bshk", latent, params["wk_up"],
                        preferred_element_type=jnp.float32).astype(x.dtype)
    v = jnp.einsum("bsr,rhk->bshk", latent, params["wv_up"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    q = jnp.concatenate([q_nope, q_rope], -1)
    k = jnp.concatenate([k_nope,
                         jnp.broadcast_to(k_rope, (b, s, h, cfg.qk_rope_dim))],
                        -1)
    if cfg.attn_impl == "chunked":
        o = _sdpa_chunked(q, k, v, cfg)
    else:
        mask = _causal_mask(s, s, 0)
        o = _sdpa(q, k, v, mask)
    return jnp.einsum("bshk,hkd->bsd", o, params["wo"],
                      preferred_element_type=jnp.float32).astype(x.dtype)


class MLACache(NamedTuple):
    latent: jax.Array  # (b, s_max, kv_lora)
    k_rope: jax.Array  # (b, s_max, rope_dim)


def init_mla_cache(cfg: ModelConfig, batch, s_max, dtype) -> MLACache:
    return MLACache(
        latent=jnp.zeros((batch, s_max, cfg.kv_lora_rank), dtype),
        k_rope=jnp.zeros((batch, s_max, cfg.qk_rope_dim), dtype))


def mla_cache_axes() -> MLACache:
    return MLACache(latent=("batch", "seq", "kv_lora"),
                    k_rope=("batch", "seq", None))


def _mla_absorbed_attend(params, cfg: ModelConfig, x_dtype, q_nope, q_rope,
                         cl, cr, mask):
    """Shared absorbed-projection attention against the latent cache.
    ``mask`` broadcasts against scores (b, h, s, t)."""
    q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, params["wk_up"],
                       preferred_element_type=jnp.float32)
    scores = (jnp.einsum("bshr,btr->bhst", q_lat.astype(x_dtype), cl,
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bshk,btk->bhst", q_rope, cr,
                           preferred_element_type=jnp.float32))
    scale = 1.0 / jnp.sqrt(cfg.qk_nope_dim + cfg.qk_rope_dim).astype(
        jnp.float32)
    probs = jax.nn.softmax(scores * scale + mask, axis=-1).astype(x_dtype)
    ctx_lat = jnp.einsum("bhst,btr->bshr", probs, cl,
                         preferred_element_type=jnp.float32).astype(x_dtype)
    o = jnp.einsum("bshr,rhk->bshk", ctx_lat, params["wv_up"],
                   preferred_element_type=jnp.float32).astype(x_dtype)
    return jnp.einsum("bshk,hkd->bsd", o, params["wo"],
                      preferred_element_type=jnp.float32).astype(x_dtype)


def mla_decode(params, cfg: ModelConfig, x, cache: MLACache, index
               ) -> Tuple[jax.Array, MLACache]:
    """Absorbed-projection decode: score/value computed in latent space, so
    per-step FLOPs and cache bytes scale with kv_lora, not H*hd."""
    b = x.shape[0]
    pos = jnp.full((b, 1), index, jnp.int32)
    q_nope, q_rope, latent, k_rope = _mla_qkv_full(params, cfg, x, pos)
    cl = jax.lax.dynamic_update_slice(cache.latent, latent, (0, index, 0))
    cr = jax.lax.dynamic_update_slice(cache.k_rope, k_rope[:, :, 0, :],
                                      (0, index, 0))
    kpos = jnp.arange(cl.shape[1])[None, :]
    mask = jnp.where(kpos <= index, 0.0, -1e30).astype(jnp.float32)
    out = _mla_absorbed_attend(params, cfg, x.dtype, q_nope, q_rope,
                               cl, cr, mask)
    return out, MLACache(cl, cr)


def mla_decode_packed(params, cfg: ModelConfig, x, cache: MLACache, indices
                      ) -> Tuple[jax.Array, MLACache]:
    """Packed-slot MLA decode: per-row write/attend positions (b,)."""
    pos = indices[:, None]                                   # (b, 1)
    q_nope, q_rope, latent, k_rope = _mla_qkv_full(params, cfg, x, pos)
    row_write2 = jax.vmap(
        lambda c, r, i: jax.lax.dynamic_update_slice(c, r, (i, 0)))
    cl = row_write2(cache.latent, latent, indices)
    cr = row_write2(cache.k_rope, k_rope[:, :, 0, :], indices)
    kpos = jnp.arange(cl.shape[1])[None, :]                  # (1, t)
    mask = jnp.where(kpos <= indices[:, None], 0.0, -1e30
                     ).astype(jnp.float32)[:, None, None, :]  # (b,1,1,t)
    out = _mla_absorbed_attend(params, cfg, x.dtype, q_nope, q_rope,
                               cl, cr, mask)
    return out, MLACache(cl, cr)


def mla_chunk_append(params, cfg: ModelConfig, x, cache: MLACache, start
                     ) -> Tuple[jax.Array, MLACache]:
    """Chunked prefill for MLA: x (b, c, d) at positions start..start+c,
    latent/rope-key rows appended, absorbed attention over the cache."""
    b, c, _ = x.shape
    pos = start + jnp.arange(c, dtype=jnp.int32)[None, :]
    pos = jnp.broadcast_to(pos, (b, c))
    q_nope, q_rope, latent, k_rope = _mla_qkv_full(params, cfg, x, pos)
    cl = jax.lax.dynamic_update_slice(cache.latent, latent, (0, start, 0))
    cr = jax.lax.dynamic_update_slice(cache.k_rope, k_rope[:, :, 0, :],
                                      (0, start, 0))
    qpos = start + jnp.arange(c)[:, None]                    # (c, 1)
    kpos = jnp.arange(cl.shape[1])[None, :]                  # (1, t)
    mask = jnp.where(kpos <= qpos, 0.0, -1e30).astype(jnp.float32)
    out = _mla_absorbed_attend(params, cfg, x.dtype, q_nope, q_rope,
                               cl, cr, mask)
    return out, MLACache(cl, cr)
