"""Sharded, async, atomic checkpointing with resharding restore.

Layout: ``<dir>/step_<N>/{meta.json, arrays.npz}``. Leaves are addressed by
their flattened keypath. Writes go to ``step_<N>.tmp`` then ``rename`` —
a crashed writer never corrupts the latest checkpoint (fault-tolerance
invariant). ``save_async`` runs serialization on a worker thread so the
train loop only blocks on device→host transfer.

Restore takes *target shardings*, so a checkpoint written on one mesh can
be loaded onto a different mesh/shape (elastic restart: the ``device_put``
against the new shardings is the reshard). Data-pipeline state (the step)
rides in ``meta.json`` — the loader is stateless given a step.

On a real multi-host pod each host writes only its addressable shards
(same layout, per-host shard files); this single-process implementation
writes full arrays and documents the extension point.
"""
from __future__ import annotations

import json
import shutil
# the async checkpoint writer predates the FlushDispatcher and owns its
# own (single) flusher thread; folding it into the store's dispatcher is
# a ROADMAP item — until then this import is an audited exception
import threading  # flashlint: disable=FL004
import time
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", getattr(
            p, "name", p)))) for p in path)
        flat[key] = leaf
    return flat


def save_checkpoint(ckpt_dir: str | Path, step: int, tree,
                    extra_meta: Optional[dict] = None) -> Path:
    ckpt_dir = Path(ckpt_dir)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    flat = _flatten(tree)
    arrays = {}
    dtypes = {}
    for k, v in flat.items():
        a = np.asarray(v)
        dtypes[k] = str(a.dtype)
        if a.dtype.kind not in "biufc":  # ml_dtypes (bfloat16, fp8, ...)
            a = a.view(np.uint16) if a.dtype.itemsize == 2 else \
                a.view(np.uint8)
        arrays[k] = a
    np.savez(tmp / "arrays.npz", **arrays)
    meta = {"step": step, "time": time.time(),
            "keys": sorted(arrays.keys()), "dtypes": dtypes,
            "data_state": {"step": step}}
    meta.update(extra_meta or {})
    (tmp / "meta.json").write_text(json.dumps(meta, indent=2))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)  # atomic publish
    return final


def latest_step(ckpt_dir: str | Path) -> Optional[int]:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in ckpt_dir.glob("step_*")
             if p.is_dir() and not p.name.endswith(".tmp")]
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str | Path, target_tree,
                       step: Optional[int] = None,
                       shardings=None):
    """Restore into the structure of ``target_tree``; if ``shardings`` is
    given, leaves are device_put against it (elastic reshard)."""
    ckpt_dir = Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = ckpt_dir / f"step_{step:08d}"
    data = np.load(d / "arrays.npz")
    meta = json.loads((d / "meta.json").read_text())
    flat_target = _flatten(target_tree)
    missing = set(flat_target) - set(data.files)
    if missing:
        raise KeyError(f"checkpoint missing keys: {sorted(missing)[:5]} ...")
    flat_shard = _flatten(shardings) if shardings is not None else {}

    leaves_t, treedef = jax.tree_util.tree_flatten(target_tree)
    keys = list(_flatten(target_tree).keys())
    out_leaves = []
    import ml_dtypes  # jax dependency; restores bf16/fp8 views
    saved_dtypes = meta.get("dtypes", {})
    for key, tgt in zip(keys, leaves_t):
        arr = data[key]
        sdt = saved_dtypes.get(key)
        if sdt and arr.dtype.kind in "ui" and sdt not in (str(arr.dtype),):
            try:
                arr = arr.view(np.dtype(sdt))
            except TypeError:
                arr = arr.view(getattr(ml_dtypes, sdt))
        if tuple(arr.shape) != tuple(tgt.shape):
            raise ValueError(f"{key}: shape {arr.shape} != {tgt.shape}")
        arr = arr.astype(tgt.dtype)
        if key in flat_shard:
            arr = jax.device_put(arr, flat_shard[key])
        out_leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out_leaves), meta


class CheckpointManager:
    """Async periodic checkpointing + retention + emergency saves.

    ``quiesce`` callables (e.g. ``FlashStore.quiesce``) run before every
    serialization, so a checkpoint never captures a state that a
    background drain is mid-donating — the store-side barrier joins the
    in-flight drain first (DESIGN.md §11)."""

    def __init__(self, ckpt_dir: str | Path, every_steps: int = 100,
                 keep: int = 3, quiesce=()):
        self.dir = Path(ckpt_dir)
        self.every = every_steps
        self.keep = keep
        self._quiesce = list(quiesce)
        self._thread: Optional[threading.Thread] = None
        self.last_saved: Optional[int] = None

    def register_quiesce(self, fn) -> None:
        """Add a barrier to run before every save (idempotent per fn)."""
        if fn not in self._quiesce:
            self._quiesce.append(fn)

    def _join_quiesce(self, best_effort: bool = False) -> None:
        for fn in self._quiesce:
            try:
                fn()
            except Exception:
                if not best_effort:
                    raise             # emergency saves swallow (the store
                                      # may be poisoned mid-crash)

    def maybe_save(self, step: int, tree, blocking: bool = False,
                   extra_meta: Optional[dict] = None) -> bool:
        if step % self.every != 0:
            return False
        self.save(step, tree, blocking=blocking, extra_meta=extra_meta)
        return True

    def save(self, step: int, tree, blocking: bool = False,
             extra_meta: Optional[dict] = None) -> None:
        self.wait()
        self._join_quiesce()          # no mid-donation state in the copy
        # device→host copy happens here (so the step can't race the write)
        host_tree = jax.tree.map(np.asarray, tree)

        def work():
            save_checkpoint(self.dir, step, host_tree, extra_meta)
            self._gc()

        self.last_saved = step
        if blocking:
            work()
        else:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()

    def emergency(self, step: int, tree) -> None:
        """Blocking best-effort save on failure paths. Joins registered
        quiesce barriers first (best-effort: a poisoned store must not
        veto saving everything else) so even an emergency snapshot never
        serializes a mid-donation state."""
        try:
            self.wait()
            self._join_quiesce(best_effort=True)
            save_checkpoint(self.dir, step, jax.tree.map(np.asarray, tree),
                            {"emergency": True})
        except Exception:
            pass

    def wait(self) -> None:
        if self._thread is not None and self._thread.is_alive():
            self._thread.join()
        self._thread = None

    def _gc(self) -> None:
        steps = sorted(int(p.name.split("_")[1])
                       for p in self.dir.glob("step_*") if p.is_dir())
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)
