from .checkpoint import (CheckpointManager, latest_step,  # noqa: F401
                         restore_checkpoint, save_checkpoint)
