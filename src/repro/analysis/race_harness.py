"""Runtime lock-discipline + happens-before harness (DESIGN.md §10).

The static rules (flashlint) prove the *code* takes the lock and pairs
rebinds with invalidations; this harness proves the *executions* do.
Attach a :class:`Tracer` to a live store and every contract-relevant
event — H_R seal/swap, drain dispatch, device-state rebind, cache
invalidate, lookup/insert — is recorded with a vector-clock timestamp.
:meth:`Tracer.check` then replays the log and reports interleavings no
serial execution could produce, turning the stress lane's "didn't crash
in 3 seeds" into "no unserializable interleaving observed".

Happens-before edges (the only orderings the checker trusts):

1. **program order** — events of one thread, in sequence;
2. **lock edges** — releasing the traced state lock publishes the
   holder's clock; the next acquirer merges it (release → acquire);
3. **submit/join edges** — ``FlushDispatcher.submit`` forks the
   caller's clock into the drain job (submit → job start) and
   ``wait()`` joins the finished job's clock back (job end → barrier
   return).

Two events *conflict* when they touch the same resource (``hr:active``,
``hr:inflight``, ``state``, ``cache`` — per shard where sharded) and at
least one writes. Three checks run over the log:

- **data-race** — conflicting events on different threads whose clocks
  are incomparable: neither happened before the other, so the
  interleaving was a coin flip (e.g. sealing H_R over a chunk the
  worker is still draining);
- **unfenced-rebind** — a drain job rebound the device state and
  reached its end without invalidating the paired query engine
  (skipped when the log has no invalidations at all: an engine with no
  cache has nothing to fence);
- **stale-cache-insert** — a cache insert whose captured epoch is
  smaller than the number of invalidations that happened-before it:
  the inserted count predates an invalidation yet outlived it.

The tracer records only accesses the contracts care about; deliberately
benign unlocked reads (``buffered_entries``, the pre-barrier poison
probe) are untraced, so a clean store yields a clean log. Everything
here is stdlib-only — no jax import.
"""
from __future__ import annotations

import dataclasses
# the harness instruments the dispatcher's lock and worker; it is the
# audited second home for threading primitives (flashlint FL004 allows
# exactly core/store.py and this file)
import threading
from typing import Dict, List, Optional, Tuple

Clock = Dict[int, int]


@dataclasses.dataclass(frozen=True)
class Event:
    """One traced access, stamped with the recording thread's clock."""

    seq: int
    thread: int
    kind: str
    resource: Optional[str]
    rw: Optional[str]               # "r" / "w" / None (informational)
    clock: Tuple[Tuple[int, int], ...]  # frozen vector clock
    meta: Tuple[Tuple[str, object], ...]

    def get(self, key: str, default=None):
        return dict(self.meta).get(key, default)

    def describe(self) -> str:
        extra = "".join(f" {k}={v}" for k, v in self.meta)
        res = f" {self.resource}/{self.rw}" if self.resource else ""
        return f"#{self.seq} t{self.thread} {self.kind}{res}{extra}"


@dataclasses.dataclass(frozen=True)
class Finding:
    """One detected contract violation in a recorded execution."""

    kind: str                       # data-race / unfenced-rebind /
                                    # stale-cache-insert
    message: str
    events: Tuple[Event, ...]

    def describe(self) -> str:
        lines = [f"[{self.kind}] {self.message}"]
        lines += [f"    {e.describe()}" for e in self.events]
        return "\n".join(lines)


def _leq(a: Clock, b: Clock) -> bool:
    return all(v <= b.get(t, 0) for t, v in a.items())


def _concurrent(a: Clock, b: Clock) -> bool:
    return not _leq(a, b) and not _leq(b, a)


class Tracer:
    """Vector-clock event recorder. Thread-safe; its internal mutex is
    *not* a happens-before edge (it orders appends, not the program)."""

    def __init__(self):
        self._mu = threading.Lock()
        self._events: List[Event] = []
        self._clocks: Dict[int, Clock] = {}
        self._locks: Dict[str, Clock] = {}
        self._seq = 0

    # -- clock plumbing ------------------------------------------------
    def _own(self, tid: int) -> Clock:
        return self._clocks.setdefault(tid, {})

    def _tick(self, tid: int) -> None:
        c = self._own(tid)
        c[tid] = c.get(tid, 0) + 1

    def _merge(self, tid: int, snap: Clock) -> None:
        c = self._own(tid)
        for t, v in snap.items():
            if v > c.get(t, 0):
                c[t] = v

    # -- recording -----------------------------------------------------
    def record(self, kind: str, resource: Optional[str] = None,
               rw: Optional[str] = None, **meta) -> None:
        tid = threading.get_ident()
        with self._mu:
            self._tick(tid)
            self._events.append(Event(
                seq=self._seq, thread=tid, kind=kind, resource=resource,
                rw=rw, clock=tuple(sorted(self._own(tid).items())),
                meta=tuple(sorted(meta.items()))))
            self._seq += 1

    def fork(self) -> Clock:
        """Snapshot the calling thread's clock for handoff to a job the
        receiving thread will :meth:`join` (submit → job-start edge)."""
        tid = threading.get_ident()
        with self._mu:
            self._tick(tid)
            return dict(self._own(tid))

    def join(self, snap: Optional[Clock]) -> None:
        """Merge a forked snapshot into the calling thread's clock
        (job-end → barrier-return edge, and the job-start side)."""
        if snap is None:
            return
        tid = threading.get_ident()
        with self._mu:
            self._merge(tid, snap)
            self._tick(tid)

    def acquired(self, name: str) -> None:
        """Called *after* the real lock is held: merge the clock the last
        releaser published (release → acquire edge)."""
        tid = threading.get_ident()
        with self._mu:
            self._merge(tid, self._locks.get(name, {}))
            self._tick(tid)

    def released(self, name: str) -> None:
        """Called *before* the real lock is dropped: publish the holder's
        clock for the next acquirer."""
        tid = threading.get_ident()
        with self._mu:
            self._tick(tid)
            self._locks[name] = dict(self._own(tid))

    @property
    def events(self) -> List[Event]:
        with self._mu:
            return list(self._events)

    # -- the replay checker ---------------------------------------------
    def check(self) -> List[Finding]:
        """Replay the log; one :class:`Finding` per violated contract."""
        events = self.events
        out: List[Finding] = []
        out.extend(_check_unfenced_rebinds(events))
        out.extend(_check_data_races(events))
        out.extend(_check_stale_cache_inserts(events))
        return out


def _check_data_races(events: List[Event]) -> List[Finding]:
    touch = [e for e in events if e.resource is not None and e.rw]
    out: List[Finding] = []
    seen = set()
    for i, a in enumerate(touch):
        for b in touch[i + 1:]:
            if (a.resource != b.resource or a.thread == b.thread
                    or ("w" not in (a.rw, b.rw))):
                continue
            if not _concurrent(dict(a.clock), dict(b.clock)):
                continue
            sig = (a.resource, frozenset((a.kind, b.kind)))
            if sig in seen:
                continue            # one finding per (resource, kind pair)
            seen.add(sig)
            out.append(Finding(
                "data-race",
                f"unordered conflicting accesses to {a.resource}: "
                f"{a.kind} ({a.rw}) vs {b.kind} ({b.rw}) — no "
                "happens-before edge orders them",
                (a, b)))
    return out


def _check_unfenced_rebinds(events: List[Event]) -> List[Finding]:
    if not any(e.kind == "invalidate" for e in events):
        return []                   # no cache in play: nothing to fence
    out: List[Finding] = []
    open_rebinds: Dict[int, List[Event]] = {}
    for e in events:
        pend = open_rebinds.setdefault(e.thread, [])
        if e.kind == "state_rebind":
            pend.append(e)
        elif e.kind == "invalidate":
            pend.clear()            # fences every rebind before it
        elif e.kind == "job_end" and pend:
            for r in pend:
                out.append(Finding(
                    "unfenced-rebind",
                    "drain job rebound the device state and ended "
                    "without invalidating the paired query engine — "
                    "cached counts now describe a donated-away state",
                    (r, e)))
            pend.clear()
    for pend in open_rebinds.values():   # rebinds never fenced at all
        for r in pend:
            out.append(Finding(
                "unfenced-rebind",
                "device-state rebind was never followed by a query-"
                "engine invalidation on its thread",
                (r,)))
    return out


def _check_stale_cache_inserts(events: List[Event]) -> List[Finding]:
    invals = [e for e in events if e.kind == "invalidate"]
    out: List[Finding] = []
    for e in events:
        if e.kind != "cache_insert":
            continue
        epoch = e.get("epoch")
        if epoch is None:
            continue
        ec = dict(e.clock)
        before = [iv for iv in invals if _leq(dict(iv.clock), ec)]
        if len(before) > int(epoch):
            out.append(Finding(
                "stale-cache-insert",
                f"cache insert fenced at epoch {epoch} but "
                f"{len(before)} invalidation(s) happened-before it — "
                "a count probed against a pre-drain state outlived the "
                "drain's invalidation",
                (before[-1], e)))
    return out


class TracedLock:
    """Wraps the dispatcher's state lock so every acquire/release becomes
    a happens-before edge in the trace. Re-entrant (delegates to the
    underlying RLock); redundant edge merges from nested acquires are
    harmless."""

    def __init__(self, inner, tracer: Tracer, name: str = "state-lock"):
        self._inner = inner
        self._tracer = tracer
        self._name = name

    def acquire(self, *a, **kw):
        got = self._inner.acquire(*a, **kw)
        if got:
            self._tracer.acquired(self._name)
        return got

    def release(self):
        self._tracer.released(self._name)
        self._inner.release()

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc):
        self.release()
        return False


def attach(store) -> Tracer:
    """Instrument a live store (a ``FlashStore`` or a bare backend):
    returns the :class:`Tracer` now wired into its dispatcher, lock and
    query engine. Attach *before* driving traffic; the checker assumes
    the log covers every epoch bump it is asked to reason about."""
    backend = getattr(store, "_b", store)
    disp = getattr(backend, "_disp", None) or getattr(
        backend, "dispatcher", None)
    if disp is None:
        raise ValueError(f"{type(backend).__name__} has no FlushDispatcher "
                         "to instrument")
    tracer = Tracer()
    disp.tracer = tracer
    disp.lock = TracedLock(disp.lock, tracer)
    qe = getattr(backend, "query_engine", None)
    if qe is not None:
        qe.tracer = tracer
    return tracer
