"""Dataflow rules.

FL002 — use-after-donation. JAX donation (``donate_argnums`` /
``donate=True`` factory calls) consumes the argument's buffers: any
later read of the same binding sees deleted arrays (at best a
``RuntimeError`` from ``assert_live``, at worst garbage on a backend
that skips the check). The rule runs a linear, statement-ordered scan of
each function body: a name (or dotted ``self.x`` chain) passed in a
donated position becomes *spent*; reading a spent binding — or any
deeper attribute of it — flags, until an assignment rebinds it.

The scan is deliberately shallow: only plain ``Name``/``Attribute``
chains are tracked (a donated *expression* like ``f(state)`` has no
binding to poison), and branches merge conservatively (spent in either
arm ⇒ spent after).

FL003 — flush→invalidate. Every function that rebinds a ``.state``
attribute (the donated table state living on an engine/backend) must
also invalidate the paired query engine, or stale cached counts survive
the swap. ``__init__`` (first bind, nothing cached yet) is exempt.

FL003 additionally guards the Bloom-filter contract (DESIGN.md §12):
a ``DeviceTableState(...)`` rebuild that lists fields by keyword but
drops ``filter_words`` silently zombifies the filter (the pytree
re-shapes and the no-false-negatives invariant dies at the next probe),
and a device ``merge``/``merge_dirty`` call that passes table arrays but
no filter argument skips the in-kernel maintenance that keeps merged
keys covered. Both are flagged at the call site.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Tuple

from .rules_base import Rule, attr_chain, donation_indices, table_jax_aliases

#: ``table_jax`` entry points that donate their state argument
#: (positional index 1, after ``cfg``). ``update_copying`` deliberately
#: does not donate and is not listed.
_TJ_DONATING = {"update": (1,), "flush": (1,)}

_INVALIDATE_NAMES = frozenset({"invalidate", "_invalidate"})


def _donating_map(tree: ast.Module) -> Dict[str, Tuple[int, ...]]:
    """Trailing-name → donated indices, for callables *defined in this
    file* with a donation marker: ``upd = jax.jit(f, donate_argnums=…)``,
    ``self._upd = make_update_fn(…, donate=True)``, or a decorated
    ``def``. Keyed on the trailing identifier so both ``upd(…)`` and
    ``self._upd(…)`` call sites resolve."""
    out: Dict[str, Tuple[int, ...]] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign):
            idx = donation_indices(node.value)
            if idx is None:
                continue
            for t in node.targets:
                chain = attr_chain(t)
                if chain:
                    out[chain.split(".")[-1]] = idx
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                idx = donation_indices(dec)
                if idx is not None:
                    out[node.name] = idx
    return out


class _DonationScan:
    """Linear statement-order scan of one function body."""

    def __init__(self, ctx, tj_aliases, donating):
        self.ctx = ctx
        self.tj_aliases = tj_aliases
        self.donating = donating
        self.spent: Dict[str, int] = {}        # chain -> donation lineno
        self.out: List = []

    # -- call-site donation resolution -------------------------------
    def _donated_indices(self, call: ast.Call) -> Optional[Tuple[int, ...]]:
        f = call.func
        if isinstance(f, ast.Attribute):
            base = attr_chain(f.value)
            if base in self.tj_aliases and f.attr in _TJ_DONATING:
                return _TJ_DONATING[f.attr]
            if f.attr in self.donating:
                return self.donating[f.attr]
        elif isinstance(f, ast.Name) and f.id in self.donating:
            return self.donating[f.id]
        # an inline donating wrapper: (jax.jit(f, donate_argnums=…))(x)
        idx = donation_indices(f) if not isinstance(f, ast.Name) else None
        return idx

    # -- spent-set bookkeeping ---------------------------------------
    def _read(self, chain: str, node) -> None:
        for key, line in self.spent.items():
            if chain == key or chain.startswith(key + "."):
                self.out.append(self.ctx.violation(
                    "FL002", node,
                    f"'{chain}' read after being donated on line {line} — "
                    "donated buffers are spent; rebind the result instead"))
                return

    def _kill(self, chain: str) -> None:
        for key in [k for k in self.spent
                    if k == chain or k.startswith(chain + ".")]:
            del self.spent[key]

    # -- expression walk (reads + donations, in evaluation order) ----
    def _expr(self, node) -> None:
        if node is None:
            return
        if isinstance(node, (ast.Name, ast.Attribute)):
            chain = attr_chain(node)
            if chain is not None:
                self._read(chain, node)
                return                      # chain fully handled
            # fall through: complex base (subscript/call) — walk children
        if isinstance(node, ast.Call):
            for child in list(node.args) + [kw.value for kw in node.keywords]:
                self._expr(child)
            self._expr(node.func)
            idx = self._donated_indices(node)
            if idx:
                for i in idx:
                    if i < len(node.args):
                        chain = attr_chain(node.args[i])
                        if chain:
                            self.spent[chain] = node.lineno
            return
        if isinstance(node, (ast.Lambda, ast.FunctionDef,
                             ast.AsyncFunctionDef)):
            return                          # separate scope, scanned apart
        for child in ast.iter_child_nodes(node):
            self._expr(child)

    def _target(self, node) -> None:
        """Assignment target: kill rebound chains (value side was already
        scanned); subscript/starred targets still *read* their base."""
        if isinstance(node, (ast.Tuple, ast.List)):
            for elt in node.elts:
                self._target(elt)
        elif isinstance(node, ast.Starred):
            self._target(node.value)
        elif isinstance(node, (ast.Name, ast.Attribute)):
            chain = attr_chain(node)
            if chain:
                self._kill(chain)
            else:
                self._expr(node.value)      # e.g. ``f(x).attr = v``
        elif isinstance(node, ast.Subscript):
            self._expr(node.value)          # ``spent[i] = v`` reads spent
            self._expr(node.slice)

    # -- statement walk ----------------------------------------------
    def _merge(self, *snapshots: Dict[str, int]) -> None:
        merged: Dict[str, int] = {}
        for snap in snapshots:
            merged.update(snap)
        self.spent = merged

    def _branch(self, body) -> Dict[str, int]:
        saved = dict(self.spent)
        self._stmts(body)
        result = self.spent
        self.spent = saved
        return result

    def _stmts(self, body) -> None:
        for st in body:
            self._stmt(st)

    def _stmt(self, st) -> None:
        if isinstance(st, ast.Assign):
            self._expr(st.value)
            for t in st.targets:
                self._target(t)
        elif isinstance(st, ast.AnnAssign):
            self._expr(st.value)
            self._target(st.target)
        elif isinstance(st, ast.AugAssign):
            self._expr(st.value)
            chain = attr_chain(st.target)
            if chain:
                self._read(chain, st.target)  # x += v reads then rebinds
                self._kill(chain)
        elif isinstance(st, (ast.Expr, ast.Return)):
            self._expr(st.value)
        elif isinstance(st, ast.Delete):
            for t in st.targets:
                chain = attr_chain(t)
                if chain:
                    self._kill(chain)
        elif isinstance(st, ast.If):
            self._expr(st.test)
            a = self._branch(st.body)
            b = self._branch(st.orelse)
            self._merge(a, b)
        elif isinstance(st, (ast.For, ast.AsyncFor)):
            self._expr(st.iter)
            self._target(st.target)
            a = self._branch(st.body)
            b = self._branch(st.orelse)
            self._merge(self.spent, a, b)
        elif isinstance(st, ast.While):
            self._expr(st.test)
            a = self._branch(st.body)
            b = self._branch(st.orelse)
            self._merge(self.spent, a, b)
        elif isinstance(st, (ast.With, ast.AsyncWith)):
            for item in st.items:
                self._expr(item.context_expr)
                if item.optional_vars is not None:
                    self._target(item.optional_vars)
            self._stmts(st.body)
        elif isinstance(st, ast.Try):
            snaps = [self._branch(st.body)]
            for h in st.handlers:
                snaps.append(self._branch(h.body))
            snaps.append(self._branch(st.orelse))
            self._merge(*snaps)
            self._stmts(st.finalbody)
        elif isinstance(st, (ast.Raise, ast.Assert)):
            self._expr(getattr(st, "exc", None) or getattr(st, "test", None))
            self._expr(getattr(st, "cause", None) or getattr(st, "msg", None))
        elif isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            pass                            # separate scope, scanned apart
        else:
            for child in ast.iter_child_nodes(st):
                if isinstance(child, ast.expr):
                    self._expr(child)


def _check_fl002(ctx) -> List:
    tj_aliases = table_jax_aliases(ctx.tree)
    donating = _donating_map(ctx.tree)
    out: List = []
    scopes = [n for n in ast.walk(ctx.tree)
              if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]
    for fn in scopes:
        scan = _DonationScan(ctx, tj_aliases, donating)
        scan._stmts(fn.body)
        out.extend(scan.out)
    # module level (rare but real: scripts donating at top level)
    scan = _DonationScan(ctx, tj_aliases, donating)
    scan._stmts([s for s in ctx.tree.body
                 if not isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef,
                                       ast.ClassDef))])
    out.extend(scan.out)
    return out


def _check_fl003(ctx) -> List:
    out: List = []
    for fn in ast.walk(ctx.tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if fn.name == "__init__":
            continue                        # first bind: nothing cached yet
        rebinds = []
        invalidates = False
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign):
                for t in node.targets:
                    for sub in ast.walk(t):
                        if (isinstance(sub, ast.Attribute)
                                and sub.attr == "state"
                                and isinstance(sub.ctx, ast.Store)):
                            rebinds.append(sub)
            elif isinstance(node, ast.Call):
                f = node.func
                if (isinstance(f, ast.Attribute)
                        and f.attr in _INVALIDATE_NAMES):
                    invalidates = True
                elif isinstance(f, ast.Name) and f.id in _INVALIDATE_NAMES:
                    invalidates = True
        if rebinds and not invalidates:
            for r in rebinds:
                out.append(ctx.violation(
                    "FL003", r,
                    f"'{fn.name}' rebinds a .state attribute without "
                    "calling query_engine.invalidate() — stale cached "
                    "counts survive the swap (flush→invalidate contract)"))
    out.extend(_check_filter_contract(ctx))
    return out


#: DeviceTableState field count (segments.py); a keyword-style rebuild
#: naming fewer fields than this while omitting filter_words is dropping
#: the filter arrays, not renaming them.
_STATE_FIELDS = 10

_MERGE_NAMES = frozenset({"merge", "merge_dirty"})


def _mentions_filter(node: ast.expr) -> bool:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Name) and "filter" in sub.id:
            return True
        if isinstance(sub, ast.Attribute) and "filter" in sub.attr:
            return True
    return False


def _check_filter_contract(ctx) -> List:
    """Bloom-filter lifecycle (DESIGN.md §12): state rebuilds must carry
    ``filter_words``; device merges must pass the filter through."""
    out: List = []
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        f = node.func
        name = (f.id if isinstance(f, ast.Name)
                else f.attr if isinstance(f, ast.Attribute) else None)
        if name == "DeviceTableState" and node.keywords:
            kw_names = {kw.arg for kw in node.keywords}
            if (None not in kw_names            # **kwargs may carry it
                    and "filter_words" not in kw_names
                    and len(node.args) + len(node.keywords) < _STATE_FIELDS):
                out.append(ctx.violation(
                    "FL003", node,
                    "DeviceTableState(...) rebuilt without filter_words — "
                    "dropping the Bloom filter arrays breaks the "
                    "no-false-negatives invariant (DESIGN.md §12)"))
        elif (name in _MERGE_NAMES and len(node.args) >= 4
                and not any(_mentions_filter(a) for a in node.args)
                and not any(_mentions_filter(kw.value)
                            for kw in node.keywords)):
            # ≥4 positional args = the kernel/ops merge signature (pair,
            # keys, counts, …), not an engine-level merge(wait=...)
            out.append(ctx.violation(
                "FL003", node,
                f"'{name}' called without a filter argument — merges must "
                "thread filter_words so inserted keys stay covered "
                "(DESIGN.md §12)"))
    return out


FL002 = Rule(
    id="FL002",
    summary="no read of a binding after it was passed to a donating call",
    scope="all",
    check=_check_fl002,
)

FL003 = Rule(
    id="FL003",
    summary="every .state rebind must invalidate the paired query engine",
    scope="src",
    check=_check_fl003,
)
