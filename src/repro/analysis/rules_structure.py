"""Structural rules: who may construct engines (FL001), who may spin
threads (FL004), and the deprecated-shim ban (FL005)."""
from __future__ import annotations

import ast
from typing import List

from .rules_base import Rule, callee_name, path_endswith

#: engine/backend classes whose pairing contract (write engine + query
#: engine + dispatcher share one lock and one invalidation channel)
#: only ``core/store.py`` is allowed to assemble.
ENGINE_NAMES = frozenset({
    "BatchedWriteEngine", "BatchedQueryEngine", "FlushDispatcher",
    "SimBackend", "DeviceBackend", "ShardedBackend", "SealedFront",
})

#: modules that hand out threads or executors. ``core/store.py`` owns the
#: one worker pool; the race harness instruments it.
THREADING_MODULES = frozenset({
    "threading", "_thread", "concurrent", "concurrent.futures",
    "multiprocessing",
})

#: names removed with the PR-4 facade. The old CI grep matched the bare
#: strings; a parser also catches ``import ... as`` laundering.
SHIM_NAMES = frozenset({"DeviceTableAdapter", "make_device_table"})

#: CorpusStats keyword args from the pre-facade constructor signature.
SHIM_KEYWORDS = frozenset({"engine", "writer"})

_FL001_ALLOWED = ("core/store.py", "core/write_engine.py",
                  "core/query_engine.py")
_FL004_ALLOWED = ("core/store.py", "core/wal.py",
                  "analysis/race_harness.py",
                  # trace-replay feeder workers (DESIGN.md §13); other
                  # serving files must stay thread-free
                  "serving/scheduler.py")


def _check_fl001(ctx) -> List:
    """Engine construction outside the store module.

    ``write_engine.py``/``query_engine.py`` stay allowed for their own
    class definitions and internal helpers (same allowance the original
    ``tests/test_store.py`` walker made)."""
    if path_endswith(ctx, *_FL001_ALLOWED):
        return []
    out = []
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Call) and callee_name(node) in ENGINE_NAMES:
            out.append(ctx.violation(
                "FL001", node,
                f"{callee_name(node)}() constructed outside core/store.py — "
                "engine pairing (shared lock + invalidation) lives only in "
                "the FlashStore backends"))
    return out


def _check_fl004(ctx) -> List:
    if path_endswith(ctx, *_FL004_ALLOWED):
        return []
    out = []
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                root = a.name.split(".")[0]
                if a.name in THREADING_MODULES or root in THREADING_MODULES:
                    out.append(ctx.violation(
                        "FL004", node,
                        f"direct import of '{a.name}' — threads/executors "
                        "belong to core/store.py's FlushDispatcher"))
        elif isinstance(node, ast.ImportFrom) and node.level == 0:
            mod = node.module or ""
            if mod in THREADING_MODULES or mod.split(".")[0] in THREADING_MODULES:
                out.append(ctx.violation(
                    "FL004", node,
                    f"direct import from '{mod}' — threads/executors "
                    "belong to core/store.py's FlushDispatcher"))
    return out


def _check_fl005(ctx) -> List:
    out = []
    for node in ast.walk(ctx.tree):
        if isinstance(node, (ast.Import, ast.ImportFrom)):
            for a in node.names:
                # a.name is the *original* name — aliasing can't hide it
                if a.name.split(".")[-1] in SHIM_NAMES:
                    out.append(ctx.violation(
                        "FL005", node,
                        f"import of removed shim '{a.name}'"
                        + (f" (aliased as '{a.asname}')" if a.asname else "")
                        + " — use repro.core.store.FlashStore"))
        elif isinstance(node, ast.Name) and isinstance(node.ctx, ast.Load):
            if node.id in SHIM_NAMES:
                out.append(ctx.violation(
                    "FL005", node,
                    f"reference to removed shim '{node.id}' — use "
                    "repro.core.store.FlashStore"))
        elif isinstance(node, ast.Attribute) and node.attr in SHIM_NAMES:
            out.append(ctx.violation(
                "FL005", node,
                f"reference to removed shim '.{node.attr}' — use "
                "repro.core.store.FlashStore"))
        elif isinstance(node, ast.Call) and callee_name(node) == "CorpusStats":
            for kw in node.keywords:
                if kw.arg in SHIM_KEYWORDS:
                    out.append(ctx.violation(
                        "FL005", node,
                        f"CorpusStats({kw.arg}=...) uses the pre-facade "
                        "constructor signature — pass a FlashStore config"))
    return out


FL001 = Rule(
    id="FL001",
    summary="no engine/backend construction outside core/store.py",
    scope="src",
    check=_check_fl001,
)

FL004 = Rule(
    id="FL004",
    summary="no direct threading/executor use outside the store dispatcher",
    scope="src",
    check=_check_fl004,
)

FL005 = Rule(
    id="FL005",
    summary="no deprecated-shim imports or references",
    scope="src",
    check=_check_fl005,
)
