"""Shared plumbing for flashlint rules: the ``Rule`` record and the AST
helpers every rule leans on (dotted-name chains, import-alias maps,
donation-keyword extraction)."""
from __future__ import annotations

import ast
import dataclasses
from typing import Callable, List, Optional


@dataclasses.dataclass(frozen=True)
class Rule:
    """One named contract. ``check(ctx) -> list[Violation]`` runs over a
    parsed :class:`~.flashlint.FileContext`; ``scope`` is ``"src"`` for
    contracts about package code only (see the flashlint docstring) or
    ``"all"``."""

    id: str
    summary: str
    scope: str
    check: Callable[[object], List]


def attr_chain(node: ast.AST) -> Optional[str]:
    """Dotted name of a ``Name``/``Attribute`` chain (``self.state``,
    ``st.cfg``), or ``None`` when the base is not a plain name
    (calls, subscripts, literals)."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def callee_name(call: ast.Call) -> Optional[str]:
    """Trailing identifier of a call's target: ``Foo(...)`` → ``Foo``,
    ``mod.Foo(...)`` → ``Foo``."""
    f = call.func
    if isinstance(f, ast.Name):
        return f.id
    if isinstance(f, ast.Attribute):
        return f.attr
    return None


def table_jax_aliases(tree: ast.Module) -> set:
    """Names the module binds to :mod:`repro.core.table_jax` (``tj`` in
    most of the tree): ``from ... import table_jax [as X]`` and
    ``import ...table_jax as X``."""
    out = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom):
            for a in node.names:
                if a.name == "table_jax":
                    out.add(a.asname or a.name)
        elif isinstance(node, ast.Import):
            for a in node.names:
                if a.name.split(".")[-1] == "table_jax" and a.asname:
                    out.add(a.asname)
    return out


def donation_indices(value: ast.AST) -> Optional[tuple]:
    """If ``value`` (an assignment RHS / decorator expression) carries a
    donation marker, return the donated positional indices.

    ``donate_argnums=<int|tuple>`` is read literally;
    ``donate=True`` marks the repo's sharded-program factories
    (:func:`repro.core.distributed.make_update_fn` and friends), whose
    produced callables donate argument 0."""
    for node in ast.walk(value):
        if not isinstance(node, ast.Call):
            continue
        for kw in node.keywords:
            if kw.arg == "donate_argnums":
                try:
                    got = ast.literal_eval(kw.value)
                except ValueError:
                    # dynamic (e.g. ``(0,) if donate else ()``): assume
                    # the donating branch — conservative for a linter
                    return (0,)
                if isinstance(got, int):
                    return (got,)
                return tuple(got)
            if (kw.arg == "donate"
                    and isinstance(kw.value, ast.Constant)
                    and kw.value.value is True):
                return (0,)
    return None


def path_endswith(ctx, *suffixes: str) -> bool:
    """True when the file's path ends with any of the given
    ``/``-separated suffixes (``core/store.py``)."""
    p = ctx.path.resolve().as_posix()
    return any(p.endswith(s) for s in suffixes)
