"""Correctness tooling for the FlashStore concurrency contracts.

Two halves (DESIGN.md §10):

- :mod:`.flashlint` — AST-based static checker; named rules FL001–FL006
  enforce engine-pairing, donation, flush→invalidate, threading, shim,
  and lock-discipline contracts. CLI:
  ``python -m repro.analysis.flashlint src tests benchmarks examples``.
- :mod:`.race_harness` — opt-in runtime instrumentation: a vector-clock
  tracer attached to a live store records seal/swap/drain/invalidate/
  lookup events, and a replay checker flags unordered conflicting
  accesses to the H_R buffers and the hot cache.

Nothing here imports jax; the package is safe to use in lint-only CI
jobs without an accelerator stack.
"""
from __future__ import annotations

__all__ = ["flashlint", "race_harness"]
