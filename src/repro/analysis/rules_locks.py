"""FL006 — dispatcher lock discipline.

Classes that share mutable state with the FlushDispatcher worker declare
it explicitly::

    class BatchedWriteEngine:
        _fl_guarded = ("state", "_inflight")

Any ``self.<guarded>`` access inside a method must then sit lexically
inside a ``with self._lock():`` / ``with self.dispatcher.lock:`` block.
Two def-line markers opt a whole method out, and double as
documentation of *why* it is safe:

- ``# flashlint: under-lock`` — the method is only ever invoked with the
  lock already held (e.g. worker-side drain bodies submitted via
  ``dispatcher.submit``, which wraps the job in the lock).
- ``# flashlint: quiescent`` — the method begins by waiting out the
  in-flight job (``_barrier``/``wait``), so no worker can race it.

``__init__`` is exempt (no worker exists yet). Nested functions are
scanned as lock-free: a closure capturing ``self`` gives no lexical
evidence it runs under the lock — mark the enclosing method instead.
"""
from __future__ import annotations

import ast
from typing import List, Optional, Tuple

from .rules_base import Rule, attr_chain

_LOCK_CALL_NAMES = frozenset({"_lock", "lock"})
_MARKERS = ("# flashlint: under-lock", "# flashlint: quiescent")


def _guarded_fields(cls: ast.ClassDef) -> Optional[Tuple[str, ...]]:
    """The class's ``_fl_guarded = ("a", "b")`` declaration, if any."""
    for st in cls.body:
        targets = []
        if isinstance(st, ast.Assign):
            targets = st.targets
            value = st.value
        elif isinstance(st, ast.AnnAssign) and st.value is not None:
            targets = [st.target]
            value = st.value
        else:
            continue
        for t in targets:
            if isinstance(t, ast.Name) and t.id == "_fl_guarded":
                try:
                    got = ast.literal_eval(value)
                except ValueError:
                    return None
                return tuple(got)
    return None


def _is_lock_ctx(expr: ast.AST) -> bool:
    """Does this ``with``-item expression take the state lock?
    Recognized shapes: ``self._lock()`` / ``self.dispatcher.lock`` /
    ``self._disp.lock`` / anything ending in ``.lock`` or a ``*_lock()``
    call."""
    if isinstance(expr, ast.Call):
        f = expr.func
        name = f.attr if isinstance(f, ast.Attribute) else (
            f.id if isinstance(f, ast.Name) else None)
        return name in _LOCK_CALL_NAMES
    chain = attr_chain(expr)
    return bool(chain) and chain.split(".")[-1] in _LOCK_CALL_NAMES


class _LockScan(ast.NodeVisitor):
    def __init__(self, ctx, guarded, method_name):
        self.ctx = ctx
        self.guarded = guarded
        self.method = method_name
        self.locked = 0
        self.out: List = []

    def visit_With(self, node: ast.With) -> None:
        takes = any(_is_lock_ctx(i.context_expr) for i in node.items)
        for i in node.items:
            self.visit(i.context_expr)
            if i.optional_vars is not None:
                self.visit(i.optional_vars)
        self.locked += takes
        for st in node.body:
            self.visit(st)
        self.locked -= takes

    visit_AsyncWith = visit_With

    def visit_Attribute(self, node: ast.Attribute) -> None:
        if (not self.locked
                and node.attr in self.guarded
                and isinstance(node.value, ast.Name)
                and node.value.id == "self"):
            mode = "written" if isinstance(node.ctx, ast.Store) else "read"
            self.out.append(self.ctx.violation(
                "FL006", node,
                f"self.{node.attr} {mode} outside the state lock in "
                f"'{self.method}' — guarded by _fl_guarded; wrap in "
                "'with self._lock():' or mark the method "
                "'# flashlint: under-lock' / '# flashlint: quiescent'"))
        self.generic_visit(node)

    def visit_FunctionDef(self, node) -> None:
        # nested def: no lexical lock evidence crosses the boundary
        saved, self.locked = self.locked, 0
        self.generic_visit(node)
        self.locked = saved

    visit_AsyncFunctionDef = visit_FunctionDef
    visit_Lambda = visit_FunctionDef


def _check_fl006(ctx) -> List:
    out: List = []
    for cls in ast.walk(ctx.tree):
        if not isinstance(cls, ast.ClassDef):
            continue
        guarded = _guarded_fields(cls)
        if not guarded:
            continue
        for fn in cls.body:
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if fn.name == "__init__":
                continue
            sig = ctx.def_marker_lines(fn)
            if any(m in sig for m in _MARKERS):
                continue
            scan = _LockScan(ctx, frozenset(guarded), fn.name)
            for st in fn.body:
                scan.visit(st)
            out.extend(scan.out)
    return out


FL006 = Rule(
    id="FL006",
    summary="guarded dispatcher state only accessed under the state lock",
    scope="src",
    check=_check_fl006,
)
