"""flashlint — the repo's contract checker (DESIGN.md §10).

The store's correctness rests on conventions the type system cannot see:
engine pairing lives only in :mod:`repro.core.store`, donated table
states are rebound and never reused, every state rebind is fenced by a
query-engine invalidation, dispatcher-guarded fields are only touched
under the state lock. Before this module those contracts were enforced
by scattered one-off mechanisms — an AST walk buried in
``tests/test_store.py``, a ``forbid-shims`` grep in CI, runtime
``assert_live`` guards that only fire once the damage is done. flashlint
is the single static pass: each contract is a named, individually
suppressible rule.

Rules (see DESIGN.md §10 for the full table):

========  ==================================================================
FL001     no engine/backend construction outside ``core/store.py``
FL002     use-after-donation: a value passed to a donating call site
          (``donate=True`` / ``donate_argnums``) must be rebound before
          any further read
FL003     every code path that rebinds ``<backend>.state`` must invalidate
          the paired query engine (the flush→invalidate contract)
FL004     no direct ``threading``/executor imports outside the store's
          dispatcher (plus the race harness and the serving scheduler's
          trace-replay feeders)
FL005     no deprecated-shim imports/references (replaces the CI grep —
          a real parser also catches aliased imports)
FL006     dispatcher-guarded fields (``_fl_guarded`` declarations) are
          only accessed under the state lock, or in methods annotated
          ``# flashlint: under-lock`` / ``# flashlint: quiescent``
========  ==================================================================

Suppression: append ``# flashlint: disable=FL002`` (comma-separate for
several rules) to the offending line, or put the comment on its own line
directly above; ``# flashlint: disable-file=FLxxx`` anywhere in a file
disables a rule for the whole file. Suppressions are for *intentional*
contract violations (e.g. the test that proves donated buffers really
die) — each one should read as documentation.

Scoping: rules marked ``scope="src"`` encode contracts about package
code only (tests and benchmarks legitimately construct bare engines or
spin threads to exercise them); they run only on files with a ``src``
path component. Rules marked ``scope="all"`` run everywhere. Fixture
trees (directories named ``lint_fixtures``) are skipped by the recursive
walk — point flashlint at a fixture file explicitly to lint it, and give
fixtures a ``src`` path component when they must trip src-scoped rules.

CLI::

    python -m repro.analysis.flashlint src tests benchmarks examples

exits 0 on a clean tree, 1 with ``file:line:col: FLxxx message`` per
violation, 2 when nothing was scanned (fail-closed: a typo'd path must
not pass CI).
"""
from __future__ import annotations

import argparse
import ast
import dataclasses
import os
import re
import sys
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence

#: directories the recursive walk never descends into. ``lint_fixtures``
#: holds deliberately-violating files for the rule tests.
SKIP_DIRS = frozenset({"__pycache__", "lint_fixtures", ".git", ".github",
                       ".venv", "node_modules"})

_DISABLE_RE = re.compile(
    r"#\s*flashlint:\s*disable(?P<file>-file)?\s*=\s*"
    r"(?P<ids>[A-Za-z0-9_*]+(?:\s*,\s*[A-Za-z0-9_*]+)*)")


@dataclasses.dataclass(frozen=True)
class Violation:
    """One contract violation at a source location."""

    rule: str
    path: str
    line: int
    col: int
    message: str

    def format(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule} {self.message}"


class FileContext:
    """Parsed source + metadata handed to every rule's ``check``."""

    def __init__(self, path: Path, display: Optional[str] = None):
        self.path = Path(path)
        self.display = display if display is not None else str(path)
        self.source = self.path.read_text()
        self.lines = self.source.splitlines()
        self.tree = ast.parse(self.source, filename=str(path))
        #: src-scoped rules only run when the file sits under a ``src``
        #: path component (the package tree, or a fixture mimicking it)
        parts = self.path.resolve().parts
        self.src_scoped = "src" in parts

    def violation(self, rule: str, node, message: str) -> Violation:
        return Violation(rule, self.display, getattr(node, "lineno", 0),
                         getattr(node, "col_offset", 0), message)

    def line_text(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1]
        return ""

    def def_marker_lines(self, node) -> str:
        """Source lines where FL006's ``under-lock``/``quiescent``
        markers live: the ``def`` signature plus the comment line
        directly above it (above any decorators)."""
        start = node.lineno
        if node.decorator_list:
            start = min(start, node.decorator_list[0].lineno)
        start = max(1, start - 1)     # the comment line above
        end = node.body[0].lineno if node.body else node.lineno + 1
        return "\n".join(self.lines[start - 1:end])


def _suppressions(ctx: FileContext) -> tuple[Dict[int, set], set]:
    """Per-line and file-level suppressed rule ids."""
    per_line: Dict[int, set] = {}
    whole_file: set = set()
    for i, text in enumerate(ctx.lines, start=1):
        m = _DISABLE_RE.search(text)
        if not m:
            continue
        ids = {s.strip().upper() for s in m.group("ids").split(",")}
        if m.group("file"):
            whole_file |= ids
        else:
            # a trailing comment covers its own line; a comment-only
            # line covers the statement below it
            target = i + 1 if text.lstrip().startswith("#") else i
            per_line.setdefault(target, set()).update(ids)
    return per_line, whole_file


def _is_suppressed(v: Violation, per_line: Dict[int, set],
                   whole_file: set) -> bool:
    if v.rule in whole_file or "*" in whole_file:
        return True
    ids = per_line.get(v.line)
    return bool(ids and (v.rule in ids or "*" in ids))


def all_rules():
    """The registry, id → rule module (import deferred so ``--list-rules``
    stays cheap and rule modules can share this module's helpers)."""
    from . import rules_dataflow, rules_locks, rules_structure
    return {
        "FL001": rules_structure.FL001,
        "FL002": rules_dataflow.FL002,
        "FL003": rules_dataflow.FL003,
        "FL004": rules_structure.FL004,
        "FL005": rules_structure.FL005,
        "FL006": rules_locks.FL006,
    }


def iter_py_files(paths: Sequence) -> Iterable[Path]:
    for p in paths:
        p = Path(p)
        if p.is_file():
            if p.suffix == ".py":
                yield p
        elif p.is_dir():
            for root, dirs, files in os.walk(p):
                dirs[:] = sorted(d for d in dirs if d not in SKIP_DIRS
                                 and not d.startswith("."))
                for f in sorted(files):
                    if f.endswith(".py"):
                        yield Path(root) / f


def lint_file(path, select: Optional[Sequence[str]] = None,
              display: Optional[str] = None) -> List[Violation]:
    """Run every (selected) rule over one file, honoring scope and
    suppressions. Parse failures surface as an ``FL000`` violation so a
    broken file can never slip through as 'clean'."""
    rules = all_rules()
    if select:
        want = {s.strip().upper() for s in select}
        unknown = want - set(rules)
        if unknown:
            raise ValueError(f"unknown rule id(s): {sorted(unknown)}")
        rules = {k: v for k, v in rules.items() if k in want}
    try:
        ctx = FileContext(path, display=display)
    except SyntaxError as e:
        return [Violation("FL000", display or str(path), e.lineno or 0,
                          e.offset or 0, f"file does not parse: {e.msg}")]
    per_line, whole_file = _suppressions(ctx)
    out: List[Violation] = []
    for rule in rules.values():
        if rule.scope == "src" and not ctx.src_scoped:
            continue
        for v in rule.check(ctx):
            if not _is_suppressed(v, per_line, whole_file):
                out.append(v)
    return out


def lint_paths(paths: Sequence,
               select: Optional[Sequence[str]] = None
               ) -> tuple[List[Violation], int]:
    """Lint every ``.py`` file under ``paths``. Returns
    ``(violations, files_scanned)``."""
    violations: List[Violation] = []
    n = 0
    cwd = Path.cwd()
    for f in iter_py_files(paths):
        n += 1
        try:
            display = str(f.resolve().relative_to(cwd))
        except ValueError:
            display = str(f)
        violations.extend(lint_file(f, select=select, display=display))
    violations.sort(key=lambda v: (v.path, v.line, v.col, v.rule))
    return violations, n


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.flashlint",
        description="contract checker for the FlashStore concurrency "
                    "invariants (DESIGN.md §10)")
    ap.add_argument("paths", nargs="*", help="files or directories to lint")
    ap.add_argument("--select", default=None,
                    help="comma-separated rule ids to run (default: all)")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule table and exit")
    args = ap.parse_args(argv)
    if args.list_rules:
        for rid, rule in sorted(all_rules().items()):
            print(f"{rid}  [{rule.scope:>3}]  {rule.summary}")
        return 0
    if not args.paths:
        ap.error("no paths given (and --list-rules not requested)")
    select = args.select.split(",") if args.select else None
    violations, n_files = lint_paths(args.paths, select=select)
    for v in violations:
        print(v.format())
    if n_files == 0:
        # fail-closed: a typo'd path in CI must not read as a clean pass
        print("flashlint: error: no Python files found under "
              f"{list(map(str, args.paths))}", file=sys.stderr)
        return 2
    if violations:
        print(f"flashlint: {len(violations)} violation(s) "
              f"in {n_files} file(s) scanned", file=sys.stderr)
        return 1
    print(f"flashlint: clean ({n_files} file(s) scanned)", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
