"""Pure-jnp oracle for the flash-attention kernel: dense causal SDPA with
GQA grouping and fp32 softmax (the kernel's bit-contract up to bf16
accumulation differences)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sdpa_ref(q, k, v, causal: bool = True):
    """q: (b, s, h, d); k/v: (b, s, kvh, d/dv) → (b, s, h, dv)."""
    b, s, h, d = q.shape
    kvh = k.shape[2]
    g = h // kvh
    dv = v.shape[3]
    qr = q.reshape(b, s, kvh, g, d)
    scores = jnp.einsum("bskgd,btkd->bkgst", qr, k,
                        preferred_element_type=jnp.float32)
    scores = scores / jnp.sqrt(d).astype(jnp.float32)
    if causal:
        qpos = jnp.arange(s)[:, None]
        kpos = jnp.arange(s)[None, :]
        scores = jnp.where((kpos <= qpos)[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, s, h, dv).astype(v.dtype)
