"""jit'd wrapper: flash attention with oracle fallback.

``flash_attention(q, k, v)`` dispatches to the Pallas kernel (interpret
mode on CPU; compiled Mosaic on real TPUs). The dense oracle lives in
ref.py; tests sweep shapes/dtypes asserting allclose.
"""
from __future__ import annotations

from .kernel import flash_attention_fwd
from .ref import sdpa_ref  # noqa: F401


def flash_attention(q, k, v, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool = True):
    return flash_attention_fwd(q, k, v, block_q=block_q, block_k=block_k,
                               causal=causal, interpret=interpret)
