"""Pallas TPU flash-attention (forward): causal GQA, online softmax.

Tiling: grid (b·kvh·g, nq); each step owns one (block_q × d) query tile and
scans KV in (block_k × d) tiles held in VMEM — running max/denominator/
accumulator live in VMEM scratch for the whole row of KV tiles, so the
only HBM traffic is Q/K/V reads and O writes (the point of the kernel;
cf. EXPERIMENTS.md §Perf granite iteration 1, where the lax.scan
formulation was refuted because XLA materializes scan carries per step).

MXU alignment: block_q/block_k multiples of 128 on real TPUs (the lane
dim); head_dim is the minor-most dim of every tile. Validated bit-for-bit
against ``ref.sdpa_ref`` under ``interpret=True`` (CPU) across
shape/dtype sweeps in tests/test_flash_attn.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, *, block_k: int, scale: float,
                causal: bool):
    _, block_q, d = q_ref.shape
    s = k_ref.shape[1]
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32) * scale         # (bq, d) in VMEM
    q_pos = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, 1), 0)

    nk = s // block_k

    def body(ki, carry):
        m, l, acc = carry
        k_tile = k_ref[0, pl.ds(ki * block_k, block_k), :].astype(
            jnp.float32)
        v_tile = v_ref[0, pl.ds(ki * block_k, block_k), :]
        scores = jax.lax.dot_general(
            q, k_tile, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)      # (bq, bk)
        if causal:
            k_pos = ki * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (1, block_k), 1)
            scores = jnp.where(k_pos <= q_pos, scores, -1e30)
        m_new = jnp.maximum(m, scores.max(axis=1, keepdims=True))
        p = jnp.exp(scores - m_new)
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=1, keepdims=True)
        acc_new = acc * alpha + jax.lax.dot_general(
            p.astype(v_tile.dtype), v_tile, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return m_new, l_new, acc_new

    m0 = jnp.full((block_q, 1), -1e30, jnp.float32)
    l0 = jnp.zeros((block_q, 1), jnp.float32)
    a0 = jnp.zeros((block_q, v_ref.shape[2]), jnp.float32)
    if causal:  # skip fully-masked KV tiles (static grid bound per q tile)
        upper = jnp.minimum(
            jnp.maximum(((qi + 1) * block_q + block_k - 1) // block_k, 1),
            nk)
    else:
        upper = nk
    m, l, acc = jax.lax.fori_loop(0, upper, body, (m0, l0, a0))
    o_ref[0] = (acc / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("block_q", "block_k", "causal",
                                    "interpret"))
def flash_attention_fwd(q, k, v, block_q: int = 128, block_k: int = 128,
                        causal: bool = True, interpret: bool = True):
    """q: (b, s, h, d); k/v: (b, s, kvh, d/dv) → o: (b, s, h, dv).

    GQA: query head hq reads kv head hq // (h // kvh).
    """
    b, s, h, d = q.shape
    kvh = k.shape[2]
    dv = v.shape[3]
    g = h // kvh
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    assert s % block_q == 0 and s % block_k == 0
    scale = 1.0 / (d ** 0.5)

    # flatten (b, h) into the grid's first axis; block index maps pick the
    # right batch row / kv head for each q head
    qt = q.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    kt = k.transpose(0, 2, 1, 3).reshape(b * kvh, s, d)
    vt = v.transpose(0, 2, 1, 3).reshape(b * kvh, s, dv)

    kern = functools.partial(_fwd_kernel, block_k=block_k, scale=scale,
                             causal=causal)
    out = pl.pallas_call(
        kern,
        grid=(b * h, s // block_q),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, qi: (bh, qi, 0)),
            pl.BlockSpec((1, s, d),
                         lambda bh, qi, g=g, kvh=kvh:
                         ((bh // (g * kvh)) * kvh + (bh % (g * kvh)) // g,
                          0, 0)),
            pl.BlockSpec((1, s, dv),
                         lambda bh, qi, g=g, kvh=kvh:
                         ((bh // (g * kvh)) * kvh + (bh % (g * kvh)) // g,
                          0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, dv), lambda bh, qi: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, dv), v.dtype),
        interpret=interpret,
    )(qt, kt, vt)
    return out.reshape(b, h, s, dv).transpose(0, 2, 1, 3)
