# Pallas TPU kernels for the paper's compute hot-spot: the counting hash
# table's block-level merge/query (validated on CPU via interpret=True).
