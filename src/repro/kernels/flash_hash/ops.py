"""jit'd wrappers around the flash-hash Pallas kernels.

Adds the outside-the-kernel plumbing the paper's schemes need:

* ``bucket_rows`` — generic drain: pack staged updates into the dense
  ``(n_rows, max_u)`` layout the merge kernels tile over, given an
  arbitrary destination-row assignment (block id for a full merge, grid
  position for a dirty-permutation merge, partition-local offset for an
  MDB partition drain). Updates beyond a row's ``max_u`` capacity are
  *carried over* (returned, stay staged) — the deferred-update discipline
  that bounds VMEM per tile.
* ``bucket_updates`` — RAM-buffer drain: ``bucket_rows`` with rows =
  destination block (the secondary hash ``s``).
* ``accumulate`` — the TPU-native RAM buffer: sort + segment-sum dedup of a
  token batch into (unique key, count) pairs (open-hash pre-aggregation).
* ``merge`` / ``merge_dirty`` — merge kernel entry points.
* ``query_sorted`` / ``query_blocked`` — per-key vs batched query entry
  points (the latter buckets the batch by block so each queried tile is
  fetched once per wave).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from ...core.hashing import Pow2Hash
from . import kernel as _k

EMPTY = _k.EMPTY


@functools.partial(jax.jit, static_argnums=(3, 4))
def bucket_rows(rows, keys, counts, n_rows: int, max_u: int):
    """Pack (keys, counts) updates into (n_rows, max_u) per-row buffers.

    ``rows`` is the destination row per update — for a full-table merge it
    is the block id ``s(key)``; for a dirty-block merge it is the key's
    position in the dirty-block list; for an MDB partition drain it is the
    block offset within the partition. Entries with ``rows`` outside
    ``[0, n_rows)`` or ``key == EMPTY`` are padding and dropped.

    Returns (upd_keys, upd_counts, carry_keys, carry_counts, n_carried):
    carry_* hold updates that exceeded a row's ``max_u`` capacity (sparse,
    same (U,) layout, EMPTY-padded) and must stay staged.
    """
    (U,) = keys.shape
    valid = (keys != EMPTY) & (rows >= 0) & (rows < n_rows)
    rw = jnp.where(valid, rows, n_rows).astype(jnp.int32)
    order = jnp.argsort(rw, stable=True)
    sk = keys[order]
    sc = counts[order]
    sr = rw[order]
    # position within the row's group
    start = jnp.searchsorted(sr, jnp.arange(n_rows + 1, dtype=sr.dtype))
    pos_in_r = jnp.arange(U, dtype=jnp.int32) - start[jnp.clip(sr, 0, n_rows)]
    keep = (sr < n_rows) & (pos_in_r < max_u)
    row = jnp.where(keep, sr, n_rows)  # out-of-bounds rows get dropped
    upd_keys = jnp.full((n_rows, max_u), EMPTY, dtype=keys.dtype)
    upd_counts = jnp.zeros((n_rows, max_u), dtype=counts.dtype)
    col = jnp.where(keep, pos_in_r, 0)
    upd_keys = upd_keys.at[row, col].set(sk, mode="drop")
    upd_counts = upd_counts.at[row, col].set(sc, mode="drop")
    carried = (sr < n_rows) & ~keep
    carry_keys = jnp.where(carried, sk, EMPTY)
    carry_counts = jnp.where(carried, sc, 0)
    return (upd_keys, upd_counts, carry_keys, carry_counts,
            carried.sum(dtype=jnp.int32))


@functools.partial(jax.jit, static_argnums=(0, 3))
def bucket_updates(pair: Pow2Hash, keys, counts, max_u: int):
    """Pack (keys, counts) updates into (n_b, max_u) per-block buffers.

    keys/counts: (U,) int32; EMPTY-keyed entries are padding and dropped.
    Returns (upd_keys, upd_counts, carry_keys, carry_counts, n_carried):
    carry_* hold updates that exceeded a block's capacity (sparse, same
    (U,) layout, EMPTY-padded).
    """
    n_b = pair.num_slots
    rows = jnp.where(keys != EMPTY, pair.s(keys), n_b).astype(jnp.int32)
    return bucket_rows(rows, keys, counts, n_b, max_u)


@jax.jit
def accumulate(tokens) -> Tuple[jax.Array, jax.Array]:
    """Open-hash RAM buffer, TPU-native: dedup a batch into (keys, counts).

    tokens: (T,) int32 (EMPTY entries ignored). Returns (T,)-shaped unique
    keys (EMPTY-padded) + int32 counts: sort, then segment-sum runs.
    """
    t = jnp.sort(tokens)
    is_head = jnp.concatenate([jnp.ones((1,), bool), t[1:] != t[:-1]])
    is_head &= t != EMPTY
    seg = jnp.cumsum(is_head) - 1                     # run ids
    ones = (t != EMPTY).astype(jnp.int32)
    counts = jax.ops.segment_sum(ones, seg, num_segments=t.shape[0])
    heads_idx = jnp.where(is_head, jnp.arange(t.shape[0]), t.shape[0] - 1)
    # compact run heads to the front, EMPTY-pad the tail
    order = jnp.argsort(jnp.where(is_head, 0, 1), stable=True)
    keys = jnp.where(is_head[order], t[order], EMPTY)
    cnts = jnp.where(is_head[order],
                     counts[jnp.clip(seg[order], 0, t.shape[0] - 1)], 0)
    return keys, cnts.astype(jnp.int32)


def merge(pair: Pow2Hash, table_keys, table_counts, filter_words,
          upd_keys, upd_counts, interpret: bool = True):
    return _k.merge(pair, table_keys, table_counts, filter_words,
                    upd_keys, upd_counts, interpret)


def merge_dirty(pair: Pow2Hash, table_keys, table_counts, filter_words,
                dirty_blocks, upd_keys, upd_counts, interpret: bool = True):
    return _k.merge_dirty(pair, table_keys, table_counts, filter_words,
                          dirty_blocks, upd_keys, upd_counts, interpret)


@functools.partial(jax.jit, static_argnums=(0, 4))
def query_sorted(pair: Pow2Hash, table_keys, table_counts, q_keys,
                 interpret: bool = True):
    """Point queries; sorts by block first so consecutive grid steps reuse
    the same VMEM tile (Pallas elides the re-fetch), then unsorts.

    One grid step per query — the per-key reference path. Batches should
    use :func:`query_blocked`, which fetches each queried tile once."""
    blk = pair.s(q_keys)
    order = jnp.argsort(blk, stable=True)
    cnts, dists = _k.query(pair, table_keys, table_counts, q_keys[order],
                           1, interpret)
    inv = jnp.argsort(order, stable=True)
    return cnts[inv], dists[inv]


@functools.partial(jax.jit, static_argnums=(0, 4, 5))
def query_blocked_ex(pair: Pow2Hash, table_keys, table_counts, q_keys,
                     qcap: int = 128, interpret: bool = True,
                     filter_words=None):
    """Batched point queries, sized for large batches (paper §2.7).

    Buckets the batch by destination block into the dense
    ``(n_rows, qcap)`` layout :func:`kernel.query_grid` tiles over, with
    one row per *queried* block (``n_rows = min(n_b, Q)`` rows
    statically; unqueried blocks get no row, surplus rows all point at
    block 0, which consecutive-step Pallas tile reuse makes near-free).
    One *wave* answers up to ``qcap`` queries per block with a
    single tile fetch per queried block, instead of one grid step per
    query. Blocks holding more than ``qcap`` queries drain over
    additional waves (``fori_loop``; with deduped batches one wave is
    the common case).

    With ``filter_words`` (the ``(n_b, fw)`` blocked-Bloom rows from
    ``state.filter_words``), a :func:`kernel.filter_probe_grid` pre-pass
    tests every key against its block's SMEM-resident filter row first
    and the survivors are *re-bucketed*: blocks whose queries were all
    definite misses drop out of the queried-block list entirely, so they
    cost no tile fetch, and the post-filter ``max_load`` shrinks the
    wave count (an all-filtered batch runs zero query waves). Filtered
    keys answer ``(0, 0)``.

    q_keys: (Q,) int32, ``EMPTY`` entries are padding and return
    ``(0, 0)``. Returns (counts, probe_distances, n_tiles) with the first
    two aligned with ``q_keys`` — bit-identical to :func:`query_sorted`
    for valid unfiltered keys — and ``n_tiles`` the number of distinct
    block tiles the query waves fetched (the batch's accounted
    ``tile_loads``; 0 when the filter killed everything).
    """
    n_b, _ = table_keys.shape
    (Q,) = q_keys.shape
    if Q == 0:
        return (jnp.zeros((0,), table_counts.dtype),
                jnp.zeros((0,), jnp.int32), jnp.zeros((), jnp.int32))
    qcap = max(min(qcap, Q), 1)
    n_rows = min(n_b, Q)       # ≤ Q distinct blocks can be queried
    q = q_keys.astype(jnp.int32)
    valid = q != EMPTY

    def bucket(alive):
        blk = jnp.where(alive, pair.s(q), n_b).astype(jnp.int32)
        order = jnp.argsort(blk, stable=True)
        sq, sb = q[order], blk[order]
        start = jnp.searchsorted(sb, jnp.arange(n_b + 1, dtype=sb.dtype))
        pos = jnp.arange(Q, dtype=jnp.int32) - start[jnp.clip(sb, 0, n_b)]
        max_load = jnp.max(start[1:] - start[:-1])  # fullest block's queries
        # dense rank of each query's block within the queried-block set
        is_first = (sb < n_b) & jnp.concatenate(
            [jnp.ones((1,), bool), sb[1:] != sb[:-1]])
        rank = jnp.cumsum(is_first) - 1
        grid_blocks = jnp.zeros((n_rows,), jnp.int32).at[
            jnp.where(is_first, rank, n_rows)].set(sb, mode="drop")
        return order, sq, sb, pos, max_load, is_first, rank, grid_blocks

    order, sq, sb, pos, max_load, is_first, rank, grid_blocks = bucket(valid)

    def dense_rows(p, sb, pos, rank, sq):
        win = (sb < n_b) & (pos >= p * qcap) & (pos < (p + 1) * qcap)
        row = jnp.where(win, rank, n_rows)
        col = jnp.where(win, pos - p * qcap, 0)
        dense = jnp.full((n_rows, qcap), EMPTY, jnp.int32
                         ).at[row, col].set(sq, mode="drop")
        g = (jnp.clip(rank, 0, n_rows - 1),
             jnp.clip(pos - p * qcap, 0, qcap - 1))
        return win, dense, g

    if filter_words is not None:
        def fwave(p, may_s):
            win, dense, g = dense_rows(p, sb, pos, rank, sq)
            m = _k.filter_probe_grid(filter_words, grid_blocks, dense,
                                     interpret)
            return jnp.where(win, m[g], may_s)

        n_fwaves = (max_load + qcap - 1) // qcap
        may_s = jax.lax.fori_loop(0, n_fwaves, fwave,
                                  jnp.zeros((Q,), jnp.int32))
        may = jnp.zeros((Q,), jnp.int32).at[order].set(may_s)
        # re-bucket the survivors: fully-filtered blocks vanish from the
        # grid list (no tile fetch) and the post-filter max_load shrinks
        # the wave loop — possibly to zero waves
        order, sq, sb, pos, max_load, is_first, rank, grid_blocks = bucket(
            valid & (may > 0))

    n_tiles = is_first.sum(dtype=jnp.int32)

    def wave(p, acc):
        cnt_s, dist_s = acc
        win, dense, g = dense_rows(p, sb, pos, rank, sq)
        c, d = _k.query_grid(pair, table_keys, table_counts, grid_blocks,
                             dense, interpret)
        cnt_s = jnp.where(win, c[g], cnt_s)
        dist_s = jnp.where(win, d[g], dist_s)
        return cnt_s, dist_s

    n_waves = (max_load + qcap - 1) // qcap
    cnt_s, dist_s = jax.lax.fori_loop(
        0, n_waves, wave,
        (jnp.zeros((Q,), table_counts.dtype), jnp.zeros((Q,), jnp.int32)))
    cnts = jnp.zeros((Q,), table_counts.dtype).at[order].set(cnt_s)
    dists = jnp.zeros((Q,), jnp.int32).at[order].set(dist_s)
    return cnts, dists, n_tiles


def query_blocked(pair: Pow2Hash, table_keys, table_counts, q_keys,
                  qcap: int = 128, interpret: bool = True,
                  filter_words=None):
    """:func:`query_blocked_ex` without the tile count (compat entry)."""
    cnts, dists, _ = query_blocked_ex(pair, table_keys, table_counts,
                                      q_keys, qcap, interpret, filter_words)
    return cnts, dists
