"""jit'd wrappers around the flash-hash Pallas kernels.

Adds the outside-the-kernel plumbing the paper's schemes need:

* ``bucket_rows`` — generic drain: pack staged updates into the dense
  ``(n_rows, max_u)`` layout the merge kernels tile over, given an
  arbitrary destination-row assignment (block id for a full merge, grid
  position for a dirty-permutation merge, partition-local offset for an
  MDB partition drain). Updates beyond a row's ``max_u`` capacity are
  *carried over* (returned, stay staged) — the deferred-update discipline
  that bounds VMEM per tile.
* ``bucket_updates`` — RAM-buffer drain: ``bucket_rows`` with rows =
  destination block (the secondary hash ``s``).
* ``accumulate`` — the TPU-native RAM buffer: sort + segment-sum dedup of a
  token batch into (unique key, count) pairs (open-hash pre-aggregation).
* ``merge`` / ``merge_dirty`` / ``query`` — kernel entry points.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from ...core.hashing import Pow2Hash
from . import kernel as _k

EMPTY = _k.EMPTY


@functools.partial(jax.jit, static_argnums=(3, 4))
def bucket_rows(rows, keys, counts, n_rows: int, max_u: int):
    """Pack (keys, counts) updates into (n_rows, max_u) per-row buffers.

    ``rows`` is the destination row per update — for a full-table merge it
    is the block id ``s(key)``; for a dirty-block merge it is the key's
    position in the dirty-block list; for an MDB partition drain it is the
    block offset within the partition. Entries with ``rows`` outside
    ``[0, n_rows)`` or ``key == EMPTY`` are padding and dropped.

    Returns (upd_keys, upd_counts, carry_keys, carry_counts, n_carried):
    carry_* hold updates that exceeded a row's ``max_u`` capacity (sparse,
    same (U,) layout, EMPTY-padded) and must stay staged.
    """
    (U,) = keys.shape
    valid = (keys != EMPTY) & (rows >= 0) & (rows < n_rows)
    rw = jnp.where(valid, rows, n_rows).astype(jnp.int32)
    order = jnp.argsort(rw, stable=True)
    sk = keys[order]
    sc = counts[order]
    sr = rw[order]
    # position within the row's group
    start = jnp.searchsorted(sr, jnp.arange(n_rows + 1, dtype=sr.dtype))
    pos_in_r = jnp.arange(U, dtype=jnp.int32) - start[jnp.clip(sr, 0, n_rows)]
    keep = (sr < n_rows) & (pos_in_r < max_u)
    row = jnp.where(keep, sr, n_rows)  # out-of-bounds rows get dropped
    upd_keys = jnp.full((n_rows, max_u), EMPTY, dtype=keys.dtype)
    upd_counts = jnp.zeros((n_rows, max_u), dtype=counts.dtype)
    col = jnp.where(keep, pos_in_r, 0)
    upd_keys = upd_keys.at[row, col].set(sk, mode="drop")
    upd_counts = upd_counts.at[row, col].set(sc, mode="drop")
    carried = (sr < n_rows) & ~keep
    carry_keys = jnp.where(carried, sk, EMPTY)
    carry_counts = jnp.where(carried, sc, 0)
    return (upd_keys, upd_counts, carry_keys, carry_counts,
            carried.sum(dtype=jnp.int32))


@functools.partial(jax.jit, static_argnums=(0, 3))
def bucket_updates(pair: Pow2Hash, keys, counts, max_u: int):
    """Pack (keys, counts) updates into (n_b, max_u) per-block buffers.

    keys/counts: (U,) int32; EMPTY-keyed entries are padding and dropped.
    Returns (upd_keys, upd_counts, carry_keys, carry_counts, n_carried):
    carry_* hold updates that exceeded a block's capacity (sparse, same
    (U,) layout, EMPTY-padded).
    """
    n_b = pair.num_slots
    rows = jnp.where(keys != EMPTY, pair.s(keys), n_b).astype(jnp.int32)
    return bucket_rows(rows, keys, counts, n_b, max_u)


@jax.jit
def accumulate(tokens) -> Tuple[jax.Array, jax.Array]:
    """Open-hash RAM buffer, TPU-native: dedup a batch into (keys, counts).

    tokens: (T,) int32 (EMPTY entries ignored). Returns (T,)-shaped unique
    keys (EMPTY-padded) + int32 counts: sort, then segment-sum runs.
    """
    t = jnp.sort(tokens)
    is_head = jnp.concatenate([jnp.ones((1,), bool), t[1:] != t[:-1]])
    is_head &= t != EMPTY
    seg = jnp.cumsum(is_head) - 1                     # run ids
    ones = (t != EMPTY).astype(jnp.int32)
    counts = jax.ops.segment_sum(ones, seg, num_segments=t.shape[0])
    heads_idx = jnp.where(is_head, jnp.arange(t.shape[0]), t.shape[0] - 1)
    # compact run heads to the front, EMPTY-pad the tail
    order = jnp.argsort(jnp.where(is_head, 0, 1), stable=True)
    keys = jnp.where(is_head[order], t[order], EMPTY)
    cnts = jnp.where(is_head[order],
                     counts[jnp.clip(seg[order], 0, t.shape[0] - 1)], 0)
    return keys, cnts.astype(jnp.int32)


def merge(pair: Pow2Hash, table_keys, table_counts, upd_keys, upd_counts,
          interpret: bool = True):
    return _k.merge(pair, table_keys, table_counts, upd_keys, upd_counts,
                    interpret)


def merge_dirty(pair: Pow2Hash, table_keys, table_counts, dirty_blocks,
                upd_keys, upd_counts, interpret: bool = True):
    return _k.merge_dirty(pair, table_keys, table_counts, dirty_blocks,
                          upd_keys, upd_counts, interpret)


@functools.partial(jax.jit, static_argnums=(0, 4))
def query_sorted(pair: Pow2Hash, table_keys, table_counts, q_keys,
                 interpret: bool = True):
    """Point queries; sorts by block first so consecutive grid steps reuse
    the same VMEM tile (Pallas elides the re-fetch), then unsorts."""
    blk = pair.s(q_keys)
    order = jnp.argsort(blk, stable=True)
    cnts, dists = _k.query(pair, table_keys, table_counts, q_keys[order],
                           1, interpret)
    inv = jnp.argsort(order, stable=True)
    return cnts[inv], dists[inv]
