"""Pure-jnp oracle for the flash-hash Pallas kernels.

Semantics (per block; the paper's closed-table rules, §2.2/§2.5):

* entries live in a block of ``r`` (power of two) slots; key ``EMPTY=-1``
  marks a free slot (free slots always carry count 0);
* a key's home slot is ``home = g(x) mod r``; linear probing proceeds
  cyclically *within the block only* (the paper never probes across block
  boundaries — overflow spills to the overflow region, handled by the
  caller);
* merging an update ``(k, Δ)``: walk from ``home``; the first slot that
  either holds ``k`` (accumulate ``Δ``) or is empty (insert ``k`` with
  ``Δ``) wins; if the block is full and ``k`` absent → spill.

The oracle is scan-over-updates, vmapped over blocks — bit-exact contract
for the kernel across shapes/dtypes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ...core.hashing import Pow2Hash

EMPTY = -1


def _home_in_block(pair: Pow2Hash, k):
    return (pair.g(k) & (pair.r - 1)).astype(jnp.int32)


def merge_block_ref(pair: Pow2Hash, keys, counts, upd_keys, upd_counts):
    """Merge updates into one block. All inputs 1-D of length r / max_u.

    Returns (new_keys, new_counts, spill_keys, spill_counts); spill arrays
    have shape (max_u,), padded with EMPTY.
    """
    r = keys.shape[0]
    max_u = upd_keys.shape[0]
    ar = jnp.arange(r, dtype=jnp.int32)
    au = jnp.arange(max_u, dtype=jnp.int32)
    inf = jnp.int32(r + 1)

    def step(carry, upd):
        keys, counts, spill_k, spill_c, n_spill = carry
        k, c = upd
        valid = k != EMPTY
        home = _home_in_block(pair, k)
        d = (ar - home) & (r - 1)  # cyclic probe distance of every slot
        d_match = jnp.min(jnp.where(keys == k, d, inf))
        d_empty = jnp.min(jnp.where(keys == EMPTY, d, inf))
        d_tgt = jnp.minimum(d_match, d_empty)
        found = valid & (d_tgt < inf)
        hit = (d == d_tgt) & found      # one-hot (d is a permutation)
        is_insert = d_empty < d_match
        new_keys = jnp.where(hit & is_insert, k, keys)
        new_counts = jnp.where(hit, counts + c, counts)
        do_spill = valid & ~found
        s_hit = (au == n_spill) & do_spill
        spill_k = jnp.where(s_hit, k, spill_k)
        spill_c = jnp.where(s_hit, c, spill_c)
        n_spill = n_spill + do_spill.astype(jnp.int32)
        return (new_keys, new_counts, spill_k, spill_c, n_spill), None

    init = (keys, counts,
            jnp.full((max_u,), EMPTY, jnp.int32),
            jnp.zeros((max_u,), counts.dtype),
            jnp.int32(0))
    (keys, counts, spill_k, spill_c, _), _ = jax.lax.scan(
        step, init, (upd_keys, upd_counts))
    return keys, counts, spill_k, spill_c


@functools.partial(jax.jit, static_argnums=0)
def merge_ref(pair: Pow2Hash, table_keys, table_counts, upd_keys, upd_counts):
    """Oracle for the full merge: vmap of merge_block_ref over blocks.

    table_keys/table_counts: (n_b, r); upd_keys/upd_counts: (n_b, max_u)
    (updates pre-bucketed by destination block, EMPTY-padded).
    """
    fn = functools.partial(merge_block_ref, pair)
    return jax.vmap(fn)(table_keys, table_counts, upd_keys, upd_counts)


@functools.partial(jax.jit, static_argnums=0)
def query_ref(pair: Pow2Hash, table_keys, table_counts, q_keys):
    """Oracle for point queries against the data segment only.

    Returns (counts, probe_distance) per query; probe_distance is the
    paper's page-read span proxy (slots walked from home, inclusive);
    absent keys probe to the first empty slot (closed-table termination).
    ``EMPTY`` queries are padding and return ``(0, 0)`` — the batched
    entry's (:func:`ops.query_blocked`) padding contract.
    """
    r = table_keys.shape[1]
    inf = jnp.int32(r + 1)
    ar = jnp.arange(r, dtype=jnp.int32)

    def one(k):
        blk = pair.s(k)
        keys = table_keys[blk]
        counts = table_counts[blk]
        home = _home_in_block(pair, k)
        d = (ar - home) & (r - 1)
        d_match = jnp.min(jnp.where(keys == k, d, inf))
        d_empty = jnp.min(jnp.where(keys == EMPTY, d, inf))
        found = d_match < d_empty
        hit = (d == d_match) & found
        cnt = jnp.sum(jnp.where(hit, counts, 0)).astype(counts.dtype)
        dist = jnp.where(found, d_match, jnp.minimum(d_empty, r - 1)) + 1
        pad = k == EMPTY
        return (jnp.where(pad, 0, cnt).astype(counts.dtype),
                jnp.where(pad, 0, dist).astype(jnp.int32))

    return jax.vmap(one)(q_keys)
