"""Pallas TPU kernels for the flash-hash counting table.

TPU adaptation of the paper's block-level update (§2.1): the HBM-resident
data segment is tiled ``(1, r)`` per grid step — one *flash block* == one
VMEM tile. The grid walks blocks in ascending order (the paper's
*semi-random write* discipline → in-order single-store tiles), each tile is
read and written exactly once per merge (the paper's one-clean-per-block
property), and all probing math inside the tile is vectorized compare/min
over the lane dimension — no scatter, no per-element HBM traffic.

Kernels
-------
* ``merge``       — grid over all blocks; per block, fold its (EMPTY-padded)
  update list into the tile with vectorized cyclic linear probing.
* ``merge_dirty`` — beyond-paper variant: grid only over *dirty* blocks via
  a scalar-prefetched block-id list (saves the read+write of clean tiles —
  on-device analogue of "only merge blocks with staged updates").
* ``query``       — block-table indirection: scalar-prefetched block ids
  pick the tile each query batch reads (PagedAttention-style indexing).
* ``filter_probe_grid`` — negative-lookup pre-pass (DESIGN.md §12): each
  grid step holds one block's blocked-Bloom filter row (a few uint32
  lanes — SMEM/VMEM-resident, ~64× smaller than the tile) and answers
  membership for up to ``qcap`` queries without touching the tile. Both
  merge kernels OR the inserted keys' Bloom bits into the filter row of
  exactly the dirty blocks they visit, in the same tile pass.

All kernels run under ``interpret=True`` on CPU for validation; BlockSpecs
use power-of-two ``r`` (lane-dim multiples of 128 for real TPUs).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ...core.hashing import Pow2Hash, bloom_positions

EMPTY = -1


def _bloom_or_row(filt, aw, u, valid, bits_log2):
    """OR one key's Bloom bits into a ``(1, fw)`` filter row.

    ``aw`` is the lane iota over the row, ``u`` the key as uint32. All
    lane-parallel select/shift — no scatter."""
    for p in bloom_positions(u, bits_log2):
        w = (p >> jnp.uint32(5)).astype(jnp.int32)
        mask = jnp.left_shift(jnp.uint32(1), p & jnp.uint32(31))
        filt = jnp.where((aw == w) & valid, filt | mask, filt)
    return filt


def _bloom_test_row(filt, aw, u, bits_log2):
    """Test one key against a ``(1, fw)`` filter row (k-probe AND)."""
    hit = jnp.uint32(1)
    for p in bloom_positions(u, bits_log2):
        w = (p >> jnp.uint32(5)).astype(jnp.int32)
        word = jnp.sum(jnp.where(aw == w, filt, jnp.uint32(0)))
        hit &= (word >> (p & jnp.uint32(31))) & jnp.uint32(1)
    return hit != 0


# --------------------------------------------------------------------------
# merge kernel
# --------------------------------------------------------------------------
def _merge_kernel(pair: Pow2Hash, tk_ref, tc_ref, tf_ref, uk_ref, uc_ref,
                  ok_ref, oc_ref, of_ref, sk_ref, sc_ref):
    r = tk_ref.shape[1]
    fw = tf_ref.shape[1]
    max_u = uk_ref.shape[1]
    keys0 = tk_ref[...]          # (1, r) int32 tile in VMEM
    counts0 = tc_ref[...]
    filt0 = tf_ref[...]          # (1, fw) uint32 blocked-Bloom filter row
    uk = uk_ref[...]             # (1, max_u)
    uc = uc_ref[...]
    ar = jax.lax.broadcasted_iota(jnp.int32, (1, r), 1)
    aw = jax.lax.broadcasted_iota(jnp.int32, (1, fw), 1)
    au = jax.lax.broadcasted_iota(jnp.int32, (1, max_u), 1)
    inf = jnp.int32(r + 1)
    rmask = jnp.int32(r - 1)
    fbits_log2 = (fw * 32).bit_length() - 1

    def body(j, carry):
        keys, counts, filt, spill_k, spill_c, n_spill = carry
        k = jax.lax.dynamic_index_in_dim(uk[0], j, keepdims=False)
        c = jax.lax.dynamic_index_in_dim(uc[0], j, keepdims=False)
        valid = k != EMPTY
        home = (pair.g(k) & rmask).astype(jnp.int32)
        d = (ar - home) & rmask                      # cyclic probe distance
        d_match = jnp.min(jnp.where(keys == k, d, inf))
        d_empty = jnp.min(jnp.where(keys == EMPTY, d, inf))
        d_tgt = jnp.minimum(d_match, d_empty)
        found = valid & (d_tgt < inf)
        hit = (d == d_tgt) & found                   # one-hot over the tile
        is_insert = d_empty < d_match
        keys = jnp.where(hit & is_insert, k, keys)
        counts = jnp.where(hit, counts + c, counts)
        # every valid update key gets its filter bits — including spills,
        # whose home block is this one (queries consult the home filter)
        filt = _bloom_or_row(filt, aw, k.astype(jnp.uint32), valid,
                             fbits_log2)
        do_spill = valid & ~found
        s_hit = (au == n_spill) & do_spill
        spill_k = jnp.where(s_hit, k, spill_k)
        spill_c = jnp.where(s_hit, c, spill_c)
        n_spill = n_spill + do_spill.astype(jnp.int32)
        return keys, counts, filt, spill_k, spill_c, n_spill

    init = (keys0, counts0, filt0,
            jnp.full((1, max_u), EMPTY, jnp.int32),
            jnp.zeros((1, max_u), counts0.dtype),
            jnp.int32(0))
    keys, counts, filt, spill_k, spill_c, _ = jax.lax.fori_loop(
        0, max_u, body, init)
    ok_ref[...] = keys
    oc_ref[...] = counts
    of_ref[...] = filt
    sk_ref[...] = spill_k
    sc_ref[...] = spill_c


@functools.partial(jax.jit, static_argnums=(0, 6))
def merge(pair: Pow2Hash, table_keys, table_counts, filter_words,
          upd_keys, upd_counts, interpret: bool = True):
    """Merge bucketed updates into the data segment.

    table_keys/table_counts: (n_b, r) int32
    filter_words:            (n_b, fw) uint32 blocked-Bloom filter rows
    upd_keys/upd_counts:     (n_b, max_u) int32, EMPTY-padded
    Returns (new_keys, new_counts, new_filter, spill_keys, spill_counts).
    """
    n_b, r = table_keys.shape
    _, fw = filter_words.shape
    _, max_u = upd_keys.shape
    kern = functools.partial(_merge_kernel, pair)
    return pl.pallas_call(
        kern,
        grid=(n_b,),
        in_specs=[
            pl.BlockSpec((1, r), lambda b: (b, 0)),
            pl.BlockSpec((1, r), lambda b: (b, 0)),
            pl.BlockSpec((1, fw), lambda b: (b, 0)),
            pl.BlockSpec((1, max_u), lambda b: (b, 0)),
            pl.BlockSpec((1, max_u), lambda b: (b, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, r), lambda b: (b, 0)),
            pl.BlockSpec((1, r), lambda b: (b, 0)),
            pl.BlockSpec((1, fw), lambda b: (b, 0)),
            pl.BlockSpec((1, max_u), lambda b: (b, 0)),
            pl.BlockSpec((1, max_u), lambda b: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_b, r), table_keys.dtype),
            jax.ShapeDtypeStruct((n_b, r), table_counts.dtype),
            jax.ShapeDtypeStruct((n_b, fw), filter_words.dtype),
            jax.ShapeDtypeStruct((n_b, max_u), upd_keys.dtype),
            jax.ShapeDtypeStruct((n_b, max_u), upd_counts.dtype),
        ],
        input_output_aliases={0: 0, 1: 1, 2: 2},   # in-place tile update
        interpret=interpret,
    )(table_keys, table_counts, filter_words, upd_keys, upd_counts)


# --------------------------------------------------------------------------
# dirty-only merge (beyond-paper §Perf optimization)
# --------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnums=(0, 7))
def merge_dirty(pair: Pow2Hash, table_keys, table_counts, filter_words,
                dirty_blocks, upd_keys, upd_counts, interpret: bool = True):
    """Like :func:`merge`, but the grid only visits ``dirty_blocks``.

    dirty_blocks: (n_d,) int32 block ids (may repeat the last id as padding —
    revisiting an already-merged block with EMPTY updates is a no-op).
    upd_keys/upd_counts: (n_d, max_u) updates for the listed blocks.
    The filter rows of exactly the dirty blocks are OR-updated in the same
    pass; clean blocks' rows pass through untouched via the aliasing.
    """
    n_b, r = table_keys.shape
    _, fw = filter_words.shape
    n_d, max_u = upd_keys.shape

    def kern(blocks_ref, *refs):  # scalar-prefetch ref only feeds index_maps
        del blocks_ref
        _merge_kernel(pair, *refs)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_d,),
        in_specs=[
            pl.BlockSpec((1, r), lambda i, blocks: (blocks[i], 0)),
            pl.BlockSpec((1, r), lambda i, blocks: (blocks[i], 0)),
            pl.BlockSpec((1, fw), lambda i, blocks: (blocks[i], 0)),
            pl.BlockSpec((1, max_u), lambda i, blocks: (i, 0)),
            pl.BlockSpec((1, max_u), lambda i, blocks: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, r), lambda i, blocks: (blocks[i], 0)),
            pl.BlockSpec((1, r), lambda i, blocks: (blocks[i], 0)),
            pl.BlockSpec((1, fw), lambda i, blocks: (blocks[i], 0)),
            pl.BlockSpec((1, max_u), lambda i, blocks: (i, 0)),
            pl.BlockSpec((1, max_u), lambda i, blocks: (i, 0)),
        ],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((n_b, r), table_keys.dtype),
            jax.ShapeDtypeStruct((n_b, r), table_counts.dtype),
            jax.ShapeDtypeStruct((n_b, fw), filter_words.dtype),
            jax.ShapeDtypeStruct((n_d, max_u), upd_keys.dtype),
            jax.ShapeDtypeStruct((n_d, max_u), upd_counts.dtype),
        ],
        input_output_aliases={1: 0, 2: 1, 3: 2},  # offset by scalar-prefetch
        interpret=interpret,
    )(dirty_blocks, table_keys, table_counts, filter_words,
      upd_keys, upd_counts)


# --------------------------------------------------------------------------
# query kernel (block-table indirection)
# --------------------------------------------------------------------------
def _query_kernel(pair: Pow2Hash, blocks_ref, qk_ref, tk_ref, tc_ref,
                  cnt_ref, dist_ref):
    del blocks_ref  # only used by the index_map
    r = tk_ref.shape[1]
    qchunk = qk_ref.shape[1]
    keys = tk_ref[...]
    counts = tc_ref[...]
    qk = qk_ref[...]                              # (1, qchunk)
    ar = jax.lax.broadcasted_iota(jnp.int32, (1, r), 1)
    inf = jnp.int32(r + 1)
    rmask = jnp.int32(r - 1)

    def one(j, carry):
        cnts, dists = carry
        k = jax.lax.dynamic_index_in_dim(qk[0], j, keepdims=False)
        home = (pair.g(k) & rmask).astype(jnp.int32)
        d = (ar - home) & rmask
        d_match = jnp.min(jnp.where(keys == k, d, inf))
        d_empty = jnp.min(jnp.where(keys == EMPTY, d, inf))
        found = d_match < d_empty
        hit = (d == d_match) & found
        cnt = jnp.sum(jnp.where(hit, counts, 0))
        dist = jnp.where(found, d_match, jnp.minimum(d_empty, r - 1)) + 1
        au = jax.lax.broadcasted_iota(jnp.int32, (1, qchunk), 1)
        sel = au == j
        cnts = jnp.where(sel, cnt, cnts)
        dists = jnp.where(sel, dist, dists)
        return cnts, dists

    cnts0 = jnp.zeros((1, qchunk), counts.dtype)
    dists0 = jnp.zeros((1, qchunk), jnp.int32)
    cnts, dists = jax.lax.fori_loop(0, qchunk, one, (cnts0, dists0))
    cnt_ref[...] = cnts
    dist_ref[...] = dists


@functools.partial(jax.jit, static_argnums=(0, 5))
def query_grid(pair: Pow2Hash, table_keys, table_counts, blocks, q2,
               interpret: bool = True):
    """Point queries over an explicit chunk layout (the batched entry).

    q2: (n_rows, qcap) int32 — grid step ``i`` reads the tile of block
    ``blocks[i]`` once and answers all of row ``i``'s queries against it,
    so a row **must** only hold keys whose ``s()`` is ``blocks[i]``
    (callers bucket; :func:`ops.query_blocked` builds this layout).
    Padding lanes (``EMPTY`` or foreign-block keys) produce junk values
    that callers never gather. Sized for large batches: HBM tile traffic
    is one read per *queried block*, not one per query/chunk."""
    n_b, r = table_keys.shape
    n_rows, qcap = q2.shape
    kern = functools.partial(_query_kernel, pair)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_rows,),
        in_specs=[
            pl.BlockSpec((1, qcap), lambda i, blocks: (i, 0)),
            pl.BlockSpec((1, r), lambda i, blocks: (blocks[i], 0)),
            pl.BlockSpec((1, r), lambda i, blocks: (blocks[i], 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, qcap), lambda i, blocks: (i, 0)),
            pl.BlockSpec((1, qcap), lambda i, blocks: (i, 0)),
        ],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((n_rows, qcap), table_counts.dtype),
            jax.ShapeDtypeStruct((n_rows, qcap), jnp.int32),
        ],
        interpret=interpret,
    )(blocks.astype(jnp.int32), q2, table_keys, table_counts)


@functools.partial(jax.jit, static_argnums=(0, 4, 5))
def query(pair: Pow2Hash, table_keys, table_counts, q_keys,
          qchunk: int = 128, interpret: bool = True):
    """Point queries. q_keys: (Q,) int32, Q % qchunk == 0. Queries must be
    pre-sorted so that each chunk hits one block (callers use
    ``ops.query``, which sorts/buckets); here each chunk's block id is the
    block of its first key — keys in a chunk from other blocks return junk,
    so ops-level bucketing pads chunks with the chunk's own block keys."""
    (Q,) = q_keys.shape
    assert Q % qchunk == 0
    n_chunks = Q // qchunk
    q2 = q_keys.reshape(n_chunks, qchunk)
    blocks = pair.s(q2[:, 0]).astype(jnp.int32)    # (n_chunks,)
    cnts, dists = query_grid(pair, table_keys, table_counts, blocks, q2,
                             interpret)
    return cnts.reshape(Q), dists.reshape(Q)


# --------------------------------------------------------------------------
# blocked-Bloom filter probe (negative-lookup pre-pass, DESIGN.md §12)
# --------------------------------------------------------------------------
def _filter_probe_kernel(blocks_ref, qk_ref, tf_ref, may_ref):
    del blocks_ref  # only used by the index_map
    fw = tf_ref.shape[1]
    qchunk = qk_ref.shape[1]
    filt = tf_ref[...]                            # (1, fw) uint32 row
    qk = qk_ref[...]                              # (1, qchunk)
    aw = jax.lax.broadcasted_iota(jnp.int32, (1, fw), 1)
    au = jax.lax.broadcasted_iota(jnp.int32, (1, qchunk), 1)
    fbits_log2 = (fw * 32).bit_length() - 1

    def one(j, may):
        k = jax.lax.dynamic_index_in_dim(qk[0], j, keepdims=False)
        hit = _bloom_test_row(filt, aw, k.astype(jnp.uint32), fbits_log2)
        ok = (k != EMPTY) & hit
        return jnp.where(au == j, ok.astype(jnp.int32), may)

    may_ref[...] = jax.lax.fori_loop(
        0, qchunk, one, jnp.zeros((1, qchunk), jnp.int32))


@functools.partial(jax.jit, static_argnums=(3,))
def filter_probe_grid(filter_words, blocks, q2, interpret: bool = True):
    """Membership pre-pass over the same chunk layout as :func:`query_grid`.

    Grid step ``i`` holds only block ``blocks[i]``'s filter row — a few
    uint32 lanes, SMEM/VMEM-resident, ~``r/fw`` times smaller than the
    tile — and answers all of row ``i``'s queries against it with zero
    tile traffic. Returns a ``(n_rows, qcap)`` int32 mask: 0 ⇒ the key is
    definitively absent from the block (and, because staging paths also
    maintain the filter, from the change segment and overflow region
    too); 1 ⇒ maybe present, fetch the tile. Rows must be bucketed like
    :func:`query_grid`'s (``ops.query_blocked`` builds both layouts);
    the Bloom hash ignores the block id, so foreign-lane junk is
    harmless — callers never gather those lanes."""
    n_b, fw = filter_words.shape
    n_rows, qcap = q2.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_rows,),
        in_specs=[
            pl.BlockSpec((1, qcap), lambda i, blocks: (i, 0)),
            pl.BlockSpec((1, fw), lambda i, blocks: (blocks[i], 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, qcap), lambda i, blocks: (i, 0)),
        ],
    )
    (may,) = pl.pallas_call(
        _filter_probe_kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((n_rows, qcap), jnp.int32)],
        interpret=interpret,
    )(blocks.astype(jnp.int32), q2, filter_words)
    return may
