"""Training driver: ``python -m repro.launch.train --arch <id> [--tiny]``.

Wires every substrate together: config registry → deterministic loader
(with optional flash-hash TF-IDF document filtering) → sharded train step
(on whatever mesh the process has; 1 CPU device here, a pod slice in
production) → AdamW → resilient runtime (watchdog, NaN rollback,
checkpoint/restart) → flash-hash corpus/expert statistics.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..checkpoint import CheckpointManager, latest_step, restore_checkpoint
from ..configs import get_config
from ..data import CorpusStats, LoaderConfig, SyntheticCorpus, make_batch
from ..models import model as M
from ..optim import AdamWConfig, adamw_init
from ..runtime import NaNGuard, ResilientTrainer, StepWatchdog
from . import steps as steps_mod


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama32_3b")
    ap.add_argument("--tiny", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--peak-lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="checkpoints")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--tfidf-filter", action="store_true",
                    help="filter documents by flash-hash TF-IDF score")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, tiny=args.tiny)
    corpus = SyntheticCorpus(num_docs=512, mean_doc_len=args.seq_len,
                             vocab_size=cfg.vocab_size, seed=args.seed)

    doc_filter = None
    stats = None
    if args.tfidf_filter:
        stats = CorpusStats.create(q_log2=16, r_log2=9)
        for d in corpus:
            stats.ingest(d)
        stats.flush()
        doc_filter = stats.doc_filter(threshold=0.0)
        print(f"[stats] corpus: {stats.tokens_seen} tokens, "
              f"{stats.docs_seen} docs via flash-hash table")

    lcfg = LoaderConfig(
        corpus=corpus, seq_len=args.seq_len, global_batch=args.global_batch,
        microbatches=args.microbatches, vocab_size=cfg.vocab_size,
        num_patches=cfg.num_patches if cfg.frontend != "none" else 0,
        d_model=cfg.d_model, doc_filter=doc_filter)

    opt_cfg = AdamWConfig()
    hyper = steps_mod.TrainHyper(peak_lr=args.peak_lr, warmup_steps=20,
                                 total_steps=args.steps)
    train_step = jax.jit(steps_mod.make_train_step(cfg, opt_cfg, hyper))

    params = M.init_params(jax.random.key(args.seed), cfg)
    opt = adamw_init(opt_cfg, params)
    state = {"params": params, "opt": opt}

    ckpt = CheckpointManager(args.ckpt_dir, every_steps=args.ckpt_every)
    start = 0
    if latest_step(args.ckpt_dir) is not None:
        state, meta = restore_checkpoint(args.ckpt_dir, state)
        start = int(meta["step"]) + 1
        print(f"[resume] from step {start}")

    expert_stats = CorpusStats.create(q_log2=12, r_log2=8) \
        if cfg.num_experts else None

    def step_fn(state, step):
        batch = jax.tree.map(jnp.asarray, make_batch(lcfg, step))
        if cfg.frontend != "none":
            batch["frontend_embeds"] = batch["frontend_embeds"].astype(
                jnp.dtype(cfg.dtype))
        params, opt, metrics = train_step(state["params"], state["opt"],
                                          batch)
        return {"params": params, "opt": opt}, metrics

    trainer = ResilientTrainer(step_fn, ckpt, NaNGuard(), StepWatchdog(
        on_straggler=lambda s, t, m: print(
            f"[watchdog] step {s} straggled: {t:.2f}s vs median {m:.2f}s")))

    t0 = time.time()
    state, report = trainer.run(state, num_steps=args.steps,
                                start_step=start)
    dt = time.time() - t0
    print(f"[done] steps={report.steps_done} loss={report.final_loss:.4f} "
          f"restarts={report.restarts} rollbacks={report.rollbacks} "
          f"wall={dt:.1f}s "
          f"tok/s={report.steps_done * args.global_batch * args.seq_len / max(dt, 1e-9):.0f}")


if __name__ == "__main__":
    main()
