"""Mesh construction for the production pods.

``make_production_mesh`` is the prescribed entry point: a 16×16 = 256-chip
pod (axes ``data × model``), or 2×16×16 = 512 chips with a leading ``pod``
axis (DCN-connected data parallelism across pods).

Per-arch *logical factoring*: attention sharding needs the ``model`` axis
split into (kv, group, replica) sub-axes so GQA head counts that don't
divide 16 still shard cleanly (DESIGN.md §4). ``arch_mesh`` reshapes the
same device array into ``(pod?, data, tp_kv, tp_g, tp_r)`` — identical
devices, identical ICI neighborhoods (the split nests inside the original
``model`` axis), just finer axis names.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Tuple

import jax
from jax.sharding import Mesh

from ..models.config import ModelConfig

MODEL_AXIS = 16  # model-parallel width of one pod row


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    """Per-arch factoring of the model axis (tp_kv * tp_g * tp_r = 16)."""

    tp_kv: int      # shards kv_heads (GQA) / q-head block (MLA)
    tp_g: int       # shards the q-head group dim (heads // kv_heads)
    tp_r: int       # attention-replicated remainder (still used by FFN/EP)
    multi_pod: bool

    @property
    def batch_axes(self) -> Tuple[str, ...]:
        return ("pod", "data") if self.multi_pod else ("data",)

    @property
    def tp_axes(self) -> Tuple[str, ...]:
        return ("tp_kv", "tp_g", "tp_r")

    @property
    def heads_axes(self) -> Tuple[str, ...]:
        return ("tp_kv", "tp_g")

    @property
    def attn_tp(self) -> int:
        return self.tp_kv * self.tp_g


def plan_for(cfg: ModelConfig, *, multi_pod: bool = False,
             model_axis: int = MODEL_AXIS) -> MeshPlan:
    m = model_axis
    if cfg.layer_pattern == ("ssm",) * len(cfg.layer_pattern):
        return MeshPlan(1, 1, m, multi_pod)          # attention-free
    if cfg.attn_type == "mla":
        # latent is head-shared; factor q heads directly
        mh = math.gcd(cfg.num_heads, m)
        return MeshPlan(mh, 1, m // mh, multi_pod)
    kv = math.gcd(cfg.num_kv_heads, m)
    g = cfg.num_heads // cfg.num_kv_heads
    mg = math.gcd(g, m // kv)
    return MeshPlan(kv, mg, m // (kv * mg), multi_pod)


def arch_mesh(base_mesh: Mesh, plan: MeshPlan) -> Mesh:
    """Reshape the production mesh's device array to the arch's factoring.

    The model axis is split in nested order (kv outermost), preserving ICI
    adjacency within each sub-axis.
    """
    devices = base_mesh.devices
    lead = devices.shape[:-1]
    new_shape = lead + (plan.tp_kv, plan.tp_g, plan.tp_r)
    names = (("pod",) if plan.multi_pod else ()) + ("data",) + plan.tp_axes
    return Mesh(devices.reshape(new_shape), names)


def small_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    """Test helper: build a mesh from however many devices exist."""
    return jax.make_mesh(shape, axes)
