import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))
os.environ.setdefault("JAX_COMPILATION_CACHE_DIR", "/root/.cache/jax_comp")
os.environ.setdefault("JAX_PERSISTENT_CACHE_MIN_COMPILE_TIME_SECS", "10")

_DOC = """Multi-pod dry-run: AOT lower + compile every (arch × shape × mesh) cell.

For each cell this proves, without hardware: (i) the sharding config is
coherent (GSPMD partitions every op), (ii) the program fits (per-device
memory analysis), and (iii) extracts the roofline terms: HLO FLOPs/bytes
from ``cost_analysis()`` and collective bytes parsed from the post-SPMD
HLO text. Artifacts land in ``artifacts/dryrun/*.json``; benchmarks/
bench_roofline.py turns them into the §Roofline table.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch granite_moe_1b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh both]
"""

# NOTE: no `from __future__` here — the XLA_FLAGS lines must be the very
# first statements (before jax locks the device count).
import argparse
import json
import time
from pathlib import Path

import jax

from ..configs import ARCH_IDS, get_config
from ..models import model as M
from ..models.config import SHAPES, shapes_for
from ..models.sharding_hints import use_hints
from ..optim import AdamWConfig
from . import input_specs as ispec
from . import sharding as shd
from . import steps as steps_mod
from .mesh import arch_mesh, make_production_mesh, plan_for

def _mem_dict(compiled) -> dict:
    out = {}
    try:
        ma = compiled.memory_analysis()
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "alias_size_in_bytes",
                  "generated_code_size_in_bytes",
                  "host_argument_size_in_bytes",
                  "peak_memory_in_bytes"):
            v = getattr(ma, k, None)
            if v is not None:
                out[k] = int(v)
    except Exception as e:  # pragma: no cover
        out["error"] = str(e)
    return out


OPT_PLAN_OVERRIDES = {
    # §Perf: fewer grad-accum microbatches → FSDP param all-gathers per
    # step drop proportionally (memory headroom bought by chunked attn)
    "nemotron4_340b": 2,
    "jamba15_large_398b": 4,
}


def optimized_config(cfg):
    # dense attention stays in the graph; the flash-kernel substitution is
    # accounted via bytes_accessed_flashproj (kernels/flash_attn realizes
    # it on hardware — the lax.scan "chunked" variant was refuted, see
    # EXPERIMENTS.md §Perf iteration 1)
    import dataclasses
    return dataclasses.replace(cfg, opt_conv_split=True,
                               opt_bf16_grads=True)


def dryrun_cell(arch: str, shape_name: str, multi_pod: bool,
                out_dir: Path, save_hlo: bool = False,
                opt: bool = False) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    pp = steps_mod.plan_of(arch)
    if opt:
        cfg = optimized_config(cfg)
        if arch in OPT_PLAN_OVERRIDES:
            import dataclasses as _dc
            pp = _dc.replace(pp, microbatches=OPT_PLAN_OVERRIDES[arch])
    plan = plan_for(cfg, multi_pod=multi_pod)
    base = make_production_mesh(multi_pod=multi_pod)
    mesh = arch_mesh(base, plan)
    dp = (2 if multi_pod else 1) * 16

    t0 = time.time()
    rules = shd.logical_rules(plan, pp)
    param_rules = (shd.tp_only_rules(plan)
                   if (opt and pp.fsdp and shape.kind == "train") else None)
    with mesh, use_hints(mesh, rules, param_rules):
        p_sh = shd.param_shardings(mesh, cfg, plan, pp)
        rep = shd.replicated(mesh)
        params_abs = M.abstract_params(cfg)

        if shape.kind == "train":
            mb = ispec.effective_microbatches(pp, shape, dp)
            specs = ispec.train_specs(cfg, shape, mb)
            b_sh = shd.batch_shardings(mesh, cfg, plan, shape)
            opt_cfg = AdamWConfig(m_dtype="bfloat16"
                                  if pp.fsdp else "float32")
            opt_abs = steps_mod.abstract_opt_state(cfg, opt_cfg)
            from ..optim.adamw import AdamWState
            o_sh = AdamWState(m=p_sh, v=p_sh, count=rep)
            step = steps_mod.make_train_step(cfg, opt_cfg)
            met_sh = {"loss": rep, "grad_norm": rep, "lr": rep}
            jitted = jax.jit(step,
                             in_shardings=(p_sh, o_sh, b_sh),
                             out_shardings=(p_sh, o_sh, met_sh),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(params_abs, opt_abs, specs)
        elif shape.kind == "prefill":
            specs = ispec.prefill_specs(cfg, shape)
            b_sh = shd.batch_shardings(mesh, cfg, plan, shape)
            c_sh = shd.cache_shardings(mesh, cfg, plan, pp, shape)
            logits_sh = shd.replicated(mesh)
            step = steps_mod.make_prefill_step(cfg)
            jitted = jax.jit(step, in_shardings=(p_sh, b_sh),
                             out_shardings=(logits_sh, c_sh))
            lowered = jitted.lower(params_abs, specs)
        else:  # decode
            specs = ispec.decode_specs(cfg, shape)
            b_sh = shd.batch_shardings(mesh, cfg, plan, shape)
            c_sh = shd.cache_shardings(mesh, cfg, plan, pp, shape)
            step = steps_mod.make_decode_step(cfg)
            jitted = jax.jit(
                step,
                in_shardings=(p_sh, c_sh, b_sh["tokens"], rep),
                out_shardings=(rep, c_sh, rep),
                donate_argnums=(1,))
            lowered = jitted.lower(params_abs, specs["caches"],
                                   specs["tokens"], specs["index"])
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    cost = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    from .hlo_analysis import HloAnalysis
    ana = HloAnalysis(hlo, seq_len=shape.seq_len).summary()
    mem = _mem_dict(compiled)
    rec = {
        "arch": arch,
        "variant": "opt" if opt else "baseline",
        "config_name": cfg.name,
        "shape": shape_name,
        "kind": shape.kind,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": 512 if multi_pod else 256,
        "plan": {"tp_kv": plan.tp_kv, "tp_g": plan.tp_g, "tp_r": plan.tp_r,
                 "fsdp": pp.fsdp, "fsdp_pod": pp.fsdp_pod,
                 "microbatches": (ispec.effective_microbatches(pp, shape, dp)
                                  if shape.kind == "train" else 1)},
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
        # loop-weighted, per-device (from HLO parse; see hlo_analysis.py)
        "flops": float(ana["dot_flops"]),
        "bytes_accessed": float(ana["hbm_bytes"]),
        "bytes_accessed_upper": float(ana["hbm_bytes_upper"]),
        "bytes_accessed_flashproj": float(ana["hbm_bytes_flashproj"]),
        "score_bytes": float(ana["score_bytes"]),
        "transcendentals": float(ana["transcendentals"]),
        # unweighted XLA aggregates, for reference only
        "xla_flops_unweighted": float(cost.get("flops", -1.0)),
        "xla_bytes_unweighted": float(cost.get("bytes accessed", -1.0)),
        "collectives": ana["collectives"],
        "while_trips": ana["while_trips"],
        "memory": mem,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "hlo_lines": hlo.count("\n"),
    }
    out_dir.mkdir(parents=True, exist_ok=True)
    name = f"{arch}__{shape_name}__{rec['mesh'].replace('x', '_')}"
    (out_dir / f"{name}.json").write_text(json.dumps(rec, indent=2))
    if save_hlo:
        (out_dir / f"{name}.hlo.txt").write_text(hlo)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--opt", action="store_true",
                    help="beyond-paper optimized variant (chunked attention,"
                         " split SSM convs, tuned microbatching)")
    args = ap.parse_args()

    out_dir = Path(args.out if not args.opt or args.out != "artifacts/dryrun"
                   else "artifacts/dryrun_opt")
    archs = ARCH_IDS if (args.all or args.arch is None) else [args.arch]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    failures = []
    for arch in archs:
        cfg = get_config(arch)
        shape_names = ([args.shape] if args.shape else
                       [s.name for s in shapes_for(cfg)])
        for shape_name in shape_names:
            for mp in meshes:
                tag = f"{arch} × {shape_name} × {'2x16x16' if mp else '16x16'}"
                try:
                    rec = dryrun_cell(arch, shape_name, mp, out_dir,
                                      args.save_hlo, opt=args.opt)
                    print(f"[OK] {tag}: flops={rec['flops']:.3e} "
                          f"coll={rec['collectives']['total_bytes']:.3e}B "
                          f"compile={rec['compile_s']}s", flush=True)
                except Exception as e:
                    failures.append((tag, repr(e)))
                    print(f"[FAIL] {tag}: {e!r}", flush=True)
    if failures:
        raise SystemExit(f"{len(failures)} dry-run cells failed: "
                         + "; ".join(t for t, _ in failures))
    print("all dry-run cells compiled")


if __name__ == "__main__":
    main()
