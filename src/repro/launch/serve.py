"""Serving driver: ``python -m repro.launch.serve --arch <id> --tiny``.

Batched greedy decoding with the flash-hash prefix KV cache (counting
refcounts; DESIGN.md §5). Prints per-request outputs + cache statistics.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..configs import get_config
from ..models import model as M
from ..serving import PrefixKVCache, Request, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama32_3b")
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--shared-prefix", type=int, default=16,
                    help="tokens shared across requests (exercises the "
                         "prefix cache)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, tiny=args.tiny)
    params = M.init_params(jax.random.key(args.seed), cfg)
    cache = PrefixKVCache(block_tokens=8, capacity_blocks=64)
    engine = ServeEngine(cfg, params, prefix_cache=cache)

    rng = np.random.default_rng(args.seed)
    shared = rng.integers(0, cfg.vocab_size, args.shared_prefix).tolist()
    reqs = []
    for _ in range(args.requests):
        tail = rng.integers(0, cfg.vocab_size,
                            args.prompt_len - args.shared_prefix).tolist()
        reqs.append(Request(prompt=shared + tail,
                            max_new_tokens=args.max_new))

    t0 = time.time()
    done = engine.serve(reqs)
    dt = time.time() - t0
    for i, r in enumerate(done):
        print(f"req{i}: out={r.output[:8]}...")
    tok = sum(len(r.output) for r in done)
    print(f"[serve] {len(done)} requests, {tok} tokens in {dt:.2f}s "
          f"({tok / max(dt, 1e-9):.1f} tok/s)")
    print(f"[prefix-cache] {cache.stats()}")


if __name__ == "__main__":
    main()
