"""Serving driver: ``python -m repro.launch.serve --arch <id> --tiny``.

Greedy decoding with the flash-hash prefix KV cache (counting
refcounts; DESIGN.md §5). ``--continuous`` swaps the serial engine for
the continuous-batching scheduler over the paged block pool
(DESIGN.md §13). Prints per-request outputs + cache statistics.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..configs import get_config
from ..models import model as M
from ..serving import (ContinuousBatchingScheduler, PrefixKVCache,
                       Request, SchedRequest, ServeEngine)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama32_3b")
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--shared-prefix", type=int, default=16,
                    help="tokens shared across requests (exercises the "
                         "prefix cache)")
    ap.add_argument("--continuous", action="store_true",
                    help="continuous-batching scheduler over the paged "
                         "block pool instead of the serial engine")
    ap.add_argument("--slots", type=int, default=4,
                    help="packed decode slots (--continuous only)")
    ap.add_argument("--backend", default="device",
                    choices=("device", "sim"),
                    help="refcount-table backend for the prefix cache")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, tiny=args.tiny)
    params = M.init_params(jax.random.key(args.seed), cfg)
    bt = 16 if args.continuous else 8
    cache = PrefixKVCache(block_tokens=bt, capacity_blocks=64,
                          backend=args.backend)

    rng = np.random.default_rng(args.seed)
    shared = rng.integers(0, cfg.vocab_size, args.shared_prefix).tolist()
    prompts = [shared + rng.integers(
        0, cfg.vocab_size,
        args.prompt_len - args.shared_prefix).tolist()
        for _ in range(args.requests)]

    t0 = time.time()
    if args.continuous:
        sched = ContinuousBatchingScheduler(
            cfg, params, prefix_cache=cache, max_slots=args.slots,
            max_context=args.prompt_len + args.max_new + bt)
        done = sched.run([SchedRequest(prompt=p,
                                       max_new_tokens=args.max_new,
                                       request_id=i)
                          for i, p in enumerate(prompts)])
        done = sorted(done, key=lambda r: r.request_id)
    else:
        engine = ServeEngine(cfg, params, prefix_cache=cache)
        done = engine.serve([Request(prompt=p,
                                     max_new_tokens=args.max_new)
                             for p in prompts])
    dt = time.time() - t0
    for i, r in enumerate(done):
        print(f"req{i}: cached={r.cached_tokens} out={r.output[:8]}...")
    tok = sum(len(r.output) for r in done)
    mode = "continuous" if args.continuous else "serial"
    print(f"[serve:{mode}] {len(done)} requests, {tok} tokens in "
          f"{dt:.2f}s ({tok / max(dt, 1e-9):.1f} tok/s)")
    print(f"[prefix-cache] {cache.stats()}")


if __name__ == "__main__":
    main()
