"""ShapeDtypeStruct stand-ins for every model input (no device allocation).

Train batches arrive *pre-microbatched*: ``(mb, B/mb, S)`` with the device
batch dim (axis 1) sharded over (pod, data) — the loader emits this layout
directly so grad accumulation needs no resharding reshape.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from ..models import model as M
from ..models.config import ModelConfig, ShapeConfig
from .sharding import ParallelPlan


def effective_microbatches(pp: ParallelPlan, shape: ShapeConfig,
                           dp: int) -> int:
    """Largest mb ≤ plan's that keeps B/mb divisible by dp."""
    mb = pp.microbatches
    while mb > 1 and (shape.global_batch % mb != 0
                      or (shape.global_batch // mb) % dp != 0):
        mb //= 2
    return max(mb, 1)


def train_specs(cfg: ModelConfig, shape: ShapeConfig, mb: int
                ) -> Dict[str, jax.ShapeDtypeStruct]:
    b = shape.global_batch // mb
    s = shape.seq_len
    specs = {
        "tokens": jax.ShapeDtypeStruct((mb, b, s), jnp.int32),
        "labels": jax.ShapeDtypeStruct((mb, b, s), jnp.int32),
    }
    if cfg.frontend != "none":
        specs["frontend_embeds"] = jax.ShapeDtypeStruct(
            (mb, b, cfg.num_patches, cfg.d_model), jnp.dtype(cfg.dtype))
    return specs


def prefill_specs(cfg: ModelConfig, shape: ShapeConfig
                  ) -> Dict[str, jax.ShapeDtypeStruct]:
    b, s = shape.global_batch, shape.seq_len
    specs = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    if cfg.frontend != "none":
        specs["frontend_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.num_patches, cfg.d_model), jnp.dtype(cfg.dtype))
    return specs


def decode_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """decode: one new token against a seq_len-deep cache."""
    b, s = shape.global_batch, shape.seq_len
    return {
        "tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32),
        "caches": M.abstract_caches(cfg, b, s, jnp.dtype(cfg.dtype)),
        "index": jax.ShapeDtypeStruct((), jnp.int32),
    }


def input_specs(cfg: ModelConfig, shape: ShapeConfig, mb: int = 1
                ) -> Dict[str, Any]:
    if shape.kind == "train":
        return train_specs(cfg, shape, mb)
    if shape.kind == "prefill":
        return prefill_specs(cfg, shape)
    return decode_specs(cfg, shape)
