"""Sharding-rules engine: logical param/activation axes → PartitionSpecs.

Every model module declares logical axis names per param dim
(``models.model.param_axes``); this module maps them onto the arch mesh
(mesh.py) given a :class:`ParallelPlan`:

* TP  — ``vocab``/``ffn``/``experts``/``inner``/``conv_chan`` shard over the
  full model factoring (tp_kv·tp_g·tp_r = 16); ``heads`` over (tp_kv, tp_g);
  ``kv_heads`` over tp_kv.
* FSDP — the ``embed`` dim of every matrix (and the first-moment/second-
  moment states, which inherit param specs) shards over ``data`` (+``pod``)
  for the XL archs.
* DP  — ``batch`` over (``pod``,) ``data``; ``seq`` (long-context KV) over
  ``data``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.config import ModelConfig, ShapeConfig
from ..models import model as M
from .mesh import MeshPlan


@dataclasses.dataclass(frozen=True)
class ParallelPlan:
    """Arch × shape parallelism settings."""

    fsdp: bool = False               # shard params over data axis
    fsdp_pod: bool = False           # ... and over the pod axis too
    microbatches: int = 1            # grad-accumulation steps per train step
    seq_shard_decode: bool = True    # shard KV cache seq dim when batch < DP


def _fsdp_axes(plan: MeshPlan, pp: ParallelPlan) -> Tuple[str, ...]:
    if not pp.fsdp:
        return ()
    return ("pod", "data") if (pp.fsdp_pod and plan.multi_pod) else ("data",)


def logical_rules(plan: MeshPlan, pp: ParallelPlan) -> Dict[str, Any]:
    fsdp = _fsdp_axes(plan, pp)
    return {
        "vocab": plan.tp_axes,
        "ffn": plan.tp_axes,
        "experts": plan.tp_axes,
        "inner": plan.tp_axes,
        "conv_chan": plan.tp_axes,
        "heads": plan.heads_axes,
        "kv_heads": ("tp_kv",),
        "ssm_heads": plan.tp_axes,
        "embed": fsdp if fsdp else None,
        "q_lora": None,
        "kv_lora": None,
        "kv_lora_rope": None,
        "head_dim": None,
        "layers": None,
        "batch": plan.batch_axes,
        "seq": None,   # overridden for long-context decode
    }


def tp_only_rules(plan: MeshPlan) -> Dict[str, Any]:
    """Param rules with FSDP removed — used as ``param_rules`` inside the
    scanned layer body to force per-layer weight all-gather."""
    return logical_rules(plan, ParallelPlan(fsdp=False))


def spec_from_axes(axes: Tuple, rules: Dict[str, Any]) -> P:
    """Map one param's logical dims to a PartitionSpec. A mesh axis may
    appear once per spec; earlier dims win (e.g. MoE ``(experts, embed,
    ffn)``: EP takes the model axes, the per-expert ffn dim stays local)."""
    parts = []
    used = set()
    for ax in axes:
        r = rules.get(ax, None) if ax is not None else None
        if r is None:
            parts.append(None)
            continue
        r = r if isinstance(r, tuple) else (r,)
        r = tuple(a for a in r if a not in used)
        used.update(r)
        if not r:
            parts.append(None)
        elif len(r) == 1:
            parts.append(r[0])
        else:
            parts.append(r)
    return P(*parts)


def tree_specs(axes_tree, rules) -> Any:
    return jax.tree.map(
        lambda a: spec_from_axes(a, rules), axes_tree,
        is_leaf=M.is_axes_leaf)


def param_shardings(mesh: Mesh, cfg: ModelConfig, plan: MeshPlan,
                    pp: ParallelPlan):
    rules = logical_rules(plan, pp)
    specs = tree_specs(M.param_axes(cfg), rules)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda s: isinstance(s, P))


def cache_shardings(mesh: Mesh, cfg: ModelConfig, plan: MeshPlan,
                    pp: ParallelPlan, shape: ShapeConfig):
    rules = dict(logical_rules(plan, pp))
    rules["embed"] = None  # caches never FSDP-shard
    # batch=1 long-context decode: shard the KV-cache sequence dim instead
    # of the (unshardable) batch dim — sequence parallelism for decode.
    dp = (2 if plan.multi_pod else 1) * 16
    if shape.kind == "decode" and shape.global_batch < dp:
        rules["batch"] = None
        if pp.seq_shard_decode:
            rules["seq"] = ("data",)
    specs = tree_specs(M.cache_axes(cfg), rules)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda s: isinstance(s, P))


def batch_shardings(mesh: Mesh, cfg: ModelConfig, plan: MeshPlan,
                    shape: ShapeConfig):
    """Input-batch shardings.

    train: leaves are (mb, B/mb, seq[, ...]) — device batch is axis 1;
    prefill/decode: (B, seq[, ...]) — device batch is axis 0 (replicated
    when B < dp, e.g. long_500k's batch of 1).
    """
    dp = (2 if plan.multi_pod else 1) * 16
    b_ax = plan.batch_axes if shape.global_batch >= dp else None
    if shape.kind == "train":
        tok = P(None, b_ax, None)
        fe = P(None, b_ax, None, None)
    else:
        tok = P(b_ax, None)
        fe = P(b_ax, None, None)
    out = {"tokens": NamedSharding(mesh, tok)}
    if shape.kind == "train":
        out["labels"] = NamedSharding(mesh, tok)
    if cfg.frontend != "none":
        out["frontend_embeds"] = NamedSharding(mesh, fe)
    return out


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())
