"""jit-able train / prefill / decode steps + per-arch parallel plans.

``train_step`` consumes a *pre-microbatched* batch — tokens shaped
``(microbatches, global_batch/microbatches, seq)`` with the device batch
dim sharded over (pod, data). Grad accumulation is a ``lax.scan`` over the
leading dim (fp32 accumulators, single bucketed all-reduce at the end —
XLA overlaps the per-microbatch reduce-scatters with the next microbatch's
compute under the latency-hiding scheduler). The optimizer update runs on
the param sharding (FSDP keeps moments sharded).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict

import jax
import jax.numpy as jnp

from ..models import model as M
from ..models.config import ModelConfig
from ..optim import AdamWConfig, adamw_init, adamw_update, cosine_schedule
from .sharding import ParallelPlan

# ---------------------------------------------------------------------------
# per-arch parallel plans (microbatch counts sized for ≤ ~1GB fp32 scores
# per device at train_4k; FSDP for the ≥40B archs)
# ---------------------------------------------------------------------------
PLANS: Dict[str, ParallelPlan] = {
    "granite_moe_1b": ParallelPlan(microbatches=1),
    "phi35_moe_42b": ParallelPlan(fsdp=True, microbatches=4),
    "minicpm3_4b": ParallelPlan(microbatches=8),
    "starcoder2_7b": ParallelPlan(microbatches=8),
    "llama32_3b": ParallelPlan(microbatches=4),
    "nemotron4_340b": ParallelPlan(fsdp=True, fsdp_pod=True, microbatches=8),
    "llava_next_mistral_7b": ParallelPlan(microbatches=4),
    "mamba2_2p7b": ParallelPlan(microbatches=1),
    "musicgen_large": ParallelPlan(microbatches=2),
    "jamba15_large_398b": ParallelPlan(fsdp=True, fsdp_pod=True,
                                       microbatches=8),
}


def plan_of(arch: str) -> ParallelPlan:
    return PLANS.get(arch, ParallelPlan())


@dataclasses.dataclass(frozen=True)
class TrainHyper:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000


# ---------------------------------------------------------------------------
# steps
# ---------------------------------------------------------------------------
def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig,
                    hyper: TrainHyper = TrainHyper()) -> Callable:
    """(params, opt_state, batch) → (params, opt_state, metrics).

    batch leaves are (microbatches, B_mb, ...) — scan accumulates grads.
    """

    def train_step(params, opt_state, batch):
        def mb_grads(p, mbb):
            (loss, metrics), grads = jax.value_and_grad(
                M.loss_fn, has_aux=True)(p, cfg, mbb)
            return loss, metrics, grads

        def body(carry, mbb):
            gsum, loss_sum = carry
            loss, metrics, grads = mb_grads(params, mbb)
            gsum = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32), gsum, grads)
            return (gsum, loss_sum + loss), None

        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        n_mb = jax.tree.leaves(batch)[0].shape[0]
        (gsum, loss_sum), _ = jax.lax.scan(body, (zeros, jnp.float32(0.0)),
                                           batch)
        grads = jax.tree.map(lambda g: g / n_mb, gsum)
        lr = cosine_schedule(opt_state.count, peak_lr=hyper.peak_lr,
                             warmup_steps=hyper.warmup_steps,
                             total_steps=hyper.total_steps)
        params, opt_state, gnorm = adamw_update(opt_cfg, grads, opt_state,
                                                params, lr)
        metrics = {"loss": loss_sum / n_mb, "grad_norm": gnorm, "lr": lr}
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig) -> Callable:
    def prefill_step(params, batch):
        logits, caches = M.prefill(params, cfg, batch)
        return logits, caches
    return prefill_step


def make_decode_step(cfg: ModelConfig) -> Callable:
    def decode_step(params, caches, tokens, index):
        logits, new_caches = M.decode_step(params, cfg, tokens, caches,
                                           index)
        # greedy next token (sampling lives in the serving loop)
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return logits, new_caches, next_tok
    return decode_step


def abstract_opt_state(cfg: ModelConfig, opt_cfg: AdamWConfig):
    params = M.abstract_params(cfg)
    return jax.eval_shape(functools.partial(adamw_init, opt_cfg), params)
