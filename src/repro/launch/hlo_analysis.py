"""Loop-weighted roofline terms from post-SPMD HLO text.

XLA:CPU's ``compiled.cost_analysis()`` counts each ``while`` body ONCE —
useless for scanned layer stacks (24–96 trips). This module re-derives the
three roofline inputs by parsing the scheduled HLO with per-computation
symbol tables and multiplying by while-loop trip counts:

* ``dot_flops``    — 2 · |result| · |contraction| per dot, loop-weighted;
* ``hbm_bytes``    — Σ (operands + result) bytes over non-bookkeeping
  instructions (each fusion = one read of its inputs + one write of its
  output: exactly the HBM-traffic model of a fused program);
* ``collectives``  — operand bytes per collective kind, loop-weighted.

Everything is per-device (the HLO is the per-partition SPMD program).

HBM-traffic model (``hbm_bytes``): each instruction's result is written to
HBM once; reads are fused into producers except dot/conv operand streams
(weights re-read per use); tensors ≤ ``VMEM_RESIDENT_BYTES`` are treated
as fusion-resident (XLA:TPU keeps loop tiles in VMEM — v5e has 128MB; we
use a conservative 4MiB). ``hbm_bytes_upper`` counts every operand+result
with no residency credit (the XLA:CPU one-op-per-fusion view).
"""
from __future__ import annotations

import re
from typing import Dict, List, Tuple

VMEM_RESIDENT_BYTES = 4 * 1024 * 1024

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1, "c64": 8,
                "c128": 16, "s4": 1, "u4": 1}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute", "ragged-all-to-all")

_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{\s*$")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(?[\w\[\],{}\s/]*?\)?)\s*"
    r"([\w\-]+)\(")
_SHAPE = re.compile(r"(\w+)\[([\d,]*)\]")
_OPERAND = re.compile(r"%([\w.\-]+)")
_WHILE = re.compile(r"condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_CONST_S32 = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST = re.compile(r"replica_groups=\{\{([^}]*)\}")

_BOOKKEEPING = {"tuple", "get-tuple-element", "parameter", "constant",
                "bitcast", "after-all", "add-dependency", "copy-start",
                "copy-done", "partition-id", "replica-id", "iota",
                "broadcast", "while", "conditional", "call",
                "optimization-barrier", "reshape"}

# ops whose operands/results cannot be fused away on TPU — the realistic
# HBM-traffic set. Elementwise chains are assumed fused into these
# producers/consumers (XLA:TPU does; XLA:CPU's scheduled HLO does not, so
# summing *all* instructions gives only an upper bound).
_MAJOR = {"dot", "convolution", "gather", "scatter", "reduce",
          "reduce-window", "sort", "concatenate", "dynamic-slice",
          "dynamic-update-slice", "pad", "transpose", "copy", "slice",
          "select-and-scatter", "cholesky", "triangular-solve", "fft",
          "custom-call", "rng-bit-generator"}
_BRANCHES = re.compile(
    r"(?:true_computation=%?([\w.\-]+))|(?:false_computation=%?([\w.\-]+))"
    r"|(?:branch_computations=\{([^}]*)\})")


def _shape_list_bytes(text: str) -> Tuple[int, List[Tuple[str, List[int]]]]:
    shapes = []
    total = 0
    for dt, dims in _SHAPE.findall(text):
        d = [int(x) for x in dims.split(",") if x]
        n = 1
        for x in d:
            n *= x
        total += n * _DTYPE_BYTES.get(dt, 4)
        shapes.append((dt, d))
    return total, shapes


class HloAnalysis:
    def __init__(self, hlo_text: str, seq_len: int = 0):
        """``seq_len``: when >0, tracks the bytes of (…, S, S) score-shaped
        tensors separately — ``hbm_bytes_flashproj`` = hbm_bytes minus that
        traffic, i.e. the projected traffic when attention runs as the
        fused Pallas flash kernel (kernels/flash_attn — validated vs
        oracle), whose S×S tiles stay in VMEM by construction."""
        self.seq_len = seq_len
        self.comps: Dict[str, List[str]] = {}
        self.entry = None
        self._split(hlo_text)
        self.mult = self._while_multipliers()
        self._analyze()

    # -- parsing ------------------------------------------------------------
    def _split(self, text: str) -> None:
        cur, depth = None, 0
        for line in text.splitlines():
            if depth == 0:
                m = _COMP_HDR.match(line.strip())
                if m:
                    name = m.group(2)
                    cur = []
                    self.comps[name] = cur
                    if m.group(1):
                        self.entry = name
                    depth = 1
                continue
            depth += line.count("{") - line.count("}")
            if cur is not None and depth >= 1:
                cur.append(line)
            if depth <= 0:
                cur, depth = None, 0

    def _while_multipliers(self) -> Dict[str, int]:
        mult = {name: 1 for name in self.comps}
        edges: Dict[str, List[Tuple[str, int]]] = {}
        for name, lines in self.comps.items():
            for line in lines:
                if " while(" not in line:
                    continue
                m = _WHILE.search(line)
                if not m:
                    continue
                cond, body = m.group(1), m.group(2)
                trips = 1
                for cl in self.comps.get(cond, []):
                    c = _CONST_S32.search(cl)
                    if c:
                        trips = max(trips, int(c.group(1)))
                edges.setdefault(name, []).append((body, trips))
                edges.setdefault(name, []).append((cond, trips))
        for _ in range(64):
            changed = False
            for src, outs in edges.items():
                for dst, trips in outs:
                    want = mult.get(src, 1) * trips
                    if mult.get(dst, 1) < want:
                        mult[dst] = want
                        changed = True
            if not changed:
                break
        return mult

    # -- analysis -----------------------------------------------------------
    def _countable(self):
        """Computations that execute as control flow (not fusion bodies):
        ENTRY + while bodies/conditions + conditional branches. Fusion /
        reduce-applier / comparator computations are *inlined* into their
        call sites and must not be separately counted."""
        names = set()
        if self.entry:
            names.add(self.entry)
        frontier = [self.entry] if self.entry else []
        while frontier:
            cur = frontier.pop()
            for line in self.comps.get(cur, []):
                m = _WHILE.search(line)
                targets = []
                if m:
                    targets += [m.group(1), m.group(2)]
                for b in _BRANCHES.finditer(line):
                    for g in b.groups():
                        if g:
                            targets += [t.strip().lstrip("%")
                                        for t in g.split(",") if t.strip()]
                for t in targets:
                    if t in self.comps and t not in names:
                        names.add(t)
                        frontier.append(t)
        return names

    def _analyze(self) -> None:
        self.dot_flops = 0
        self.hbm_bytes = 0          # major-op traffic (TPU-fusion model)
        self.hbm_bytes_upper = 0    # every instruction (CPU-HLO upper bound)
        self.score_bytes = 0        # (…, S, S) score-shaped traffic
        self.transcendentals = 0
        self.collectives = {c: {"bytes": 0, "count": 0, "static_count": 0}
                            for c in COLLECTIVES}
        countable = self._countable()
        for name, lines in self.comps.items():
            if name not in countable:
                continue
            k = self.mult.get(name, 1)
            sym: Dict[str, int] = {}          # result bytes per name
            sym_shapes: Dict[str, List[List[int]]] = {}
            for line in lines:
                m = _INSTR.match(line)
                if not m:
                    continue
                iname, shape_txt, opcode = m.groups()
                res_bytes, res_shapes = _shape_list_bytes(shape_txt)
                sym[iname] = res_bytes
                sym_shapes[iname] = [d for _, d in res_shapes]
                if opcode in _BOOKKEEPING:
                    continue
                # operand names: inside the first (...) group
                paren = line[m.end():]
                close = paren.find(")")
                operands = _OPERAND.findall(paren[:close])
                op_bytes = sum(sym.get(o, 0) for o in operands)
                base = opcode.replace("-start", "")
                self.hbm_bytes_upper += (res_bytes + op_bytes) * k
                # materialize-once model (see module docstring)
                if res_bytes > VMEM_RESIDENT_BYTES or \
                        base in self.collectives:
                    self.hbm_bytes += res_bytes * k
                    if self.seq_len and any(
                            len(d) >= 2 and d[-1] == self.seq_len
                            and d[-2] == self.seq_len
                            for _, d in res_shapes):
                        self.score_bytes += res_bytes * k
                if opcode in ("dot", "convolution"):
                    self.hbm_bytes += sum(
                        b for b in (sym.get(o, 0) for o in operands)
                        if b > VMEM_RESIDENT_BYTES) * k
                if base in self.collectives:
                    p = 1
                    g = _GROUPS_IOTA.search(line)
                    if g:
                        p = int(g.group(2))
                    else:
                        g2 = _GROUPS_LIST.search(line)
                        if g2:
                            p = len([x for x in g2.group(1).split(",")
                                     if x.strip()])
                    if base == "all-gather":
                        operand_b = res_bytes // max(p, 1)
                    elif base == "reduce-scatter":
                        operand_b = res_bytes * p
                    else:
                        operand_b = res_bytes
                    c = self.collectives[base]
                    c["bytes"] += operand_b * k
                    c["count"] += k
                    c["static_count"] += 1
                if opcode == "dot":
                    flops = self._dot_flops(line, res_shapes, operands,
                                            sym_shapes)
                    self.dot_flops += flops * k
                elif opcode in ("exponential", "tanh", "logistic", "rsqrt",
                                "log", "power"):
                    n = 1
                    for _, d in res_shapes:
                        for x in d:
                            n *= x
                    self.transcendentals += n * k

    @staticmethod
    def _dot_flops(line, res_shapes, operands, sym_shapes) -> int:
        if not res_shapes:
            return 0
        res_elems = 1
        for x in res_shapes[0][1]:
            res_elems *= x
        contract = 1
        m = _CONTRACT.search(line)
        if m and operands:
            lhs_shape = sym_shapes.get(operands[0])
            if lhs_shape and lhs_shape[0] is not None and len(lhs_shape) > 0:
                dims = [int(x) for x in m.group(1).split(",") if x]
                shape0 = lhs_shape[0]
                for dd in dims:
                    if dd < len(shape0):
                        contract *= shape0[dd]
        return 2 * res_elems * contract

    def summary(self) -> dict:
        total = sum(v["bytes"] for v in self.collectives.values())
        return {
            "dot_flops": int(self.dot_flops),
            "hbm_bytes": int(self.hbm_bytes),
            "hbm_bytes_upper": int(self.hbm_bytes_upper),
            "hbm_bytes_flashproj": int(self.hbm_bytes - self.score_bytes),
            "score_bytes": int(self.score_bytes),
            "transcendentals": int(self.transcendentals),
            "collectives": dict(self.collectives,
                                total_bytes=int(total)),
            "while_trips": {k: v for k, v in self.mult.items() if v > 1},
        }
