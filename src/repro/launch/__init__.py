from .mesh import (MeshPlan, arch_mesh, make_production_mesh,  # noqa: F401
                   plan_for)
from .sharding import ParallelPlan  # noqa: F401
