"""repro: "Hash in a Flash" — flash-friendly counting hash tables, rebuilt as a
TPU-native JAX framework (data-pipeline statistics, MoE load accounting,
KV-prefix refcounting) plus a multi-arch LM training/serving stack."""

__version__ = "0.1.0"
