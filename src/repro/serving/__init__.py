from .block_pool import BlockPool, NUM_TOKENS_IN_BLOCK  # noqa: F401
from .prefix_cache import PrefixKVCache  # noqa: F401
from .engine import ServeEngine, Request  # noqa: F401
from .scheduler import (ContinuousBatchingScheduler, SchedRequest,  # noqa: F401
                        TraceReport, replay_trace)
from .trace import TraceItem, make_trace  # noqa: F401
