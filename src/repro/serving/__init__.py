from .prefix_cache import PrefixKVCache  # noqa: F401
from .engine import ServeEngine, Request  # noqa: F401
