"""Zipf-prompt trace generator over a simulated user population.

Serving traffic is skewed: a few "users" (agents, templates, tenants)
account for most requests, and each user's requests share a long system
prompt. Flashield (PAPERS.md) shows cache-admission and wear decisions
only become visible under such skewed streams, so the load harness
replays exactly that shape: users are drawn from a Zipf(s) distribution,
every request reuses its user's fixed system-prefix (block-aligned so the
paged prefix cache can share it bitwise) followed by a random per-request
suffix, and arrivals follow a Poisson process.

No threading here — replay lives in :mod:`repro.serving.scheduler`
(the one serving file flashlint FL004 lets spawn workers).
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np


@dataclasses.dataclass
class TraceItem:
    prompt: List[int]
    max_new_tokens: int
    arrival_s: float
    user: int


def _zipf_weights(n: int, s: float) -> np.ndarray:
    w = 1.0 / np.arange(1, n + 1, dtype=np.float64) ** s
    return w / w.sum()


def make_trace(num_requests: int = 32, num_users: int = 8,
               zipf_s: float = 1.2, prefix_blocks: int = 2,
               block_tokens: int = 16, suffix_tokens: Tuple[int, int] = (4, 12),
               max_new_tokens: int = 8, vocab_size: int = 509,
               arrival_rate_hz: float = 50.0,
               seed: int = 0) -> List[TraceItem]:
    """Build a reproducible arrival-timed request trace.

    Each user owns a fixed system prefix of ``prefix_blocks`` whole cache
    blocks (``prefix_blocks * block_tokens`` tokens) — so two requests
    from the same user share that many block-aligned prefix tokens, and
    the expected prefix-cache token hit rate on replay is governed by the
    Zipf skew. Suffix lengths are uniform in ``suffix_tokens`` and
    deliberately *not* block-aligned.
    """
    rng = np.random.default_rng(seed)
    # token 0 is the scheduler's pad token — keep prompts clear of it so
    # traces can assert exact prompt roundtrips
    prefixes = rng.integers(1, vocab_size,
                            size=(num_users, prefix_blocks * block_tokens))
    users = rng.choice(num_users, size=num_requests,
                       p=_zipf_weights(num_users, zipf_s))
    gaps = rng.exponential(1.0 / arrival_rate_hz, size=num_requests)
    arrivals = np.cumsum(gaps)
    items = []
    for i in range(num_requests):
        u = int(users[i])
        nsuf = int(rng.integers(suffix_tokens[0], suffix_tokens[1] + 1))
        suffix = rng.integers(1, vocab_size, size=nsuf)
        items.append(TraceItem(
            prompt=[int(t) for t in prefixes[u]] + [int(t) for t in suffix],
            max_new_tokens=max_new_tokens,
            arrival_s=float(arrivals[i]),
            user=u))
    return items
