"""Paged KV block pool: fixed-size token blocks behind a free-list.

The serving layer stores prefill KV state in fixed-size *blocks* of
``NUM_TOKENS_IN_BLOCK`` tokens (pie/vLLM-style paged KV). This module owns
the physical side only: a fixed slab of slots, a free-list allocator, and
occupancy accounting. The *logical* side — which token chain lives in
which slot, who holds it pinned, which zero-ref slot to evict — is the
:class:`~repro.serving.prefix_cache.PrefixKVCache`, whose counting
flash-hash refcounts ARE the page table (DESIGN.md §13).

Copy-on-write sharing falls out of content hashing: a block slot is
keyed by the rolling hash of its token chain, so two requests sharing a
prefix pin the *same* slots, and a request that diverges hashes to fresh
keys and allocates fresh slots — shared block values are never mutated.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional

#: default tokens per KV block (the pie backend's NUM_TOKENS_IN_BLOCK)
NUM_TOKENS_IN_BLOCK = 16


class BlockPool:
    """Fixed-capacity slab of KV block slots with a free-list allocator.

    Values are opaque (host pytrees of device arrays in the scheduler;
    anything hashable-free in tests). The pool never copies or mutates a
    stored value — copy-on-write is enforced structurally: a slot's value
    is written once at :meth:`alloc` and only dropped at :meth:`free`.
    """

    def __init__(self, capacity_blocks: int):
        if capacity_blocks <= 0:
            raise ValueError(f"capacity_blocks must be > 0, got "
                             f"{capacity_blocks}")
        self.capacity = int(capacity_blocks)
        self._slots: List[Any] = [None] * self.capacity
        # LIFO free-list: recently-freed slots are re-used first (their
        # refcount keys are the ones whose H_R ±1 pairs still cancel)
        self._free: List[int] = list(range(self.capacity - 1, -1, -1))
        self.allocs = 0
        self.frees = 0
        self.high_water = 0

    # -- allocator ----------------------------------------------------------
    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return self.capacity - len(self._free)

    def alloc(self, value: Any) -> Optional[int]:
        """Take a free slot, store ``value``, return its block id — or
        None when the pool is exhausted (the caller evicts and retries)."""
        if not self._free:
            return None
        bid = self._free.pop()
        self._slots[bid] = value
        self.allocs += 1
        self.high_water = max(self.high_water, self.in_use)
        return bid

    def get(self, bid: int) -> Any:
        """Read a slot's value (shared, never copied — CoW discipline)."""
        return self._slots[bid]

    def free(self, bid: int) -> None:
        """Return a slot to the free list and drop its value."""
        if self._slots[bid] is None and bid in self._free:
            raise ValueError(f"double free of block {bid}")
        self._slots[bid] = None
        self._free.append(bid)
        self.frees += 1

    def stats(self) -> Dict[str, int]:
        return {"pool_capacity": self.capacity, "pool_in_use": self.in_use,
                "pool_free": self.num_free, "pool_allocs": self.allocs,
                "pool_frees": self.frees,
                "pool_high_water": self.high_water}
