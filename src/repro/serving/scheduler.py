"""Continuous-batching scheduler over a packed slot table (DESIGN.md §13).

Replaces the serial ``ServeEngine.serve`` loop with iteration-level
scheduling: a fixed table of ``max_slots`` decode slots, each at its own
sequence position, advanced by one jitted
:func:`~repro.models.model.decode_step_packed` per tick. Requests join a
free slot mid-flight, prefill in fixed-size chunks *interleaved* with
decode ticks, and leave the moment they finish — no batch barrier.

Prefill is paged: chunk size equals the prefix cache's block size, chunks
cover absolute aligned windows ``[k·B, (k+1)·B)`` and are always padded
to that fixed shape (one XLA compile; the causal mask hides padding rows
and later decode writes overwrite them). That alignment makes every
fully-computed chunk bitwise-identical to the cached segment any other
request would produce for the same token chain, so chunks flow straight
into the :class:`~repro.serving.prefix_cache.PrefixKVCache` block pool
(``insert_block``) and cached prefixes flow straight back out
(``acquire_blocks`` → per-block row scatter) — the counting flash-hash
refcounts pin each block for the lifetime of the requests using it.

Hybrid/SSM stacks cannot enter a recurrent state mid-sequence, so they
take a whole-prompt prefill fallback (block pool and chunking disabled);
packed decode works unchanged because SSM decode is position-free.

This module is the one serving file allowed to use ``threading``
(flashlint FL004): :func:`replay_trace` replays an arrival-timed trace
through worker feeder threads MaxText-offline-inference style while the
main thread turns the scheduler crank. ``submit`` is the only
cross-thread entry point and is lock-protected; all jitted state stays
on the scheduler thread.

A scheduler should own its :class:`PrefixKVCache` exclusively — the
block-granular API stores per-block *segments*, which do not mix with
the cumulative-prefix values the legacy ``ServeEngine`` path inserts.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..models import model as M
from ..models.config import ModelConfig
from .block_pool import NUM_TOKENS_IN_BLOCK
from .prefix_cache import PrefixKVCache


@dataclasses.dataclass
class SchedRequest:
    """One request's lifecycle: waiting → prefill → decode → done."""
    prompt: List[int]
    max_new_tokens: int = 16
    request_id: int = -1
    output: List[int] = dataclasses.field(default_factory=list)
    cached_tokens: int = 0
    pinned: List[int] = dataclasses.field(default_factory=list)
    submit_s: float = 0.0
    start_s: float = 0.0
    finish_s: float = 0.0
    # scheduler-internal
    slot: int = -1
    phase: str = "waiting"
    done: int = 0        # prompt tokens whose KV already sits in the slot

    @property
    def latency_s(self) -> float:
        return self.finish_s - self.submit_s


class ContinuousBatchingScheduler:
    def __init__(self, cfg: ModelConfig, params,
                 prefix_cache: Optional[PrefixKVCache] = None,
                 max_slots: int = 4, max_context: int = 192,
                 prefill_chunks_per_tick: int = 1):
        self.cfg = cfg
        self.params = params
        self.cache = prefix_cache
        self.max_slots = max_slots
        self.max_context = max_context
        self.prefill_chunks_per_tick = prefill_chunks_per_tick
        self.bt = (prefix_cache.block_tokens if prefix_cache is not None
                   else NUM_TOKENS_IN_BLOCK)
        self._ssm = any(k == "ssm" for k in cfg.layer_pattern)
        # slot rows run 0..max_context-1; row max_context is a scratch row
        # where idle slots "decode" a dummy token each tick (never attended
        # to: every real query position is < max_context)
        self.park = max_context
        self.s_max = max_context + 1
        self.caches = M.init_caches(cfg, max_slots, self.s_max,
                                    jnp.dtype(cfg.dtype))

        self._lock = threading.Lock()
        self._waiting: collections.deque = collections.deque()
        self._active: List[Optional[SchedRequest]] = [None] * max_slots
        self._free_slots = list(range(max_slots - 1, -1, -1))
        self.completed: List[SchedRequest] = []
        self.decode_steps = 0
        self.chunk_calls = 0

        self._decode = jax.jit(
            lambda p, c, t, i: M.decode_step_packed(p, cfg, t, c, i),
            donate_argnums=(1,))
        if self._ssm:
            self._prefill = jax.jit(lambda p, b: M.prefill(p, cfg, b))
            self._adopt = jax.jit(
                lambda c, row, slot: jax.tree.map(
                    lambda full, r: jax.lax.dynamic_update_slice_in_dim(
                        full, r, slot, axis=1), c, row),
                donate_argnums=(0,))
        else:
            bt = self.bt

            def chunk_row(p, caches, toks, slot, start):
                # gather one slot's row, run the fixed-shape chunk on a
                # batch of 1, scatter the row back: active neighbours'
                # caches are untouched and the chunk compiles once
                row = jax.tree.map(
                    lambda x: jax.lax.dynamic_slice_in_dim(x, slot, 1,
                                                           axis=1), caches)
                logits, row = M.prefill_chunk(p, cfg, toks, row, start)
                caches = jax.tree.map(
                    lambda full, r: jax.lax.dynamic_update_slice_in_dim(
                        full, r, slot, axis=1), caches, row)
                return logits, caches

            def read_block(caches, slot, start):
                def rd(x):
                    sizes = (x.shape[0], 1, bt) + x.shape[3:]
                    starts = (0, slot, start) + (0,) * (x.ndim - 3)
                    return jax.lax.dynamic_slice(x, starts, sizes)
                return jax.tree.map(rd, caches)

            def write_block(caches, seg, slot, start):
                def wr(full, s):
                    starts = (0, slot, start) + (0,) * (full.ndim - 3)
                    return jax.lax.dynamic_update_slice(full, s, starts)
                return jax.tree.map(wr, caches, seg)

            self._chunk = jax.jit(chunk_row, donate_argnums=(1,))
            self._read_block = jax.jit(read_block)
            self._write_block = jax.jit(write_block, donate_argnums=(0,))

    # -- submission (the one cross-thread entry point) -----------------------
    def submit(self, req: SchedRequest) -> SchedRequest:
        if len(req.prompt) + req.max_new_tokens > self.max_context:
            raise ValueError(
                f"request needs {len(req.prompt) + req.max_new_tokens} "
                f"rows > max_context={self.max_context}")
        req.submit_s = time.monotonic()
        with self._lock:
            self._waiting.append(req)
        return req

    # -- admission -----------------------------------------------------------
    def _admit(self) -> None:
        while self._free_slots:
            with self._lock:
                if not self._waiting:
                    return
                req = self._waiting.popleft()
            slot = self._free_slots.pop()
            req.slot = slot
            req.start_s = time.monotonic()
            if self._ssm:
                self._admit_whole_prompt(req)
            else:
                self._admit_paged(req)
            self._active[slot] = req

    def _admit_paged(self, req: SchedRequest) -> None:
        """Reuse cached prefix blocks: scatter each pinned segment into
        the slot's rows, then chunk-prefill only the remainder."""
        n = 0
        if self.cache is not None:
            n, values, req.pinned = self.cache.acquire_blocks(req.prompt)
            for j, seg in enumerate(values):
                self.caches = self._write_block(
                    self.caches, seg, jnp.int32(req.slot),
                    jnp.int32(j * self.bt))
        req.cached_tokens = n
        req.done = n
        # n == len(prompt) (exact full-prompt hit) goes straight to decode
        # with an empty output; the first decode tick re-decodes the final
        # prompt token at its own position to recover first-token logits
        req.phase = "prefill" if n < len(req.prompt) else "decode"

    def _admit_whole_prompt(self, req: SchedRequest) -> None:
        """SSM/hybrid fallback: recurrent state cannot be entered
        mid-sequence, so prefill the whole prompt at exact length (one
        compile per distinct prompt length) and adopt the row."""
        batch = {"tokens": jnp.asarray([req.prompt], jnp.int32)}
        if self.cfg.frontend != "none":
            batch["frontend_embeds"] = jnp.zeros(
                (1, self.cfg.num_patches, self.cfg.d_model),
                jnp.dtype(self.cfg.dtype))
        logits, row = self._prefill(self.params, batch)
        row = M.pad_caches(self.cfg, row, self.s_max)
        self.caches = self._adopt(self.caches, row, jnp.int32(req.slot))
        req.done = len(req.prompt)
        req.output.append(
            int(jnp.argmax(logits[0, -1, :self.cfg.vocab_size])))
        req.phase = "decode"

    # -- chunked prefill ------------------------------------------------------
    def _prefill_one_chunk(self, req: SchedRequest) -> None:
        P = len(req.prompt)
        k = req.done // self.bt
        start = k * self.bt
        toks = req.prompt[start:start + self.bt]
        pad = self.bt - len(toks)
        arr = jnp.asarray([toks + [0] * pad], jnp.int32)
        logits, self.caches = self._chunk(
            self.params, self.caches, arr, jnp.int32(req.slot),
            jnp.int32(start))
        self.chunk_calls += 1
        req.done = min(start + self.bt, P)
        if self.cache is not None and pad == 0:
            # a fully-real chunk IS a cache block: read the rows back and
            # register them (pinning the new block for this request)
            seg = self._read_block(self.caches, jnp.int32(req.slot),
                                   jnp.int32(start))
            key = self.cache.insert_block(req.prompt, k, seg)
            if key is not None:
                req.pinned.append(key)
        if req.done >= P:
            off = (P - 1) - start
            req.output.append(
                int(jnp.argmax(logits[0, off, :self.cfg.vocab_size])))
            req.phase = "decode"

    def _prefill_tick(self) -> bool:
        budget = self.prefill_chunks_per_tick
        did = False
        for req in self._active:
            if budget <= 0:
                break
            if req is None or req.phase != "prefill":
                continue
            self._prefill_one_chunk(req)
            did = True
            budget -= 1
        return did

    # -- packed decode --------------------------------------------------------
    def _decode_tick(self) -> bool:
        rows = [r for r in self._active
                if r is not None and r.phase == "decode"]
        if not rows:
            return False
        toks = np.zeros((self.max_slots, 1), np.int32)
        idx = np.full((self.max_slots,), self.park, np.int32)
        for req in rows:
            P = len(req.prompt)
            if req.output:
                toks[req.slot, 0] = req.output[-1]
                idx[req.slot] = P + len(req.output) - 1
            else:  # full-prompt cache hit: re-decode the last prompt token
                toks[req.slot, 0] = req.prompt[-1]
                idx[req.slot] = P - 1
        logits, self.caches = self._decode(
            self.params, self.caches, jnp.asarray(toks), jnp.asarray(idx))
        self.decode_steps += 1
        out = np.asarray(logits[:, -1, :self.cfg.vocab_size])
        for req in rows:
            req.output.append(int(np.argmax(out[req.slot])))
            if len(req.output) >= req.max_new_tokens:
                self._finish(req)
        return True

    def _finish(self, req: SchedRequest) -> None:
        req.phase = "done"
        req.finish_s = time.monotonic()
        if self.cache is not None:
            self.cache.release(req.pinned)
        self._active[req.slot] = None
        self._free_slots.append(req.slot)
        req.slot = -1
        self.completed.append(req)

    # -- crank ----------------------------------------------------------------
    def step(self) -> bool:
        """One tick: admit, advance prefill by up to
        ``prefill_chunks_per_tick`` chunks, one packed decode step."""
        self._admit()
        did = self._prefill_tick()
        if self._decode_tick():
            did = True
        return did

    def run(self, requests: Optional[Sequence[SchedRequest]] = None
            ) -> List[SchedRequest]:
        """Drain: submit ``requests`` (if given) and tick until idle."""
        if requests is not None:
            for r in requests:
                self.submit(r)
        while True:
            did = self.step()
            with self._lock:
                empty = not self._waiting
            if not did and empty and all(r is None for r in self._active):
                return self.completed


# ---------------------------------------------------------------------------
# trace replay (queue + worker feeder threads)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class TraceReport:
    requests: int
    generated_tokens: int
    wall_s: float
    tokens_per_s: float
    p50_latency_s: float
    p99_latency_s: float
    hit_rate: float          # token-level prefix-cache hit rate
    wear: int                # accounted flash wear (tile_stores / cleans)

    def summary(self) -> str:
        return (f"fig7dev: n={self.requests} "
                f"tok/s={self.tokens_per_s:.1f} "
                f"p50={self.p50_latency_s * 1e3:.1f}ms "
                f"p99={self.p99_latency_s * 1e3:.1f}ms "
                f"hit_rate={self.hit_rate:.3f} wear={self.wear}")


def replay_trace(sched: ContinuousBatchingScheduler, trace,
                 workers: int = 2, time_scale: float = 0.0) -> TraceReport:
    """Replay an arrival-timed trace through feeder worker threads.

    Trace items need ``prompt``/``max_new_tokens``/``arrival_s``
    (see :mod:`repro.serving.trace`). Items are sharded round-robin over
    ``workers`` threads which sleep until each item's (scaled) arrival
    time and ``submit`` it; the calling thread turns the scheduler crank
    until every request completes. ``time_scale=0`` replays as fast as
    the queue drains (offline / throughput mode)."""
    reqs = [SchedRequest(prompt=list(it.prompt),
                         max_new_tokens=it.max_new_tokens, request_id=i)
            for i, it in enumerate(trace)]
    t0 = time.monotonic()

    def feeder(items):
        for arrival, req in items:
            if time_scale > 0:
                delay = t0 + arrival * time_scale - time.monotonic()
                if delay > 0:
                    time.sleep(delay)
            sched.submit(req)

    shards: List[list] = [[] for _ in range(max(1, workers))]
    for i, (it, req) in enumerate(zip(trace, reqs)):
        shards[i % len(shards)].append((getattr(it, "arrival_s", 0.0), req))
    threads = [threading.Thread(target=feeder, args=(s,), daemon=True)
               for s in shards if s]
    for th in threads:
        th.start()
    # count only this replay's requests — the scheduler may already have
    # completions from warmup or earlier traces
    while any(r.phase != "done" for r in reqs):
        if not sched.step():
            time.sleep(0.001)
    for th in threads:
        th.join()
    wall = time.monotonic() - t0

    lats = np.asarray([r.latency_s for r in reqs])
    gen = sum(len(r.output) for r in reqs)
    prompt_toks = sum(len(r.prompt) for r in reqs)
    cached = sum(r.cached_tokens for r in reqs)
    wear = 0
    if sched.cache is not None:
        w = sched.cache._refs.wear()
        wear = int(w.get("tile_stores", w.get("cleans", 0)))
    return TraceReport(
        requests=len(reqs), generated_tokens=gen, wall_s=wall,
        tokens_per_s=gen / max(wall, 1e-9),
        p50_latency_s=float(np.percentile(lats, 50)) if len(lats) else 0.0,
        p99_latency_s=float(np.percentile(lats, 99)) if len(lats) else 0.0,
        hit_rate=cached / max(prompt_toks, 1),
        wear=wear)
