"""Batched serving engine: prefill + greedy decode with prefix-cache reuse.

Continuous-batching-lite: requests are grouped into fixed-size decode
batches; each request first consults the :class:`PrefixKVCache` (counting
flash-hash refcounts) and skips prefill for fully-cached prompts. The
decode loop is one jitted ``decode_step`` per token over the whole batch.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp

from ..models import model as M
from ..models.config import ModelConfig
from .prefix_cache import PrefixKVCache


@dataclasses.dataclass
class Request:
    prompt: List[int]
    max_new_tokens: int = 16
    output: Optional[List[int]] = None
    cached_tokens: int = 0


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params,
                 prefix_cache: Optional[PrefixKVCache] = None):
        self.cfg = cfg
        self.params = params
        self.cache = prefix_cache
        self._decode = jax.jit(
            lambda p, c, t, i: M.decode_step(p, cfg, t, c, i))
        self._prefill = jax.jit(
            lambda p, b: M.prefill(p, cfg, b))

    def _prefill_one(self, prompt: List[int]):
        """Prefill a single prompt, reusing a cached prefix if available.

        Returns ``(logits, caches, consumed, n_cached, pinned)`` where
        ``n_cached`` is the reused-prefix length in tokens (0 on miss).
        """
        pinned = []
        if self.cache is not None:
            n, value, pinned = self.cache.acquire(prompt)
            if n > 0 and value is not None:
                # cached block prefix: decode only the remainder from it
                caches = M.pad_caches(self.cfg, value, len(prompt))
                consumed = n
                logits = None
                for t in prompt[n:]:
                    logits, caches = self._decode_single(caches,
                                                         t, consumed)
                    consumed += 1
                if logits is None:  # exact full-prompt hit
                    logits, caches = self._decode_single(
                        caches, prompt[-1], consumed - 1)
                return logits, caches, consumed, n, pinned
        batch = {"tokens": jnp.asarray([prompt], jnp.int32)}
        if self.cfg.frontend != "none":
            batch["frontend_embeds"] = jnp.zeros(
                (1, self.cfg.num_patches, self.cfg.d_model),
                jnp.dtype(self.cfg.dtype))
        logits, caches = self._prefill(self.params, batch)
        if self.cache is not None:
            pinned += self.cache.insert(prompt, caches,
                                        slicer=self._slicer())
        return logits, caches, len(prompt), 0, pinned

    def _slicer(self):
        """Seq-axis cache trimmer — only for pure-attention stacks (SSM
        recurrent states are not sliceable; those archs reuse exact
        prefixes only)."""
        if any(k == "ssm" for k in self.cfg.layer_pattern):
            return None

        def slicer(caches, n):
            return jax.tree.map(
                lambda x: x[:, :, :n] if x.ndim >= 3 else x, caches)
        return slicer

    def _decode_single(self, caches, token: int, index: int):
        logits, caches = self._decode(
            self.params, caches, jnp.asarray([[token]], jnp.int32),
            jnp.int32(index))
        return logits, caches

    def generate(self, req: Request) -> Request:
        logits, caches, consumed, n_cached, pinned = \
            self._prefill_one(req.prompt)
        max_len = consumed + req.max_new_tokens
        caches = M.pad_caches(self.cfg, caches, max_len)
        out = []
        tok = int(jnp.argmax(logits[0, -1, :self.cfg.vocab_size]))
        out.append(tok)
        for i in range(req.max_new_tokens - 1):
            logits, caches = self._decode_single(caches, tok, consumed + i)
            tok = int(jnp.argmax(logits[0, -1, :self.cfg.vocab_size]))
            out.append(tok)
        if self.cache is not None:
            self.cache.release(pinned)
        req.output = out
        req.cached_tokens = n_cached
        return req

    def serve(self, requests: Sequence[Request]) -> List[Request]:
        return [self.generate(r) for r in requests]
