"""KV prefix-block cache: flash-hash refcounts as the page table of a
paged block pool.

The paper motivates counting hash tables with *reference counting* (§1,
garbage collection). Here that is exactly the serving-side bookkeeping:
prefill KV state is cached per prefix *block* (a fixed number of tokens),
keyed by a rolling hash of the token chain; a **counting** flash-hash
table holds per-block reference counts — +1 while a request uses a block,
−1 on release (deletion-by-decrement, §2.6), and blocks whose count drops
to 0 are evictable.

Physically the values live in a :class:`~.block_pool.BlockPool` — a
fixed slab of slots behind a free-list allocator (pie/vLLM-style paged
KV). The *page table* mapping a token-chain key to its physical slot is
this class plus the refcount store: ``acquire``/``insert``/``release``
are block-granular pin/unpin (±1 through the store's H_R, so a pin/unpin
pair cancels before any device traffic), and eviction takes a
zero-refcount slot. Copy-on-write sharing is structural: block values
are written once and never mutated; a diverging request hashes to new
keys and allocates new slots.

Eviction is **wear-aware** by default (``eviction="wear"``): among
zero-refcount blocks, evict the one whose key lives in the *hottest*
change-segment partition (per-merge ``TableStats`` wear deltas, tracked
by the store's ``track_wear`` feed). A hot partition is being rewritten
anyway, so the eventual re-insertion of that block's refcount dirties a
block that merges regardless; evicting a cold-partition block instead
would later re-dirty a quiet region and buy a fresh block rewrite.
``eviction="first_fit"`` keeps the old drop-the-first-zero-ref policy.

Two value disciplines share the pool:

* the legacy engine path (``insert(tokens, value, slicer=...)``) stores
  *cumulative-prefix* values — key i holds the cache for tokens [0, i·B);
* the scheduler path (``insert_block``/``acquire_blocks``) stores
  *per-block segments* — key i holds only rows [ (i−1)·B, i·B ), which is
  what makes sharing paged: N requests over a common prefix hold the same
  physical slots, O(prefix) memory total, not O(prefix²).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core import table_jax as tj
from ..core.store import FlashStore
from .block_pool import BlockPool


def _chain_hash(prev: int, tokens: Sequence[int]) -> int:
    h = np.uint32(prev if prev else 2166136261)
    for t in tokens:
        h = np.uint32(h ^ np.uint32(t & 0xFFFFFFFF))
        h = np.uint32(int(h) * 16777619 & 0xFFFFFFFF)
    out = int(h) & 0x3FFFFFFF
    return out if out else 1


@dataclasses.dataclass
class _Block:
    key: int
    tokens: Tuple[int, ...]
    bid: int                     # physical slot in the BlockPool


class PrefixKVCache:
    def __init__(self, block_tokens: int = 16, capacity_blocks: int = 256,
                 q_log2: int = 12, r_log2: int = 8, scheme: str = "MDB-L",
                 cs_partitions: int = 4, eviction: str = "wear",
                 backend: str = "device"):
        if eviction not in ("wear", "first_fit"):
            raise ValueError(f"unknown eviction policy {eviction!r}")
        self.block_tokens = block_tokens
        self.capacity = capacity_blocks
        self.eviction = eviction
        self.backend = backend
        self.cfg = tj.FlashTableConfig(q_log2=q_log2, r_log2=r_log2,
                                       scheme=scheme,
                                       log_capacity=1 << 10,
                                       cs_partitions=cs_partitions,
                                       max_updates_per_block=1 << 7,
                                       overflow_capacity=1 << 9)
        # batched refcount reads: evictions scan every resident block key
        # in one deduped dispatch, and repeat scans between bumps are
        # served from the store's hot cache + H_R overlay (the store
        # invalidates the cache whenever it flushes to the device).
        # track_wear feeds the per-partition heat the eviction policy uses.
        if backend == "sim":
            # costed-simulator refcounts (quickstart/CI without a device
            # table); no wear feed — "wear" degrades to first-fit order
            self._refs = FlashStore.open(
                None, backend="sim", scheme=scheme,
                flush_threshold=2 * capacity_blocks)
        else:
            self._refs = FlashStore.open(self.cfg, backend=backend,
                                         chunk=256, query_chunk=256,
                                         flush_threshold=2 * capacity_blocks,
                                         hot_capacity=4 * capacity_blocks,
                                         track_wear=True)
        self.pool = BlockPool(capacity_blocks)
        self.store: Dict[int, _Block] = {}   # page table: key -> slot
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    # -- hashing -------------------------------------------------------------
    def block_keys(self, tokens: Sequence[int]) -> List[int]:
        """Chain keys for every whole block of the token prefix."""
        keys = []
        prev = 0
        bt = self.block_tokens
        for i in range(0, len(tokens) - len(tokens) % bt, bt):
            prev = _chain_hash(prev, tokens[i:i + bt])
            keys.append(prev)
        return keys

    @property
    def refs(self) -> tj.DeviceTableState:
        """Current refcount table state (owned by the store)."""
        return self._refs.state

    def _count(self, keys: List[int]) -> np.ndarray:
        if not keys:
            return np.zeros(0, np.int32)
        # device count + buffered H_R deltas: exact even between flushes
        return self._refs.query_batch(np.asarray(keys, np.int64))

    def _bump(self, keys: List[int], delta: int) -> None:
        if not keys:
            return
        # buffered ±delta: a +1/−1 pair cancels in H_R without device
        # traffic; the store pads/chunks/invalidates when it flushes
        self._refs.update(np.asarray(keys, np.int64),
                          np.full(len(keys), delta, np.int64))

    def _value(self, key: int) -> Any:
        return self.pool.get(self.store[key].bid)

    def _put(self, key: int, tokens: Tuple[int, ...], value: Any) -> None:
        """Page-table insert: evict until a physical slot frees, then map
        ``key`` onto it. The refcount pin (+1) is the caller's."""
        bid = self.pool.alloc(value)
        while bid is None:
            self._evict()
            bid = self.pool.alloc(value)
        self.store[key] = _Block(key, tokens, bid)

    # -- public API: legacy cumulative-prefix path ---------------------------
    def acquire(self, tokens: Sequence[int]) -> Tuple[int, Optional[Any],
                                                      List[int]]:
        """Longest reusable prefix: → (n_cached_tokens, cache_value, keys).
        Bumps refcounts on the blocks the request will pin."""
        keys = self.block_keys(tokens)
        n = 0
        value = None
        for i, k in enumerate(keys):
            if k in self.store:
                n = (i + 1) * self.block_tokens
                value = self._value(k)
            else:
                break
        pinned = keys[:n // self.block_tokens]
        self._bump(pinned, +1)
        if n:
            self.hits += 1
        else:
            self.misses += 1
        return n, value, pinned

    def insert(self, tokens: Sequence[int], value: Any,
               slicer=None) -> List[int]:
        """Register cache state for every whole-block prefix (so future
        requests can reuse *partial* prefixes). ``slicer(value, n_tokens)``
        trims the cache to a block boundary; without one (e.g. SSM states
        are not seq-sliceable) only the full prefix is registered."""
        keys = self.block_keys(tokens)
        if not keys:
            return []
        pinned = []
        items = (list(enumerate(keys)) if slicer is not None
                 else [(len(keys) - 1, keys[-1])])
        for i, k in items:
            if k in self.store:
                continue
            n = (i + 1) * self.block_tokens
            v = slicer(value, n) if slicer is not None else value
            self._put(k, tuple(tokens[:n]), v)
            pinned.append(k)
        self._bump(pinned, +1)
        return pinned

    # -- public API: block-granular paged path (the scheduler's) ------------
    def lookup(self, tokens: Sequence[int]) -> int:
        """Cached-prefix length in tokens, without pinning anything."""
        n = 0
        for i, k in enumerate(self.block_keys(tokens)):
            if k not in self.store:
                break
            n = (i + 1) * self.block_tokens
        return n

    def acquire_blocks(self, tokens: Sequence[int]
                       ) -> Tuple[int, List[Any], List[int]]:
        """Paged acquire: → (n_cached_tokens, [block segment values],
        pinned keys). Each value covers only its own block's rows — the
        scheduler scatters them into a slot's cache rows one by one."""
        keys = self.block_keys(tokens)
        values = []
        for k in keys:
            if k not in self.store:
                break
            values.append(self._value(k))
        pinned = keys[:len(values)]
        self._bump(pinned, +1)
        if pinned:
            self.hits += 1
        else:
            self.misses += 1
        return len(values) * self.block_tokens, values, pinned

    def insert_block(self, tokens: Sequence[int], block_index: int,
                     segment: Any) -> Optional[int]:
        """Register one block's segment (rows [i·B, (i+1)·B) of the
        prefix ending at block ``block_index``). Pins the new block (+1);
        returns its key, or None if it was already resident (no pin —
        the caller pinned it via :meth:`acquire_blocks`)."""
        keys = self.block_keys(tokens)
        k = keys[block_index]
        if k in self.store:
            return None
        n = (block_index + 1) * self.block_tokens
        self._put(k, tuple(tokens[:n]), segment)
        self._bump([k], +1)
        return k

    def release(self, pinned: List[int]) -> None:
        """Decrement refcounts (the paper's deletion-by-decrement)."""
        self._bump(pinned, -1)

    def _evict(self) -> None:
        """Drop a zero-refcount block (full removal, §2.6) and free its
        pool slot.

        ``eviction="wear"``: among the zero-refcount candidates, evict
        the one whose key's change-segment partition has accumulated the
        most merge wear — its eventual re-insertion dirties a partition
        that is being rewritten anyway (ROADMAP wear-aware eviction)."""
        keys = list(self.store.keys())
        counts = self._count(keys)
        zero = [k for k, c in zip(keys, counts) if c <= 0]
        if not zero:
            # all pinned: drop the oldest anyway (degraded mode)
            victim = keys[0]
        else:
            victim = zero[0]
            if self.eviction == "wear" and len(zero) > 1:
                heat = self._refs.partition_heat(np.asarray(zero, np.int64))
                victim = zero[int(np.argmax(heat))]
        self.pool.free(self.store[victim].bid)
        del self.store[victim]
        self.evictions += 1

    # -- durability (unified snapshot surface, DESIGN.md §11) ---------------
    def snapshot(self, path) -> None:
        """Persist the cache through the store's own snapshot machinery:
        the refcount table goes through ``FlashStore.snapshot()`` (no
        parallel save path), the host page table + pool values +
        hit/miss counters ride in a pickle sidecar next to it."""
        import pickle
        from pathlib import Path
        path = Path(path)
        self._refs.snapshot(path / "refs")
        blocks = [(b.key, b.tokens, self.pool.get(b.bid))
                  for b in self.store.values()]
        blob = pickle.dumps({"blocks": blocks, "hits": self.hits,
                             "misses": self.misses,
                             "evictions": self.evictions,
                             "block_tokens": self.block_tokens})
        tmp = path / "cache.pkl.tmp"
        tmp.write_bytes(blob)
        tmp.rename(path / "cache.pkl")   # atomic publish

    def restore(self, path) -> None:
        """Counterpart of :meth:`snapshot`; the refcount store replays
        its WAL tail (if one is attached) via ``FlashStore.restore``."""
        import pickle
        from pathlib import Path
        path = Path(path)
        self._refs.restore(path / "refs")
        side = pickle.loads((path / "cache.pkl").read_bytes())
        self.pool = BlockPool(self.capacity)
        self.store = {}
        for key, tokens, value in side["blocks"]:
            self._put(key, tokens, value)
        self.hits = side["hits"]
        self.misses = side["misses"]
        self.evictions = side["evictions"]

    def stats(self) -> dict:
        s = self._refs.stats()
        out = {"hits": self.hits, "misses": self.misses,
               "evictions": self.evictions, "resident": len(self.store),
               "scheme": self.cfg.scheme,
               "eviction": self.eviction,
               "backend": self.backend,
               # device backends ledger tile_stores (the paper's cleans
               # analogue); the sim's counterpart is its `cleans` counter
               "tile_stores": s.get("tile_stores", s.get("cleans", 0)),
               "dropped": s.get("dropped", 0),
               "carried": s.get("carried", 0),
               "query_batches": s.get("query_batches",
                                      s.get("queries", 0)),
               "query_cache_hits": s.get("query_cache_hits", 0),
               "query_device_keys": s.get("query_device_queries", 0),
               "write_buffered": s["write_buffered"],
               "write_cancelled": s["write_cancelled"],
               "write_flushes": s["write_flushes"],
               "write_dispatches": s["write_dispatches"]}
        out.update(self.pool.stats())
        return out
