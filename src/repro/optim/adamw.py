"""AdamW with dtype-configurable moments (memory planning at 340B+ scale:
bf16 first moment + fp32 second moment = 9 bytes/param instead of 12).

Functional: ``state = adamw_init(cfg, params)``, ``new_params, new_state =
adamw_update(cfg, grads, state, params, lr)``. Global-norm clipping is done
in fp32 over the whole tree.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    m_dtype: str = "float32"   # "bfloat16" to halve first-moment memory
    v_dtype: str = "float32"


class AdamWState(NamedTuple):
    m: Any
    v: Any
    count: jax.Array


def adamw_init(cfg: AdamWConfig, params) -> AdamWState:
    m = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.dtype(cfg.m_dtype)),
                     params)
    v = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.dtype(cfg.v_dtype)),
                     params)
    return AdamWState(m=m, v=v, count=jnp.zeros((), jnp.int32))


def global_norm(tree) -> jax.Array:
    sq = jax.tree.reduce(
        lambda a, x: a + jnp.sum(jnp.square(x.astype(jnp.float32))),
        tree, jnp.float32(0.0))
    return jnp.sqrt(sq)


def adamw_update(cfg: AdamWConfig, grads, state: AdamWState, params, lr):
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    count = state.count + 1
    c1 = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    c2 = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m32 = m.astype(jnp.float32) * cfg.b1 + g * (1.0 - cfg.b1)
        v32 = v.astype(jnp.float32) * cfg.b2 + jnp.square(g) * (1.0 - cfg.b2)
        step = (m32 / c1) / (jnp.sqrt(v32 / c2) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * step).astype(p.dtype)
        return new_p, m32.astype(m.dtype), v32.astype(v.dtype)

    flat = jax.tree.map(upd, grads, state.m, state.v, params)
    new_params = jax.tree.map(lambda t: t[0], flat,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], flat,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], flat,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_params, AdamWState(m=new_m, v=new_v, count=count), gnorm
