"""int8 error-feedback gradient compression for cross-pod (DCN) sync.

At 2 pods the gradient all-reduce over the ``pod`` axis crosses the
data-center network; int8 quantization with per-leaf scales cuts those
bytes 2× vs bf16 (4× vs fp32) at the cost of quantization noise, which the
error-feedback accumulator re-injects next step (1-bit-Adam lineage —
Seide et al. 2014; arXiv:2102.02888).

Used inside shard-mapped train steps: ``compressed_psum(g, axis, err)``.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class CompressionState(NamedTuple):
    error: Any  # pytree matching grads (fp32 residuals)


def compress_init(params) -> CompressionState:
    return CompressionState(
        error=jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params))


def _quantize(x):
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def compressed_psum(grads, axis: str, state: CompressionState):
    """All-reduce ``grads`` over ``axis`` in int8 with error feedback.

    Returns (mean-reduced fp32 grads, new state). Scales are psum-maxed so
    all shards dequantize identically.
    """
    def one(g, err):
        g32 = g.astype(jnp.float32) + err
        scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
        scale = jax.lax.pmax(scale, axis)          # shared scale
        q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
        new_err = g32 - q.astype(jnp.float32) * scale
        total = jax.lax.psum(q.astype(jnp.int32), axis)
        n = jax.lax.psum(jnp.ones((), jnp.int32), axis)
        mean = total.astype(jnp.float32) * scale / n.astype(jnp.float32)
        return mean, new_err

    out = jax.tree.map(one, grads, state.error)
    mean = jax.tree.map(lambda t: t[0], out,
                        is_leaf=lambda t: isinstance(t, tuple))
    err = jax.tree.map(lambda t: t[1], out,
                       is_leaf=lambda t: isinstance(t, tuple))
    return mean, CompressionState(error=err)
