from .fault_tolerance import (NaNGuard, ResilientTrainer,  # noqa: F401
                              StepWatchdog)
from .elastic import plan_mesh_shape, remesh_shardings  # noqa: F401
