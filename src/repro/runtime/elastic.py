"""Elastic scaling: replan the mesh when the device pool changes.

Losing a node shrinks the pool; ``plan_mesh_shape`` picks the largest
(data, model) grid that (a) fits the pool, (b) keeps the model axis at the
arch's required TP width, and (c) keeps the global batch divisible.
``remesh_shardings`` rebuilds NamedShardings on the new mesh; checkpoint
restore against them is the actual reshard (checkpoint/).
"""
from __future__ import annotations

from typing import Tuple

import jax
from jax.sharding import Mesh, NamedSharding



def plan_mesh_shape(available_devices: int, model_width: int,
                    global_batch: int) -> Tuple[int, int]:
    """→ (data, model) using as many devices as possible."""
    if available_devices < model_width:
        raise ValueError(
            f"need ≥{model_width} devices for TP, have {available_devices}")
    data = available_devices // model_width
    # keep batch divisible (drop to the nearest divisor)
    while data > 1 and global_batch % data != 0:
        data -= 1
    return data, model_width


def remesh_shardings(old_shardings, new_mesh: Mesh):
    """Same PartitionSpecs, new mesh."""
    return jax.tree.map(
        lambda s: NamedSharding(new_mesh, s.spec),
        old_shardings,
        is_leaf=lambda s: isinstance(s, NamedSharding))


def handoff_hr_partitions(wal_path, survivor, shards=None,
                          base_seq: int = 0) -> Tuple[int, int]:
    """Re-own a departing store's sealed H_R partitions via its WAL.

    When a node leaves, its store's *sealed-but-undrained* chunks are
    exactly the records in its write-ahead log after the last snapshot
    (``base_seq``; see ``FlashStore.snapshot``). Replaying them into a
    ``survivor`` store re-owns the deltas: the survivor's own owner
    routing re-partitions every entry against the surviving mesh, so no
    partition math is needed here. ``shards`` optionally filters to the
    departing node's WAL partitions (the chunk-granular log records the
    H_R partition per seal precisely to make this filter possible);
    ``None`` takes everything — the safe default when the whole store
    moved.

    Returns ``(records_replayed, entries_replayed)``. The survivor's own
    WAL (if any) logs the re-owned chunks as fresh seals — they are new
    writes from its point of view."""
    from ..core.wal import SEAL, read_wal
    records, _ = read_wal(wal_path)
    keep = None if shards is None else set(shards)
    n_rec = n_ent = 0
    for r in sorted((r for r in records if r.kind == SEAL
                     and r.seq > base_seq
                     and (keep is None or r.part in keep)),
                    key=lambda r: r.seq):
        survivor.update(r.keys, r.deltas)
        n_rec += 1
        n_ent += int(r.keys.size)
    return n_rec, n_ent
