"""Elastic scaling: replan the mesh when the device pool changes.

Losing a node shrinks the pool; ``plan_mesh_shape`` picks the largest
(data, model) grid that (a) fits the pool, (b) keeps the model axis at the
arch's required TP width, and (c) keeps the global batch divisible.
``remesh_shardings`` rebuilds NamedShardings on the new mesh; checkpoint
restore against them is the actual reshard (checkpoint/).
"""
from __future__ import annotations

from typing import Tuple

import jax
from jax.sharding import Mesh, NamedSharding



def plan_mesh_shape(available_devices: int, model_width: int,
                    global_batch: int) -> Tuple[int, int]:
    """→ (data, model) using as many devices as possible."""
    if available_devices < model_width:
        raise ValueError(
            f"need ≥{model_width} devices for TP, have {available_devices}")
    data = available_devices // model_width
    # keep batch divisible (drop to the nearest divisor)
    while data > 1 and global_batch % data != 0:
        data -= 1
    return data, model_width


def remesh_shardings(old_shardings, new_mesh: Mesh):
    """Same PartitionSpecs, new mesh."""
    return jax.tree.map(
        lambda s: NamedSharding(new_mesh, s.spec),
        old_shardings,
        is_leaf=lambda s: isinstance(s, NamedSharding))


def _survivor_processes(survivor) -> Tuple[int, int]:
    """(process_index, num_processes) of the surviving mesh.

    Derived from the survivor store itself (its backend recorded the
    process topology when it built the mesh), *not* from the departing
    WAL's partition count — ``len(partitions)`` says how the departed
    store split its H_R, which is unrelated to how many processes now
    share the replay (ISSUE 10)."""
    b = getattr(survivor, "_b", survivor)
    return (int(getattr(b, "process_index", 0)),
            int(getattr(b, "num_processes", 1)))


def handoff_hr_partitions(wal_path, survivor, shards=None,
                          base_seq: int = 0) -> Tuple[int, int]:
    """Re-own a departing store's sealed H_R partitions via its WAL.

    When a node leaves, its store's *sealed-but-undrained* chunks are
    exactly the records in its write-ahead log after the last snapshot
    (``base_seq``; see ``FlashStore.snapshot``). Replaying them into a
    ``survivor`` store re-owns the deltas: the survivor's own owner
    routing re-partitions every entry against the surviving mesh, so no
    partition math is needed here. ``shards`` optionally filters to the
    departing node's WAL partitions (the chunk-granular log records the
    H_R partition per seal precisely to make this filter possible);
    ``None`` takes everything — the safe default when the whole store
    moved.

    **Process-count aware** (ISSUE 10): when the survivor spans multiple
    processes, every surviving process calls this with the same departing
    WAL, and each replays a disjoint round-robin-by-``seq`` slice of the
    records — the survivor set comes from the *mesh* (the store's
    recorded process topology), so each sealed chunk folds into exactly
    one host's H_R and the next collective drain routes it to its owner.
    Replaying everything on every process would double-apply.

    Returns ``(records_replayed, entries_replayed)`` for *this* process.
    The survivor's own WAL (if any) logs the re-owned chunks as fresh
    seals — they are new writes from its point of view."""
    from ..core.wal import SEAL, read_wal
    records, _ = read_wal(wal_path)
    keep = None if shards is None else set(shards)
    me, n_procs = _survivor_processes(survivor)
    n_rec = n_ent = 0
    for i, r in enumerate(sorted(
            (r for r in records if r.kind == SEAL
             and r.seq > base_seq
             and (keep is None or r.part in keep)),
            key=lambda r: r.seq)):
        if i % n_procs != me:
            continue
        survivor.update(r.keys, r.deltas)
        n_rec += 1
        n_ent += int(r.keys.size)
    return n_rec, n_ent
