"""Elastic scaling: replan the mesh when the device pool changes.

Losing a node shrinks the pool; ``plan_mesh_shape`` picks the largest
(data, model) grid that (a) fits the pool, (b) keeps the model axis at the
arch's required TP width, and (c) keeps the global batch divisible.
``remesh_shardings`` rebuilds NamedShardings on the new mesh; checkpoint
restore against them is the actual reshard (checkpoint/).
"""
from __future__ import annotations

from typing import Tuple

import jax
from jax.sharding import Mesh, NamedSharding



def plan_mesh_shape(available_devices: int, model_width: int,
                    global_batch: int) -> Tuple[int, int]:
    """→ (data, model) using as many devices as possible."""
    if available_devices < model_width:
        raise ValueError(
            f"need ≥{model_width} devices for TP, have {available_devices}")
    data = available_devices // model_width
    # keep batch divisible (drop to the nearest divisor)
    while data > 1 and global_batch % data != 0:
        data -= 1
    return data, model_width


def remesh_shardings(old_shardings, new_mesh: Mesh):
    """Same PartitionSpecs, new mesh."""
    return jax.tree.map(
        lambda s: NamedSharding(new_mesh, s.spec),
        old_shardings,
        is_leaf=lambda s: isinstance(s, NamedSharding))
