"""Fault-tolerant training runtime.

Production failure modes covered:

* **Node loss / crash** — periodic async checkpoints + atomic publish
  (checkpoint/); ``ResilientTrainer.run`` restarts from the latest
  checkpoint and the stateless loader resumes from the step number.
* **Loss spikes / NaN** — :class:`NaNGuard` detects non-finite or spiking
  loss, rolls back to the last checkpoint and *skips* the offending data
  window (deterministic loader makes the skip reproducible).
* **Stragglers** — :class:`StepWatchdog` times each step against a rolling
  median; slow steps raise an alert callback (on a real cluster this feeds
  the scheduler's hot-spare replacement; here it is surfaced + logged).
* **Pre-emption** — ``emergency()`` checkpoint on any exception path.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable, List, Optional

from ..checkpoint import CheckpointManager, latest_step, restore_checkpoint


class StepWatchdog:
    """Detects straggling steps: wall-time > factor × rolling median."""

    def __init__(self, factor: float = 3.0, window: int = 32,
                 min_samples: int = 5,
                 on_straggler: Optional[Callable[[int, float, float],
                                                 None]] = None):
        self.factor = factor
        self.window = window
        self.min_samples = min_samples
        self.on_straggler = on_straggler
        self.times: List[float] = []
        self.stragglers: List[int] = []

    def observe(self, step: int, seconds: float) -> bool:
        hist = sorted(self.times[-self.window:])
        is_slow = False
        if len(hist) >= self.min_samples:
            median = hist[len(hist) // 2]
            if seconds > self.factor * median:
                is_slow = True
                self.stragglers.append(step)
                if self.on_straggler:
                    self.on_straggler(step, seconds, median)
        self.times.append(seconds)
        return is_slow


class NaNGuard:
    """Rolls back on non-finite or spiking loss."""

    def __init__(self, spike_factor: float = 10.0, window: int = 16):
        self.spike_factor = spike_factor
        self.window = window
        self.history: List[float] = []
        self.rollbacks = 0

    def check(self, loss: float) -> bool:
        """True = healthy; False = roll back."""
        if not math.isfinite(loss):
            self.rollbacks += 1
            return False
        hist = self.history[-self.window:]
        if len(hist) >= self.window // 2:
            mean = sum(hist) / len(hist)
            if loss > self.spike_factor * max(mean, 1e-6):
                self.rollbacks += 1
                return False
        self.history.append(loss)
        return True


@dataclasses.dataclass
class TrainerReport:
    steps_done: int = 0
    restarts: int = 0
    rollbacks: int = 0
    stragglers: int = 0
    final_loss: float = float("nan")


class ResilientTrainer:
    """Checkpointed, NaN-guarded, watchdogged train loop.

    ``step_fn(state, step) -> (state, metrics)`` where metrics["loss"] is a
    float-able scalar. ``state`` is any pytree (params+opt).
    """

    def __init__(self, step_fn, ckpt: CheckpointManager,
                 guard: Optional[NaNGuard] = None,
                 watchdog: Optional[StepWatchdog] = None,
                 inject_failure_at: Optional[int] = None,
                 stores=()):
        self.step_fn = step_fn
        self.ckpt = ckpt
        self.guard = guard or NaNGuard()
        self.watchdog = watchdog or StepWatchdog()
        self.inject_failure_at = inject_failure_at  # for tests
        self._injected = False
        # side-table stores (corpus stats, prefix caches): their in-flight
        # drains are joined before every checkpoint — including the
        # emergency path — so a save never serializes alongside a store
        # state that a background drain is still donating
        self.stores = tuple(stores)
        for s in self.stores:
            ckpt.register_quiesce(s.quiesce)

    def run(self, state, num_steps: int, start_step: int = 0,
            shardings=None) -> tuple:
        report = TrainerReport()
        step = start_step
        while step < num_steps:
            try:
                if (self.inject_failure_at is not None
                        and step == self.inject_failure_at
                        and not self._injected):
                    self._injected = True
                    raise RuntimeError("injected node failure")
                t0 = time.time()
                state, metrics = self.step_fn(state, step)
                loss = float(metrics["loss"])
                if self.watchdog.observe(step, time.time() - t0):
                    report.stragglers += 1
                if not self.guard.check(loss):
                    # roll back to last checkpoint, skip this data window
                    restored = self._restore(state, shardings)
                    if restored is not None:
                        state, meta = restored
                    report.rollbacks += 1
                    step += 1  # skip the poisoned batch
                    continue
                report.final_loss = loss
                self.ckpt.maybe_save(step, state)
                step += 1
                report.steps_done += 1
            except KeyboardInterrupt:
                self.ckpt.emergency(step, state)
                raise
            except RuntimeError:
                # node failure: emergency-save is skipped (node is gone);
                # restart from the latest published checkpoint.
                report.restarts += 1
                restored = self._restore(state, shardings)
                if restored is None:
                    raise
                state, meta = restored
                step = int(meta["step"]) + 1
        self.ckpt.wait()
        return state, report

    def _restore(self, like_state, shardings):
        if latest_step(self.ckpt.dir) is None:
            return None
        return restore_checkpoint(self.ckpt.dir, like_state,
                                  shardings=shardings)
