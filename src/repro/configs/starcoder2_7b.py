"""starcoder2-7b [arXiv:2402.19173] — GQA, RoPE, GELU FFN.

32L d_model=4608 36H (GQA kv=4) d_ff=18432 vocab=49152.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b", family="dense",
    num_layers=32, d_model=4608, vocab_size=49152,
    num_heads=36, num_kv_heads=4, head_dim=128,
    d_ff=18432, ffn_act="gelu",
    layer_pattern=("attn",), ffn_pattern=("dense",),
)

TINY = ModelConfig(
    name="starcoder2-tiny", family="dense",
    num_layers=2, d_model=72, vocab_size=307,
    num_heads=6, num_kv_heads=2, head_dim=12,
    d_ff=288, ffn_act="gelu",
    layer_pattern=("attn",), ffn_pattern=("dense",),
)
