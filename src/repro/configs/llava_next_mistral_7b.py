"""llava-next-mistral-7b [hf:llava-hf/llava-v1.6-mistral-7b-hf].

Mistral-7B backbone: 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000. Vision frontend is a STUB per the brief: input_specs()
provides precomputed anyres patch embeddings (num_patches positions
prepended to the text stream).
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b", family="vlm",
    num_layers=32, d_model=4096, vocab_size=32000,
    num_heads=32, num_kv_heads=8, head_dim=128,
    d_ff=14336, ffn_act="swiglu",
    layer_pattern=("attn",), ffn_pattern=("dense",),
    frontend="vision_stub", num_patches=576,
)

TINY = ModelConfig(
    name="llava-next-tiny", family="vlm",
    num_layers=2, d_model=64, vocab_size=257,
    num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=160, ffn_act="swiglu",
    layer_pattern=("attn",), ffn_pattern=("dense",),
    frontend="vision_stub", num_patches=16,
)
