"""phi3.5-moe-42b-a6.6b [hf:microsoft/Phi-3.5-MoE-instruct].

32L d_model=4096 32H (GQA kv=8) d_ff=6400/expert, MoE 16 experts top-2,
vocab 32064. SwiGLU experts, RoPE.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b", family="moe",
    num_layers=32, d_model=4096, vocab_size=32064,
    num_heads=32, num_kv_heads=8, head_dim=128,
    d_ff=6400, ffn_act="swiglu",
    num_experts=16, experts_per_token=2,
    layer_pattern=("attn",), ffn_pattern=("moe",),
)

TINY = ModelConfig(
    name="phi3.5-moe-tiny", family="moe",
    num_layers=2, d_model=64, vocab_size=499,
    num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=96, ffn_act="swiglu",
    num_experts=4, experts_per_token=2,
    layer_pattern=("attn",), ffn_pattern=("moe",),
)
