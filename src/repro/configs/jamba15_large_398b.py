"""jamba-1.5-large-398b [arXiv:2403.19887] — Mamba+attention 1:7, MoE 16e top-2.

72L d_model=8192; attention layers: 64H (GQA kv=8) head_dim=128; d_ff=24576;
vocab=65536. Layer group of 8 = [attn, ssm×7]; MoE every other layer
(4 of 8 slots). Mamba: d_inner=16384, d_state=128, headdim=64.
Sub-quadratic (1:7 attention) → runs long_500k.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    num_layers=72, d_model=8192, vocab_size=65536,
    num_heads=64, num_kv_heads=8, head_dim=128,
    d_ff=24576, ffn_act="swiglu",
    num_experts=16, experts_per_token=2,
    ssm_state=128, ssm_expand=2, ssm_headdim=64, ssm_chunk=256,
    layer_pattern=("attn", "ssm", "ssm", "ssm", "ssm", "ssm", "ssm", "ssm"),
    ffn_pattern=("dense", "moe", "dense", "moe", "dense", "moe", "dense",
                 "moe"),
    subquadratic=True,
)

TINY = ModelConfig(
    name="jamba-tiny", family="hybrid",
    num_layers=8, d_model=64, vocab_size=401,
    num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, ffn_act="swiglu",
    num_experts=4, experts_per_token=2,
    ssm_state=16, ssm_expand=2, ssm_headdim=16, ssm_chunk=32,
    layer_pattern=("attn", "ssm", "ssm", "ssm", "ssm", "ssm", "ssm", "ssm"),
    ffn_pattern=("dense", "moe", "dense", "moe", "dense", "moe", "dense",
                 "moe"),
    subquadratic=True,
)
