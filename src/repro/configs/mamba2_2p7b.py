"""mamba2-2.7b [arXiv:2405.21060] — SSD (state-space duality), attn-free.

64L d_model=2560, d_inner=5120 (expand 2), d_state=128, headdim=64
(→ 80 SSM heads), vocab=50280. Sub-quadratic → runs long_500k.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b", family="ssm",
    num_layers=64, d_model=2560, vocab_size=50280,
    ssm_state=128, ssm_expand=2, ssm_headdim=64, ssm_chunk=256,
    layer_pattern=("ssm",), ffn_pattern=("none",),
    tie_embeddings=True, subquadratic=True,
)

TINY = ModelConfig(
    name="mamba2-tiny", family="ssm",
    num_layers=2, d_model=64, vocab_size=379,
    ssm_state=16, ssm_expand=2, ssm_headdim=16, ssm_chunk=32,
    layer_pattern=("ssm",), ffn_pattern=("none",),
    tie_embeddings=True, subquadratic=True,
)
