"""musicgen-large [arXiv:2306.05284] — decoder-only over EnCodec tokens.

48L d_model=2048 32H (MHA, kv=32) d_ff=8192 vocab=2048 (codebook size).
The EnCodec frontend is a STUB per the brief: input_specs() provides
precomputed codec-frame embeddings for the conditioning prefix; the
backbone is a plain decoder over audio tokens (GELU FFN, learned-abs-pos
replaced by RoPE — noted in DESIGN.md).
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large", family="audio",
    num_layers=48, d_model=2048, vocab_size=2048,
    num_heads=32, num_kv_heads=32, head_dim=64,
    d_ff=8192, ffn_act="gelu",
    layer_pattern=("attn",), ffn_pattern=("dense",),
    frontend="audio_stub", num_patches=128,
)

TINY = ModelConfig(
    name="musicgen-tiny", family="audio",
    num_layers=2, d_model=64, vocab_size=256,
    num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=160, ffn_act="gelu",
    layer_pattern=("attn",), ffn_pattern=("dense",),
    frontend="audio_stub", num_patches=8,
)
