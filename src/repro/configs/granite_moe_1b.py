"""granite-moe-1b-a400m [hf:ibm-granite/granite-3.0-1b-a400m-base].

24L d_model=1024 16H (GQA kv=8) d_ff=512/expert, MoE 32 experts top-8,
vocab 49155. SwiGLU experts, RoPE.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m", family="moe",
    num_layers=24, d_model=1024, vocab_size=49155,
    num_heads=16, num_kv_heads=8, head_dim=64,
    d_ff=512, ffn_act="swiglu",
    num_experts=32, experts_per_token=8,
    layer_pattern=("attn",), ffn_pattern=("moe",),
    tie_embeddings=True,
)

TINY = ModelConfig(
    name="granite-moe-1b-a400m-tiny", family="moe",
    num_layers=2, d_model=64, vocab_size=503,
    num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=32, ffn_act="swiglu",
    num_experts=8, experts_per_token=2,
    layer_pattern=("attn",), ffn_pattern=("moe",),
    tie_embeddings=True,
)
