"""Assigned-architecture registry: ``--arch <id>`` resolves here.

Each module defines ``CONFIG`` (the exact published config) and ``TINY``
(a reduced same-family config for CPU smoke tests).
"""
from __future__ import annotations

import importlib
from typing import Dict

from ..models.config import ModelConfig

ARCH_IDS = [
    "granite_moe_1b",
    "phi35_moe_42b",
    "minicpm3_4b",
    "starcoder2_7b",
    "llama32_3b",
    "nemotron4_340b",
    "llava_next_mistral_7b",
    "mamba2_2p7b",
    "musicgen_large",
    "jamba15_large_398b",
]

# external ids (from the assignment table) → module names
ALIASES = {
    "granite-moe-1b-a400m": "granite_moe_1b",
    "phi3.5-moe-42b-a6.6b": "phi35_moe_42b",
    "minicpm3-4b": "minicpm3_4b",
    "starcoder2-7b": "starcoder2_7b",
    "llama3.2-3b": "llama32_3b",
    "nemotron-4-340b": "nemotron4_340b",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "mamba2-2.7b": "mamba2_2p7b",
    "musicgen-large": "musicgen_large",
    "jamba-1.5-large-398b": "jamba15_large_398b",
}


def get_config(arch: str, tiny: bool = False) -> ModelConfig:
    mod_name = ALIASES.get(arch, arch)
    mod = importlib.import_module(f".{mod_name}", __package__)
    return mod.TINY if tiny else mod.CONFIG


def all_configs(tiny: bool = False) -> Dict[str, ModelConfig]:
    return {a: get_config(a, tiny) for a in ARCH_IDS}
