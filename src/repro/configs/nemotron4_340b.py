"""nemotron-4-340b [arXiv:2402.16819] — GQA, squared-ReLU FFN.

96L d_model=18432 96H (GQA kv=8) head_dim=192 d_ff=73728 vocab=256000.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b", family="dense",
    num_layers=96, d_model=18432, vocab_size=256000,
    num_heads=96, num_kv_heads=8, head_dim=192,
    d_ff=73728, ffn_act="squared_relu",
    layer_pattern=("attn",), ffn_pattern=("dense",),
)

TINY = ModelConfig(
    name="nemotron-tiny", family="dense",
    num_layers=2, d_model=96, vocab_size=512,
    num_heads=6, num_kv_heads=2, head_dim=16,
    d_ff=384, ffn_act="squared_relu",
    layer_pattern=("attn",), ffn_pattern=("dense",),
)
