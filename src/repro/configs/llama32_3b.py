"""llama3.2-3b [hf:meta-llama/Llama-3.2-3B] — small llama3.

28L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=128256, tied embeddings,
rope_theta=500000.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-3b", family="dense",
    num_layers=28, d_model=3072, vocab_size=128256,
    num_heads=24, num_kv_heads=8, head_dim=128,
    rope_theta=500_000.0,
    d_ff=8192, ffn_act="swiglu",
    layer_pattern=("attn",), ffn_pattern=("dense",),
    tie_embeddings=True,
)

TINY = ModelConfig(
    name="llama32-tiny", family="dense",
    num_layers=2, d_model=64, vocab_size=509,
    num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=160, ffn_act="swiglu",
    layer_pattern=("attn",), ffn_pattern=("dense",),
    tie_embeddings=True,
)
