"""minicpm3-4b [hf:openbmb/MiniCPM3-4B] — MLA (multi-head latent attention).

62L d_model=2560 40H d_ff=6400 vocab=73448. MLA dims from the HF config:
q_lora_rank=768, kv_lora_rank=256, qk_nope=64, qk_rope=32, v_head=64.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b", family="dense",
    num_layers=62, d_model=2560, vocab_size=73448,
    num_heads=40, num_kv_heads=40, head_dim=64,
    attn_type="mla",
    q_lora_rank=768, kv_lora_rank=256,
    qk_nope_dim=64, qk_rope_dim=32, v_head_dim=64,
    d_ff=6400, ffn_act="swiglu",
    layer_pattern=("attn",), ffn_pattern=("dense",),
)

TINY = ModelConfig(
    name="minicpm3-tiny", family="dense",
    num_layers=2, d_model=64, vocab_size=251,
    num_heads=4, num_kv_heads=4, head_dim=16,
    attn_type="mla",
    q_lora_rank=32, kv_lora_rank=16,
    qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16,
    d_ff=128, ffn_act="swiglu",
    layer_pattern=("attn",), ffn_pattern=("dense",),
)
