"""Corpus statistics service: the paper's counting hash table as the data
layer's streaming statistics engine.

``CorpusStats`` ingests token batches into a flash-hash device table
(MDB-L policy by default — the paper's recommendation) and answers
frequency queries. Ingest rides the
:class:`~repro.core.write_engine.BatchedWriteEngine` (host H_R dedup,
threshold-triggered donated flushes — DESIGN.md §7), which also drives
the paired query engine's invalidation, so reads between ingests are
never stale. On top of it:

* ``tfidf_weights`` — per-token IDF weights for corpus filtering/weighting,
* ``doc_filter`` — the paper's TF-IDF keyword criterion as a document
  filter for the pretraining loader,
* ``expert_stats`` — counting-table accumulation of MoE expert-load
  histograms (counting semantics across steps; DESIGN.md §5).
"""
from __future__ import annotations

from typing import Dict, Optional

import numpy as np

from ..core import table_jax as tj
from ..core.query_engine import BatchedQueryEngine
from ..core.write_engine import BatchedWriteEngine


class CorpusStats:
    def __init__(self, cfg: tj.FlashTableConfig,
                 state: Optional[tj.DeviceTableState] = None,
                 docs_seen: int = 0, tokens_seen: int = 0,
                 engine: Optional[BatchedQueryEngine] = None,
                 writer: Optional[BatchedWriteEngine] = None):
        self.cfg = cfg
        self.docs_seen = docs_seen
        self.tokens_seen = tokens_seen
        self.engine = engine if engine is not None else BatchedQueryEngine(
            cfg, chunk=1024)
        # the write engine owns the device state; a hand-built state
        # (tests/restores) is adopted as its starting point
        self.writer = writer if writer is not None else BatchedWriteEngine(
            cfg, state=state, query_engine=self.engine)

    @classmethod
    def create(cls, q_log2: int = 18, r_log2: int = 10,
               scheme: str = "MDB-L", **table_kw) -> "CorpusStats":
        """Any device scheme (MB / MDB / MDB-L) backs the stats engine;
        ``table_kw`` forwards change-segment knobs (``log_capacity``,
        ``cs_partitions``, ...) to :class:`tj.FlashTableConfig`."""
        cfg = tj.FlashTableConfig(q_log2=q_log2, r_log2=r_log2,
                                  scheme=scheme, **table_kw)
        return cls(cfg=cfg)

    @property
    def state(self) -> tj.DeviceTableState:
        """Current device table state (owned by the write engine)."""
        return self.writer.state

    def wear(self) -> Dict[str, int]:
        """Device wear/traffic counters (``tile_stores`` = paper cleans);
        includes ``dropped``/``carried`` so capacity losses are visible."""
        s = self.writer.state.stats
        return {f: int(getattr(s, f)) for f in s._fields}

    def query_stats(self) -> Dict[str, int]:
        """Batch-aggregated read-path counters (dedup ratio, cache hits,
        probe-distance totals) from the query engine."""
        return self.engine.stats.as_dict()

    def write_stats(self) -> Dict[str, int]:
        """H_R write-path counters (buffered/deduped/dispatched entries,
        flush counts) from the write engine."""
        return self.writer.stats.as_dict()

    # -- ingestion ----------------------------------------------------------
    def ingest(self, tokens: np.ndarray) -> None:
        """Add one batch/document of token ids (host array): buffered in
        H_R, dispatched to the device at the flush threshold."""
        t = np.asarray(tokens).reshape(-1)
        self.writer.update(t)
        self.docs_seen += 1
        self.tokens_seen += int(t.size)

    def flush(self) -> None:
        """Drain H_R and force the device merge (checkpoint boundary)."""
        self.writer.merge()

    # -- queries ------------------------------------------------------------
    def counts(self, tokens: np.ndarray) -> np.ndarray:
        """Batched frequency lookup: deduped, fixed-shape chunks, served
        through the hot-key cache between ingests (DESIGN.md §6), with
        the buffered H_R deltas overlaid (DESIGN.md §7)."""
        q = np.asarray(tokens).reshape(-1)
        return self.writer.query_batch(q)

    def tfidf_weights(self, tokens: np.ndarray) -> np.ndarray:
        """IDF-style weights: log(total / freq) per queried token."""
        c = np.maximum(self.counts(tokens), 1)
        return np.log(max(self.tokens_seen, 1) / c)

    def doc_score(self, doc_tokens: np.ndarray) -> float:
        """Mean TF-IDF of the document against corpus stats (paper §1:
        keyword threshold → here a doc-quality score)."""
        toks, tf = np.unique(np.asarray(doc_tokens), return_counts=True)
        idf = self.tfidf_weights(toks)
        return float((tf / max(len(doc_tokens), 1) * idf).sum())

    def doc_filter(self, threshold: float):
        """Loader-pluggable filter: keep docs above the TF-IDF score."""
        def keep(doc_tokens: np.ndarray) -> bool:
            return self.doc_score(doc_tokens) >= threshold
        return keep

    # -- MoE accounting -------------------------------------------------------
    def ingest_expert_counts(self, layer: int, counts: np.ndarray) -> None:
        """Accumulate per-expert token counts into the same table (keys are
        (layer, expert) pairs — counting semantics, deletion-capable)."""
        e = counts.shape[0]
        keys = (np.arange(e, dtype=np.int64) | (np.int64(layer) << 16))
        self.writer.update(keys, np.asarray(counts, np.int64))

    def expert_counts(self, layer: int, num_experts: int) -> np.ndarray:
        keys = (np.arange(num_experts, dtype=np.int64)
                | (np.int64(layer) << 16))
        return self.counts(keys)
