"""Corpus statistics service: the paper's counting hash table as the data
layer's streaming statistics engine.

``CorpusStats`` ingests token batches into a flash-hash device table
(MDB-L policy by default — the paper's recommendation) and answers
frequency queries. On top of it:

* ``tfidf_weights`` — per-token IDF weights for corpus filtering/weighting,
* ``doc_filter`` — the paper's TF-IDF keyword criterion as a document
  filter for the pretraining loader,
* ``expert_stats`` — counting-table accumulation of MoE expert-load
  histograms (counting semantics across steps; DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax.numpy as jnp
import numpy as np

from ..core import table_jax as tj
from ..core.query_engine import BatchedQueryEngine


@dataclasses.dataclass
class CorpusStats:
    cfg: tj.FlashTableConfig
    state: tj.DeviceTableState
    docs_seen: int = 0
    tokens_seen: int = 0
    engine: Optional[BatchedQueryEngine] = None

    @classmethod
    def create(cls, q_log2: int = 18, r_log2: int = 10,
               scheme: str = "MDB-L", **table_kw) -> "CorpusStats":
        """Any device scheme (MB / MDB / MDB-L) backs the stats engine;
        ``table_kw`` forwards change-segment knobs (``log_capacity``,
        ``cs_partitions``, ...) to :class:`tj.FlashTableConfig`."""
        cfg = tj.FlashTableConfig(q_log2=q_log2, r_log2=r_log2,
                                  scheme=scheme, **table_kw)
        return cls(cfg=cfg, state=tj.init(cfg),
                   engine=BatchedQueryEngine(cfg, chunk=1024))

    def wear(self) -> Dict[str, int]:
        """Device wear/traffic counters (``tile_stores`` = paper cleans);
        includes ``dropped``/``carried`` so capacity losses are visible."""
        s = self.state.stats
        return {f: int(getattr(s, f)) for f in s._fields}

    def query_stats(self) -> Dict[str, int]:
        """Batch-aggregated read-path counters (dedup ratio, cache hits,
        probe-distance totals) from the query engine."""
        return self.engine.stats.as_dict() if self.engine else {}

    def _invalidate(self) -> None:
        if self.engine is not None:
            self.engine.invalidate()

    # -- ingestion ----------------------------------------------------------
    def ingest(self, tokens: np.ndarray) -> None:
        """Add one batch/document of token ids (host array)."""
        t = jnp.asarray(np.asarray(tokens).reshape(-1), jnp.int32)
        self.state = tj.update(self.cfg, self.state, t)
        self.docs_seen += 1
        self.tokens_seen += int(t.shape[0])
        self._invalidate()

    def flush(self) -> None:
        self.state = tj.flush(self.cfg, self.state)
        self._invalidate()

    # -- queries ------------------------------------------------------------
    def counts(self, tokens: np.ndarray) -> np.ndarray:
        """Batched frequency lookup: deduped, fixed-shape chunks, served
        through the hot-key cache between ingests (DESIGN.md §6)."""
        q = np.asarray(tokens).reshape(-1)
        if self.engine is None:  # states built by hand (tests/restores)
            self.engine = BatchedQueryEngine(self.cfg, chunk=1024)
        return self.engine.query_batch(self.state, q)

    def tfidf_weights(self, tokens: np.ndarray) -> np.ndarray:
        """IDF-style weights: log(total / freq) per queried token."""
        c = np.maximum(self.counts(tokens), 1)
        return np.log(max(self.tokens_seen, 1) / c)

    def doc_score(self, doc_tokens: np.ndarray) -> float:
        """Mean TF-IDF of the document against corpus stats (paper §1:
        keyword threshold → here a doc-quality score)."""
        toks, tf = np.unique(np.asarray(doc_tokens), return_counts=True)
        idf = self.tfidf_weights(toks)
        return float((tf / max(len(doc_tokens), 1) * idf).sum())

    def doc_filter(self, threshold: float):
        """Loader-pluggable filter: keep docs above the TF-IDF score."""
        def keep(doc_tokens: np.ndarray) -> bool:
            return self.doc_score(doc_tokens) >= threshold
        return keep

    # -- MoE accounting -------------------------------------------------------
    def ingest_expert_counts(self, layer: int, counts: np.ndarray) -> None:
        """Accumulate per-expert token counts into the same table (keys are
        (layer, expert) pairs — counting semantics, deletion-capable)."""
        e = counts.shape[0]
        keys = (np.arange(e, dtype=np.int64) | (np.int64(layer) << 16))
        reps = jnp.asarray(keys, jnp.int32)
        deltas = jnp.asarray(counts, jnp.int32)
        self.state = tj.update(self.cfg, self.state, reps, deltas)
        self._invalidate()

    def expert_counts(self, layer: int, num_experts: int) -> np.ndarray:
        keys = (np.arange(num_experts, dtype=np.int64)
                | (np.int64(layer) << 16))
        return self.counts(keys)
