"""Corpus statistics service: the paper's counting hash table as the data
layer's streaming statistics engine.

``CorpusStats`` ingests token batches into a flash-hash table (MDB-L
policy by default — the paper's recommendation) and answers frequency
queries. Since PR 4 the table is a
:class:`~repro.core.store.FlashStore` (DESIGN.md §8): the store owns the
H_R buffering, threshold-triggered donated flushes and the
flush → invalidate contract, so reads between ingests are never stale —
and ``backend="sharded"`` scales the same service across every local
device with zero caller changes. On top of it:

* ``tfidf_weights`` — per-token IDF weights for corpus filtering/weighting,
* ``doc_filter`` — the paper's TF-IDF keyword criterion as a document
  filter for the pretraining loader,
* ``expert_stats`` — counting-table accumulation of MoE expert-load
  histograms (counting semantics across steps; DESIGN.md §5).
"""
from __future__ import annotations

from typing import Dict, Optional

import numpy as np

from ..core import table_jax as tj
from ..core.store import FlashStore


class CorpusStats:
    def __init__(self, cfg: tj.FlashTableConfig,
                 state: Optional[tj.DeviceTableState] = None,
                 docs_seen: int = 0, tokens_seen: int = 0,
                 backend: str = "device", wal=None):
        self.cfg = cfg
        self.docs_seen = docs_seen
        self.tokens_seen = tokens_seen
        if backend == "sharded" and state is not None:
            raise ValueError("sharded backend cannot adopt a single-table "
                             "state")
        kw = {"state": state} if backend == "device" else {}
        self.store = FlashStore.open(cfg, backend=backend, wal=wal, **kw)

    @classmethod
    def create(cls, q_log2: int = 18, r_log2: int = 10,
               scheme: str = "MDB-L", backend: str = "device",
               **table_kw) -> "CorpusStats":
        """Any device scheme (MB / MDB / MDB-L) backs the stats engine;
        ``table_kw`` forwards change-segment knobs (``log_capacity``,
        ``cs_partitions``, ...) to :class:`tj.FlashTableConfig`."""
        cfg = tj.FlashTableConfig(q_log2=q_log2, r_log2=r_log2,
                                  scheme=scheme, **table_kw)
        return cls(cfg=cfg, backend=backend)

    @property
    def state(self) -> tj.DeviceTableState:
        """Current device table state (owned by the store)."""
        return self.store.state

    def wear(self) -> Dict[str, int]:
        """Device wear/traffic counters (``tile_stores`` = paper cleans);
        includes ``dropped``/``carried`` so capacity losses are visible."""
        return self.store.wear()

    def query_stats(self) -> Dict[str, int]:
        """Batch-aggregated read-path counters (dedup ratio, cache hits,
        probe-distance totals) from the store's query path."""
        return {k[len("query_"):]: v for k, v in self.store.stats().items()
                if k.startswith("query_")}

    def write_stats(self) -> Dict[str, int]:
        """H_R write-path counters (buffered/deduped/dispatched entries,
        flush counts) from the store's write path."""
        return {k[len("write_"):]: v for k, v in self.store.stats().items()
                if k.startswith("write_")}

    # -- ingestion ----------------------------------------------------------
    def ingest(self, tokens: np.ndarray) -> None:
        """Add one batch/document of token ids (host array): buffered in
        H_R, dispatched to the device at the flush threshold."""
        t = np.asarray(tokens).reshape(-1)
        self.store.update(t)
        self.docs_seen += 1
        self.tokens_seen += int(t.size)

    def flush(self) -> None:
        """Drain H_R and force the device merge (checkpoint boundary)."""
        self.store.flush()

    # -- durability (unified snapshot surface, DESIGN.md §11) ---------------
    def snapshot(self, path) -> None:
        """Persist through the store's own snapshot machinery (no
        parallel save path): the ``docs_seen``/``tokens_seen`` counters
        ride in the snapshot's ``meta.json``."""
        self.store.snapshot(path, extra_meta={
            "docs_seen": self.docs_seen, "tokens_seen": self.tokens_seen})

    def restore(self, path=None):
        """Counterpart of :meth:`snapshot`: restores the table (and
        replays any WAL tail), then the counters from the snapshot meta.
        Returns the store's ``RestoreReport``."""
        rep = self.store.restore(path)
        self.docs_seen = int(rep.meta.get("docs_seen", 0))
        self.tokens_seen = int(rep.meta.get("tokens_seen", 0))
        return rep

    # -- queries ------------------------------------------------------------
    def counts(self, tokens: np.ndarray) -> np.ndarray:
        """Batched frequency lookup: deduped, fixed-shape chunks, served
        through the hot-key cache between ingests (DESIGN.md §6), with
        the buffered H_R deltas overlaid (DESIGN.md §7)."""
        q = np.asarray(tokens).reshape(-1)
        return self.store.query_batch(q)

    def tfidf_weights(self, tokens: np.ndarray) -> np.ndarray:
        """IDF-style weights: log(total / freq) per queried token."""
        c = np.maximum(self.counts(tokens), 1)
        return np.log(max(self.tokens_seen, 1) / c)

    def doc_score(self, doc_tokens: np.ndarray) -> float:
        """Mean TF-IDF of the document against corpus stats (paper §1:
        keyword threshold → here a doc-quality score)."""
        toks, tf = np.unique(np.asarray(doc_tokens), return_counts=True)
        idf = self.tfidf_weights(toks)
        return float((tf / max(len(doc_tokens), 1) * idf).sum())

    def doc_filter(self, threshold: float):
        """Loader-pluggable filter: keep docs above the TF-IDF score."""
        def keep(doc_tokens: np.ndarray) -> bool:
            return self.doc_score(doc_tokens) >= threshold
        return keep

    # -- MoE accounting -------------------------------------------------------
    def ingest_expert_counts(self, layer: int, counts: np.ndarray) -> None:
        """Accumulate per-expert token counts into the same table (keys are
        (layer, expert) pairs — counting semantics, deletion-capable)."""
        e = counts.shape[0]
        keys = (np.arange(e, dtype=np.int64) | (np.int64(layer) << 16))
        self.store.update(keys, np.asarray(counts, np.int64))

    def expert_counts(self, layer: int, num_experts: int) -> np.ndarray:
        keys = (np.arange(num_experts, dtype=np.int64)
                | (np.int64(layer) << 16))
        return self.counts(keys)
