"""Deterministic, resumable, shardable batch loader.

The loader is *stateless*: ``make_batch(cfg, step)`` materializes the exact
global batch for any step from ``(seed, step)`` alone, already in the
pre-microbatched layout train_step consumes. Resume-after-failure is
"set step and go" — no iterator state to checkpoint beyond the step number
(recorded in the checkpoint metadata). On a real cluster each host builds
only its slice (``host_slice``); here the full batch is built and
device_put against the batch shardings.

Documents are packed into fixed-length rows; labels are next-token targets
with cross-document positions masked (-1). Optionally, a TF-IDF document
filter (the paper's workload driving the framework's data layer) drops
low-information documents before packing.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

import numpy as np

from .corpus import SyntheticCorpus


@dataclasses.dataclass(frozen=True)
class LoaderConfig:
    corpus: SyntheticCorpus
    seq_len: int
    global_batch: int
    microbatches: int = 1
    vocab_size: int = 50_000        # model vocab; corpus ids are folded in
    num_patches: int = 0            # >0: emit frontend_embeds stub
    d_model: int = 0
    doc_filter: Optional[Callable[[np.ndarray], bool]] = None
    docs_per_row_hint: int = 16


def data_state(step: int) -> Dict[str, int]:
    """What a checkpoint needs to resume the pipeline exactly."""
    return {"step": int(step)}


def _pack_row(cfg: LoaderConfig, rng: np.random.Generator,
              row_id: int) -> np.ndarray:
    """Pack documents into one row of seq_len+1 tokens (for next-token
    shifting); -1 separators mask the loss across doc boundaries."""
    need = cfg.seq_len + 1
    out = np.full(need, -1, dtype=np.int64)
    pos = 0
    doc = rng.integers(0, cfg.corpus.num_docs)
    tries = 0
    while pos < need and tries < 4 * cfg.docs_per_row_hint:
        toks = cfg.corpus.doc_tokens(int(doc))
        tries += 1
        doc = (doc + 1) % cfg.corpus.num_docs
        if cfg.doc_filter is not None and not cfg.doc_filter(toks):
            continue
        take = min(len(toks), need - pos)
        out[pos:pos + take] = toks[:take] % cfg.vocab_size
        pos += take + 1  # leave one -1 separator
    return out


def make_batch(cfg: LoaderConfig, step: int) -> Dict[str, np.ndarray]:
    """Global batch for ``step``: tokens/labels (mb, B/mb, S)."""
    b, s, mb = cfg.global_batch, cfg.seq_len, cfg.microbatches
    rows = np.empty((b, s + 1), dtype=np.int64)
    for i in range(b):
        rng = np.random.default_rng(
            (cfg.corpus.seed << 40) ^ (step << 16) ^ i)
        rows[i] = _pack_row(cfg, rng, i)
    tokens = np.maximum(rows[:, :-1], 0).astype(np.int32)
    labels = rows[:, 1:].astype(np.int32)  # -1 positions are masked in loss
    out = {
        "tokens": tokens.reshape(mb, b // mb, s),
        "labels": labels.reshape(mb, b // mb, s),
    }
    if cfg.num_patches:
        rng = np.random.default_rng((cfg.corpus.seed << 40) ^ (step << 16)
                                    ^ 0xFEED)
        out["frontend_embeds"] = rng.standard_normal(
            (mb, b // mb, cfg.num_patches, cfg.d_model)).astype(np.float32)
    return out


def host_slice(batch: Dict[str, np.ndarray], host_id: int,
               num_hosts: int) -> Dict[str, np.ndarray]:
    """Per-host slice of the device-batch dim (axis 1)."""
    def sl(x):
        per = x.shape[1] // num_hosts
        return x[:, host_id * per:(host_id + 1) * per]
    return {k: sl(v) for k, v in batch.items()}
