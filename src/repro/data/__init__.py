from .corpus import SyntheticCorpus, read_text_corpus  # noqa: F401
from .loader import LoaderConfig, make_batch, data_state  # noqa: F401
from .stats import CorpusStats  # noqa: F401
