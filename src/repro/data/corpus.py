"""Corpora for the TF-IDF workload and LM pretraining.

``SyntheticCorpus`` is a seeded Zipf document stream matching the paper's
workload statistics knobs (unique/total token ratio — Wiki ≈ 7%, Meme ≈ 4%):
documents are generated on demand from ``(seed, doc_id)`` so any worker can
materialize any document independently (deterministic, resumable,
shardable — no shared state).
"""
from __future__ import annotations

import dataclasses
from pathlib import Path
from typing import Iterator, List

import numpy as np


@dataclasses.dataclass(frozen=True)
class SyntheticCorpus:
    """Zipf-distributed token stream, generated per-document from the seed."""

    num_docs: int = 10_000
    mean_doc_len: int = 400
    vocab_size: int = 1 << 20
    zipf_a: float = 1.3
    seed: int = 0

    def doc_tokens(self, doc_id: int) -> np.ndarray:
        rng = np.random.default_rng((self.seed << 32) ^ doc_id)
        n = max(int(rng.poisson(self.mean_doc_len)), 8)
        toks = rng.zipf(self.zipf_a, size=n).astype(np.int64)
        return toks % self.vocab_size

    def __iter__(self) -> Iterator[np.ndarray]:
        for d in range(self.num_docs):
            yield self.doc_tokens(d)

    def token_stream(self, start_doc: int = 0) -> Iterator[np.ndarray]:
        d = start_doc
        while True:
            yield self.doc_tokens(d % self.num_docs)
            d += 1


def read_text_corpus(path: str | Path, key_space: int = 1 << 30
                     ) -> List[np.ndarray]:
    """Read a directory of .txt files (or one file) into token-id docs,
    using the paper's tokenizer (word split + FNV-1a ids)."""
    from ..core.tfidf import token_id, tokenize
    p = Path(path)
    files = sorted(p.glob("**/*.txt")) if p.is_dir() else [p]
    docs = []
    for f in files:
        for para in f.read_text(errors="ignore").split("\n\n"):
            toks = tokenize(para)
            if toks:
                docs.append(np.fromiter((token_id(t, key_space)
                                         for t in toks),
                                        dtype=np.int64, count=len(toks)))
    return docs
