"""Two-level hash function pair from the paper (§2).

    g(x) = (a*x + b) mod q          -- primary (entry-level, closed table)
    s(x) = g(x) div r               -- secondary (block-level, open buffer)

The *placement property*: all keys in secondary slot ``m`` land in the
contiguous primary range ``[r*m, r*(m+1))`` (modulo probe overflow), so a
buffered slot can be merged with exactly one device block.

Two implementations:

* :class:`HashPair` — the paper's linear-congruential pair, used by the
  event-level SSD simulation (numpy int64; exact).
* :class:`Pow2Hash` — TPU-native variant for the JAX/Pallas path: ``q`` and
  ``r`` are powers of two, so ``mod``/``div`` become mask/shift and the whole
  computation stays inside uint32 (no 64-bit multiplies, which TPUs lack and
  jax-without-x64 forbids). ``g(x) = (x * mult) & (q-1)`` with an odd Knuth
  multiplier; eq. (3) ``s(x) = g(x) div r`` holds identically, which is all
  the placement property needs. Recorded as a hardware adaptation in
  DESIGN.md §2.
"""
from __future__ import annotations

import dataclasses

# Knuth multiplicative constants (odd, fit uint32).
_DEFAULT_A = 2_654_435_761
_DEFAULT_B = 1_013_904_223

def bloom_positions(x, bits_log2: int):
    """k=2 Bloom bit positions in ``[0, 2**bits_log2)`` for keys ``x``.

    One murmur3-finalizer mix (xor-shift + odd multiplies, all uint32 —
    the same mask/shift-only discipline as :class:`Pow2Hash`), then both
    positions sliced from disjoint bit ranges of the mixed word. A single
    multiplicative hash per probe is *not* enough here: for the dense
    small-integer key populations the table serves (token ids), two
    linear probes stay correlated and the measured false-positive rate
    lands ~3× above the independent-probe prediction; the finalizer's
    avalanche restores it. Requires ``bits_log2 <= 16``; identical math
    runs in numpy (sim twin), XLA (engine pre-filter) and inside Pallas
    kernels (merge / probe). Returns a tuple of uint32 position arrays.
    """
    import numpy as _np
    h = x.astype("uint32")
    h = h ^ (h >> _np.uint32(16))
    h = h * _np.uint32(0x85EBCA6B)
    h = h ^ (h >> _np.uint32(13))
    h = h * _np.uint32(0xC2B2AE35)
    h = h ^ (h >> _np.uint32(16))
    m = _np.uint32((1 << bits_log2) - 1)
    return (h & m, (h >> _np.uint32(bits_log2)) & m)


def filter_words_for(block_entries: int) -> int:
    """uint32 lanes per block-filter row: smallest power of two giving
    ≥4 bits per entry of block capacity (≈8 bits/key at 50% load →
    ~5% false-positive rate with k=2; DESIGN.md §12)."""
    words = 4
    while words * 32 < block_entries * 4 and words < 2048:
        words *= 2
    return words  # capped at 2**16 bits: bloom_positions slices two
                  # disjoint 16-bit ranges from one mixed uint32


@dataclasses.dataclass(frozen=True)
class HashPair:
    """The paper's (g, s) pair. ``q`` = total entries, ``r`` = entries/block."""

    q: int  # number of entries in the primary (closed) table
    r: int  # entries per block == primary entries per secondary slot
    a: int = _DEFAULT_A
    b: int = _DEFAULT_B

    def __post_init__(self):
        if self.q % self.r != 0:
            raise ValueError(f"q={self.q} must be a multiple of r={self.r}")
        if self.q <= 0 or self.r <= 0:
            raise ValueError("q and r must be positive")

    @property
    def num_slots(self) -> int:
        """Secondary-table slot count (== number of primary blocks)."""
        return self.q // self.r

    # Inputs: python ints or numpy int64 arrays with x < 2**31 → a*x+b < 2**63
    # stays exact in int64.
    def g(self, x):
        return (self.a * x + self.b) % self.q

    def s(self, x):
        return self.g(x) // self.r

    def block_of(self, x):
        """The device block a key belongs to (== s(x))."""
        return self.s(x)

    def home_within_block(self, x):
        """Entry offset of the key's home position inside its block."""
        return self.g(x) % self.r


@dataclasses.dataclass(frozen=True)
class Pow2Hash:
    """uint32-only (g, s) pair with power-of-two table geometry (JAX path)."""

    q_log2: int  # log2(total entries)
    r_log2: int  # log2(entries per block)
    mult: int = _DEFAULT_A  # odd multiplier

    def __post_init__(self):
        if self.r_log2 > self.q_log2:
            raise ValueError("r must not exceed q")
        if self.mult % 2 == 0:
            raise ValueError("multiplier must be odd")

    @property
    def q(self) -> int:
        return 1 << self.q_log2

    @property
    def r(self) -> int:
        return 1 << self.r_log2

    @property
    def num_slots(self) -> int:
        return 1 << (self.q_log2 - self.r_log2)

    def g(self, x):
        """x: int32/uint32 array (jax or numpy) or python int → int32 in [0,q)."""
        if isinstance(x, int):
            return ((x * self.mult) & 0xFFFFFFFF) & (self.q - 1)
        # jax/numpy: cast to uint32; multiply wraps; mask keeps it in range.
        import numpy as _np
        u = x.astype("uint32") * _np.uint32(self.mult)
        return (u & _np.uint32(self.q - 1)).astype("int32")

    def s(self, x):
        return self.g(x) >> self.r_log2

    def home_within_block(self, x):
        return self.g(x) & (self.r - 1)


def hash_pair_for(num_blocks: int, block_entries: int, a: int = _DEFAULT_A,
                  b: int = _DEFAULT_B) -> HashPair:
    return HashPair(q=num_blocks * block_entries, r=block_entries, a=a, b=b)
