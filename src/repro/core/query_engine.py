"""Host-side batched query engine for the device flash-hash table.

The paper's query axis (§2.7, Figure 3) measures consolidation cost:
every point query must combine the data segment, the change segment and
the overflow region. Serving that one key at a time pays a full jitted
dispatch — data-segment probe plus whole change-segment scan — per key.
This engine is the batched front door every consumer (TF-IDF, corpus
stats, the serving prefix cache) goes through instead:

* **dedup before dispatch** — duplicate keys in a batch resolve to one
  device probe (``np.unique``), then fan back out to their positions;
* **fixed-shape padded chunks** — misses are EMPTY-padded up to
  ``chunk`` so every table sees exactly one compiled lookup program,
  regardless of batch size;
* **hot-key cache** — a small host dict in front of the device table.
  Counts are global aggregates, so *any* update/merge/flush may move any
  key's count: writers call :meth:`invalidate` (wholesale clear) after
  every mutation rather than tracking per-key dirtiness (DESIGN.md §6);
* **invalidate fencing** — drains run on a background worker thread
  since the store went async (DESIGN.md §9), so an invalidation can land
  while a batch lookup is mid-flight. Every ``invalidate()`` bumps an
  epoch; a lookup only populates the cache if the epoch it started under
  is still current, so a count probed against a pre-drain state can
  never be cached after the drain's invalidation (it would be served
  stale forever);
* **filter-backed negative verdicts** (DESIGN.md §12) — when the table
  carries blocked-Bloom filters, one cheap ``filter_fn`` dispatch tests
  the whole miss set first: definite misses answer 0 with *no* lookup
  dispatch at all (skipping the tile probe *and* the change-segment /
  overflow scans) and enter the hot cache as negative entries under the
  same epoch fence, so a concurrent drain evicts them exactly like
  positive entries;
* **probe-distance aggregation** — per-key probe distances from the
  device are folded into batch-level wear/latency stats (sum + max +
  served-query count); cache hits do not re-probe and add nothing.

The engine is deliberately state-free with respect to the table: callers
pass the current ``DeviceTableState`` to :meth:`query_batch`, so
functional state updates (``state -> op -> state``) stay outside.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np


@dataclasses.dataclass
class QueryEngineStats:
    """Batch-aggregated query-path counters (DESIGN.md §6)."""

    batches: int = 0            # query_batch calls
    keys: int = 0               # keys requested (incl. duplicates)
    unique_keys: int = 0        # after dedup
    cache_hits: int = 0         # unique keys served from the hot cache
    device_queries: int = 0     # unique keys sent to the device
    device_dispatches: int = 0  # compiled lookup launches (chunks)
    invalidations: int = 0      # hot-cache clears by writers
    fenced: int = 0             # cache inserts dropped because a writer
                                # invalidated while the lookup was in
                                # flight (epoch fence, DESIGN.md §9)
    probe_total: int = 0        # sum of device probe distances
    probe_max: int = 0          # worst single probe in any batch
    filter_negatives: int = 0   # unique keys answered 0 by the Bloom
                                # pre-filter with no lookup dispatch (§12)
    tile_loads: int = 0         # data-segment tiles fetched by dispatched
                                # lookups (when the lookup_fn reports them;
                                # true negatives contribute 0)

    def as_dict(self) -> Dict[str, int]:
        return dataclasses.asdict(self)


class BatchedQueryEngine:
    """Dedup + chunk + hot-cache front end over ``table_jax.lookup``."""

    def __init__(self, cfg, chunk: int = 1024, hot_capacity: int = 4096,
                 lookup_fn=None, filter_fn=None):
        import jax.numpy as jnp  # deferred: sim-only users stay jax-free

        from . import table_jax as tj
        self._jnp = jnp
        self._tj = tj
        self.cfg = cfg
        self.chunk = int(chunk)
        self.hot_capacity = int(hot_capacity)
        # pluggable device dispatch: any (state, keys) -> (counts, dists)
        # or (counts, dists, tile_loads) with table_jax.lookup's contract
        # (EMPTY -> (0, 0)). The sharded backend passes its shard_map'd
        # consolidated lookup here; the default is the single-table path,
        # which reports tile loads.
        self._lookup = (lookup_fn if lookup_fn is not None
                        else lambda state, q: tj.lookup_ex(self.cfg,
                                                           state, q))
        # optional Bloom pre-filter: (state, keys) -> bool/int may-contain
        # mask (False ⇒ definitively absent from the whole device table).
        # The store wires table_jax.filter_probe (or the sharded psum'd
        # twin) here when cfg.filters is on.
        self._filter = filter_fn
        self._hot: Dict[int, int] = {}
        # invalidation epoch: bumped on every invalidate(). Lookups fence
        # their cache inserts on it so a count probed against a pre-drain
        # state is never remembered after the drain invalidated.
        self._epoch = 0
        # opt-in happens-before recorder (analysis.race_harness.attach)
        self.tracer = None
        self.stats = QueryEngineStats()

    def _trace(self, kind: str, resource=None, rw=None, **meta) -> None:
        if self.tracer is not None:
            self.tracer.record(kind, resource=resource, rw=rw, **meta)

    # -- cache maintenance --------------------------------------------------
    def invalidate(self) -> None:
        """Writers call this after any update/merge/flush: counts are
        global aggregates, so the whole hot cache goes at once. Also
        bumps the epoch fence — a lookup racing this call will drop its
        (now possibly stale) cache inserts."""
        self._epoch += 1
        self._trace("invalidate", "cache", "w", epoch=self._epoch)
        if self._hot:
            self._hot.clear()
            self.stats.invalidations += 1

    def _remember(self, key: int, count: int) -> None:
        if self.hot_capacity <= 0:
            return  # cache disabled
        if len(self._hot) >= self.hot_capacity and key not in self._hot:
            # FIFO eviction via dict insertion order — cheap, and good
            # enough for a cache that is cleared on every table write.
            self._hot.pop(next(iter(self._hot)))
        self._hot[key] = count

    # -- the batched read path ---------------------------------------------
    def query_batch(self, state, keys) -> np.ndarray:
        """Counts for ``keys`` (any shape, flattened) against ``state``.

        Returns an int64 array aligned with the flattened input;
        duplicate keys share one probe, ``EMPTY`` keys return 0.
        """
        jnp, tj = self._jnp, self._tj
        flat = np.asarray(keys).reshape(-1).astype(np.int64)
        self.stats.batches += 1
        self.stats.keys += flat.size
        if flat.size == 0:
            return np.zeros(0, np.int64)
        uniq, inv = np.unique(flat, return_inverse=True)
        self.stats.unique_keys += uniq.size
        ucnt = np.zeros(uniq.size, np.int64)
        if not self._hot:
            # cold cache (the steady state under interleaved writes):
            # skip the per-key probe loop entirely
            miss_idx = np.flatnonzero(uniq != tj.EMPTY).tolist()
        else:
            self._trace("cache_read", "cache", "r")
            miss_idx = []
            for i, k in enumerate(uniq):
                if k == tj.EMPTY:
                    continue  # padding key: count 0, never probed or cached
                c = self._hot.get(int(k))
                if c is None:
                    miss_idx.append(i)
                else:
                    ucnt[i] = c
                    self.stats.cache_hits += 1
        if miss_idx:
            epoch = self._epoch          # fence: inserts only if unchanged
            self._trace("lookup_begin", "state", "r", epoch=epoch)
            miss = uniq[miss_idx]
            if self._filter is not None and miss.size:
                # Bloom pre-pass (DESIGN.md §12): one cheap dispatch over
                # the whole miss set. False ⇒ the key is in none of data /
                # change / overflow, so the entire lookup is skipped —
                # ucnt already holds 0 for those positions.
                step = self.chunk
                may = np.empty(miss.size, bool)
                for lo in range(0, miss.size, step):
                    part = miss[lo:lo + step]
                    pad = step - part.size
                    if pad:
                        part = np.concatenate(
                            [part, np.full(pad, tj.EMPTY, np.int64)])
                    m = np.asarray(
                        self._filter(state, jnp.asarray(part, jnp.int32)))
                    may[lo:lo + step - pad] = m[:step - pad].astype(bool)
                neg = miss[~may]
                if neg.size:
                    self.stats.filter_negatives += neg.size
                    if epoch == self._epoch:
                        # negative entries are ordinary count-0 entries:
                        # the next invalidate() evicts them wholesale
                        self._trace("cache_insert", "cache", "w",
                                    epoch=epoch)
                        for k in neg:
                            self._remember(int(k), 0)
                    else:
                        self._trace("lookup_fenced", epoch=self._epoch)
                        self.stats.fenced += neg.size
                    keep = np.flatnonzero(may)
                    miss_idx = [miss_idx[i] for i in keep]
                    miss = miss[may]
            self.stats.device_queries += miss.size
            got = np.empty(miss.size, np.int64)
            step = self.chunk
            for lo in range(0, miss.size, step):
                part = miss[lo:lo + step]
                pad = step - part.size
                if pad:  # fixed shapes → one compiled program per table
                    part = np.concatenate(
                        [part, np.full(pad, tj.EMPTY, np.int64)])
                res = self._lookup(state, jnp.asarray(part, jnp.int32))
                cnt, dist = res[0], res[1]
                if len(res) == 3:
                    # scalar (single table) or per-shard vector (sharded)
                    self.stats.tile_loads += int(np.asarray(res[2]).sum())
                n_real = step - pad
                cnt = np.asarray(cnt)[:n_real]
                dist = np.asarray(dist)[:n_real]
                got[lo:lo + n_real] = cnt
                self.stats.device_dispatches += 1
                self.stats.probe_total += int(dist.sum())
                if dist.size:
                    self.stats.probe_max = max(self.stats.probe_max,
                                               int(dist.max()))
            ucnt[miss_idx] = got
            if epoch == self._epoch:
                self._trace("cache_insert", "cache", "w", epoch=epoch)
                for k, c in zip(miss, got):
                    self._remember(int(k), int(c))
            else:
                # a drain invalidated mid-lookup: these counts may predate
                # it, so they must not outlive the invalidation
                self._trace("lookup_fenced", epoch=self._epoch)
                self.stats.fenced += miss.size
        return ucnt[inv]

    def query(self, state, key: int) -> int:
        """Single-key convenience wrapper (one-element batch)."""
        return int(self.query_batch(state, np.asarray([key]))[0])
