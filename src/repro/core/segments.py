"""Segment layer of the device flash-hash table (DESIGN.md §3, §7).

The paper's table is a composition of four regions — the *data segment*
(closed hash table in blocks), the *change segment* (either a monolithic
log or ``cs_partitions`` partitioned buffers), the *overflow region*, and
the RAM buffer H_R.  This module owns the on-device state record for the
first three and every op that is shared between the MB / MDB / MDB-L
policies; :mod:`table_jax` is reduced to scheme policy (when to stage,
when to drain) over these primitives, and :mod:`write_engine` is the
host-side H_R in front of them.

Shared primitives
-----------------
* :func:`scatter_rows`   — pointer-bumped append into per-row buffers.
  One code path serves both the overflow region (one row) and the MDB
  partitioned change segment (``cs_partitions`` rows); the old
  ``_append_overflow`` / ``_mdb_scatter`` twins collapsed into it.
* :func:`append_overflow` / :func:`append_log` /
  :func:`scatter_partitions` — the three staging surfaces.
* :func:`merge_dirty_batch` / :func:`drain_log` /
  :func:`merge_partition` — the merge paths (all through the
  ``merge_dirty`` Pallas kernel; wear accounted per dirty block).
* :func:`scan_segment`    — batched masked scan used by the query path.
* :func:`accumulate_deltas` — sort+segment-sum dedup of a (token, Δ)
  batch (the in-kernel RAM-buffer analogue).

Functions take the table config duck-typed (anything with ``pair``,
``num_blocks``, ``max_updates_per_block``, ``interpret`` and — for the
partitioned ops — ``cs_partitions`` / ``blocks_per_partition`` /
``partition_capacity``), so this module has no import cycle with
:mod:`table_jax`.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..kernels.flash_hash import ops as hops
from .hashing import bloom_positions

EMPTY = hops.EMPTY


class TableStats(NamedTuple):
    tile_loads: jax.Array       # blocks read from HBM during merges
    tile_stores: jax.Array      # blocks rewritten (the paper's "cleans")
    staged_entries: jax.Array   # entries appended to the log (seq writes)
    merges: jax.Array
    stages: jax.Array
    dropped: jax.Array          # capacity losses (should be 0)
    carried: jax.Array          # updates deferred past a tile's max_u cap


class DeviceTableState(NamedTuple):
    keys: jax.Array        # (n_b, r) int32 — data segment
    counts: jax.Array      # (n_b, r) int32
    log_keys: jax.Array    # change segment: (log_cap,) for MDB-L,
                           # (cs_partitions, part_cap) for MDB
    log_counts: jax.Array  # same shape as log_keys
    log_ptr: jax.Array     # () int32 for MDB-L, (cs_partitions,) for MDB
    ov_keys: jax.Array     # (ov_cap,) int32 — overflow region
    ov_counts: jax.Array   # (ov_cap,) int32
    ov_ptr: jax.Array      # () int32
    filter_words: jax.Array  # (n_b, fw) uint32 — per-block blocked-Bloom
                             # filter rows (DESIGN.md §12). Monotone: bits
                             # are only ever OR'd in, covering every key in
                             # the data/change/overflow segments, so a
                             # filter-negative is a definitive miss.
    stats: TableStats


def zero_stats() -> TableStats:
    z = lambda: jnp.zeros((), jnp.int32)
    return TableStats(tile_loads=z(), tile_stores=z(), staged_entries=z(),
                      merges=z(), stages=z(), dropped=z(), carried=z())


def init_state(num_blocks: int, block_entries: int, log_shape,
               log_ptr_shape, overflow_capacity: int,
               filter_words: int) -> DeviceTableState:
    """Fresh segment state: EMPTY data/change/overflow regions."""
    return DeviceTableState(
        keys=jnp.full((num_blocks, block_entries), EMPTY, jnp.int32),
        counts=jnp.zeros((num_blocks, block_entries), jnp.int32),
        log_keys=jnp.full(log_shape, EMPTY, jnp.int32),
        log_counts=jnp.zeros(log_shape, jnp.int32),
        log_ptr=jnp.zeros(log_ptr_shape, jnp.int32),
        ov_keys=jnp.full((overflow_capacity,), EMPTY, jnp.int32),
        ov_counts=jnp.zeros((overflow_capacity,), jnp.int32),
        ov_ptr=jnp.zeros((), jnp.int32),
        filter_words=jnp.zeros((num_blocks, filter_words), jnp.uint32),
        stats=zero_stats(),
    )


# ---------------------------------------------------------------------------
# per-block blocked-Bloom filter (DESIGN.md §12)
# ---------------------------------------------------------------------------
def filter_or_keys(pair, filt, keys):
    """OR the Bloom bits of ``keys`` into their home blocks' filter rows.

    Maintenance is *monotone*: the device table never removes keys
    (counting semantics — deletion is a −Δ on the count), so filter bits
    are only ever set. Every staging and merge path can therefore OR its
    keys in independently, in any order, without coordination, and the
    no-false-negative invariant holds by induction over key entry points
    (DESIGN.md §12). ``EMPTY`` keys are padding and contribute nothing.

    JAX has no ``.at[].or_``, so the scatter-OR is: flatten each
    (key, probe) to a global bit id, sort, drop duplicate heads, then
    ``.at[].add`` the single-bit masks — after dedup all bits are
    distinct, so add ≡ or.
    """
    n_b, fw = filt.shape
    bits_log2 = (fw * 32).bit_length() - 1
    valid = keys != EMPTY
    blk = jnp.where(valid, pair.s(keys), n_b).astype(jnp.int32)
    base = blk * (fw * 32)
    fids = jnp.concatenate(
        [base + p.astype(jnp.int32) for p in bloom_positions(keys, bits_log2)])
    fids = jnp.sort(fids)
    is_head = jnp.concatenate([jnp.ones((1,), bool), fids[1:] != fids[:-1]])
    is_head &= fids < n_b * fw * 32
    word = jnp.where(is_head, fids >> 5, n_b * fw)
    mask = jnp.where(
        is_head,
        jnp.left_shift(jnp.int32(1), fids & 31).astype(jnp.uint32),
        jnp.uint32(0))
    new = jnp.zeros((n_b * fw,), jnp.uint32).at[word].add(mask, mode="drop")
    return filt | new.reshape(n_b, fw)


def filter_may_contain(pair, filt, q):
    """Test a query batch against the per-block filters (plain XLA).

    Returns a bool ``(Q,)`` mask: False ⇒ the key is definitively absent
    from the data, change and overflow segments (the filter covers all
    three); True ⇒ maybe present (~5% false positives at design load).
    ``EMPTY`` keys test False. This is the engine-level pre-filter; the
    in-kernel twin is :func:`kernel.filter_probe_grid`.
    """
    n_b, fw = filt.shape
    bits_log2 = (fw * 32).bit_length() - 1
    valid = q != EMPTY
    blk = jnp.where(valid, pair.s(q), 0).astype(jnp.int32)
    may = valid
    for p in bloom_positions(q, bits_log2):
        word = filt[blk, (p >> jnp.uint32(5)).astype(jnp.int32)]
        may &= ((word >> (p & jnp.uint32(31))) & jnp.uint32(1)) != 0
    return may


def rebuild_filters(pair, state: DeviceTableState) -> DeviceTableState:
    """Recompute every filter row from the live segments.

    Normal operation never needs this (maintenance is incremental and
    monotone); it exists for filter-width migrations and as the oracle
    the property tests compare incremental maintenance against. The
    result is a *superset* of the minimal bit set only through overflow
    keys whose home tile later compacted — same conservative direction
    as incremental maintenance."""
    filt = jnp.zeros_like(state.filter_words)
    for keys in (state.keys.reshape(-1), state.log_keys.reshape(-1),
                 state.ov_keys):
        filt = filter_or_keys(pair, filt, keys)
    return state._replace(filter_words=filt)


@jax.jit
def accumulate_deltas(tokens, deltas):
    """RAM-buffer dedup with explicit deltas (supports deletion-by-−1)."""
    order = jnp.argsort(tokens, stable=True)
    t = tokens[order]
    d = deltas[order]
    is_head = jnp.concatenate([jnp.ones((1,), bool), t[1:] != t[:-1]])
    is_head &= t != EMPTY
    seg = jnp.cumsum(is_head) - 1
    sums = jax.ops.segment_sum(jnp.where(t != EMPTY, d, 0), seg,
                               num_segments=t.shape[0])
    comp = jnp.argsort(jnp.where(is_head, 0, 1), stable=True)
    keys = jnp.where(is_head[comp], t[comp], EMPTY)
    cnts = jnp.where(is_head[comp],
                     sums[jnp.clip(seg[comp], 0, t.shape[0] - 1)], 0)
    return keys, cnts.astype(jnp.int32)


def assert_live(state) -> None:
    """Off-thread donation guard (DESIGN.md §9).

    ``update``/``flush`` donate the state, and since the store's flush
    went asynchronous those donations happen on a background worker: a
    dispatch that starts from an already-donated value would die deep in
    XLA with an opaque deleted-buffer error. Every drain calls this on
    the state it is about to donate — a failure means two drains raced,
    or a caller reused a stale reference it captured before a drain."""
    for leaf in jax.tree.leaves(state):
        if getattr(leaf, "is_deleted", None) is not None and leaf.is_deleted():
            raise RuntimeError(
                "device table state was already donated: a drain is "
                "running (or ran) on this value — rebind state after "
                "every update/flush and never dispatch two drains on "
                "the same state (DESIGN.md §9)")


def compact(keys, counts):
    """Compact valid entries to the front, EMPTY-pad the tail."""
    valid = keys != EMPTY
    comp = jnp.argsort(~valid, stable=True)
    return (jnp.where(valid[comp], keys[comp], EMPTY),
            jnp.where(valid[comp], counts[comp], 0),
            valid.sum(dtype=jnp.int32))


# ---------------------------------------------------------------------------
# pointer-bumped staging (overflow region + partitioned change segment)
# ---------------------------------------------------------------------------
def scatter_rows(buf_keys, buf_counts, ptrs, rows, keys, cnts):
    """Pointer-bumped append of (keys, cnts) into per-row buffers.

    ``buf_keys``/``buf_counts`` are ``(R, cap)``; ``ptrs`` is the ``(R,)``
    per-row fill pointer; ``rows`` assigns each entry a destination row
    (``EMPTY`` keys or rows outside ``[0, R)`` are padding and ignored).
    Entries are packed at their row's pointer in stable input order — the
    paper's semi-random page-write discipline. Entries past a row's
    capacity do *not* fit and are returned for the caller to handle
    (retry after a drain, or count as dropped).

    Returns ``(buf_keys, buf_counts, new_ptrs, rest_keys, rest_cnts,
    n_fit)``: rest_* hold the non-fitting entries (EMPTY-masked, same
    ``(U,)`` layout), ``n_fit`` the per-row appended count.
    """
    R, cap = buf_keys.shape
    (U,) = keys.shape
    valid = (keys != EMPTY) & (rows >= 0) & (rows < R)
    rw = jnp.where(valid, rows, R).astype(jnp.int32)
    order = jnp.argsort(rw, stable=True)
    sk, sc, sr = keys[order], cnts[order], rw[order]
    start = jnp.searchsorted(sr, jnp.arange(R + 1, dtype=sr.dtype))
    rank = jnp.arange(U, dtype=jnp.int32) - start[jnp.clip(sr, 0, R)]
    pos = ptrs[jnp.clip(sr, 0, R - 1)] + rank
    fits = (sr < R) & (pos < cap)
    row = jnp.where(fits, sr, R)
    col = jnp.where(fits, pos, 0)
    buf_keys = buf_keys.at[row, col].set(sk, mode="drop")
    buf_counts = buf_counts.at[row, col].set(sc, mode="drop")
    n_fit = jnp.zeros((R,), jnp.int32).at[row].add(fits.astype(jnp.int32),
                                                   mode="drop")
    rest = (sr < R) & ~fits
    rest_k = jnp.where(rest, sk, EMPTY)
    rest_c = jnp.where(rest, sc, 0)
    return buf_keys, buf_counts, ptrs + n_fit, rest_k, rest_c, n_fit


def append_overflow(state: DeviceTableState, spill_k, spill_c
                    ) -> DeviceTableState:
    """Compact spilled entries into the overflow region (page-chained in
    the paper; a pointer-bumped array here). Entries past the capacity
    are genuine losses, surfaced in ``stats.dropped``."""
    flat_k = spill_k.reshape(-1)
    flat_c = spill_c.reshape(-1)
    ov_k, ov_c, ptrs, rest_k, _, _ = scatter_rows(
        state.ov_keys[None, :], state.ov_counts[None, :],
        state.ov_ptr[None], jnp.zeros(flat_k.shape, jnp.int32),
        flat_k, flat_c)
    n_dropped = (rest_k != EMPTY).sum(dtype=jnp.int32)
    return state._replace(
        ov_keys=ov_k[0], ov_counts=ov_c[0], ov_ptr=ptrs[0],
        stats=state.stats._replace(dropped=state.stats.dropped + n_dropped))


def append_log(cfg, state: DeviceTableState, keys, cnts) -> DeviceTableState:
    """Append a deduped chunk to the monolithic log (sequential write).

    Pure staging primitive: the caller (:func:`table_jax._stage`)
    guarantees the chunk fits behind ``log_ptr`` (merging first if not).
    """
    log_keys = jax.lax.dynamic_update_slice(state.log_keys, keys,
                                            (state.log_ptr,))
    log_counts = jax.lax.dynamic_update_slice(state.log_counts, cnts,
                                              (state.log_ptr,))
    n_new = (keys != EMPTY).sum(dtype=jnp.int32)
    stats = state.stats._replace(
        staged_entries=state.stats.staged_entries + n_new,
        stages=state.stats.stages + 1)
    # staged keys become device-visible here, so their filter bits must be
    # set *now* — a filter-negative must also rule out the change segment
    return state._replace(log_keys=log_keys, log_counts=log_counts,
                          log_ptr=state.log_ptr + keys.shape[0],
                          filter_words=filter_or_keys(
                              cfg.pair, state.filter_words, keys),
                          stats=stats)


def partition_of(cfg, keys):
    """MDB: partition id per key; invalid keys map to the sentinel P."""
    P = cfg.cs_partitions
    return jnp.where(keys != EMPTY,
                     cfg.pair.s(keys) // cfg.blocks_per_partition,
                     P).astype(jnp.int32)


def scatter_partitions(cfg, state: DeviceTableState, keys, cnts):
    """Append a deduped chunk into its partitions (semi-random page
    writes). Returns (state, rest_keys, rest_counts): entries whose
    partition was full are *not* staged and come back EMPTY-masked for
    the caller to retry after a merge."""
    log_keys, log_counts, log_ptr, rest_k, rest_c, n_fit = scatter_rows(
        state.log_keys, state.log_counts, state.log_ptr,
        partition_of(cfg, keys), keys, cnts)
    stats = state.stats._replace(
        staged_entries=state.stats.staged_entries
        + n_fit.sum(dtype=jnp.int32))
    # conservative filter maintenance: OR in *all* valid keys, including
    # the non-fitting rest — those retry (and land) right after the
    # partition merge, so pre-setting their bits is a harmless superset
    state = state._replace(log_keys=log_keys, log_counts=log_counts,
                           log_ptr=log_ptr,
                           filter_words=filter_or_keys(
                               cfg.pair, state.filter_words, keys),
                           stats=stats)
    return state, rest_k, rest_c


# ---------------------------------------------------------------------------
# merge paths (all through the merge_dirty Pallas kernel)
# ---------------------------------------------------------------------------
def merge_dirty_batch(cfg, state: DeviceTableState, keys, cnts):
    """One dirty-block merge pass over a flat batch of staged updates.

    The dirty set is computed from the staged keys' ``s()`` values; the
    kernel grid walks a *permutation* of all blocks with the dirty ones
    first (every block id appears exactly once, so revisit hazards cannot
    arise), but only the dirty prefix carries updates and only it is
    charged to ``tile_loads``/``tile_stores``. Updates beyond a block's
    ``max_updates_per_block`` are returned as carry and must stay staged.

    Pallas grids are static, so the permutation still has ``num_blocks``
    steps — the clean suffix is a no-op visit, and the *counters* (not
    the kernel walltime) model the paper's per-scheme cleans here. A
    truly partial grid needs a statically-known dirty count; that is
    exactly what MDB's partition layout provides
    (:func:`merge_partition`, grid length ``k``).
    """
    pair = cfg.pair
    n_b = cfg.num_blocks
    valid = keys != EMPTY
    blk = jnp.where(valid, pair.s(keys), 0).astype(jnp.int32)
    per_block = jnp.zeros((n_b,), jnp.int32).at[blk].add(
        valid.astype(jnp.int32))
    dirty = per_block > 0
    # grid order: dirty blocks (ascending id — the semi-random write
    # discipline), then clean blocks with EMPTY update rows (no-op visits).
    perm = jnp.argsort(jnp.where(dirty, 0, 1), stable=True).astype(jnp.int32)
    inv = jnp.zeros((n_b,), jnp.int32).at[perm].set(
        jnp.arange(n_b, dtype=jnp.int32))
    rows = jnp.where(valid, inv[blk], n_b).astype(jnp.int32)
    uk, uc, carry_k, carry_c, n_carried = hops.bucket_rows(
        rows, keys, cnts, n_b, cfg.max_updates_per_block)
    nk, nc, nf, spill_k, spill_c = hops.merge_dirty(
        pair, state.keys, state.counts, state.filter_words, perm, uk, uc,
        cfg.interpret)
    state = state._replace(keys=nk, counts=nc, filter_words=nf)
    state = append_overflow(state, spill_k, spill_c)
    n_dirty = dirty.sum(dtype=jnp.int32)
    stats = state.stats._replace(
        tile_loads=state.stats.tile_loads + n_dirty,
        tile_stores=state.stats.tile_stores + n_dirty,
        carried=state.stats.carried + n_carried)
    return state._replace(stats=stats), carry_k, carry_c


def drain_log(cfg, state: DeviceTableState) -> DeviceTableState:
    """Drain the monolithic log into the data segment (dirty-block merge).

    Carried updates (exceeded a tile's max_u) stay staged, compacted to
    the log head; everything else is cleared."""
    state, carry_k, carry_c = merge_dirty_batch(
        cfg, state, state.log_keys, state.log_counts)
    log_keys, log_counts, n_carry = compact(carry_k, carry_c)
    stats = state.stats._replace(merges=state.stats.merges + 1)
    return state._replace(log_keys=log_keys, log_counts=log_counts,
                          log_ptr=n_carry, stats=stats)


def merge_partition(cfg, state: DeviceTableState, p) -> DeviceTableState:
    """Drain change-segment partition ``p`` into its ``k`` data blocks.

    The dirty set is exactly the partition's block range
    ``[p*k, (p+1)*k)`` — the paper's §2.4 CS-block merge — so the merge
    costs ``k`` tile loads + stores, never ``num_blocks``."""
    pair = cfg.pair
    k = cfg.blocks_per_partition
    sk = jax.lax.dynamic_index_in_dim(state.log_keys, p, keepdims=False)
    sc = jax.lax.dynamic_index_in_dim(state.log_counts, p, keepdims=False)
    rows = jnp.where(sk != EMPTY, pair.s(sk) - p * k, k).astype(jnp.int32)
    uk, uc, carry_k, carry_c, n_carried = hops.bucket_rows(
        rows, sk, sc, k, cfg.max_updates_per_block)
    dirty = (p * k + jnp.arange(k)).astype(jnp.int32)
    nk, nc, nf, spill_k, spill_c = hops.merge_dirty(
        pair, state.keys, state.counts, state.filter_words, dirty, uk, uc,
        cfg.interpret)
    state = state._replace(keys=nk, counts=nc, filter_words=nf)
    state = append_overflow(state, spill_k, spill_c)
    # carried updates stay staged at the head of the partition
    new_k, new_c, n_carry = compact(carry_k, carry_c)
    log_keys = jax.lax.dynamic_update_index_in_dim(
        state.log_keys, new_k, p, 0)
    log_counts = jax.lax.dynamic_update_index_in_dim(
        state.log_counts, new_c, p, 0)
    stats = state.stats._replace(
        tile_loads=state.stats.tile_loads + k,
        tile_stores=state.stats.tile_stores + k,
        merges=state.stats.merges + 1,
        carried=state.stats.carried + n_carried)
    return state._replace(log_keys=log_keys, log_counts=log_counts,
                          log_ptr=state.log_ptr.at[p].set(n_carry),
                          stats=stats)


# ---------------------------------------------------------------------------
# query-side scan (change segment + overflow, shared across a batch)
# ---------------------------------------------------------------------------
def scan_segment(seg_keys, seg_counts, q, chunk: int = 1024):
    """Masked linear scan of a log/overflow segment for a query batch.

    One scan serves the whole batch (the ``(Q, chunk)`` compare is shared
    across every query), so batched lookups pay the change-segment read
    once rather than per key. The segment is EMPTY-padded up to a chunk
    multiple: ``dynamic_slice`` clamps out-of-range starts, so an
    unpadded non-multiple tail would re-read (and double-count) the
    overlap with the previous chunk.
    """
    cap = seg_keys.shape[0]
    chunk = min(chunk, cap)
    pad = -cap % chunk
    if pad:
        seg_keys = jnp.concatenate(
            [seg_keys, jnp.full((pad,), EMPTY, seg_keys.dtype)])
        seg_counts = jnp.concatenate(
            [seg_counts, jnp.zeros((pad,), seg_counts.dtype)])
    n_chunks = (cap + pad) // chunk

    def body(i, acc):
        lk = jax.lax.dynamic_slice(seg_keys, (i * chunk,), (chunk,))
        lc = jax.lax.dynamic_slice(seg_counts, (i * chunk,), (chunk,))
        m = (q[:, None] == lk[None, :]) & (lk[None, :] != EMPTY)
        return acc + jnp.sum(m * lc[None, :], axis=1, dtype=jnp.int32)

    return jax.lax.fori_loop(0, n_chunks,
                             body, jnp.zeros(q.shape, jnp.int32))
