"""Event-level simulation of the paper's three hash-table schemes.

Faithful functional model of §2 of the paper — the drive-resident *data
segment* is a closed (linear-probing) counting hash table laid out in
blocks/pages; a memory-resident *RAM buffer* (open hash, secondary hash
function ``s``) batches updates; the MDB/MDB-L schemes add an SSD-resident
*change segment*. All device traffic is accounted in a :class:`CostLedger`
(the DiskSim-slave replacement), which the benchmarks convert to time per
SSD configuration.

Schemes
-------
* :class:`MBTable`    — RAM buffer only; flush == block-level merges (§2.3).
* :class:`MDBTable`   — partitioned change segment: each CS block buffers k
  data-segment blocks; stage = semi-random page writes; a full CS block
  triggers a merge of its k data blocks (§2.4).
* :class:`MDBLTable`  — linear log change segment; stage = sequential page
  writes; a full log triggers a global merge (§2.4, MDB-L).
* :class:`NaiveTable` — bufferless baseline of §3.5 (random page writes
  through the FTL GC model).

Counting semantics: ``insert(key, +1)``; deletion-by-decrement
(``delta=-1``); full removal with tombstoning + compaction-on-merge (§2.6).
Linear probing never crosses a block boundary; probe overflow spills to the
page-chained *overflow region* (§2.5).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from .flash_model import CostLedger, TableGeometry
from .hashing import HashPair, bloom_positions, filter_words_for, hash_pair_for

EMPTY = -1
TOMBSTONE = -2


@dataclasses.dataclass
class QueryStats:
    queries: int = 0
    found: int = 0
    ds_page_reads: int = 0
    cs_block_reads: int = 0
    cs_page_reads: int = 0
    overflow_page_reads: int = 0
    filter_negatives: int = 0  # queries answered by the RAM-resident
                               # Bloom filter with zero flash reads (§12)

    def time_us(self, dev) -> float:
        return ((self.ds_page_reads + self.cs_page_reads +
                 self.overflow_page_reads) * dev.page_read_us
                + self.cs_block_reads * dev.block_read_us)

    def avg_time_ms(self, dev) -> float:
        return self.time_us(dev) / max(self.queries, 1) / 1000.0


class _DataSegment:
    """Closed hash table on the device: blocks of linear-probed entries,
    plus the page-chained overflow region (§2.5)."""

    def __init__(self, geom: TableGeometry, pair: HashPair,
                 ledger: CostLedger, overflow_blocks: int = 1):
        assert pair.q == geom.total_entries and pair.r == geom.block_entries
        self.geom = geom
        self.pair = pair
        self.ledger = ledger
        q = geom.total_entries
        self.keys = np.full(q, EMPTY, dtype=np.int64)
        self.counts = np.zeros(q, dtype=np.int64)
        # position index mirrors the on-device layout; lets the simulation
        # skip O(r) scans per op while still accounting exact probe spans.
        self.index: Dict[int, int] = {}
        # overflow region: entries stored past the main table, page-chained.
        self.overflow_capacity = (overflow_blocks * geom.pages_per_block
                                  * geom.entries_per_page)
        self.ov_keys: List[int] = []
        self.ov_counts: List[int] = []
        self.ov_index: Dict[int, int] = {}
        # per-block number of overflow entries (for query chain-read costs)
        self.block_overflow: Dict[int, int] = {}
        self.tombstones: Dict[int, int] = {}  # block -> count

    # -- geometry helpers -------------------------------------------------
    def block_range(self, b: int):
        r = self.geom.block_entries
        return b * r, (b + 1) * r

    # -- in-memory application of one staged item (costs accounted by caller
    #    at block granularity, exactly like the paper's merge) -------------
    def apply(self, key: int, delta: int) -> None:
        pos = self.index.get(key)
        if pos is not None:
            self.counts[pos] += delta
            return
        ovpos = self.ov_index.get(key)
        if ovpos is not None:
            self.ov_counts[ovpos] += delta
            return
        self._insert_new(key, delta)

    def _insert_new(self, key: int, delta: int) -> None:
        home = int(self.pair.g(key))
        b = home // self.geom.block_entries
        lo, hi = self.block_range(b)
        # first empty slot at or after home, cyclic *within the block* (§2.5)
        free_after = np.flatnonzero(self.keys[home:hi] == EMPTY)
        if free_after.size:
            pos = home + int(free_after[0])
        else:
            free_before = np.flatnonzero(self.keys[lo:home] == EMPTY)
            if free_before.size:
                pos = lo + int(free_before[0])
            else:
                self._insert_overflow(b, key, delta)
                return
        self.keys[pos] = key
        self.counts[pos] = delta
        self.index[key] = pos

    def _insert_overflow(self, b: int, key: int, delta: int) -> None:
        if len(self.ov_keys) >= self.overflow_capacity:
            raise RuntimeError("overflow region exhausted; grow the table")
        self.ov_index[key] = len(self.ov_keys)
        self.ov_keys.append(key)
        self.ov_counts.append(delta)
        self.block_overflow[b] = self.block_overflow.get(b, 0) + 1

    # -- §2.6 removal + compaction ---------------------------------------
    def remove(self, key: int) -> bool:
        pos = self.index.pop(key, None)
        if pos is not None:
            self.keys[pos] = TOMBSTONE
            self.counts[pos] = 0
            b = pos // self.geom.block_entries
            self.tombstones[b] = self.tombstones.get(b, 0) + 1
            return True
        ovpos = self.ov_index.pop(key, None)
        if ovpos is not None:
            self.ov_keys[ovpos] = TOMBSTONE
            self.ov_counts[ovpos] = 0
            return True
        return False

    def compact_block(self, b: int) -> None:
        """Re-hash a block in memory, dropping tombstones (done during merge;
        the block read/write is already accounted by the merge)."""
        if not self.tombstones.get(b):
            return
        lo, hi = self.block_range(b)
        live = [(int(k), int(c)) for k, c in
                zip(self.keys[lo:hi], self.counts[lo:hi]) if k >= 0]
        self.keys[lo:hi] = EMPTY
        self.counts[lo:hi] = 0
        for k, _ in live:
            self.index.pop(k, None)
        self.tombstones.pop(b, None)
        for k, c in live:
            self.apply(k, c)

    # -- query cost model --------------------------------------------------
    def probe_cost_pages(self, key: int):
        """(found, count, ds_pages, ov_pages) for a point query (§2.7)."""
        home = int(self.pair.g(key))
        b = home // self.geom.block_entries
        epp = self.geom.entries_per_page
        pos = self.index.get(key)
        if pos is not None:
            if pos >= home:
                span = pos - home
            else:  # wrapped within block
                lo, hi = self.block_range(b)
                span = (hi - home) + (pos - lo)
            return True, int(self.counts[pos]), span // epp + 1, 0
        ovpos = self.ov_index.get(key)
        if ovpos is not None:
            # read the home block pages up to the block end, then chase the
            # overflow page chain for this block
            lo, hi = self.block_range(b)
            ds_pages = (hi - home) // epp + 1
            ov_pages = self.block_overflow.get(b, 0) // epp + 1
            return True, int(self.ov_counts[ovpos]), ds_pages, ov_pages
        # absent: probe to the first empty slot
        lo, hi = self.block_range(b)
        free_after = np.flatnonzero(self.keys[home:hi] == EMPTY)
        if free_after.size:
            span = int(free_after[0])
            return False, 0, span // epp + 1, 0
        free_before = np.flatnonzero(self.keys[lo:home] == EMPTY)
        if free_before.size:
            span = (hi - home) + int(free_before[0])
            ov_pages = 0
        else:
            span = hi - home
            ov_pages = self.block_overflow.get(b, 0) // epp + 1
        return False, 0, span // epp + 1, ov_pages

    def total_count(self, key: int) -> int:
        pos = self.index.get(key)
        if pos is not None:
            return int(self.counts[pos])
        ovpos = self.ov_index.get(key)
        if ovpos is not None:
            return int(self.ov_counts[ovpos])
        return 0

    @property
    def load_factor(self) -> float:
        return len(self.index) / self.geom.total_entries


class _RamBuffer:
    """Open secondary hash table H_R: slot m buffers block m's updates."""

    def __init__(self, pair: HashPair, capacity_entries: int):
        self.pair = pair
        self.capacity = max(int(capacity_entries), 1)
        self.items: Dict[int, int] = {}  # key -> accumulated delta

    def add(self, key: int, delta: int) -> None:
        new = self.items.get(key, 0) + delta
        if new == 0 and key in self.items:
            # paper §2.6: zero-frequency entries are not retained in memory
            del self.items[key]
        else:
            self.items[key] = new

    def add_batch(self, keys: np.ndarray, deltas: Optional[np.ndarray] = None):
        if deltas is None:
            uniq, cnt = np.unique(keys, return_counts=True)
            for k, c in zip(uniq.tolist(), cnt.tolist()):
                self.add(k, c)
        else:
            order = np.argsort(keys, kind="stable")
            ks, ds = keys[order], deltas[order]
            bounds = np.flatnonzero(np.diff(ks)) + 1
            sums = np.add.reduceat(ds, np.r_[0, bounds])
            for k, d in zip(ks[np.r_[0, bounds]].tolist(), sums.tolist()):
                if d:
                    self.add(int(k), int(d))

    @property
    def full(self) -> bool:
        return len(self.items) >= self.capacity

    def get(self, key: int) -> int:
        return self.items.get(key, 0)

    def drain_by_block(self) -> Dict[int, List]:
        """Group buffered items by destination block (slot id) and clear."""
        if not self.items:
            return {}
        keys = np.fromiter(self.items.keys(), dtype=np.int64,
                           count=len(self.items))
        deltas = np.fromiter(self.items.values(), dtype=np.int64,
                             count=len(self.items))
        blocks = self.pair.s(keys)
        order = np.argsort(blocks, kind="stable")
        keys, deltas, blocks = keys[order], deltas[order], blocks[order]
        out: Dict[int, List] = {}
        bounds = np.flatnonzero(np.diff(blocks)) + 1
        starts = np.r_[0, bounds]
        ends = np.r_[bounds, len(blocks)]
        for s, e in zip(starts.tolist(), ends.tolist()):
            out[int(blocks[s])] = [keys[s:e], deltas[s:e]]
        self.items = {}
        return out


class _BlockedBloom:
    """RAM-resident per-block Bloom filter array — the event-level twin of
    the device table's ``filter_words`` (DESIGN.md §12). Same geometry
    (:func:`filter_words_for`), same hash (:func:`bloom_positions`), same
    monotone-OR discipline: bits are only ever set, when keys become
    flash-visible at a drain. ``remove()`` leaves stale positives behind
    (conservative — a false positive costs a probe, never correctness)."""

    def __init__(self, num_blocks: int, block_entries: int):
        fw = filter_words_for(block_entries)
        self.bits_log2 = (fw * 32).bit_length() - 1
        self.words = np.zeros((num_blocks, fw), dtype=np.uint32)

    def add_batch(self, block: int, keys: np.ndarray) -> None:
        row = self.words[block]
        for p in bloom_positions(np.asarray(keys, np.int64), self.bits_log2):
            np.bitwise_or.at(row, (p >> np.uint32(5)).astype(np.int64),
                             np.left_shift(np.uint32(1),
                                           p & np.uint32(31)))

    def may_contain(self, block: int, key: int) -> bool:
        row = self.words[block]
        for p in bloom_positions(np.asarray([key], np.int64), self.bits_log2):
            i = int(p[0])
            if not (int(row[i >> 5]) >> (i & 31)) & 1:
                return False
        return True


class FlashHashTableBase:
    """Shared machinery: insert/update/delete path, RAM buffer, merges."""

    scheme = "?"

    def __init__(self, geom: TableGeometry, ram_buffer_pct: float,
                 a: Optional[int] = None, overflow_blocks: int = 1,
                 filters: bool = True):
        self.geom = geom
        kwargs = {} if a is None else {"a": a}
        self.pair = hash_pair_for(geom.num_blocks, geom.block_entries, **kwargs)
        self.ledger = CostLedger(_pages_per_block=geom.pages_per_block)
        self.ds = _DataSegment(geom, self.pair, self.ledger, overflow_blocks)
        cap = int(ram_buffer_pct / 100.0 * geom.total_entries)
        self.ram = _RamBuffer(self.pair, cap)
        self.filters = (_BlockedBloom(geom.num_blocks, geom.block_entries)
                        if filters else None)
        self.qstats = QueryStats()

    # -- element insertion / update / deletion (§2.5, §2.6) ---------------
    def insert(self, key: int, delta: int = 1) -> None:
        self.ram.add(int(key), int(delta))
        if self.ram.full:
            self.flush()

    def insert_batch(self, keys: np.ndarray,
                     deltas: Optional[np.ndarray] = None,
                     chunk: Optional[int] = None) -> None:
        keys = np.asarray(keys, dtype=np.int64)
        if chunk is None:
            # granularity tied to the RAM buffer so the flush threshold is
            # honored within ~25% (element-wise inserts would be exact but
            # O(python) slow; the paper's event loop is per-record)
            chunk = int(min(max(self.ram.capacity // 4, 16), 16384))
        for i in range(0, len(keys), chunk):
            self.ram.add_batch(keys[i:i + chunk],
                               None if deltas is None else deltas[i:i + chunk])
            if self.ram.full:
                self.flush()

    def delete(self, key: int) -> None:
        """Deletion-by-decrement (paper §2.6, first kind)."""
        self.insert(key, -1)

    def remove(self, key: int) -> bool:
        """Full removal (paper §2.6, second kind): drop any buffered delta,
        tombstone the drive entry; compaction happens at next merge."""
        self.ram.items.pop(int(key), None)
        self._remove_staged(int(key))
        return self.ds.remove(int(key))

    def _remove_staged(self, key: int) -> None:
        pass  # overridden by change-segment schemes

    # -- scheme hooks -------------------------------------------------------
    def flush(self) -> None:
        raise NotImplementedError

    def finalize(self) -> None:
        """Push everything to the data segment (end-of-run)."""
        raise NotImplementedError

    # -- RAM drain + Bloom maintenance --------------------------------------
    def _drain(self) -> Dict[int, List]:
        """``ram.drain_by_block()`` plus filter maintenance: this boundary
        is where keys become flash-visible (staged or merged), so their
        Bloom bits are OR'd in here — before that the RAM buffer itself
        answers them, after that the bits cover them forever (monotone)."""
        groups = self.ram.drain_by_block()
        if self.filters is not None:
            for b, (keys, _deltas) in groups.items():
                self.filters.add_batch(b, keys)
        return groups

    # -- merge helper: one data-segment block ------------------------------
    def _merge_block(self, b: int, keys: np.ndarray, deltas: np.ndarray):
        self.ledger.read_block()
        self.ds.compact_block(b)
        for k, d in zip(keys.tolist(), deltas.tolist()):
            self.ds.apply(int(k), int(d))
        self.ledger.write_block()  # erase-before-write accounted inside

    # -- queries (§2.7) -----------------------------------------------------
    def query(self, key: int) -> int:
        key = int(key)
        if (self.filters is not None
                and not self.filters.may_contain(int(self.pair.s(key)), key)):
            # definitive miss on all of data / change / overflow: only the
            # RAM buffer can still hold the key; zero flash reads accrue
            total = self.ram.get(key)
            self.qstats.queries += 1
            self.qstats.filter_negatives += 1
            if total != 0:
                self.qstats.found += 1
            return total
        total = self.ram.get(key)                    # negligible cost
        total += self._query_change_segment(key)     # scheme-specific cost
        found, cnt, ds_pages, ov_pages = self.ds.probe_cost_pages(key)
        self.qstats.queries += 1
        self.qstats.ds_page_reads += ds_pages
        self.qstats.overflow_page_reads += ov_pages
        total += cnt
        if total != 0 or found:
            self.qstats.found += 1
        return total

    def _query_change_segment(self, key: int) -> int:
        return 0

    def query_batch(self, keys) -> np.ndarray:
        """Batched counts — API twin of the device adapter's batched
        path. The event-level simulation still accounts each key's SSD
        cost individually (the paper's per-query ledger); batching here
        is an interface property, not a cost model change. EMPTY
        padding keys return 0 at no cost, matching the device engine."""
        flat = np.asarray(keys).reshape(-1)
        return np.fromiter(
            (self.query(int(k)) if k != EMPTY else 0 for k in flat),
            dtype=np.int64, count=flat.size)

    def update_batch(self, keys, deltas: Optional[np.ndarray] = None) -> None:
        """Batched (token, Δ) writes — API twin of the device write
        engine's dispatch chunks. Accepts the engine's EMPTY-padded
        fixed-shape layout: EMPTY keys are padding and are ignored at no
        cost, and explicit deltas carry counting semantics (±Δ,
        deletion-by-decrement). This keeps the event-level sim a drop-in
        oracle for workloads driven through ``BatchedWriteEngine``."""
        flat = np.asarray(keys).reshape(-1).astype(np.int64)
        if deltas is None:
            d = np.ones(flat.size, dtype=np.int64)
        else:
            d = np.asarray(deltas).reshape(-1).astype(np.int64)
            if d.size != flat.size:
                raise ValueError(f"deltas size {d.size} != keys {flat.size}")
        m = flat != EMPTY
        if m.any():
            self.insert_batch(flat[m], d[m])

    # convenience for tests: exact logical count, no cost accounting
    def logical_count(self, key: int) -> int:
        return (self.ram.get(int(key)) + self._staged_count(int(key))
                + self.ds.total_count(int(key)))

    def _staged_count(self, key: int) -> int:
        return 0


class MBTable(FlashHashTableBase):
    """Memory-Bounded buffering (§2.3): flush == merge every dirty block."""

    scheme = "MB"

    def flush(self) -> None:
        groups = self._drain()
        if not groups:
            return
        self.ledger.merge_event()
        for b in sorted(groups):  # ascending block order (semi-random)
            keys, deltas = groups[b]
            self._merge_block(b, keys, deltas)

    def finalize(self) -> None:
        self.flush()


class MDBTable(FlashHashTableBase):
    """Memory+Disk buffering with a *partitioned* change segment (§2.4)."""

    scheme = "MDB"

    def __init__(self, geom: TableGeometry, ram_buffer_pct: float,
                 change_segment_pct: float = 12.5, **kw):
        super().__init__(geom, ram_buffer_pct, **kw)
        self.cs_blocks = max(int(round(change_segment_pct / 100.0
                                       * geom.num_blocks)), 1)
        # each CS block serves k consecutive data blocks
        self.k = -(-geom.num_blocks // self.cs_blocks)  # ceil
        # staged[c] = {key: delta}; pages_used[c] = CS pages consumed
        self.staged: List[Dict[int, int]] = [dict() for _ in range(self.cs_blocks)]
        self.cs_pages_used = np.zeros(self.cs_blocks, dtype=np.int64)

    def _cs_of_block(self, b: int) -> int:
        return min(b // self.k, self.cs_blocks - 1)

    def flush(self) -> None:
        groups = self._drain()
        if not groups:
            return
        self.ledger.stage_event()
        # pack each slot's entries into CS pages (semi-random writes)
        per_cs_entries: Dict[int, int] = {}
        for b, (keys, deltas) in groups.items():
            c = self._cs_of_block(b)
            st = self.staged[c]
            for k_, d_ in zip(keys.tolist(), deltas.tolist()):
                st[k_] = st.get(k_, 0) + d_
            per_cs_entries[c] = per_cs_entries.get(c, 0) + len(keys)
        epp = self.geom.entries_per_page
        for c, n_entries in per_cs_entries.items():
            pages = -(-n_entries // epp)
            self.ledger.write_page_semi(pages)
            self.cs_pages_used[c] += pages
            if self.cs_pages_used[c] >= self.geom.pages_per_block:
                self._merge_cs_block(c)

    def _merge_cs_block(self, c: int) -> None:
        """A CS block filled: merge its staged entries into the k data blocks
        it serves, then erase it (§2.4)."""
        st = self.staged[c]
        self.ledger.merge_event()
        self.ledger.read_block()            # read the CS block
        if st:
            keys = np.fromiter(st.keys(), dtype=np.int64, count=len(st))
            deltas = np.fromiter(st.values(), dtype=np.int64, count=len(st))
            blocks = self.pair.s(keys)
            for b in np.unique(blocks):
                m = blocks == b
                self._merge_block(int(b), keys[m], deltas[m])
        self.staged[c] = {}
        self.cs_pages_used[c] = 0
        self.ledger.erase_block()           # clean the CS block for reuse

    def finalize(self) -> None:
        self.flush()
        for c in range(self.cs_blocks):
            if self.staged[c]:
                self._merge_cs_block(c)

    def _remove_staged(self, key: int) -> None:
        c = self._cs_of_block(int(self.pair.s(key)))
        self.staged[c].pop(key, None)

    def _staged_count(self, key: int) -> int:
        c = self._cs_of_block(int(self.pair.s(key)))
        return self.staged[c].get(key, 0)

    def _query_change_segment(self, key: int) -> int:
        """MDB query: one *block-level* read of the CS block for this slot
        (paper §2.7/§3.4 — dominated by block reads)."""
        c = self._cs_of_block(int(self.pair.s(key)))
        if self.cs_pages_used[c] > 0 or self.staged[c]:
            self.qstats.cs_block_reads += 1
        return self.staged[c].get(key, 0)


class MDBLTable(FlashHashTableBase):
    """MDB-Linear (§2.4): monolithic log-structured change segment."""

    scheme = "MDB-L"

    def __init__(self, geom: TableGeometry, ram_buffer_pct: float,
                 change_segment_pct: float = 12.5, **kw):
        super().__init__(geom, ram_buffer_pct, **kw)
        self.log_capacity_pages = max(
            int(round(change_segment_pct / 100.0 * geom.total_pages)), 1)
        self.log_pages_used = 0
        # staged entries per destination data block + page-pointer ranges
        self.staged: Dict[int, Dict[int, int]] = {}
        self.slot_pages: Dict[int, set] = {}  # slot -> log pages holding it

    def flush(self) -> None:
        groups = self._drain()
        if not groups:
            return
        self.ledger.stage_event()
        epp = self.geom.entries_per_page
        # pack entries of all slots densely into the log, FCFS (§2.4):
        # a log page may contain entries from multiple slots.
        entry_cursor = self.log_pages_used * epp
        for b in sorted(groups):
            keys, deltas = groups[b]
            st = self.staged.setdefault(b, {})
            for k_, d_ in zip(keys.tolist(), deltas.tolist()):
                st[k_] = st.get(k_, 0) + d_
            first_pg = entry_cursor // epp
            entry_cursor += len(keys)
            last_pg = (entry_cursor - 1) // epp if len(keys) else first_pg
            self.slot_pages.setdefault(b, set()).update(
                range(first_pg, last_pg + 1))
        new_pages_used = -(-entry_cursor // epp)
        self.ledger.write_page_seq(new_pages_used - self.log_pages_used)
        self.log_pages_used = new_pages_used
        if self.log_pages_used >= self.log_capacity_pages:
            self._merge_log()

    def _merge_log(self) -> None:
        """Log full: drain everything into the data segment (§2.4). Page
        reads are *repetitive*: every page is read once per data block that
        has entries staged on it (paper §2.4)."""
        self.ledger.merge_event()
        repetitive_reads = sum(len(p) for p in self.slot_pages.values())
        self.ledger.read_page(repetitive_reads)
        for b in sorted(self.staged):
            st = self.staged[b]
            if not st:
                continue
            keys = np.fromiter(st.keys(), dtype=np.int64, count=len(st))
            deltas = np.fromiter(st.values(), dtype=np.int64, count=len(st))
            self._merge_block(b, keys, deltas)
        # erase the log blocks for reuse
        log_blocks = -(-self.log_pages_used // self.geom.pages_per_block)
        self.ledger.erase_block(log_blocks)
        self.staged = {}
        self.slot_pages = {}
        self.log_pages_used = 0

    def finalize(self) -> None:
        self.flush()
        if self.staged:
            self._merge_log()

    def _remove_staged(self, key: int) -> None:
        b = int(self.pair.s(key))
        if b in self.staged:
            self.staged[b].pop(key, None)

    def _staged_count(self, key: int) -> int:
        return self.staged.get(int(self.pair.s(key)), {}).get(key, 0)

    def _query_change_segment(self, key: int) -> int:
        """MDB-L query: pointer-guided *page-level* reads of only the log
        pages holding this slot's entries (§2.7)."""
        b = int(self.pair.s(key))
        pages = self.slot_pages.get(b)
        if pages:
            self.qstats.cs_page_reads += len(pages)
        return self.staged.get(b, {}).get(key, 0)


class NaiveTable(FlashHashTableBase):
    """§3.5 baseline: no buffering — every update is a random page write."""

    scheme = "naive"

    def __init__(self, geom: TableGeometry, **kw):
        super().__init__(geom, ram_buffer_pct=0.0, **kw)
        self.ram.capacity = 1  # flush on every insert

    def flush(self) -> None:
        groups = self._drain()
        for b, (keys, deltas) in groups.items():
            for k, d in zip(keys.tolist(), deltas.tolist()):
                self.ledger.read_page()
                self.ds.apply(int(k), int(d))
                self.ledger.write_page_random()

    def finalize(self) -> None:
        self.flush()


SCHEMES = {"MB": MBTable, "MDB": MDBTable, "MDB-L": MDBLTable,
           "naive": NaiveTable}


def make_table(scheme: str, geom: TableGeometry, ram_buffer_pct: float = 5.0,
               change_segment_pct: float = 12.5, **kw) -> FlashHashTableBase:
    cls = SCHEMES[scheme]
    if scheme in ("MDB", "MDB-L"):
        return cls(geom, ram_buffer_pct, change_segment_pct, **kw)
    if scheme == "naive":
        return cls(geom, **kw)
    return cls(geom, ram_buffer_pct, **kw)
