"""TF-IDF on counting hash tables — the paper's driving application (§1, §3.2).

Two counting tables are maintained while streaming a corpus:

* ``term_table``  — global term frequencies (every token occurrence),
* ``doc_table``   — document frequencies (each unique token once per doc).

``tfidf(w, d) = tf(w, d) * log(N / df(w))`` (Salton–Buckley weighting [32]).

Any of the paper's schemes (MB / MDB / MDB-L / naive) can back either table;
the I/O ledgers of the tables are what the paper's Figures 3–5 measure.
"""
from __future__ import annotations

import math
from typing import Dict, Iterable, List, Optional, Sequence

import numpy as np

from .flash_model import TableGeometry
from .table_sim import FlashHashTableBase, make_table


def tokenize(text: str) -> List[str]:
    return [t for t in
            "".join(c.lower() if c.isalnum() else " " for c in text).split()
            if t]


def token_id(token: str, key_space: int = 1 << 30) -> int:
    """Stable 31-bit token id (FNV-1a); the hash-table key domain."""
    h = 2166136261
    for ch in token.encode("utf-8"):
        h ^= ch
        h = (h * 16777619) & 0xFFFFFFFF
    return h % key_space


class TfIdfPipeline:
    """Streaming TF-IDF scorer over a counting hash table."""

    def __init__(self, geom: TableGeometry, scheme: str = "MDB-L",
                 ram_buffer_pct: float = 5.0, change_segment_pct: float = 12.5,
                 track_df: bool = True):
        self.term_table: FlashHashTableBase = make_table(
            scheme, geom, ram_buffer_pct, change_segment_pct)
        self.doc_table: Optional[FlashHashTableBase] = (
            make_table(scheme, geom, ram_buffer_pct, change_segment_pct)
            if track_df else None)
        self.num_docs = 0
        self.total_tokens = 0

    # -- ingestion ---------------------------------------------------------
    def add_document(self, tokens: Sequence[str]) -> None:
        ids = np.fromiter((token_id(t) for t in tokens), dtype=np.int64,
                          count=len(tokens))
        self.add_document_ids(ids)

    def add_document_ids(self, ids: np.ndarray) -> None:
        if len(ids) == 0:
            self.num_docs += 1
            return
        self.term_table.insert_batch(ids)
        if self.doc_table is not None:
            self.doc_table.insert_batch(np.unique(ids))
        self.num_docs += 1
        self.total_tokens += len(ids)

    # -- queries -------------------------------------------------------------
    def term_frequency(self, token: str) -> int:
        """A paper-workload query: 'how frequent is this keyword' (§3.3)."""
        return self.term_table.query(token_id(token))

    def idf(self, token: str) -> float:
        if self.doc_table is None:
            raise ValueError("df tracking disabled")
        df = self.doc_table.query(token_id(token))
        if df <= 0:
            return 0.0
        return math.log(self.num_docs / df)

    def tfidf(self, doc_tokens: Sequence[str]) -> Dict[str, float]:
        """Score one document against the accumulated corpus statistics."""
        tf: Dict[str, int] = {}
        for t in doc_tokens:
            tf[t] = tf.get(t, 0) + 1
        return {t: (c / max(len(doc_tokens), 1)) * self.idf(t)
                for t, c in tf.items()}

    def keywords(self, doc_tokens: Sequence[str], threshold: float) -> List[str]:
        """Paper §1: keywords = words with TF-IDF above a threshold."""
        scores = self.tfidf(doc_tokens)
        return sorted((t for t, v in scores.items() if v >= threshold),
                      key=lambda t: -scores[t])

    def finalize(self) -> None:
        self.term_table.finalize()
        if self.doc_table is not None:
            self.doc_table.finalize()
