"""TF-IDF on counting hash tables — the paper's driving application (§1, §3.2).

Two counting tables are maintained while streaming a corpus:

* ``term_table``  — global term frequencies (every token occurrence),
* ``doc_table``   — document frequencies (each unique token once per doc).

``tfidf(w, d) = tf(w, d) * log(N / df(w))`` (Salton–Buckley weighting [32]).

Any of the paper's schemes (MB / MDB / MDB-L / naive) can back either table;
the I/O ledgers of the tables are what the paper's Figures 3–5 measure.

Two backends expose the same scheme landscape:

* ``backend="sim"``    — the event-level NumPy simulator (exact SSD cost
  ledger; the paper's measurement harness).
* ``backend="device"`` — the JAX/Pallas device table (``core.table_jax``;
  wear accounted as ``tile_stores``), for sim-vs-device comparisons of
  MB / MDB / MDB-L on one workload.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np

from .flash_model import TableGeometry
from .table_sim import make_table


class DeviceTableAdapter:
    """``table_sim``-compatible facade over the device table.

    Wraps :mod:`core.table_jax` behind the small surface the TF-IDF
    pipeline uses (``insert_batch`` / ``query`` / ``query_batch`` /
    ``finalize``), so the same workload can be driven through the
    on-device MB / MDB / MDB-L implementations. Writes go through a
    :class:`..core.write_engine.BatchedWriteEngine` (host H_R dedup,
    threshold flushes, EMPTY-padded fixed-shape chunks, donated
    dispatches — DESIGN.md §7), which owns the table state and
    invalidates the paired :class:`..core.query_engine.BatchedQueryEngine`
    on every flush. Reads consolidate the device count with the buffered
    H_R overlay, so unflushed writes are never stale. ``wear()`` exposes
    the device stats whose ``tile_stores`` field is the simulator
    ledger's clean-count analogue.
    """

    def __init__(self, cfg, chunk: int = 4096, query_chunk: int = 1024,
                 flush_threshold: Optional[int] = None):
        from .query_engine import BatchedQueryEngine
        from .write_engine import BatchedWriteEngine
        self.cfg = cfg
        self.scheme = cfg.scheme
        self.engine = BatchedQueryEngine(cfg, chunk=query_chunk)
        self.writer = BatchedWriteEngine(cfg, chunk=chunk,
                                         flush_threshold=flush_threshold,
                                         query_engine=self.engine)

    @property
    def state(self):
        """Current device table state (owned by the write engine)."""
        return self.writer.state

    @property
    def chunk(self) -> int:
        return self.writer.chunk

    @chunk.setter
    def chunk(self, value: int) -> None:
        self.writer.chunk = int(value)

    def insert_batch(self, keys: np.ndarray,
                     deltas: Optional[np.ndarray] = None,
                     chunk: Optional[int] = None) -> None:
        # ``chunk`` (sim-API compatibility) keeps its pre-engine,
        # call-scoped meaning: this call dispatches at that width, now
        # (write-through, draining anything already buffered with it).
        # Without it, writes buffer in H_R at the engine's own width.
        if chunk is None:
            self.writer.update(keys, deltas)
            return
        prev = self.writer.chunk
        self.writer.chunk = int(chunk)
        try:
            self.writer.update(keys, deltas)
            self.writer.flush()
        finally:
            self.writer.chunk = prev

    def query(self, key: int) -> int:
        return self.writer.query(int(key))

    def query_batch(self, keys) -> np.ndarray:
        """Batched counts (paper §2.7, batched regime): one deduped,
        chunked dispatch for the whole key set instead of a per-key
        lookup loop — the change-segment scan is paid once per chunk,
        plus the H_R overlay for buffered (unflushed) writes."""
        return self.writer.query_batch(keys)

    # the device table has no separate uncosted path; counts are exact
    logical_count = query

    def finalize(self) -> None:
        self.writer.finalize()

    def wear(self) -> Dict[str, int]:
        s = self.writer.state.stats
        return {f: int(getattr(s, f)) for f in s._fields}

    def write_stats(self) -> Dict[str, int]:
        """H_R-side write-path counters (dedup ratio, flushes, dispatches)."""
        return self.writer.stats.as_dict()


def make_device_table(scheme: str, q_log2: int = 14, r_log2: int = 9,
                      **kw) -> DeviceTableAdapter:
    """Device-backed twin of :func:`table_sim.make_table`."""
    from . import table_jax as tj
    cfg = tj.FlashTableConfig(q_log2=q_log2, r_log2=r_log2, scheme=scheme,
                              **kw)
    return DeviceTableAdapter(cfg)


def tokenize(text: str) -> List[str]:
    return [t for t in
            "".join(c.lower() if c.isalnum() else " " for c in text).split()
            if t]


def token_id(token: str, key_space: int = 1 << 30) -> int:
    """Stable 31-bit token id (FNV-1a); the hash-table key domain."""
    h = 2166136261
    for ch in token.encode("utf-8"):
        h ^= ch
        h = (h * 16777619) & 0xFFFFFFFF
    return h % key_space


class TfIdfPipeline:
    """Streaming TF-IDF scorer over a counting hash table."""

    def __init__(self, geom: TableGeometry, scheme: str = "MDB-L",
                 ram_buffer_pct: float = 5.0, change_segment_pct: float = 12.5,
                 track_df: bool = True, backend: str = "sim",
                 q_log2: int = 14, r_log2: int = 9):
        if backend == "sim":
            mk = lambda: make_table(scheme, geom, ram_buffer_pct,
                                    change_segment_pct)
        elif backend == "device":
            if scheme == "naive":
                raise ValueError("the device table has no naive scheme")
            mk = lambda: make_device_table(scheme, q_log2, r_log2)
        else:
            raise ValueError(f"unknown backend {backend!r}")
        self.backend = backend
        self.term_table = mk()
        self.doc_table = mk() if track_df else None
        self.num_docs = 0
        self.total_tokens = 0

    # -- ingestion ---------------------------------------------------------
    def add_document(self, tokens: Sequence[str]) -> None:
        ids = np.fromiter((token_id(t) for t in tokens), dtype=np.int64,
                          count=len(tokens))
        self.add_document_ids(ids)

    def add_document_ids(self, ids: np.ndarray) -> None:
        if len(ids) == 0:
            self.num_docs += 1
            return
        self.term_table.insert_batch(ids)
        if self.doc_table is not None:
            self.doc_table.insert_batch(np.unique(ids))
        self.num_docs += 1
        self.total_tokens += len(ids)

    # -- queries -------------------------------------------------------------
    def term_frequency(self, token: str) -> int:
        """A paper-workload query: 'how frequent is this keyword' (§3.3)."""
        return self.term_table.query(token_id(token))

    def _df_many(self, tokens: Sequence[str]) -> np.ndarray:
        """Document frequencies for a token list, one batched lookup."""
        if self.doc_table is None:
            raise ValueError("df tracking disabled")
        ids = np.fromiter((token_id(t) for t in tokens), dtype=np.int64,
                          count=len(tokens))
        return np.asarray(self.doc_table.query_batch(ids), dtype=np.int64)

    def idf(self, token: str) -> float:
        return float(self.idf_many([token])[0])

    def idf_many(self, tokens: Sequence[str]) -> np.ndarray:
        """Vectorized IDF: all tokens resolved in one batched df lookup
        (duplicates deduped before dispatch by the query engine)."""
        df = self._df_many(tokens)
        out = np.zeros(len(tokens), np.float64)
        pos = df > 0
        out[pos] = np.log(self.num_docs / df[pos])
        return out

    def tfidf(self, doc_tokens: Sequence[str]) -> Dict[str, float]:
        """Score one document against the accumulated corpus statistics.

        The document's unique terms are resolved in a single batched df
        lookup (paper §2.7 batched regime) instead of one device
        round-trip per term."""
        if not doc_tokens:
            return {}
        tf: Dict[str, int] = {}
        for t in doc_tokens:
            tf[t] = tf.get(t, 0) + 1
        idf = self.idf_many(list(tf))   # insertion order = unique terms
        n = len(doc_tokens)
        return {t: (c / n) * idf[i] for i, (t, c) in enumerate(tf.items())}

    def keywords(self, doc_tokens: Sequence[str], threshold: float) -> List[str]:
        """Paper §1: keywords = words with TF-IDF above a threshold (all
        terms scored through one batched lookup via :meth:`tfidf`)."""
        scores = self.tfidf(doc_tokens)
        return sorted((t for t, v in scores.items() if v >= threshold),
                      key=lambda t: -scores[t])

    def finalize(self) -> None:
        self.term_table.finalize()
        if self.doc_table is not None:
            self.doc_table.finalize()
