"""TF-IDF on counting hash tables — the paper's driving application (§1, §3.2).

Two counting tables are maintained while streaming a corpus:

* ``term_table``  — global term frequencies (every token occurrence),
* ``doc_table``   — document frequencies (each unique token once per doc).

``tfidf(w, d) = tf(w, d) * log(N / df(w))`` (Salton–Buckley weighting [32]).

Any of the paper's schemes (MB / MDB / MDB-L / naive) can back either
table, and since PR 4 every table is a
:class:`~repro.core.store.FlashStore` — the backend-agnostic facade
(DESIGN.md §8) that owns the H_R buffering, flush/invalidate contract and
batched read path. ``backend=`` selects:

* ``"sim"``     — event-level NumPy simulator (exact SSD cost ledger),
* ``"device"``  — single-table JAX/Pallas path,
* ``"sharded"`` — the multi-device table (one shard per local device).
"""
from __future__ import annotations

from typing import Dict, List, Sequence

import numpy as np

from .flash_model import TableGeometry
from .store import FlashStore


def tokenize(text: str) -> List[str]:
    return [t for t in
            "".join(c.lower() if c.isalnum() else " " for c in text).split()
            if t]


def token_id(token: str, key_space: int = 1 << 30) -> int:
    """Stable 31-bit token id (FNV-1a); the hash-table key domain."""
    h = 2166136261
    for ch in token.encode("utf-8"):
        h ^= ch
        h = (h * 16777619) & 0xFFFFFFFF
    return h % key_space


class TfIdfPipeline:
    """Streaming TF-IDF scorer over counting hash tables, all backends
    through the one :class:`~repro.core.store.FlashStore` facade."""

    def __init__(self, geom: TableGeometry, scheme: str = "MDB-L",
                 ram_buffer_pct: float = 5.0, change_segment_pct: float = 12.5,
                 track_df: bool = True, backend: str = "sim",
                 q_log2: int = 14, r_log2: int = 9):
        if backend == "sim":
            mk = lambda: FlashStore.open(
                geom, backend="sim", scheme=scheme,
                ram_buffer_pct=ram_buffer_pct,
                change_segment_pct=change_segment_pct)
        elif backend in ("device", "sharded"):
            if scheme == "naive":
                raise ValueError(f"the {backend} table has no naive scheme")
            mk = lambda: FlashStore.open(
                backend=backend, scheme=scheme, q_log2=q_log2,
                r_log2=r_log2)
        else:
            raise ValueError(f"unknown backend {backend!r}")
        self.backend = backend
        self.term_table = mk()
        self.doc_table = mk() if track_df else None
        self.num_docs = 0
        self.total_tokens = 0

    # -- ingestion ---------------------------------------------------------
    def add_document(self, tokens: Sequence[str]) -> None:
        ids = np.fromiter((token_id(t) for t in tokens), dtype=np.int64,
                          count=len(tokens))
        self.add_document_ids(ids)

    def add_document_ids(self, ids: np.ndarray) -> None:
        if len(ids) == 0:
            self.num_docs += 1
            return
        self.term_table.update(ids)
        if self.doc_table is not None:
            self.doc_table.update(np.unique(ids))
        self.num_docs += 1
        self.total_tokens += len(ids)

    # -- queries -------------------------------------------------------------
    def term_frequency(self, token: str) -> int:
        """A paper-workload query: 'how frequent is this keyword' (§3.3)."""
        return self.term_table.query(token_id(token))

    def _df_many(self, tokens: Sequence[str]) -> np.ndarray:
        """Document frequencies for a token list, one batched lookup."""
        if self.doc_table is None:
            raise ValueError("df tracking disabled")
        ids = np.fromiter((token_id(t) for t in tokens), dtype=np.int64,
                          count=len(tokens))
        return np.asarray(self.doc_table.query_batch(ids), dtype=np.int64)

    def idf(self, token: str) -> float:
        return float(self.idf_many([token])[0])

    def idf_many(self, tokens: Sequence[str]) -> np.ndarray:
        """Vectorized IDF: all tokens resolved in one batched df lookup
        (duplicates deduped before dispatch by the store)."""
        df = self._df_many(tokens)
        out = np.zeros(len(tokens), np.float64)
        pos = df > 0
        out[pos] = np.log(self.num_docs / df[pos])
        return out

    def tfidf(self, doc_tokens: Sequence[str]) -> Dict[str, float]:
        """Score one document against the accumulated corpus statistics.

        The document's unique terms are resolved in a single batched df
        lookup (paper §2.7 batched regime) instead of one device
        round-trip per term."""
        if not doc_tokens:
            return {}
        tf: Dict[str, int] = {}
        for t in doc_tokens:
            tf[t] = tf.get(t, 0) + 1
        idf = self.idf_many(list(tf))   # insertion order = unique terms
        n = len(doc_tokens)
        return {t: (c / n) * idf[i] for i, (t, c) in enumerate(tf.items())}

    def keywords(self, doc_tokens: Sequence[str], threshold: float) -> List[str]:
        """Paper §1: keywords = words with TF-IDF above a threshold (all
        terms scored through one batched lookup via :meth:`tfidf`)."""
        scores = self.tfidf(doc_tokens)
        return sorted((t for t, v in scores.items() if v >= threshold),
                      key=lambda t: -scores[t])

    def finalize(self) -> None:
        self.term_table.flush()
        if self.doc_table is not None:
            self.doc_table.flush()
