"""Device-resident (JAX) counting hash table — the TPU-native twin of
:mod:`table_sim`, used by the framework's data-statistics, MoE-accounting
and serving layers.

Mapping (DESIGN.md §2): HBM table = data segment; ``sort+segment_sum``
dedup = RAM buffer; HBM append-log = MDB-L change segment; Pallas tile
merge = block-level update. Stats counters mirror the paper's ledger:
``tile_stores`` is the clean/wear analogue (one per block rewrite).

Everything is functional: ``state -> op -> state`` and jit-friendly; the
scheme (MB vs MDB-L) is a static config choice, so each policy compiles to
its own program.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..kernels.flash_hash import ops as hops
from .hashing import Pow2Hash

EMPTY = hops.EMPTY


@dataclasses.dataclass(frozen=True)
class FlashTableConfig:
    """Geometry + policy of a device table."""

    q_log2: int = 16              # total entries (power of two)
    r_log2: int = 10              # entries per block (≥128-lane friendly)
    scheme: str = "MDB-L"         # "MB" | "MDB-L"
    log_capacity: int = 1 << 14   # change-segment entries (MDB-L)
    max_updates_per_block: int = 1 << 9   # VMEM cap per tile merge
    overflow_capacity: int = 1 << 10
    interpret: bool = True        # Pallas interpret mode (CPU container)

    @property
    def pair(self) -> Pow2Hash:
        return Pow2Hash(q_log2=self.q_log2, r_log2=self.r_log2)

    @property
    def num_blocks(self) -> int:
        return 1 << (self.q_log2 - self.r_log2)

    @property
    def block_entries(self) -> int:
        return 1 << self.r_log2


class TableStats(NamedTuple):
    tile_loads: jax.Array       # blocks read from HBM during merges
    tile_stores: jax.Array      # blocks rewritten (the paper's "cleans")
    staged_entries: jax.Array   # entries appended to the log (seq writes)
    merges: jax.Array
    stages: jax.Array
    dropped: jax.Array          # overflow-capacity losses (should be 0)


class DeviceTableState(NamedTuple):
    keys: jax.Array        # (n_b, r) int32
    counts: jax.Array      # (n_b, r) int32
    log_keys: jax.Array    # (log_cap,) int32 — MDB-L change segment
    log_counts: jax.Array  # (log_cap,) int32
    log_ptr: jax.Array     # () int32
    ov_keys: jax.Array     # (ov_cap,) int32 — overflow region
    ov_counts: jax.Array   # (ov_cap,) int32
    ov_ptr: jax.Array      # () int32
    stats: TableStats


def init(cfg: FlashTableConfig) -> DeviceTableState:
    n_b, r = cfg.num_blocks, cfg.block_entries
    z = lambda: jnp.zeros((), jnp.int32)
    return DeviceTableState(
        keys=jnp.full((n_b, r), EMPTY, jnp.int32),
        counts=jnp.zeros((n_b, r), jnp.int32),
        log_keys=jnp.full((cfg.log_capacity,), EMPTY, jnp.int32),
        log_counts=jnp.zeros((cfg.log_capacity,), jnp.int32),
        log_ptr=z(),
        ov_keys=jnp.full((cfg.overflow_capacity,), EMPTY, jnp.int32),
        ov_counts=jnp.zeros((cfg.overflow_capacity,), jnp.int32),
        ov_ptr=z(),
        stats=TableStats(z(), z(), z(), z(), z(), z()),
    )


@jax.jit
def accumulate_deltas(tokens, deltas):
    """RAM-buffer dedup with explicit deltas (supports deletion-by-−1)."""
    order = jnp.argsort(tokens, stable=True)
    t = tokens[order]
    d = deltas[order]
    is_head = jnp.concatenate([jnp.ones((1,), bool), t[1:] != t[:-1]])
    is_head &= t != EMPTY
    seg = jnp.cumsum(is_head) - 1
    sums = jax.ops.segment_sum(jnp.where(t != EMPTY, d, 0), seg,
                               num_segments=t.shape[0])
    comp = jnp.argsort(jnp.where(is_head, 0, 1), stable=True)
    keys = jnp.where(is_head[comp], t[comp], EMPTY)
    cnts = jnp.where(is_head[comp],
                     sums[jnp.clip(seg[comp], 0, t.shape[0] - 1)], 0)
    return keys, cnts.astype(jnp.int32)


def _append_overflow(state: DeviceTableState, spill_k, spill_c):
    """Compact spilled entries into the overflow region (page-chained in the
    paper; a pointer-bumped array here)."""
    flat_k = spill_k.reshape(-1)
    flat_c = spill_c.reshape(-1)
    valid = flat_k != EMPTY
    ov_cap = state.ov_keys.shape[0]
    pos = state.ov_ptr + jnp.cumsum(valid.astype(jnp.int32)) - 1
    in_range = valid & (pos < ov_cap)
    idx = jnp.where(in_range, pos, ov_cap)  # OOB drops
    ov_keys = state.ov_keys.at[idx].set(jnp.where(in_range, flat_k, EMPTY),
                                        mode="drop")
    ov_counts = state.ov_counts.at[idx].add(flat_c * in_range, mode="drop")
    n_spill = valid.sum(dtype=jnp.int32)
    n_fit = in_range.sum(dtype=jnp.int32)
    return state._replace(
        ov_keys=ov_keys, ov_counts=ov_counts,
        ov_ptr=jnp.minimum(state.ov_ptr + n_spill, ov_cap),
        stats=state.stats._replace(
            dropped=state.stats.dropped + (n_spill - n_fit)))


def _merge_now(cfg: FlashTableConfig, state: DeviceTableState
               ) -> DeviceTableState:
    """Drain the change segment into the data segment (full-grid merge)."""
    pair = cfg.pair
    uk, uc, carry_k, carry_c, _ = hops.bucket_updates(
        pair, state.log_keys, state.log_counts, cfg.max_updates_per_block)
    keys, counts, spill_k, spill_c = hops.merge(
        pair, state.keys, state.counts, uk, uc, cfg.interpret)
    state = state._replace(keys=keys, counts=counts)
    state = _append_overflow(state, spill_k, spill_c)
    # carried updates (exceeded a tile's max_u) stay staged, compacted to
    # the log head; everything else is cleared.
    carry_valid = carry_k != EMPTY
    comp = jnp.argsort(~carry_valid, stable=True)
    log_keys = jnp.where(carry_valid[comp], carry_k[comp], EMPTY)
    log_counts = jnp.where(carry_valid[comp], carry_c[comp], 0)
    n_carry = carry_valid.sum(dtype=jnp.int32)
    n_b = cfg.num_blocks
    stats = state.stats._replace(
        tile_loads=state.stats.tile_loads + n_b,
        tile_stores=state.stats.tile_stores + n_b,
        merges=state.stats.merges + 1)
    return state._replace(log_keys=log_keys, log_counts=log_counts,
                          log_ptr=n_carry, stats=stats)


def _stage(cfg: FlashTableConfig, state: DeviceTableState, keys, cnts
           ) -> DeviceTableState:
    """Append a deduped chunk to the MDB-L log (sequential write)."""
    chunk = keys.shape[0]
    cap = cfg.log_capacity

    def do_merge(st):
        return _merge_now(cfg, st)

    state = jax.lax.cond(state.log_ptr + chunk > cap, do_merge,
                         lambda st: st, state)
    log_keys = jax.lax.dynamic_update_slice(state.log_keys, keys,
                                            (state.log_ptr,))
    log_counts = jax.lax.dynamic_update_slice(state.log_counts, cnts,
                                              (state.log_ptr,))
    n_new = (keys != EMPTY).sum(dtype=jnp.int32)
    stats = state.stats._replace(
        staged_entries=state.stats.staged_entries + n_new,
        stages=state.stats.stages + 1)
    return state._replace(log_keys=log_keys, log_counts=log_counts,
                          log_ptr=state.log_ptr + chunk, stats=stats)


@functools.partial(jax.jit, static_argnums=0)
def update(cfg: FlashTableConfig, state: DeviceTableState, tokens,
           deltas: Optional[jax.Array] = None) -> DeviceTableState:
    """Insert a batch of tokens (or (token, Δ) pairs) into the table."""
    tokens = tokens.astype(jnp.int32)
    if deltas is None:
        keys, cnts = hops.accumulate(tokens)
    else:
        keys, cnts = accumulate_deltas(tokens, deltas.astype(jnp.int32))
    if cfg.scheme == "MB":
        # no change segment: bucket + merge on every flush (paper's MB)
        pair = cfg.pair
        uk, uc, carry_k, carry_c, _ = hops.bucket_updates(
            pair, keys, cnts, cfg.max_updates_per_block)
        nk, nc, spill_k, spill_c = hops.merge(
            pair, state.keys, state.counts, uk, uc, cfg.interpret)
        state = state._replace(keys=nk, counts=nc)
        state = _append_overflow(state, spill_k, spill_c)
        n_b = cfg.num_blocks
        stats = state.stats._replace(
            tile_loads=state.stats.tile_loads + n_b,
            tile_stores=state.stats.tile_stores + n_b,
            merges=state.stats.merges + 1)
        return state._replace(stats=stats)
    if cfg.scheme == "MDB-L":
        return _stage(cfg, state, keys, cnts)
    raise ValueError(f"unknown scheme {cfg.scheme}")


@functools.partial(jax.jit, static_argnums=0)
def flush(cfg: FlashTableConfig, state: DeviceTableState) -> DeviceTableState:
    """Force a merge of any staged state (end-of-stream / checkpoint)."""
    if cfg.scheme == "MB":
        return state
    return _merge_now(cfg, state)


def _scan_segment(seg_keys, seg_counts, q, chunk: int = 1024):
    """Masked linear scan of a log/overflow segment for a query batch."""
    cap = seg_keys.shape[0]
    chunk = min(chunk, cap)
    n_chunks = -(-cap // chunk)

    def body(i, acc):
        lk = jax.lax.dynamic_slice(seg_keys, (i * chunk,), (chunk,))
        lc = jax.lax.dynamic_slice(seg_counts, (i * chunk,), (chunk,))
        m = (q[:, None] == lk[None, :]) & (lk[None, :] != EMPTY)
        return acc + jnp.sum(m * lc[None, :], axis=1, dtype=jnp.int32)

    return jax.lax.fori_loop(0, n_chunks,
                             body, jnp.zeros(q.shape, jnp.int32))


@functools.partial(jax.jit, static_argnums=0)
def lookup(cfg: FlashTableConfig, state: DeviceTableState, q_keys
           ) -> Tuple[jax.Array, jax.Array]:
    """Point queries (paper §2.7): data segment (Pallas probe) + change
    segment scan + overflow scan. Returns (counts, probe_distances)."""
    q = q_keys.astype(jnp.int32)
    cnt, dist = hops.query_sorted(cfg.pair, state.keys, state.counts, q,
                                  cfg.interpret)
    cnt = cnt + _scan_segment(state.log_keys, state.log_counts, q)
    cnt = cnt + _scan_segment(state.ov_keys, state.ov_counts, q)
    return cnt, dist


@functools.partial(jax.jit, static_argnums=0)
def load_factor(cfg: FlashTableConfig, state: DeviceTableState) -> jax.Array:
    return (state.keys != EMPTY).mean()
