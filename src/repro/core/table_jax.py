"""Device-resident (JAX) counting hash table — the TPU-native twin of
:mod:`table_sim`, used by the framework's data-statistics, MoE-accounting
and serving layers.

Mapping (DESIGN.md §2): HBM table = data segment; ``sort+segment_sum``
dedup = RAM buffer; HBM append-log = change segment (monolithic for MDB-L,
partitioned for MDB); Pallas tile merge = block-level update. Stats
counters mirror the paper's ledger: ``tile_stores`` is the clean/wear
analogue (one per block rewrite).

This module is *scheme policy only* (DESIGN.md §3): when each of the
paper's three schemes stages, drains and merges. The segment state record
and every shared op (pointer-bumped staging, dirty-block merges, query
scans) live in :mod:`segments`; the host-side RAM buffer H_R in front of
this module is :mod:`write_engine`.

* ``MB``    — no change segment; every update batch is bucketed and merged
  immediately into the dirty blocks it touches.
* ``MDB``   — partitioned change segment: partition ``p`` buffers updates
  for the ``k`` consecutive data blocks ``[p*k, (p+1)*k)``; a full
  partition drains through a ``k``-block dirty merge (exactly ``k`` tile
  rewrites, not ``num_blocks``).
* ``MDB-L`` — monolithic log change segment; sequential appends; a full
  log drains through a dirty merge over only the blocks with staged keys.

Everything is functional: ``state -> op -> state`` and jit-friendly; the
scheme is a static config choice, so each policy compiles to its own
program. The ``update``/``flush`` entry points **donate** the incoming
state (DESIGN.md §7): the old state's buffers are reused in place rather
than copied — callers must rebind (``state = update(cfg, state, ...)``)
and never touch the donated value again.

Since the store's flush went asynchronous (DESIGN.md §9) donation happens
*off-thread*: the background drain worker is the only code allowed to
call the donated entry points while a drain is in flight, and it guards
every dispatch with :func:`segments.assert_live` (re-exported here as
``assert_live``) so a raced or reused state fails loudly instead of as
an opaque XLA deleted-buffer error.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..kernels.flash_hash import ops as hops
from . import segments as seg
from .hashing import Pow2Hash
from .hashing import filter_words_for as hashing_filter_words_for

EMPTY = seg.EMPTY

# re-exported state records: the segment layer owns them, the public API
# (and every existing consumer) reaches them through this module
TableStats = seg.TableStats
DeviceTableState = seg.DeviceTableState
accumulate_deltas = seg.accumulate_deltas
assert_live = seg.assert_live             # off-thread donation guard (§9)
_scan_segment = seg.scan_segment          # back-compat alias (tests)

_SCHEMES = ("MB", "MDB", "MDB-L")


@dataclasses.dataclass(frozen=True)
class FlashTableConfig:
    """Geometry + policy of a device table."""

    q_log2: int = 16              # total entries (power of two)
    r_log2: int = 10              # entries per block (≥128-lane friendly)
    scheme: str = "MDB-L"         # "MB" | "MDB" | "MDB-L"
    log_capacity: int = 1 << 14   # change-segment entries (MDB / MDB-L)
    cs_partitions: int = 8        # MDB: change-segment partitions
    max_updates_per_block: int = 1 << 9   # VMEM cap per tile merge
    overflow_capacity: int = 1 << 10
    interpret: bool = True        # Pallas interpret mode (CPU container)
    filters: bool = True          # consult the blocked-Bloom filters on
                                  # lookups (§12). Maintenance always runs
                                  # (state invariants stay uniform); this
                                  # only gates the negative-lookup fast
                                  # path, so it can be toggled per table
                                  # for A/B benchmarks.

    def __post_init__(self):
        if self.scheme not in _SCHEMES:
            raise ValueError(f"unknown scheme {self.scheme!r}; "
                             f"expected one of {_SCHEMES}")
        if self.scheme == "MDB":
            if self.cs_partitions <= 0:
                raise ValueError("cs_partitions must be positive")
            if self.num_blocks % self.cs_partitions:
                raise ValueError(
                    f"cs_partitions={self.cs_partitions} must divide "
                    f"num_blocks={self.num_blocks}")
            if self.log_capacity % self.cs_partitions:
                raise ValueError(
                    f"cs_partitions={self.cs_partitions} must divide "
                    f"log_capacity={self.log_capacity}")

    @property
    def pair(self) -> Pow2Hash:
        return Pow2Hash(q_log2=self.q_log2, r_log2=self.r_log2)

    @property
    def num_blocks(self) -> int:
        return 1 << (self.q_log2 - self.r_log2)

    @property
    def block_entries(self) -> int:
        return 1 << self.r_log2

    @property
    def blocks_per_partition(self) -> int:
        """MDB: data blocks covered by one change-segment partition."""
        return self.num_blocks // self.cs_partitions

    @property
    def partition_capacity(self) -> int:
        """MDB: staged entries one change-segment partition can hold."""
        return self.log_capacity // self.cs_partitions

    @property
    def filter_words(self) -> int:
        """uint32 lanes per block's blocked-Bloom filter row (§12)."""
        return hashing_filter_words_for(self.block_entries)


def init(cfg: FlashTableConfig) -> DeviceTableState:
    if cfg.scheme == "MDB":
        log_shape = (cfg.cs_partitions, cfg.partition_capacity)
        log_ptr_shape = (cfg.cs_partitions,)
    else:
        log_shape = (cfg.log_capacity,)
        log_ptr_shape = ()
    return seg.init_state(cfg.num_blocks, cfg.block_entries,
                          log_shape, log_ptr_shape, cfg.overflow_capacity,
                          cfg.filter_words)


# ---------------------------------------------------------------------------
# MB policy (§2.3): no change segment
# ---------------------------------------------------------------------------
def _mb_update(cfg: FlashTableConfig, state: DeviceTableState, keys, cnts
               ) -> DeviceTableState:
    """MB: merge the deduped batch immediately.

    Carry (a block receiving more than ``max_updates_per_block`` updates in
    one batch) is merged again until drained, so no counts are lost."""
    state, carry_k, carry_c = seg.merge_dirty_batch(cfg, state, keys, cnts)

    def cond(t):
        return (t[1] != EMPTY).any()

    def body(t):
        st, ck, cc = t
        return seg.merge_dirty_batch(cfg, st, ck, cc)

    state, _, _ = jax.lax.while_loop(cond, body, (state, carry_k, carry_c))
    return state._replace(
        stats=state.stats._replace(merges=state.stats.merges + 1))


# ---------------------------------------------------------------------------
# MDB-L policy (§2.4): monolithic log change segment
# ---------------------------------------------------------------------------
def _stage(cfg: FlashTableConfig, state: DeviceTableState, keys, cnts
           ) -> DeviceTableState:
    """Append a deduped chunk to the MDB-L log (sequential write).

    Merges *repeatedly* until the chunk fits behind the carried log head:
    a single forced merge may leave ``n_carry`` entries such that
    ``log_ptr + chunk`` still exceeds the capacity, and
    ``dynamic_update_slice`` would then clamp the start index and silently
    overwrite carried entries. Callers guarantee ``chunk <= log_capacity``
    (see :func:`update`), so the loop terminates: every merge shrinks the
    per-block carry by ``max_updates_per_block``.
    """
    chunk = keys.shape[0]
    cap = cfg.log_capacity
    assert chunk <= cap, "update() must split chunks larger than the log"

    state = jax.lax.while_loop(
        lambda st: st.log_ptr + chunk > cap,
        lambda st: seg.drain_log(cfg, st),
        state)
    return seg.append_log(cfg, state, keys, cnts)


# ---------------------------------------------------------------------------
# MDB policy (§2.4): partitioned change segment
# ---------------------------------------------------------------------------
def _mdb_merge_where(cfg: FlashTableConfig, state: DeviceTableState, mask
                     ) -> DeviceTableState:
    """Merge every partition whose ``mask`` entry is set."""
    def body(p, st):
        return jax.lax.cond(mask[p],
                            lambda s: seg.merge_partition(cfg, s, p),
                            lambda s: s, st)
    return jax.lax.fori_loop(0, cfg.cs_partitions, body, state)


def _mdb_update(cfg: FlashTableConfig, state: DeviceTableState, keys, cnts
                ) -> DeviceTableState:
    """MDB: stage into per-partition buffers; a partition that cannot fit
    the incoming entries is drained first through its k-block dirty merge.

    Like the MDB-L stage path, draining loops until everything fits: a
    merge can leave carry at the partition head, so under hot-block
    pressure one drain may not make room for the whole chunk. Callers
    guarantee ``chunk <= partition_capacity`` (see :func:`update`) and
    every drain strictly shrinks a non-empty partition's staged count, so
    the loop terminates with no counts dropped."""
    P = cfg.cs_partitions
    part = seg.partition_of(cfg, keys)
    n_inc = jnp.zeros((P,), jnp.int32).at[part].add(
        (keys != EMPTY).astype(jnp.int32), mode="drop")
    state = _mdb_merge_where(
        cfg, state, state.log_ptr + n_inc > cfg.partition_capacity)
    state, rest_k, rest_c = seg.scatter_partitions(cfg, state, keys, cnts)

    def cond(t):
        return (t[1] != EMPTY).any()

    def body(t):
        st, rk, rc = t
        n_rest = jnp.zeros((P,), jnp.int32).at[seg.partition_of(cfg, rk)
                                               ].add(
            (rk != EMPTY).astype(jnp.int32), mode="drop")
        st = _mdb_merge_where(cfg, st, n_rest > 0)
        return seg.scatter_partitions(cfg, st, rk, rc)

    state, _, _ = jax.lax.while_loop(cond, body, (state, rest_k, rest_c))
    return state._replace(
        stats=state.stats._replace(stages=state.stats.stages + 1))


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------
def _update_impl(cfg: FlashTableConfig, state: DeviceTableState, tokens,
                 deltas: Optional[jax.Array] = None) -> DeviceTableState:
    tokens = tokens.astype(jnp.int32)
    if deltas is None:
        keys, cnts = hops.accumulate(tokens)
    else:
        keys, cnts = accumulate_deltas(tokens, deltas.astype(jnp.int32))
    if cfg.scheme == "MB":
        return _mb_update(cfg, state, keys, cnts)
    if cfg.scheme == "MDB":
        step = cfg.partition_capacity
        stage_fn = _mdb_update
    else:  # MDB-L
        step = cfg.log_capacity
        stage_fn = _stage
    # oversized chunks can never fit a (drained) change segment in one
    # piece — split them statically so staging always makes progress.
    if keys.shape[0] <= step:
        return stage_fn(cfg, state, keys, cnts)
    for i in range(0, keys.shape[0], step):
        state = stage_fn(cfg, state, keys[i:i + step], cnts[i:i + step])
    return state


#: Insert a batch of tokens (or (token, Δ) pairs) into the table.
#: ``state`` is **donated**: its buffers are updated in place (no HBM copy
#: of the table per call). Rebind the result and never reuse the argument.
update = functools.partial(jax.jit, static_argnums=0,
                           donate_argnums=1)(_update_impl)

#: Un-donated twin of :func:`update` — the pre-engine per-call discipline
#: (every call copies the table state). Kept for benchmarks that measure
#: what donation buys (``fig4dev``); new code should use :func:`update`.
update_copying = functools.partial(jax.jit, static_argnums=0)(_update_impl)


@functools.partial(jax.jit, static_argnums=0, donate_argnums=1)
def flush(cfg: FlashTableConfig, state: DeviceTableState) -> DeviceTableState:
    """Force a merge of any staged state (end-of-stream / checkpoint).

    Like :func:`update`, donates ``state``."""
    if cfg.scheme == "MB":
        return state
    if cfg.scheme == "MDB":
        return _mdb_merge_where(cfg, state, state.log_ptr > 0)
    return jax.lax.cond(state.log_ptr > 0,
                        lambda st: seg.drain_log(cfg, st),
                        lambda st: st, state)


@functools.partial(jax.jit, static_argnums=0)
def lookup_ex(cfg: FlashTableConfig, state: DeviceTableState, q_keys
              ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Batched point queries (paper §2.7): data segment (blocked Pallas
    probe — one tile fetch per queried block per wave) + change segment
    scan + overflow scan, each shared across the whole batch. Returns
    (counts, probe_distances, tile_loads); ``EMPTY`` entries are padding
    → ``(0, 0)``.

    With ``cfg.filters`` the blocked-Bloom pre-pass inside
    :func:`ops.query_blocked_ex` answers definite misses before any tile
    fetch — a filter-killed key reports distance 0 and contributes no
    ``tile_loads``. The filter also covers the change segment and
    overflow (staging ORs bits in too), so a filter-negative needs the
    scans only for the *surviving* keys — but the scans are batch-shared
    fixed-shape loops, so they run regardless; the engine-level short
    circuit (:mod:`query_engine`) is what skips whole dispatches.

    Read path: ``state`` is *not* donated.
    """
    q = q_keys.astype(jnp.int32)
    fw = state.filter_words if cfg.filters else None
    cnt, dist, tiles = hops.query_blocked_ex(
        cfg.pair, state.keys, state.counts, q, 128, cfg.interpret, fw)
    if cfg.scheme != "MB":  # MB has no change segment to consolidate
        cnt = cnt + seg.scan_segment(state.log_keys.reshape(-1),
                                     state.log_counts.reshape(-1), q)
    cnt = cnt + seg.scan_segment(state.ov_keys, state.ov_counts, q)
    return cnt, dist, tiles


def lookup(cfg: FlashTableConfig, state: DeviceTableState, q_keys
           ) -> Tuple[jax.Array, jax.Array]:
    """:func:`lookup_ex` without the tile count (compat entry)."""
    cnt, dist, _ = lookup_ex(cfg, state, q_keys)
    return cnt, dist


@functools.partial(jax.jit, static_argnums=0)
def filter_probe(cfg: FlashTableConfig, state: DeviceTableState, q_keys
                 ) -> jax.Array:
    """Engine-level may-contain verdicts (one cheap dispatch, no tiles).

    Bool ``(Q,)``: False ⇒ the key is definitively absent from the whole
    device table (data + change + overflow segments — staging and merge
    both maintain the filter), so the engine can answer 0 without
    dispatching a lookup at all. ``EMPTY`` keys test False."""
    q = q_keys.astype(jnp.int32)
    return seg.filter_may_contain(cfg.pair, state.filter_words, q)


@functools.partial(jax.jit, static_argnums=0)
def load_factor(cfg: FlashTableConfig, state: DeviceTableState) -> jax.Array:
    return (state.keys != EMPTY).mean()
