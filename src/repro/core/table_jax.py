"""Device-resident (JAX) counting hash table — the TPU-native twin of
:mod:`table_sim`, used by the framework's data-statistics, MoE-accounting
and serving layers.

Mapping (DESIGN.md §2): HBM table = data segment; ``sort+segment_sum``
dedup = RAM buffer; HBM append-log = change segment (monolithic for MDB-L,
partitioned for MDB); Pallas tile merge = block-level update. Stats
counters mirror the paper's ledger: ``tile_stores`` is the clean/wear
analogue (one per block rewrite).

All three of the paper's schemes are implemented (DESIGN.md §3):

* ``MB``    — no change segment; every update batch is bucketed and merged
  immediately into the dirty blocks it touches.
* ``MDB``   — partitioned change segment: partition ``p`` buffers updates
  for the ``k`` consecutive data blocks ``[p*k, (p+1)*k)``; a full
  partition drains through a ``k``-block dirty merge (exactly ``k`` tile
  rewrites, not ``num_blocks``).
* ``MDB-L`` — monolithic log change segment; sequential appends; a full
  log drains through a dirty merge over only the blocks with staged keys.

Every merge path runs the :func:`..kernels.flash_hash.ops.merge_dirty`
Pallas kernel, so ``tile_loads``/``tile_stores`` count only blocks that
actually had staged updates (MDB additionally pays for its whole
partition, per the paper's CS-block erase) — the per-scheme clean counts
of the paper's Figure 5, on device.

Everything is functional: ``state -> op -> state`` and jit-friendly; the
scheme is a static config choice, so each policy compiles to its own
program.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..kernels.flash_hash import ops as hops
from .hashing import Pow2Hash

EMPTY = hops.EMPTY

_SCHEMES = ("MB", "MDB", "MDB-L")


@dataclasses.dataclass(frozen=True)
class FlashTableConfig:
    """Geometry + policy of a device table."""

    q_log2: int = 16              # total entries (power of two)
    r_log2: int = 10              # entries per block (≥128-lane friendly)
    scheme: str = "MDB-L"         # "MB" | "MDB" | "MDB-L"
    log_capacity: int = 1 << 14   # change-segment entries (MDB / MDB-L)
    cs_partitions: int = 8        # MDB: change-segment partitions
    max_updates_per_block: int = 1 << 9   # VMEM cap per tile merge
    overflow_capacity: int = 1 << 10
    interpret: bool = True        # Pallas interpret mode (CPU container)

    def __post_init__(self):
        if self.scheme not in _SCHEMES:
            raise ValueError(f"unknown scheme {self.scheme!r}; "
                             f"expected one of {_SCHEMES}")
        if self.scheme == "MDB":
            if self.cs_partitions <= 0:
                raise ValueError("cs_partitions must be positive")
            if self.num_blocks % self.cs_partitions:
                raise ValueError(
                    f"cs_partitions={self.cs_partitions} must divide "
                    f"num_blocks={self.num_blocks}")
            if self.log_capacity % self.cs_partitions:
                raise ValueError(
                    f"cs_partitions={self.cs_partitions} must divide "
                    f"log_capacity={self.log_capacity}")

    @property
    def pair(self) -> Pow2Hash:
        return Pow2Hash(q_log2=self.q_log2, r_log2=self.r_log2)

    @property
    def num_blocks(self) -> int:
        return 1 << (self.q_log2 - self.r_log2)

    @property
    def block_entries(self) -> int:
        return 1 << self.r_log2

    @property
    def blocks_per_partition(self) -> int:
        """MDB: data blocks covered by one change-segment partition."""
        return self.num_blocks // self.cs_partitions

    @property
    def partition_capacity(self) -> int:
        """MDB: staged entries one change-segment partition can hold."""
        return self.log_capacity // self.cs_partitions


class TableStats(NamedTuple):
    tile_loads: jax.Array       # blocks read from HBM during merges
    tile_stores: jax.Array      # blocks rewritten (the paper's "cleans")
    staged_entries: jax.Array   # entries appended to the log (seq writes)
    merges: jax.Array
    stages: jax.Array
    dropped: jax.Array          # capacity losses (should be 0)
    carried: jax.Array          # updates deferred past a tile's max_u cap


class DeviceTableState(NamedTuple):
    keys: jax.Array        # (n_b, r) int32
    counts: jax.Array      # (n_b, r) int32
    log_keys: jax.Array    # change segment: (log_cap,) for MDB-L,
                           # (cs_partitions, part_cap) for MDB
    log_counts: jax.Array  # same shape as log_keys
    log_ptr: jax.Array     # () int32 for MDB-L, (cs_partitions,) for MDB
    ov_keys: jax.Array     # (ov_cap,) int32 — overflow region
    ov_counts: jax.Array   # (ov_cap,) int32
    ov_ptr: jax.Array      # () int32
    stats: TableStats


def _zero_stats() -> TableStats:
    z = lambda: jnp.zeros((), jnp.int32)
    return TableStats(tile_loads=z(), tile_stores=z(), staged_entries=z(),
                      merges=z(), stages=z(), dropped=z(), carried=z())


def init(cfg: FlashTableConfig) -> DeviceTableState:
    n_b, r = cfg.num_blocks, cfg.block_entries
    if cfg.scheme == "MDB":
        log_shape = (cfg.cs_partitions, cfg.partition_capacity)
        log_ptr = jnp.zeros((cfg.cs_partitions,), jnp.int32)
    else:
        log_shape = (cfg.log_capacity,)
        log_ptr = jnp.zeros((), jnp.int32)
    return DeviceTableState(
        keys=jnp.full((n_b, r), EMPTY, jnp.int32),
        counts=jnp.zeros((n_b, r), jnp.int32),
        log_keys=jnp.full(log_shape, EMPTY, jnp.int32),
        log_counts=jnp.zeros(log_shape, jnp.int32),
        log_ptr=log_ptr,
        ov_keys=jnp.full((cfg.overflow_capacity,), EMPTY, jnp.int32),
        ov_counts=jnp.zeros((cfg.overflow_capacity,), jnp.int32),
        ov_ptr=jnp.zeros((), jnp.int32),
        stats=_zero_stats(),
    )


@jax.jit
def accumulate_deltas(tokens, deltas):
    """RAM-buffer dedup with explicit deltas (supports deletion-by-−1)."""
    order = jnp.argsort(tokens, stable=True)
    t = tokens[order]
    d = deltas[order]
    is_head = jnp.concatenate([jnp.ones((1,), bool), t[1:] != t[:-1]])
    is_head &= t != EMPTY
    seg = jnp.cumsum(is_head) - 1
    sums = jax.ops.segment_sum(jnp.where(t != EMPTY, d, 0), seg,
                               num_segments=t.shape[0])
    comp = jnp.argsort(jnp.where(is_head, 0, 1), stable=True)
    keys = jnp.where(is_head[comp], t[comp], EMPTY)
    cnts = jnp.where(is_head[comp],
                     sums[jnp.clip(seg[comp], 0, t.shape[0] - 1)], 0)
    return keys, cnts.astype(jnp.int32)


def _append_overflow(state: DeviceTableState, spill_k, spill_c):
    """Compact spilled entries into the overflow region (page-chained in the
    paper; a pointer-bumped array here)."""
    flat_k = spill_k.reshape(-1)
    flat_c = spill_c.reshape(-1)
    valid = flat_k != EMPTY
    ov_cap = state.ov_keys.shape[0]
    pos = state.ov_ptr + jnp.cumsum(valid.astype(jnp.int32)) - 1
    in_range = valid & (pos < ov_cap)
    idx = jnp.where(in_range, pos, ov_cap)  # OOB drops
    ov_keys = state.ov_keys.at[idx].set(jnp.where(in_range, flat_k, EMPTY),
                                        mode="drop")
    ov_counts = state.ov_counts.at[idx].add(flat_c * in_range, mode="drop")
    n_spill = valid.sum(dtype=jnp.int32)
    n_fit = in_range.sum(dtype=jnp.int32)
    return state._replace(
        ov_keys=ov_keys, ov_counts=ov_counts,
        ov_ptr=jnp.minimum(state.ov_ptr + n_spill, ov_cap),
        stats=state.stats._replace(
            dropped=state.stats.dropped + (n_spill - n_fit)))


def _compact(keys, counts):
    """Compact valid entries to the front, EMPTY-pad the tail."""
    valid = keys != EMPTY
    comp = jnp.argsort(~valid, stable=True)
    return (jnp.where(valid[comp], keys[comp], EMPTY),
            jnp.where(valid[comp], counts[comp], 0),
            valid.sum(dtype=jnp.int32))


# ---------------------------------------------------------------------------
# dirty-block merge machinery (shared by MB and MDB-L)
# ---------------------------------------------------------------------------
def _merge_dirty_batch(cfg: FlashTableConfig, state: DeviceTableState,
                       keys, cnts):
    """One dirty-block merge pass over a flat batch of staged updates.

    The dirty set is computed from the staged keys' ``s()`` values; the
    kernel grid walks a *permutation* of all blocks with the dirty ones
    first (every block id appears exactly once, so revisit hazards cannot
    arise), but only the dirty prefix carries updates and only it is
    charged to ``tile_loads``/``tile_stores``. Updates beyond a block's
    ``max_updates_per_block`` are returned as carry and must stay staged.

    Pallas grids are static, so the permutation still has ``num_blocks``
    steps — the clean suffix is a no-op visit, and the *counters* (not
    the kernel walltime) model the paper's per-scheme cleans here. A
    truly partial grid needs a statically-known dirty count; that is
    exactly what MDB's partition layout provides
    (:func:`_mdb_merge_partition`, grid length ``k``).
    """
    pair = cfg.pair
    n_b = cfg.num_blocks
    valid = keys != EMPTY
    blk = jnp.where(valid, pair.s(keys), 0).astype(jnp.int32)
    per_block = jnp.zeros((n_b,), jnp.int32).at[blk].add(
        valid.astype(jnp.int32))
    dirty = per_block > 0
    # grid order: dirty blocks (ascending id — the semi-random write
    # discipline), then clean blocks with EMPTY update rows (no-op visits).
    perm = jnp.argsort(jnp.where(dirty, 0, 1), stable=True).astype(jnp.int32)
    inv = jnp.zeros((n_b,), jnp.int32).at[perm].set(
        jnp.arange(n_b, dtype=jnp.int32))
    rows = jnp.where(valid, inv[blk], n_b).astype(jnp.int32)
    uk, uc, carry_k, carry_c, n_carried = hops.bucket_rows(
        rows, keys, cnts, n_b, cfg.max_updates_per_block)
    nk, nc, spill_k, spill_c = hops.merge_dirty(
        pair, state.keys, state.counts, perm, uk, uc, cfg.interpret)
    state = state._replace(keys=nk, counts=nc)
    state = _append_overflow(state, spill_k, spill_c)
    n_dirty = dirty.sum(dtype=jnp.int32)
    stats = state.stats._replace(
        tile_loads=state.stats.tile_loads + n_dirty,
        tile_stores=state.stats.tile_stores + n_dirty,
        carried=state.stats.carried + n_carried)
    return state._replace(stats=stats), carry_k, carry_c


def _mb_update(cfg: FlashTableConfig, state: DeviceTableState, keys, cnts
               ) -> DeviceTableState:
    """MB (§2.3): no change segment — merge the deduped batch immediately.

    Carry (a block receiving more than ``max_updates_per_block`` updates in
    one batch) is merged again until drained, so no counts are lost."""
    state, carry_k, carry_c = _merge_dirty_batch(cfg, state, keys, cnts)

    def cond(t):
        return (t[1] != EMPTY).any()

    def body(t):
        st, ck, cc = t
        return _merge_dirty_batch(cfg, st, ck, cc)

    state, _, _ = jax.lax.while_loop(cond, body, (state, carry_k, carry_c))
    return state._replace(
        stats=state.stats._replace(merges=state.stats.merges + 1))


# ---------------------------------------------------------------------------
# MDB-L: monolithic log change segment
# ---------------------------------------------------------------------------
def _merge_now(cfg: FlashTableConfig, state: DeviceTableState
               ) -> DeviceTableState:
    """Drain the MDB-L log into the data segment (dirty-block merge)."""
    state, carry_k, carry_c = _merge_dirty_batch(
        cfg, state, state.log_keys, state.log_counts)
    # carried updates (exceeded a tile's max_u) stay staged, compacted to
    # the log head; everything else is cleared.
    log_keys, log_counts, n_carry = _compact(carry_k, carry_c)
    stats = state.stats._replace(merges=state.stats.merges + 1)
    return state._replace(log_keys=log_keys, log_counts=log_counts,
                          log_ptr=n_carry, stats=stats)


def _stage(cfg: FlashTableConfig, state: DeviceTableState, keys, cnts
           ) -> DeviceTableState:
    """Append a deduped chunk to the MDB-L log (sequential write).

    Merges *repeatedly* until the chunk fits behind the carried log head:
    a single forced merge may leave ``n_carry`` entries such that
    ``log_ptr + chunk`` still exceeds the capacity, and
    ``dynamic_update_slice`` would then clamp the start index and silently
    overwrite carried entries. Callers guarantee ``chunk <= log_capacity``
    (see :func:`update`), so the loop terminates: every merge shrinks the
    per-block carry by ``max_updates_per_block``.
    """
    chunk = keys.shape[0]
    cap = cfg.log_capacity
    assert chunk <= cap, "update() must split chunks larger than the log"

    state = jax.lax.while_loop(
        lambda st: st.log_ptr + chunk > cap,
        lambda st: _merge_now(cfg, st),
        state)
    log_keys = jax.lax.dynamic_update_slice(state.log_keys, keys,
                                            (state.log_ptr,))
    log_counts = jax.lax.dynamic_update_slice(state.log_counts, cnts,
                                              (state.log_ptr,))
    n_new = (keys != EMPTY).sum(dtype=jnp.int32)
    stats = state.stats._replace(
        staged_entries=state.stats.staged_entries + n_new,
        stages=state.stats.stages + 1)
    return state._replace(log_keys=log_keys, log_counts=log_counts,
                          log_ptr=state.log_ptr + chunk, stats=stats)


# ---------------------------------------------------------------------------
# MDB: partitioned change segment
# ---------------------------------------------------------------------------
def _mdb_merge_partition(cfg: FlashTableConfig, state: DeviceTableState, p
                         ) -> DeviceTableState:
    """Drain change-segment partition ``p`` into its ``k`` data blocks.

    The dirty set is exactly the partition's block range
    ``[p*k, (p+1)*k)`` — the paper's §2.4 CS-block merge — so the merge
    costs ``k`` tile loads + stores, never ``num_blocks``."""
    pair = cfg.pair
    k = cfg.blocks_per_partition
    sk = jax.lax.dynamic_index_in_dim(state.log_keys, p, keepdims=False)
    sc = jax.lax.dynamic_index_in_dim(state.log_counts, p, keepdims=False)
    rows = jnp.where(sk != EMPTY, pair.s(sk) - p * k, k).astype(jnp.int32)
    uk, uc, carry_k, carry_c, n_carried = hops.bucket_rows(
        rows, sk, sc, k, cfg.max_updates_per_block)
    dirty = (p * k + jnp.arange(k)).astype(jnp.int32)
    nk, nc, spill_k, spill_c = hops.merge_dirty(
        pair, state.keys, state.counts, dirty, uk, uc, cfg.interpret)
    state = state._replace(keys=nk, counts=nc)
    state = _append_overflow(state, spill_k, spill_c)
    # carried updates stay staged at the head of the partition
    new_k, new_c, n_carry = _compact(carry_k, carry_c)
    log_keys = jax.lax.dynamic_update_index_in_dim(
        state.log_keys, new_k, p, 0)
    log_counts = jax.lax.dynamic_update_index_in_dim(
        state.log_counts, new_c, p, 0)
    stats = state.stats._replace(
        tile_loads=state.stats.tile_loads + k,
        tile_stores=state.stats.tile_stores + k,
        merges=state.stats.merges + 1,
        carried=state.stats.carried + n_carried)
    return state._replace(log_keys=log_keys, log_counts=log_counts,
                          log_ptr=state.log_ptr.at[p].set(n_carry),
                          stats=stats)


def _mdb_merge_where(cfg: FlashTableConfig, state: DeviceTableState, mask
                     ) -> DeviceTableState:
    """Merge every partition whose ``mask`` entry is set."""
    def body(p, st):
        return jax.lax.cond(mask[p],
                            lambda s: _mdb_merge_partition(cfg, s, p),
                            lambda s: s, st)
    return jax.lax.fori_loop(0, cfg.cs_partitions, body, state)


def _mdb_partition_of(cfg: FlashTableConfig, keys):
    """Partition id per key; invalid keys map to the sentinel P."""
    P = cfg.cs_partitions
    return jnp.where(keys != EMPTY,
                     cfg.pair.s(keys) // cfg.blocks_per_partition,
                     P).astype(jnp.int32)


def _mdb_scatter(cfg: FlashTableConfig, state: DeviceTableState, keys, cnts):
    """Append a deduped chunk into its partitions (semi-random page writes).

    Returns (state, rest_keys, rest_counts): entries whose partition was
    full are *not* staged and come back EMPTY-compacted for the caller to
    retry after a merge."""
    P = cfg.cs_partitions
    part_cap = cfg.partition_capacity
    (U,) = keys.shape
    part = _mdb_partition_of(cfg, keys)
    order = jnp.argsort(part, stable=True)
    sk, sc, sp = keys[order], cnts[order], part[order]
    start = jnp.searchsorted(sp, jnp.arange(P + 1, dtype=sp.dtype))
    rank = jnp.arange(U, dtype=jnp.int32) - start[jnp.clip(sp, 0, P)]
    pos = state.log_ptr[jnp.clip(sp, 0, P - 1)] + rank
    fits = (sp < P) & (pos < part_cap)
    row = jnp.where(fits, sp, P)
    col = jnp.where(fits, pos, 0)
    log_keys = state.log_keys.at[row, col].set(sk, mode="drop")
    log_counts = state.log_counts.at[row, col].set(sc, mode="drop")
    n_fit = jnp.zeros((P,), jnp.int32).at[row].add(fits.astype(jnp.int32),
                                                   mode="drop")
    rest = (sp < P) & ~fits
    rest_k = jnp.where(rest, sk, EMPTY)
    rest_c = jnp.where(rest, sc, 0)
    stats = state.stats._replace(
        staged_entries=state.stats.staged_entries
        + fits.sum(dtype=jnp.int32))
    state = state._replace(log_keys=log_keys, log_counts=log_counts,
                           log_ptr=state.log_ptr + n_fit, stats=stats)
    return state, rest_k, rest_c


def _mdb_update(cfg: FlashTableConfig, state: DeviceTableState, keys, cnts
                ) -> DeviceTableState:
    """MDB (§2.4): stage into per-partition buffers; a partition that
    cannot fit the incoming entries is drained first through its k-block
    dirty merge.

    Like the MDB-L stage path, draining loops until everything fits: a
    merge can leave carry at the partition head, so under hot-block
    pressure one drain may not make room for the whole chunk. Callers
    guarantee ``chunk <= partition_capacity`` (see :func:`update`) and
    every drain strictly shrinks a non-empty partition's staged count, so
    the loop terminates with no counts dropped."""
    P = cfg.cs_partitions
    part = _mdb_partition_of(cfg, keys)
    n_inc = jnp.zeros((P,), jnp.int32).at[part].add(
        (keys != EMPTY).astype(jnp.int32), mode="drop")
    state = _mdb_merge_where(
        cfg, state, state.log_ptr + n_inc > cfg.partition_capacity)
    state, rest_k, rest_c = _mdb_scatter(cfg, state, keys, cnts)

    def cond(t):
        return (t[1] != EMPTY).any()

    def body(t):
        st, rk, rc = t
        n_rest = jnp.zeros((P,), jnp.int32).at[_mdb_partition_of(cfg, rk)
                                               ].add(
            (rk != EMPTY).astype(jnp.int32), mode="drop")
        st = _mdb_merge_where(cfg, st, n_rest > 0)
        return _mdb_scatter(cfg, st, rk, rc)

    state, _, _ = jax.lax.while_loop(cond, body, (state, rest_k, rest_c))
    return state._replace(
        stats=state.stats._replace(stages=state.stats.stages + 1))


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnums=0)
def update(cfg: FlashTableConfig, state: DeviceTableState, tokens,
           deltas: Optional[jax.Array] = None) -> DeviceTableState:
    """Insert a batch of tokens (or (token, Δ) pairs) into the table."""
    tokens = tokens.astype(jnp.int32)
    if deltas is None:
        keys, cnts = hops.accumulate(tokens)
    else:
        keys, cnts = accumulate_deltas(tokens, deltas.astype(jnp.int32))
    if cfg.scheme == "MB":
        return _mb_update(cfg, state, keys, cnts)
    if cfg.scheme == "MDB":
        step = cfg.partition_capacity
        stage_fn = _mdb_update
    else:  # MDB-L
        step = cfg.log_capacity
        stage_fn = _stage
    # oversized chunks can never fit a (drained) change segment in one
    # piece — split them statically so staging always makes progress.
    if keys.shape[0] <= step:
        return stage_fn(cfg, state, keys, cnts)
    for i in range(0, keys.shape[0], step):
        state = stage_fn(cfg, state, keys[i:i + step], cnts[i:i + step])
    return state


@functools.partial(jax.jit, static_argnums=0)
def flush(cfg: FlashTableConfig, state: DeviceTableState) -> DeviceTableState:
    """Force a merge of any staged state (end-of-stream / checkpoint)."""
    if cfg.scheme == "MB":
        return state
    if cfg.scheme == "MDB":
        return _mdb_merge_where(cfg, state, state.log_ptr > 0)
    return jax.lax.cond(state.log_ptr > 0,
                        lambda st: _merge_now(cfg, st),
                        lambda st: st, state)


def _scan_segment(seg_keys, seg_counts, q, chunk: int = 1024):
    """Masked linear scan of a log/overflow segment for a query batch.

    One scan serves the whole batch (the ``(Q, chunk)`` compare is shared
    across every query), so batched lookups pay the change-segment read
    once rather than per key. The segment is EMPTY-padded up to a chunk
    multiple: ``dynamic_slice`` clamps out-of-range starts, so an
    unpadded non-multiple tail would re-read (and double-count) the
    overlap with the previous chunk.
    """
    cap = seg_keys.shape[0]
    chunk = min(chunk, cap)
    pad = -cap % chunk
    if pad:
        seg_keys = jnp.concatenate(
            [seg_keys, jnp.full((pad,), EMPTY, seg_keys.dtype)])
        seg_counts = jnp.concatenate(
            [seg_counts, jnp.zeros((pad,), seg_counts.dtype)])
    n_chunks = (cap + pad) // chunk

    def body(i, acc):
        lk = jax.lax.dynamic_slice(seg_keys, (i * chunk,), (chunk,))
        lc = jax.lax.dynamic_slice(seg_counts, (i * chunk,), (chunk,))
        m = (q[:, None] == lk[None, :]) & (lk[None, :] != EMPTY)
        return acc + jnp.sum(m * lc[None, :], axis=1, dtype=jnp.int32)

    return jax.lax.fori_loop(0, n_chunks,
                             body, jnp.zeros(q.shape, jnp.int32))


@functools.partial(jax.jit, static_argnums=0)
def lookup(cfg: FlashTableConfig, state: DeviceTableState, q_keys
           ) -> Tuple[jax.Array, jax.Array]:
    """Batched point queries (paper §2.7): data segment (blocked Pallas
    probe — one tile fetch per queried block per wave) + change segment
    scan + overflow scan, each shared across the whole batch. Returns
    (counts, probe_distances); ``EMPTY`` entries are padding → ``(0, 0)``.
    """
    q = q_keys.astype(jnp.int32)
    cnt, dist = hops.query_blocked(cfg.pair, state.keys, state.counts, q,
                                   128, cfg.interpret)
    if cfg.scheme != "MB":  # MB has no change segment to consolidate
        cnt = cnt + _scan_segment(state.log_keys.reshape(-1),
                                  state.log_counts.reshape(-1), q)
    cnt = cnt + _scan_segment(state.ov_keys, state.ov_counts, q)
    return cnt, dist


@functools.partial(jax.jit, static_argnums=0)
def load_factor(cfg: FlashTableConfig, state: DeviceTableState) -> jax.Array:
    return (state.keys != EMPTY).mean()
