"""Host-side batched write engine for the device flash-hash table.

The paper's insert/update axis (§2.2, Figure 4) is won by buffering and
batching writes *before* they reach the device: the RAM buffer H_R
absorbs and dedups the raw token stream, and only threshold-triggered
flushes touch flash. PR 2 industrialized the read path
(:class:`.query_engine.BatchedQueryEngine`); this engine is its write
twin, the front door every writer (TF-IDF ingest, corpus stats, the
serving prefix cache's refcount bumps) goes through instead of calling
``table_jax.update`` per raw batch:

* **host-side H_R** — a token→Δ dict accumulates (and dedups) incoming
  batches; duplicate tokens fold into one entry, Δs that cancel to zero
  drop out entirely (paper §2.6: zero-frequency entries are not
  retained in memory);
* **threshold-triggered flushes** — the device sees traffic only when
  the buffer reaches ``flush_threshold`` unique entries (or on an
  explicit :meth:`flush`/:meth:`merge`), in sorted, deterministic order;
* **fixed-shape padded chunks** — flushed entries are EMPTY-padded up
  to ``chunk``, so each table compiles exactly one update program
  regardless of stream batch sizes (no recompile per new shape);
* **donation** — dispatches go through the donated
  ``table_jax.update``/``flush`` entry points, so the table state is
  updated in place instead of copied per call;
* **automatic invalidation** — a paired
  :class:`~.query_engine.BatchedQueryEngine` is invalidated on every
  flush *by the engine*, not by each caller remembering to. Reads
  routed through :meth:`query_batch` additionally overlay the buffered
  (unflushed) Δs, so writers get read-your-writes semantics without
  forcing a premature device dispatch;
* **double-buffered async flush** (DESIGN.md §9) — with a store-owned
  dispatcher attached, :meth:`flush` *seals* H_R (the active dict swaps
  for a fresh one) and hands the sealed chunk to a background worker:
  ingest keeps filling the new active buffer while the worker drains the
  sealed one through the donated update programs. Reads overlay *both*
  buffers (active + sealed in-flight) on the device counts, so
  read-your-writes survives the flight; sealing again while a drain is
  in flight stalls until it lands (there are exactly two buffers).
  Without a dispatcher the engine drains inline, synchronously — the
  pre-PR5 discipline;
* **ledger** — :class:`WriteEngineStats` counts buffered / deduped /
  dispatched entries and flush events alongside the device-side
  ``TableStats`` wear counters, plus the async ledgers: ``overlap_us``
  (drain time hidden behind continued ingest) and ``stall_us`` (time
  ingest blocked waiting for a drain — the whole drain, when
  synchronous).

Unlike the (state-free) query engine, this engine *owns* the device
state: buffering means an ``update`` may not touch the device at all,
so the current ``DeviceTableState`` lives in ``engine.state`` and every
consumer reaches it through the engine.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class WriteEngineStats:
    """Write-path counters (DESIGN.md §7), the H_R-side ledger that
    complements the device ``TableStats`` wear counters."""

    updates: int = 0             # update() calls (writer-side batches)
    entries: int = 0             # valid (token, Δ) entries received
    buffered: int = 0            # entries that opened a new H_R slot
    deduped: int = 0             # entries absorbed without opening a
                                 # slot (duplicates + cancellations);
                                 # entries == buffered + deduped
    cancelled: int = 0           # Δ sums that hit zero in H_R (§2.6)
    dispatched_entries: int = 0  # unique (token, Δ) pairs sent to device
    dispatches: int = 0          # compiled update launches (chunks)
    flushes: int = 0             # H_R drain events (explicit + auto)
    auto_flushes: int = 0        # threshold-triggered drains
    merges: int = 0              # device-merge (table flush) requests
    invalidations: int = 0       # query-engine invalidations driven
    overlap_us: int = 0          # drain time hidden behind ingest (async)
    stall_us: int = 0            # ingest time blocked on a drain: the
                                 # whole drain when synchronous, only the
                                 # double-buffer waits when async

    def as_dict(self) -> Dict[str, int]:
        return dataclasses.asdict(self)


def dedup_batch(tokens, deltas, empty: int):
    """Validate and pre-fold one raw writer batch: flatten, drop ``empty``
    padding, and collapse duplicate tokens to (unique, Δ-sum) pairs.

    Returns ``(uniq, sums, n_valid)``; shared by every H_R front
    (single-table engine and the sharded store backend)."""
    flat = np.asarray(tokens).reshape(-1).astype(np.int64)
    if deltas is None:
        d = np.ones(flat.size, np.int64)
    else:
        d = np.asarray(deltas).reshape(-1).astype(np.int64)
        if d.size != flat.size:
            raise ValueError(f"deltas size {d.size} != tokens {flat.size}")
    valid = flat != empty
    n_valid = int(valid.sum())
    if n_valid == 0:
        return (np.zeros(0, np.int64),) * 2 + (0,)
    uniq, inv = np.unique(flat[valid], return_inverse=True)
    sums = np.zeros(uniq.size, np.int64)
    np.add.at(sums, inv, d[valid])
    return uniq, sums, n_valid


def fold_entry(buf: Dict[int, int], k: int, s: int) -> int:
    """Fold one (token, Δ-sum) into an H_R dict with the paper's §2.6
    semantics: duplicates accumulate, sums that hit zero drop out (never
    retained in memory). Returns +1 if a new slot opened, 0 if it folded
    into an existing slot, −1 if it cancelled (ledger: buffered /
    deduped / cancelled respectively)."""
    cur = buf.get(k)
    if cur is None:
        if s:
            buf[k] = s
            return 1
        return -1
    if cur + s:
        buf[k] = cur + s
        return 0
    del buf[k]
    return -1


class PartitionHeatLedger:
    """Per-partition write-pressure ledger shared by the wear-tracking
    backends (ISSUE 10): a staged-since-last-merge histogram plus a
    decayed per-merge heat history.

    ``note(parts_counts, wear_delta)`` is the single mutation point —
    callers hold their dispatcher lock (the single-device backend feeds
    it from ``_on_drain`` on the drain worker; the sharded backend from
    its drain body). Semantics are exactly the former
    ``DeviceBackend._on_drain`` ledgers: staged entries accumulate per
    partition; a positive ``wear_delta`` halves the existing heat and
    charges the delta to the staged partitions proportional to volume
    (recent merge pressure, not lifetime totals); ``parts_counts=None``
    marks a forced merge and clears the staged histogram after charging.

    Partition ids are caller-defined — the single-device backend uses
    change-segment partitions (MDB) or data blocks, the sharded backend
    uses *global* block ids so heat is a function of the trace, not of
    how the mesh splits it across hosts/processes.
    """

    def __init__(self) -> None:
        self.heat: Dict[int, float] = {}
        self.staged: Dict[int, int] = {}

    def note(self, parts_counts, wear_delta: float) -> None:
        if parts_counts is not None:
            for p, c in parts_counts:
                self.staged[int(p)] = self.staged.get(int(p), 0) + int(c)
        if wear_delta > 0 and self.staged:
            self.heat = {p: 0.5 * v for p, v in self.heat.items()}
            total = sum(self.staged.values())
            for p, c in self.staged.items():
                self.heat[p] = self.heat.get(p, 0.0) + wear_delta * c / total
        if parts_counts is None:
            self.staged.clear()

    def snapshot(self) -> Tuple[Dict[int, int], Dict[int, float]]:
        """Copies of (staged, heat) — take under the caller's lock, then
        combine with live-buffer pendings lock-free."""
        return dict(self.staged), dict(self.heat)

    def clear(self) -> None:
        self.heat.clear()
        self.staged.clear()


class BatchedWriteEngine:
    """H_R dedup + threshold flush + donated fixed-shape dispatch over
    ``table_jax.update``; double-buffered async drains with a dispatcher
    attached (DESIGN.md §9)."""

    # shared with the drain worker; flashlint FL006 holds every access
    # to the state lock (or an audited under-lock/quiescent method). The
    # H_R double-buffer itself lives in the store's SealedFront.
    _fl_guarded = ("state", "_staged_dirty")

    def __init__(self, cfg, state=None, chunk: int = 4096,
                 flush_threshold: Optional[int] = None,
                 query_engine=None,
                 record: Optional[List[Tuple[np.ndarray, np.ndarray]]] = None,
                 on_flush=None, dispatcher=None, wal=None):
        import jax  # deferred: sim-only users stay jax-free
        import jax.numpy as jnp

        from . import table_jax as tj
        from .store import SealedFront
        self._jax = jax
        self._jnp = jnp
        self._tj = tj
        self.cfg = cfg
        self.state = tj.init(cfg) if state is None else state
        self.chunk = int(chunk)
        self.flush_threshold = int(2 * self.chunk if flush_threshold is None
                                   else flush_threshold)
        self.query_engine = query_engine
        # optional dispatch recorder: every flushed (keys, deltas) chunk is
        # appended, letting tests/benchmarks replay the exact device
        # traffic through direct per-call updates (bit-identity oracle)
        self.record = record
        # optional wear listener: called after every device drain with
        # (drained_keys_or_None, Δtile_stores) — ``None`` keys mark the
        # forced end-of-stream merge, whose wear belongs to everything
        # staged since the last merge. Enabling it syncs the device stats
        # once per drain (flushes are rare; updates stay async).
        self.on_flush = on_flush
        # drain executor (store.FlushDispatcher or None). With one, every
        # drain runs on its worker under its lock; reads take the same
        # lock so (device state, in-flight overlay) is always a
        # consistent snapshot. Without one, drains run inline — the
        # single-threaded pre-PR5 engine needs no locking at all.
        self.dispatcher = dispatcher
        # the seal/settle/poison double-buffer lifecycle (DESIGN.md §9),
        # now owned by one SealedFront shared across backends; ``wal``
        # makes every sealed chunk durable before its drain dispatches
        self.front = SealedFront(dispatcher=dispatcher, parts=1, wal=wal)
        # device entries staged since the last merge. An adopted state may
        # arrive with a non-empty change segment, so it counts as dirty —
        # the first merge() must really run (the pre-PR5 unconditional
        # behaviour), not take the no-op path.
        self._staged_dirty = state is not None
        self.stats = WriteEngineStats()
        if dispatcher is not None:
            dispatcher.ledger = self.stats

    @property
    def _inflight(self):
        """Sealed in-flight chunk (compat alias for ``front._inflight[0]``;
        the race-harness seeded tests poke it directly)."""
        return self.front._inflight[0]

    @_inflight.setter
    def _inflight(self, value):
        self.front._inflight[0] = value

    def _lock(self):
        return (self.dispatcher.lock if self.dispatcher is not None
                else contextlib.nullcontext())

    def _submit(self, fn, label: Optional[str] = None) -> None:
        if self.dispatcher is None:
            fn()
        else:
            self.dispatcher.submit(fn, label=label)

    def _barrier(self) -> None:
        if self.dispatcher is not None:
            self.dispatcher.wait()

    def _trace(self, kind: str, resource=None, rw=None, **meta) -> None:
        """Happens-before harness event; free no-op unless a tracer is
        attached to the dispatcher (analysis.race_harness)."""
        d = self.dispatcher
        if d is not None and getattr(d, "tracer", None) is not None:
            d.tracer.record(kind, resource=resource, rw=rw, **meta)

    def _settle(self) -> None:
        """Wait out any in-flight work before sealing or taking a no-op
        decision (the double-buffer stall + poison check both live in
        :meth:`SealedFront.settle` now); a still-running job whose merge
        phase has yet to clear ``_staged_dirty`` also barriers here —
        deciding on a stale flag would schedule a redundant merge."""
        self.front.settle()

    def _tile_stores(self) -> int:  # flashlint: under-lock
        return int(np.asarray(self.state.stats.tile_stores))

    # -- the buffered write path --------------------------------------------
    def update(self, tokens, deltas=None) -> None:
        """Accumulate a (token, Δ) batch into H_R; auto-flush at the
        threshold. ``EMPTY`` tokens are padding and ignored."""
        self.stats.updates += 1
        uniq, sums, n_valid = dedup_batch(tokens, deltas, self._tj.EMPTY)
        if n_valid == 0:
            return
        self.stats.entries += n_valid
        n_new, cancelled = self.front.fold(uniq, sums)
        self.stats.cancelled += cancelled
        self.stats.buffered += n_new
        self.stats.deduped += n_valid - n_new
        if self.front.part_len() >= self.flush_threshold:
            self.stats.auto_flushes += 1
            self.flush(wait=False)

    # flashlint: quiescent (callers seal post-settle; see the docstring)
    def seal(self) -> Optional[Tuple[np.ndarray, np.ndarray]]:
        """Swap H_R: the active buffer becomes the sealed in-flight chunk
        (read-only from here; reads keep overlaying it until its drain
        lands) and a fresh active buffer takes its place. Returns the
        sealed ``(keys, deltas)`` in sorted, deterministic dispatch
        order, or ``None`` when H_R is empty. With a WAL attached the
        sealed chunk is fsync'd before this returns.

        Callers must wait out any previous in-flight drain first — there
        are exactly two buffers (:meth:`flush` does this)."""
        out = self.front.seal()
        return None if out is None else out[0]

    # flashlint: under-lock (drain-worker body, submitted via dispatcher)
    def _dispatch(self, keys: np.ndarray, dels: np.ndarray) -> None:
        """Drain one sealed chunk to the device change segment (stage, no
        forced merge): EMPTY-padded fixed-shape chunks, donated
        dispatches; then clear the in-flight overlay and invalidate the
        paired query engine — all atomically with respect to readers
        (runs under the dispatcher lock on the drain worker, or inline
        when synchronous)."""
        jnp, tj = self._jnp, self._tj
        tj.assert_live(self.state)       # off-thread donation guard (§9)
        wear_before = self._tile_stores() if self.on_flush else 0
        step = self.chunk
        for lo in range(0, keys.size, step):
            pk = keys[lo:lo + step]
            pd = dels[lo:lo + step]
            pad = step - pk.size
            if pad:  # fixed shapes → one compiled program per table
                pk = np.concatenate([pk, np.full(pad, tj.EMPTY, np.int64)])
                pd = np.concatenate([pd, np.zeros(pad, np.int64)])
            if self.record is not None:
                self.record.append((pk, pd))
            self.state = tj.update(self.cfg, self.state,
                                   jnp.asarray(pk, jnp.int32),
                                   jnp.asarray(pd, jnp.int32))
            self.stats.dispatches += 1
        if self.dispatcher is not None:
            # store contract (DESIGN.md §9): a completed drain means the
            # device really holds the entries — not merely that they sit
            # in XLA's async dispatch queue. The worker absorbs this
            # wait; the sync baseline pays it inline (that is the stall
            # double buffering exists to hide). Engines without a
            # dispatcher keep the bare pre-PR5 dispatch-and-go.
            self._jax.block_until_ready(self.state)
        self.stats.dispatched_entries += keys.size
        self._trace("state_rebind", "state", "w")
        self._staged_dirty = True
        self.front.mark_drained()
        self.stats.flushes += 1
        self._invalidate()
        if self.on_flush:
            self.on_flush(keys, self._tile_stores() - wear_before)

    # flashlint: under-lock (drain-worker body, submitted via dispatcher)
    def _merge_device(self) -> None:
        """Force the device merge of the staged change segment (runs on
        the drain worker under the dispatcher lock, or inline)."""
        tj = self._tj
        tj.assert_live(self.state)
        wear_before = self._tile_stores() if self.on_flush else 0
        self.state = tj.flush(self.cfg, self.state)
        if self.dispatcher is not None:
            self._jax.block_until_ready(self.state)   # durable, not queued
        self._trace("state_rebind", "state", "w")
        self.stats.merges += 1
        self._staged_dirty = False
        # conservative: the merge moves placement, not counts, but clear
        # the cache anyway — it is one invalidation per rare merge
        self._invalidate()
        if self.on_flush:
            self.on_flush(None, self._tile_stores() - wear_before)

    def flush(self, wait: bool = True):
        """Drain H_R to the device change segment (stage, no forced
        merge). With a dispatcher and ``wait=False`` the sealed buffer
        drains in the background while the caller keeps ingesting;
        ``wait=True`` is the durability barrier for the staged entries."""
        self._settle()
        sealed = self.seal()
        if sealed is not None:
            keys, dels = sealed
            self._submit(lambda: self._dispatch(keys, dels),
                         label=f"hr-drain#{self.front.seals}:{keys.size}e")
        if wait:
            self._barrier()
        # with wait=False a drain may still be rebinding the state: take
        # the lock so callers never observe a half-donated snapshot
        with self._lock():
            return self.state

    def merge(self, wait: bool = True):
        """Flush H_R, then force the device merge of any staged change
        segment (end-of-stream / checkpoint). A complete no-op — nothing
        buffered, nothing in flight, nothing staged since the last merge
        — touches neither the device nor the hot cache."""
        self._settle()
        sealed = self.seal()
        # post-settle probe: no job is in flight here, so the flag and
        # the state are stable until we submit below
        if (sealed is None
                and not self._staged_dirty):  # flashlint: disable=FL006
            if wait:
                self._barrier()
            # no-op path: crucially, no cache invalidation (a flush of
            # an empty engine must not evict every hot key)
            return self.state                 # flashlint: disable=FL006

        def job():
            if sealed is not None:
                self._dispatch(*sealed)
            self._merge_device()

        n = 0 if sealed is None else sealed[0].size
        self._submit(job, label=f"hr-merge#{self.front.seals}:{n}e")
        if wait:
            self._barrier()
        with self._lock():
            return self.state

    # finalize is the adapter-facing spelling of the same operation
    finalize = merge

    def _invalidate(self) -> None:
        if self.query_engine is not None:
            self.query_engine.invalidate()
            self.stats.invalidations += 1

    # -- read-your-writes ---------------------------------------------------
    @property
    def buffered_entries(self) -> int:
        """Unique (token, Δ) entries not yet durable on device: the
        active H_R buffer plus the sealed in-flight chunk (if a drain is
        running). Benign unlocked snapshot (monitoring only, may be
        momentarily stale); never used for control flow."""
        return self.front.entries()

    def pending(self, keys) -> np.ndarray:  # flashlint: under-lock
        """Not-yet-durable Δ per key — the overlay a consolidated read
        must add on top of the device count: the active H_R buffer plus
        the sealed in-flight chunk. Call under the dispatcher lock when
        one is attached (the drain worker clears the in-flight chunk
        under that lock, atomically with the device state rebind)."""
        return self.front.pending(np.asarray(keys).reshape(-1))

    def query_batch(self, keys) -> np.ndarray:
        """Consolidated batched read: device counts through the paired
        query engine, plus the H_R overlay (both buffers). Taken under
        the dispatcher lock, so the device lookup and the overlay always
        describe the same instant — a drain either fully landed (its
        entries are device counts, the in-flight overlay is gone) or not
        at all (they overlay) — never both, never neither."""
        if self.query_engine is None:
            raise ValueError("no paired query engine; construct with "
                             "query_engine=BatchedQueryEngine(cfg)")
        with self._lock():
            base = self.query_engine.query_batch(self.state, keys)
            pend = self.pending(keys)
        return base + pend

    def query(self, key: int) -> int:
        """Single-key convenience wrapper (one-element batch)."""
        return int(self.query_batch(np.asarray([key]))[0])
