"""Host-side batched write engine for the device flash-hash table.

The paper's insert/update axis (§2.2, Figure 4) is won by buffering and
batching writes *before* they reach the device: the RAM buffer H_R
absorbs and dedups the raw token stream, and only threshold-triggered
flushes touch flash. PR 2 industrialized the read path
(:class:`.query_engine.BatchedQueryEngine`); this engine is its write
twin, the front door every writer (TF-IDF ingest, corpus stats, the
serving prefix cache's refcount bumps) goes through instead of calling
``table_jax.update`` per raw batch:

* **host-side H_R** — a token→Δ dict accumulates (and dedups) incoming
  batches; duplicate tokens fold into one entry, Δs that cancel to zero
  drop out entirely (paper §2.6: zero-frequency entries are not
  retained in memory);
* **threshold-triggered flushes** — the device sees traffic only when
  the buffer reaches ``flush_threshold`` unique entries (or on an
  explicit :meth:`flush`/:meth:`merge`), in sorted, deterministic order;
* **fixed-shape padded chunks** — flushed entries are EMPTY-padded up
  to ``chunk``, so each table compiles exactly one update program
  regardless of stream batch sizes (no recompile per new shape);
* **donation** — dispatches go through the donated
  ``table_jax.update``/``flush`` entry points, so the table state is
  updated in place instead of copied per call;
* **automatic invalidation** — a paired
  :class:`~.query_engine.BatchedQueryEngine` is invalidated on every
  flush *by the engine*, not by each caller remembering to. Reads
  routed through :meth:`query_batch` additionally overlay the buffered
  (unflushed) Δs, so writers get read-your-writes semantics without
  forcing a premature device dispatch;
* **ledger** — :class:`WriteEngineStats` counts buffered / deduped /
  dispatched entries and flush events alongside the device-side
  ``TableStats`` wear counters.

Unlike the (state-free) query engine, this engine *owns* the device
state: buffering means an ``update`` may not touch the device at all,
so the current ``DeviceTableState`` lives in ``engine.state`` and every
consumer reaches it through the engine.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class WriteEngineStats:
    """Write-path counters (DESIGN.md §7), the H_R-side ledger that
    complements the device ``TableStats`` wear counters."""

    updates: int = 0             # update() calls (writer-side batches)
    entries: int = 0             # valid (token, Δ) entries received
    buffered: int = 0            # entries that opened a new H_R slot
    deduped: int = 0             # entries absorbed without opening a
                                 # slot (duplicates + cancellations);
                                 # entries == buffered + deduped
    cancelled: int = 0           # Δ sums that hit zero in H_R (§2.6)
    dispatched_entries: int = 0  # unique (token, Δ) pairs sent to device
    dispatches: int = 0          # compiled update launches (chunks)
    flushes: int = 0             # H_R drain events (explicit + auto)
    auto_flushes: int = 0        # threshold-triggered drains
    merges: int = 0              # device-merge (table flush) requests
    invalidations: int = 0       # query-engine invalidations driven

    def as_dict(self) -> Dict[str, int]:
        return dataclasses.asdict(self)


def dedup_batch(tokens, deltas, empty: int):
    """Validate and pre-fold one raw writer batch: flatten, drop ``empty``
    padding, and collapse duplicate tokens to (unique, Δ-sum) pairs.

    Returns ``(uniq, sums, n_valid)``; shared by every H_R front
    (single-table engine and the sharded store backend)."""
    flat = np.asarray(tokens).reshape(-1).astype(np.int64)
    if deltas is None:
        d = np.ones(flat.size, np.int64)
    else:
        d = np.asarray(deltas).reshape(-1).astype(np.int64)
        if d.size != flat.size:
            raise ValueError(f"deltas size {d.size} != tokens {flat.size}")
    valid = flat != empty
    n_valid = int(valid.sum())
    if n_valid == 0:
        return (np.zeros(0, np.int64),) * 2 + (0,)
    uniq, inv = np.unique(flat[valid], return_inverse=True)
    sums = np.zeros(uniq.size, np.int64)
    np.add.at(sums, inv, d[valid])
    return uniq, sums, n_valid


def fold_entry(buf: Dict[int, int], k: int, s: int) -> int:
    """Fold one (token, Δ-sum) into an H_R dict with the paper's §2.6
    semantics: duplicates accumulate, sums that hit zero drop out (never
    retained in memory). Returns +1 if a new slot opened, 0 if it folded
    into an existing slot, −1 if it cancelled (ledger: buffered /
    deduped / cancelled respectively)."""
    cur = buf.get(k)
    if cur is None:
        if s:
            buf[k] = s
            return 1
        return -1
    if cur + s:
        buf[k] = cur + s
        return 0
    del buf[k]
    return -1


class BatchedWriteEngine:
    """H_R dedup + threshold flush + donated fixed-shape dispatch over
    ``table_jax.update``."""

    def __init__(self, cfg, state=None, chunk: int = 4096,
                 flush_threshold: Optional[int] = None,
                 query_engine=None,
                 record: Optional[List[Tuple[np.ndarray, np.ndarray]]] = None,
                 on_flush=None):
        import jax.numpy as jnp  # deferred: sim-only users stay jax-free

        from . import table_jax as tj
        self._jnp = jnp
        self._tj = tj
        self.cfg = cfg
        self.state = tj.init(cfg) if state is None else state
        self.chunk = int(chunk)
        self.flush_threshold = int(2 * self.chunk if flush_threshold is None
                                   else flush_threshold)
        self.query_engine = query_engine
        # optional dispatch recorder: every flushed (keys, deltas) chunk is
        # appended, letting tests/benchmarks replay the exact device
        # traffic through direct per-call updates (bit-identity oracle)
        self.record = record
        # optional wear listener: called after every device drain with
        # (drained_keys_or_None, Δtile_stores) — ``None`` keys mark the
        # forced end-of-stream merge, whose wear belongs to everything
        # staged since the last merge. Enabling it syncs the device stats
        # once per drain (flushes are rare; updates stay async).
        self.on_flush = on_flush
        self._buf: Dict[int, int] = {}
        self.stats = WriteEngineStats()

    def _tile_stores(self) -> int:
        return int(np.asarray(self.state.stats.tile_stores))

    # -- the buffered write path --------------------------------------------
    def update(self, tokens, deltas=None) -> None:
        """Accumulate a (token, Δ) batch into H_R; auto-flush at the
        threshold. ``EMPTY`` tokens are padding and ignored."""
        self.stats.updates += 1
        uniq, sums, n_valid = dedup_batch(tokens, deltas, self._tj.EMPTY)
        if n_valid == 0:
            return
        self.stats.entries += n_valid
        buf = self._buf
        n_new = 0
        for k, s in zip(uniq.tolist(), sums.tolist()):
            opened = fold_entry(buf, k, s)
            if opened > 0:
                n_new += 1                # a slot really opened
            elif opened < 0:
                self.stats.cancelled += 1
        self.stats.buffered += n_new
        self.stats.deduped += n_valid - n_new
        if len(buf) >= self.flush_threshold:
            self.stats.auto_flushes += 1
            self.flush()

    def flush(self):
        """Drain H_R to the device change segment (stage, no forced
        merge): sorted entries, EMPTY-padded fixed-shape chunks, donated
        dispatches; then invalidate the paired query engine."""
        if not self._buf:
            return self.state
        jnp, tj = self._jnp, self._tj
        keys = np.fromiter(self._buf.keys(), np.int64, len(self._buf))
        dels = np.fromiter(self._buf.values(), np.int64, len(self._buf))
        order = np.argsort(keys, kind="stable")   # deterministic dispatch
        keys, dels = keys[order], dels[order]
        wear_before = self._tile_stores() if self.on_flush else 0
        step = self.chunk
        for lo in range(0, keys.size, step):
            pk = keys[lo:lo + step]
            pd = dels[lo:lo + step]
            pad = step - pk.size
            if pad:  # fixed shapes → one compiled program per table
                pk = np.concatenate([pk, np.full(pad, tj.EMPTY, np.int64)])
                pd = np.concatenate([pd, np.zeros(pad, np.int64)])
            if self.record is not None:
                self.record.append((pk, pd))
            self.state = tj.update(self.cfg, self.state,
                                   jnp.asarray(pk, jnp.int32),
                                   jnp.asarray(pd, jnp.int32))
            self.stats.dispatches += 1
        self.stats.dispatched_entries += keys.size
        self._buf.clear()
        self.stats.flushes += 1
        self._invalidate()
        if self.on_flush:
            self.on_flush(keys, self._tile_stores() - wear_before)
        return self.state

    def merge(self):
        """Flush H_R, then force the device merge of any staged change
        segment (end-of-stream / checkpoint)."""
        invalidated = bool(self._buf)     # flush() invalidates iff it ran
        self.flush()
        wear_before = self._tile_stores() if self.on_flush else 0
        self.state = self._tj.flush(self.cfg, self.state)
        self.stats.merges += 1
        if self.on_flush:
            self.on_flush(None, self._tile_stores() - wear_before)
        if not invalidated:
            # conservative: the device merge moves placement, not counts,
            # but clear the cache anyway — one invalidation per drain
            self._invalidate()
        return self.state

    # finalize is the adapter-facing spelling of the same operation
    finalize = merge

    def _invalidate(self) -> None:
        if self.query_engine is not None:
            self.query_engine.invalidate()
            self.stats.invalidations += 1

    # -- read-your-writes ---------------------------------------------------
    @property
    def buffered_entries(self) -> int:
        """Unique (token, Δ) entries currently held in H_R."""
        return len(self._buf)

    def pending(self, keys) -> np.ndarray:
        """Buffered (unflushed) Δ per key — the H_R contribution a
        consolidated read must add on top of the device count."""
        flat = np.asarray(keys).reshape(-1)
        if not self._buf:
            return np.zeros(flat.size, np.int64)
        buf = self._buf
        return np.fromiter((buf.get(int(k), 0) for k in flat),
                           np.int64, flat.size)

    def query_batch(self, keys) -> np.ndarray:
        """Consolidated batched read: device counts through the paired
        query engine, plus the H_R overlay. Because the device state only
        changes on flush, the hot-key cache stays warm across buffered
        writes — and reads still see every unflushed Δ."""
        if self.query_engine is None:
            raise ValueError("no paired query engine; construct with "
                             "query_engine=BatchedQueryEngine(cfg)")
        base = self.query_engine.query_batch(self.state, keys)
        if self._buf:
            base = base + self.pending(keys)
        return base

    def query(self, key: int) -> int:
        """Single-key convenience wrapper (one-element batch)."""
        return int(self.query_batch(np.asarray([key]))[0])
