"""Distributed flash-hash table: the paper's design scaled across chips.

The data segment is sharded over a mesh axis by *block id* — the two-level
hash gives the owner mapping for free:

    owner(x) = s(x) >> log2(blocks_per_shard)

Each device runs the single-device policy (``table_jax``) over its local
blocks. A distributed update is: local RAM-buffer dedup → bucket staged
entries by owner shard → one ``all_to_all`` → local stage/merge. This is
the cross-chip version of the paper's "batch updates per block": the
*only* inter-chip traffic is one fixed-size collective per flush, and all
writes land block-local on the owner (semi-random discipline end-to-end).

Fixed-capacity buckets (``bucket_cap`` entries per destination shard) keep
the collective statically shaped; overflowing entries are carried over to
the next flush (same deferred-update discipline as the tile merge).

Async-safe drains (DESIGN.md §9): the update/flush programs built with
``donate=True`` donate the global state, and the sharded store runs them
on its background drain worker. Per-shard drains therefore follow the
same off-thread discipline as the single table — exactly one drain in
flight, the worker is the only caller of the donated programs, and every
dispatch is guarded by :func:`assert_live` (re-exported from
:mod:`segments`) so a raced state fails loudly.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import table_jax as tj
from .hashing import Pow2Hash

EMPTY = tj.EMPTY
assert_live = tj.assert_live    # off-thread donation guard (DESIGN.md §9)


@dataclasses.dataclass(frozen=True)
class ShardedTableConfig:
    local: tj.FlashTableConfig = dataclasses.field(
        default_factory=tj.FlashTableConfig)
    num_shards: int = 1
    bucket_cap: int = 1 << 12     # entries per (src, dst) bucket per flush

    @property
    def global_blocks(self) -> int:
        return self.local.num_blocks * self.num_shards

    @property
    def global_pair(self) -> Pow2Hash:
        c = self.local
        shard_log2 = (self.num_shards - 1).bit_length()
        return Pow2Hash(q_log2=c.q_log2 + shard_log2, r_log2=c.r_log2)


def init_global(cfg: ShardedTableConfig) -> tj.DeviceTableState:
    """Global-view state: leaves have a leading per-shard dim stacked, i.e.
    keys (num_shards * n_b_local, r); shard over a mesh axis with
    :func:`state_pspec`."""
    local = tj.init(cfg.local)

    def rep(x):
        return jnp.tile(x[None], (cfg.num_shards,) + (1,) * x.ndim).reshape(
            (cfg.num_shards * x.shape[0],) + x.shape[1:]) if x.ndim else \
            jnp.tile(x[None], (cfg.num_shards,))

    return jax.tree.map(rep, local)


def state_pspec(axis: str,
                local: tj.FlashTableConfig | None = None
                ) -> tj.DeviceTableState:
    """PartitionSpec pytree for the global state (all leaves sharded on
    their leading, per-shard dim). The tree structure is scheme-independent
    (MDB's ``(cs_partitions,)`` log pointers tile to ``(n * cs_partitions,)``
    and shard on the same leading dim), so ``local`` is only needed when the
    default config would not build — it never changes the specs."""
    return jax.tree.map(lambda _: P(axis),
                        tj.init(local or tj.FlashTableConfig()))


def _bucket_by_owner(cfg: ShardedTableConfig, keys, cnts):
    """Pack deduped updates into (num_shards, bucket_cap) owner buckets."""
    n = cfg.num_shards
    cap = cfg.bucket_cap
    pair = cfg.global_pair
    blocks_per_shard_log2 = cfg.local.q_log2 - cfg.local.r_log2
    valid = keys != EMPTY
    owner = jnp.where(valid,
                      pair.s(keys) >> blocks_per_shard_log2, n)
    order = jnp.argsort(owner, stable=True)
    sk, sc, so = keys[order], cnts[order], owner[order]
    start = jnp.searchsorted(so, jnp.arange(n + 1, dtype=so.dtype))
    pos = jnp.arange(keys.shape[0], dtype=jnp.int32) - start[jnp.clip(so, 0, n)]
    keep = (so < n) & (pos < cap)
    row = jnp.where(keep, so, n)
    buk = jnp.full((n, cap), EMPTY, jnp.int32).at[
        row, jnp.where(keep, pos, 0)].set(sk, mode="drop")
    buc = jnp.zeros((n, cap), jnp.int32).at[
        row, jnp.where(keep, pos, 0)].set(sc, mode="drop")
    dropped = ((so < n) & ~keep)
    carry_k = jnp.where(dropped, sk, EMPTY)
    carry_c = jnp.where(dropped, sc, 0)
    return buk, buc, carry_k, carry_c


def _squeeze(state, local: tj.FlashTableConfig | None = None):
    """Drop the leading per-shard dim of scalar leaves inside shard_map.

    Scheme-aware (ISSUE 10): MB / MDB-L keep a scalar ``log_ptr`` (tiled to
    ``(n,)`` globally, ``(1,)`` per shard — squeeze to ``()``); MDB keeps a
    *vector* of per-change-segment-partition pointers (``(cs_partitions,)``
    locally, tiled to ``(n * cs_partitions,)`` globally) that arrives inside
    shard_map already in its local shape and must not be squeezed."""
    scalar_log = local is None or local.scheme != "MDB"
    return state._replace(
        log_ptr=(state.log_ptr.reshape(state.log_ptr.shape[1:])
                 if scalar_log else state.log_ptr),
        ov_ptr=state.ov_ptr.reshape(()),
        stats=jax.tree.map(lambda x: x.reshape(()), state.stats))


def _expand(state, local: tj.FlashTableConfig | None = None):
    """Restore the leading per-shard dim on scalar leaves for out_specs.
    Inverse of :func:`_squeeze` — MDB's ``(cs_partitions,)`` log pointers
    already carry their sharded leading dim and pass through untouched."""
    scalar_log = local is None or local.scheme != "MDB"
    return state._replace(
        log_ptr=(state.log_ptr.reshape((1,) + state.log_ptr.shape)
                 if scalar_log else state.log_ptr),
        ov_ptr=state.ov_ptr.reshape((1,)),
        stats=jax.tree.map(lambda x: x.reshape((1,)), state.stats))


def make_update_fn(cfg: ShardedTableConfig, mesh, axis: str,
                   with_deltas: bool = False, donate: bool = False):
    """Build a shard_map'd update: ``(state, tokens) -> (state, n_carried)``
    (or ``(state, tokens, deltas) -> ...`` with ``with_deltas``).

    ``tokens`` is sharded over ``axis`` (each shard contributes its local
    stream); state is block-sharded over the same axis. ``with_deltas``
    switches the in-kernel RAM-buffer dedup to the ±Δ variant
    (:func:`segments.accumulate_deltas`) so decrements/cancellation reach
    the sharded table too. ``donate=True`` donates the state argument —
    the engine discipline (DESIGN.md §7): buffers update in place, the
    caller rebinds and never reuses the donated value.
    """
    from ..kernels.flash_hash import ops as hops
    local_cfg = cfg.local
    spec = state_pspec(axis, local_cfg)

    def local_update(state: tj.DeviceTableState, tokens, deltas=None):
        state = _squeeze(state, local_cfg)
        if deltas is None:
            keys, cnts = hops.accumulate(tokens.astype(jnp.int32))
        else:
            keys, cnts = tj.accumulate_deltas(tokens.astype(jnp.int32),
                                              deltas.astype(jnp.int32))
        buk, buc, carry_k, carry_c = _bucket_by_owner(cfg, keys, cnts)
        # one collective per flush: (n_shards, cap) -> (n_shards, cap)
        buk = jax.lax.all_to_all(buk, axis, split_axis=0, concat_axis=0,
                                 tiled=False)
        buc = jax.lax.all_to_all(buc, axis, split_axis=0, concat_axis=0,
                                 tiled=False)
        got_k = buk.reshape(-1)
        got_c = buc.reshape(-1)
        # Key coordinates need no translation: with power-of-two geometry
        # and a shared multiplier, g_local(x) == g_global(x) & (q_local-1),
        # so local block = global block & (n_b_local-1) and the home-within-
        # block bits are identical — owner routing and local placement agree
        # by construction (placement property, sharded edition).
        state = tj.update(local_cfg, state, got_k, got_c)
        # replicated scalar (psum over shards) rather than a per-shard
        # vector: in a multi-process mesh only replicated outputs are
        # addressable from every host, and the stores only ever consumed
        # the sum anyway.
        n_carry = jax.lax.psum(
            (carry_k != EMPTY).sum(dtype=jnp.int32), axis)
        return _expand(state, local_cfg), n_carry

    from jax.experimental.shard_map import shard_map
    if with_deltas:
        body = local_update
        in_specs = (spec, P(axis), P(axis))
    else:
        body = lambda state, tokens: local_update(state, tokens)
        in_specs = (spec, P(axis))
    upd = shard_map(body, mesh=mesh, in_specs=in_specs,
                    out_specs=(spec, P()),
                    check_rep=False)
    return jax.jit(upd, donate_argnums=(0,) if donate else ())


def make_lookup_fn(cfg: ShardedTableConfig, mesh, axis: str,
                   with_dist: bool = False, with_tiles: bool = False):
    """Build a shard_map'd lookup: every shard queries the full batch
    against its local blocks; non-owned keys contribute 0; one psum
    combines. (Read path = the paper's fast random reads.)

    ``with_dist=True`` additionally returns the per-key probe distance
    (the owner shard's device probe; non-owners contribute 0), matching
    the ``(counts, distances)`` contract of :func:`table_jax.lookup` so a
    :class:`~.query_engine.BatchedQueryEngine` can front this path.
    ``with_tiles=True`` (requires ``with_dist``) appends the tile-load
    count summed over shards as a replicated scalar — the engine adds it
    to its ``tile_loads`` counter. (Replicated, not ``(n_shards,)``: a
    multi-process mesh can only read replicated outputs locally.)
    """
    local_cfg = cfg.local
    spec = state_pspec(axis, local_cfg)

    def local_lookup(state: tj.DeviceTableState, q):
        state = _squeeze(state, local_cfg)
        blocks_per_shard_log2 = cfg.local.q_log2 - cfg.local.r_log2
        owner = cfg.global_pair.s(q) >> blocks_per_shard_log2
        me = jax.lax.axis_index(axis)
        mine = owner == me
        masked_q = jnp.where(mine, q, EMPTY)
        cnt, dist, tiles = tj.lookup_ex(local_cfg, state, masked_q)
        cnt = jax.lax.psum(jnp.where(mine, cnt, 0), axis)
        if not with_dist:
            return cnt
        dist = jax.lax.psum(jnp.where(mine, dist, 0), axis)
        if not with_tiles:
            return cnt, dist
        return cnt, dist, jax.lax.psum(tiles, axis)

    from jax.experimental.shard_map import shard_map
    if with_tiles and not with_dist:
        raise ValueError("with_tiles requires with_dist")
    out_specs = (P() if not with_dist
                 else (P(), P(), P()) if with_tiles
                 else (P(), P()))
    look = shard_map(local_lookup, mesh=mesh,
                     in_specs=(spec, P()),
                     out_specs=out_specs,
                     check_rep=False)
    return jax.jit(look)


def make_filter_fn(cfg: ShardedTableConfig, mesh, axis: str):
    """Build a shard_map'd Bloom pre-filter (DESIGN.md §12): every shard
    tests the full batch against its local per-block filters; non-owned
    keys contribute 0; one psum combines. Returns an int32 may-contain
    mask (0 ⇒ definitively absent from every shard) with the
    ``(state, keys) -> mask`` contract the query engine's ``filter_fn``
    expects."""
    local_cfg = cfg.local
    spec = state_pspec(axis, local_cfg)

    def local_filter(state: tj.DeviceTableState, q):
        state = _squeeze(state, local_cfg)
        blocks_per_shard_log2 = cfg.local.q_log2 - cfg.local.r_log2
        owner = cfg.global_pair.s(q) >> blocks_per_shard_log2
        me = jax.lax.axis_index(axis)
        mine = owner == me
        masked_q = jnp.where(mine, q, EMPTY)
        may = tj.filter_probe(local_cfg, state, masked_q)
        return jax.lax.psum(
            jnp.where(mine, may, False).astype(jnp.int32), axis)

    from jax.experimental.shard_map import shard_map
    filt = shard_map(local_filter, mesh=mesh,
                     in_specs=(spec, P()),
                     out_specs=P(),
                     check_rep=False)
    return jax.jit(filt)


def make_flush_fn(cfg: ShardedTableConfig, mesh, axis: str,
                  donate: bool = False):
    """Build a shard_map'd device merge: every shard drains its staged
    change segment through :func:`table_jax.flush` (end-of-stream /
    checkpoint). No collective — merges are block-local by construction."""
    local_cfg = cfg.local
    spec = state_pspec(axis, local_cfg)

    def local_flush(state: tj.DeviceTableState):
        return _expand(tj.flush(local_cfg, _squeeze(state, local_cfg)),
                       local_cfg)

    from jax.experimental.shard_map import shard_map
    fl = shard_map(local_flush, mesh=mesh, in_specs=(spec,),
                   out_specs=spec, check_rep=False)
    return jax.jit(fl, donate_argnums=(0,) if donate else ())


# ---------------------------------------------------------------------------
# Multi-process (multi-host) helpers — ISSUE 10.
#
# Everything above is process-count agnostic: the programs are plain
# shard_map'd jits over a mesh. What changes on a multi-process mesh
# (``jax.distributed.initialize``) is *array placement*: a process can only
# materialise its addressable shards, so global inputs are built with
# ``jax.make_array_from_callback`` instead of ``device_put``/implicit
# commitment, and anything a host needs to *read back* must come out
# replicated (``P()``), which is why ``n_carry`` and the tile-load counter
# above are psums. The helpers below are also correct on a single-process
# mesh — the sharded store uses them unconditionally in multihost mode and
# the tests reuse them in-process.
# ---------------------------------------------------------------------------


def host_shards(mesh, axis: str) -> list[int]:
    """Mesh positions (== shard ids) owned by the calling process.

    With ``jax.make_mesh((n,), (axis,))`` over id-ordered devices the
    shards of process *p* are contiguous, but we derive ownership from the
    mesh itself rather than assume it."""
    me = jax.process_index()
    return [i for i, d in enumerate(mesh.devices.reshape(-1))
            if d.process_index == me]


def place_global(cfg: ShardedTableConfig, mesh, axis: str
                 ) -> tj.DeviceTableState:
    """:func:`init_global` for multi-process meshes: every process builds
    the (identical, deterministic) host-side global init and materialises
    only its addressable shards via ``jax.make_array_from_callback``."""
    import numpy as np
    from jax.sharding import NamedSharding
    local = jax.tree.map(np.asarray, tj.init(cfg.local))
    sh = NamedSharding(mesh, P(axis))

    def place(x):
        if x.ndim:
            g = np.tile(x[None], (cfg.num_shards,) + (1,) * x.ndim).reshape(
                (cfg.num_shards * x.shape[0],) + x.shape[1:])
        else:
            g = np.tile(x[None], (cfg.num_shards,))
        return jax.make_array_from_callback(
            g.shape, sh, lambda idx, g=g: g[idx])

    return jax.tree.map(place, local)


def make_global_batch(mesh, axis: str, arr) -> jax.Array:
    """Place a host-side array as a global array sharded over ``axis``.
    ``arr`` must be the *global* value (identical shape on every process);
    each process materialises only its addressable slices."""
    import numpy as np
    from jax.sharding import NamedSharding
    a = np.asarray(arr)
    sh = NamedSharding(mesh, P(axis))
    return jax.make_array_from_callback(a.shape, sh, lambda idx: a[idx])


def make_replicated(mesh, arr) -> jax.Array:
    """Place a host-side array fully replicated over ``mesh`` (for query
    batches: the read path takes the full batch on every shard). The value
    must be identical on every process — collective calls are SPMD."""
    import numpy as np
    from jax.sharding import NamedSharding
    a = np.asarray(arr)
    sh = NamedSharding(mesh, P())
    return jax.make_array_from_callback(a.shape, sh, lambda idx: a[idx])


def make_sync_fn(cfg: ShardedTableConfig, mesh, axis: str, width: int = 2):
    """Build the drain-agreement collective: ``(n_shards, width)`` int32 in
    (each process fills its own shards' rows), element-wise max over shards
    out, replicated. The multihost store runs it on the *caller* thread
    (post-settle, pre-submit) so hosts agree on the number of drain waves —
    and on whether a device merge is needed — before the worker launches
    any collective program; the global collective order stays
    ``agree_k < waves_k < agree_{k+1}`` on every host (DESIGN.md §14)."""

    def local_max(v):  # v: (1, width) per shard
        return jax.lax.pmax(v.reshape(v.shape[1:]), axis)

    from jax.experimental.shard_map import shard_map
    sync = shard_map(local_max, mesh=mesh, in_specs=(P(axis),),
                     out_specs=P(), check_rep=False)
    return jax.jit(sync)
