"""SSD cost model — replacement for the paper's DiskSim(+SSD extension) slave.

Accounts the same quantities the paper reports: page/block reads & writes,
cleans (erases), merges, stages, and converts counters into device time via
the paper's Table-1 configurations (MLC-1, MLC-2, SLC).

Block-vs-page cost ratios come from the paper's footnote 4:
  "MLC-1 is on the order of 30 and 50 times more expensive for block level
   reads and block level writes, MLC-2 is over 25 and 35, and SLC is over
   24 and 28 respectively."
Erase (clean) latency is not given in the paper; we use literature values
(NAND block erase ≈ 1.5–2 ms) and note this in EXPERIMENTS.md.

The FTL model for *random* page writes (naive, bufferless table): a log-
structured FTL garbage-collects one block per ``pages_per_block`` random page
writes; each clean also incurs a block read + block write for valid-page
copy-out. This reproduces the paper's §3.5 naive-table magnitudes
(~1 clean / 81 random writes measured there; ours gives 1/128 before
valid-copy accounting — same order).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class FlashDevice:
    """Latency model of one SSD configuration (paper Table 1 + footnote 4)."""

    name: str
    page_read_us: float
    page_write_us: float
    block_read_mult: float   # block read  = mult * page_read
    block_write_mult: float  # block write = mult * page_write
    erase_us: float
    capacity_gb: int
    cell: str  # "MLC" | "SLC"

    @property
    def block_read_us(self) -> float:
        return self.block_read_mult * self.page_read_us

    @property
    def block_write_us(self) -> float:
        return self.block_write_mult * self.page_write_us


MLC1 = FlashDevice("MLC-1", page_read_us=65.0, page_write_us=110.0,
                   block_read_mult=30.0, block_write_mult=50.0,
                   erase_us=2000.0, capacity_gb=40, cell="MLC")
MLC2 = FlashDevice("MLC-2", page_read_us=65.0, page_write_us=85.0,
                   block_read_mult=25.0, block_write_mult=35.0,
                   erase_us=2000.0, capacity_gb=80, cell="MLC")
SLC = FlashDevice("SLC", page_read_us=75.0, page_write_us=85.0,
                  block_read_mult=24.0, block_write_mult=28.0,
                  erase_us=1500.0, capacity_gb=32, cell="SLC")

DEVICES = {d.name: d for d in (MLC1, MLC2, SLC)}


@dataclasses.dataclass(frozen=True)
class TableGeometry:
    """Physical layout of the drive-resident (closed) hash table."""

    num_blocks: int
    pages_per_block: int = 128
    entries_per_page: int = 512  # 4KB page / 8B (key,count) pair

    @property
    def block_entries(self) -> int:
        return self.pages_per_block * self.entries_per_page

    @property
    def total_entries(self) -> int:
        return self.num_blocks * self.block_entries

    @property
    def total_pages(self) -> int:
        return self.num_blocks * self.pages_per_block

    def page_of_entry(self, entry_offset_in_block: int) -> int:
        return entry_offset_in_block // self.entries_per_page


@dataclasses.dataclass
class CostLedger:
    """Device-independent operation counters (the paper's Table-2 columns)."""

    page_reads: int = 0
    page_writes_seq: int = 0       # sequential (MDB-L log appends)
    page_writes_semi: int = 0      # semi-random (MDB change-segment stages)
    page_writes_rand: int = 0      # random (naive table)
    block_reads: int = 0
    block_writes: int = 0
    cleans: int = 0
    merges: int = 0
    stages: int = 0
    # FTL state for random-write garbage collection:
    _ftl_dirty: int = 0
    _pages_per_block: int = 128

    # ---- paper Table-2 aggregates ------------------------------------
    @property
    def block_ops(self) -> int:
        return self.block_reads + self.block_writes

    @property
    def page_ops(self) -> int:
        return (self.page_reads + self.page_writes_seq +
                self.page_writes_semi + self.page_writes_rand)

    @property
    def page_writes(self) -> int:
        return self.page_writes_seq + self.page_writes_semi + self.page_writes_rand

    def block_op_fraction(self) -> float:
        tot = self.block_ops + self.page_ops
        return self.block_ops / tot if tot else 0.0

    # ---- op recording --------------------------------------------------
    def read_page(self, n: int = 1):
        self.page_reads += n

    def write_page_seq(self, n: int = 1):
        self.page_writes_seq += n

    def write_page_semi(self, n: int = 1):
        self.page_writes_semi += n

    def write_page_random(self, n: int = 1):
        """Random page writes go through the FTL GC model (see module doc)."""
        self.page_writes_rand += n
        self._ftl_dirty += n
        while self._ftl_dirty >= self._pages_per_block:
            self._ftl_dirty -= self._pages_per_block
            self.cleans += 1
            self.block_reads += 1   # valid-page copy-out
            self.block_writes += 1

    def read_block(self, n: int = 1):
        self.block_reads += n

    def write_block(self, n: int = 1, clean: bool = True):
        self.block_writes += n
        if clean:  # erase-before-write
            self.cleans += n

    def erase_block(self, n: int = 1):
        self.cleans += n

    def merge_event(self, n: int = 1):
        self.merges += n

    def stage_event(self, n: int = 1):
        self.stages += n

    # ---- time conversion -------------------------------------------------
    def time_us(self, dev: FlashDevice) -> float:
        return (self.page_reads * dev.page_read_us
                + self.page_writes * dev.page_write_us
                + self.block_reads * dev.block_read_us
                + self.block_writes * dev.block_write_us
                + self.cleans * dev.erase_us)

    def snapshot(self) -> dict:
        return {
            "page_reads": self.page_reads,
            "page_writes_seq": self.page_writes_seq,
            "page_writes_semi": self.page_writes_semi,
            "page_writes_rand": self.page_writes_rand,
            "block_reads": self.block_reads,
            "block_writes": self.block_writes,
            "block_ops": self.block_ops,
            "page_ops": self.page_ops,
            "cleans": self.cleans,
            "merges": self.merges,
            "stages": self.stages,
        }

    def diff(self, before: dict) -> dict:
        now = self.snapshot()
        return {k: now[k] - before.get(k, 0) for k in now}
