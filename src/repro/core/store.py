"""One `FlashStore` facade over every flash-hash table backend (DESIGN.md §8).

The paper's central claim is that one deferred-update discipline — RAM
buffer H_R in front, semi-random block-local merges behind — serves every
scheme variant (§2, Fig 4). Before this module, the public surface leaked
the plumbing: every consumer manually constructed and paired a
:class:`~.write_engine.BatchedWriteEngine` with a
:class:`~.query_engine.BatchedQueryEngine`, while the sharded table
(:mod:`.distributed`) exposed a third, engine-less API with none of the
H_R dedup, donation or read-your-writes semantics. `FlashStore` is the
single entry point:

    with FlashStore.open(backend="device", scheme="MDB-L") as store:
        store.update(tokens)            # buffered in H_R
        store.increment(key, -1)        # deletion-by-decrement (§2.6)
        counts = store.query(keys)      # read-your-writes, batched
        store.flush()                   # durability point: drain + merge
        print(store.stats())

Three backends plug in behind the identical lifecycle via a small
``TableBackend`` protocol (duck-typed — ``update`` / ``query_batch`` /
``drain`` / ``flush`` / ``stats`` / ``pending_entries``):

* ``sim``     — the event-level NumPy simulator (exact SSD cost ledger;
  the paper's measurement harness). Its RAM buffer *is* H_R.
* ``device``  — the single-table JAX/Pallas path: the store owns the
  engine pair, and the flush → invalidate contract is enforced here,
  never by callers.
* ``sharded`` — the multi-device table: per-shard H_R partitions keyed
  by ``owner(x)``, shard-local flush thresholds (one hot shard drains
  its own partition without forcing every shard's buffer out), and
  cross-shard consolidated batched lookups (one psum per query chunk).

Engine pairing happens *only* in this module: constructing a write/query
engine by hand elsewhere is the pre-PR4 surface, deleted in PR 5.

Since PR 5 every backend flushes **asynchronously and double-buffered**
(DESIGN.md §9): ingest fills an active H_R buffer while a single
background worker (one :class:`FlushDispatcher` per store) drains the
sealed one through the donated update/merge programs. ``flush(wait=True)``
is the durability barrier; reads overlay both buffers plus the in-flight
chunk, so read-your-writes holds at every instant; ``async_flush=False``
restores the synchronous pre-PR5 discipline (drains still route through
the dispatcher so the ``stall_us`` ledger measures what async buys).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from pathlib import Path
from typing import Dict, List, Optional, Tuple

import numpy as np

from .table_sim import EMPTY


def _flat_i64(x) -> np.ndarray:
    return np.asarray(x).reshape(-1).astype(np.int64)


def _latest_step(path) -> Optional[int]:
    """Latest ``step_<N>`` snapshot directory under ``path`` (the
    checkpoint layout, scanned without importing jax so sim-only users
    stay jax-free)."""
    path = Path(path)
    if not path.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in path.glob("step_*")
             if p.is_dir() and not p.name.endswith(".tmp")]
    return max(steps) if steps else None


class DrainError(RuntimeError):
    """A background drain job died. Raised at the durability barrier
    (``flush(wait=True)`` / ``stats()`` / ``close()``), naming the
    failing job and chunk; the worker's original exception rides along
    as ``__cause__`` with its full traceback."""


# ---------------------------------------------------------------------------
# the drain dispatcher: one worker thread + state lock per store
# ---------------------------------------------------------------------------
class FlushDispatcher:
    """Background drain executor shared by every backend (DESIGN.md §9).

    Owns three things:

    * **the state lock** — every device-state access (drain dispatch,
      forced merge, batched lookup) runs under it, so a reader always
      sees a consistent (device state, in-flight overlay) snapshot and
      never a half-applied drain or a donated-away buffer;
    * **the one in-flight future** — double buffering means at most one
      sealed buffer is draining; submitting while it drains first waits
      it out (the stall the second buffer exists to minimise);
    * **the overlap/stall ledgers** — written into the attached
      :class:`~.write_engine.WriteEngineStats` (``ledger``): drain time
      spent on the worker counts as ``overlap_us`` (hidden behind
      ingest), caller time spent waiting counts as ``stall_us``. With
      ``enabled=False`` drains run inline and their full duration is
      ``stall_us`` — the synchronous baseline the async rows are
      measured against.

    ``wait()`` is the barrier: it re-raises any drain exception in the
    caller, so failures surface at ``flush(wait=True)`` / ``stats()`` /
    ``close()`` instead of dying silently on the worker.
    """

    def __init__(self, enabled: bool = True):
        self.enabled = bool(enabled)
        self.lock = threading.RLock()
        self.ledger = None            # WriteEngineStats sink (set by owner)
        # opt-in happens-before recorder (analysis.race_harness.attach):
        # when set, submit/wait emit fork/join edges and job markers
        self.tracer = None
        self._pool = (ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="flashstore-drain")
            if self.enabled else None)
        self._future = None
        self._job_info = None         # (done-snapshot holder, job#, label)
        self._jobs = 0
        self._closed = False

    def _charge(self, field: str, t0: float) -> None:
        if self.ledger is not None:
            us = int((time.perf_counter() - t0) * 1e6)
            setattr(self.ledger, field, getattr(self.ledger, field) + us)

    def trace(self, kind: str, resource=None, rw=None, **meta) -> None:
        """Record one harness event; free no-op when no tracer attached."""
        if self.tracer is not None:
            self.tracer.record(kind, resource=resource, rw=rw, **meta)

    @property
    def pending(self) -> bool:
        """A submitted job has not been waited out yet (it may still be
        running, or be finished holding an un-raised exception)."""
        return self._future is not None

    def submit(self, fn, label: Optional[str] = None) -> None:
        """Run one sealed-buffer drain under the state lock: on the
        worker when async, inline when not. Any previous in-flight drain
        is waited out first (there are exactly two buffers). ``label``
        names the chunk in the :class:`DrainError` should the job die."""
        if self._closed:
            raise ValueError("dispatcher is closed")
        self.wait()
        job = self._jobs
        self._jobs += 1
        if not self.enabled:
            self.trace("job_start", job=job, label=label)
            t0 = time.perf_counter()
            try:
                with self.lock:
                    fn()
            finally:
                self.trace("job_end", job=job)
                self._charge("stall_us", t0)
            return

        tr = self.tracer
        snap = tr.fork() if tr is not None else None
        done = {}

        def run():
            if tr is not None:        # submit → job-start edge
                tr.join(snap)
                tr.record("job_start", job=job, label=label)
            t0 = time.perf_counter()
            try:
                with self.lock:
                    fn()
            finally:
                if tr is not None:
                    tr.record("job_end", job=job)
                    done["snap"] = tr.fork()
            self._charge("overlap_us", t0)

        self._job_info = (done, job, label)
        self._future = self._pool.submit(run)

    def wait(self) -> None:
        """Durability barrier: block until the in-flight drain (if any)
        lands. A worker exception re-raises here as a :class:`DrainError`
        naming the job and its sealed chunk, chained (``from exc``) to
        the original so the worker-side traceback survives."""
        f, self._future = self._future, None
        info, self._job_info = self._job_info, None
        if f is None:
            return
        t0 = time.perf_counter()
        try:
            f.result()
        except Exception as exc:
            done, job, label = info if info else ({}, "?", None)
            chunk = f" ({label})" if label else ""
            raise DrainError(
                f"background drain job #{job}{chunk} failed: {exc}"
            ) from exc
        finally:
            self._charge("stall_us", t0)
        if self.tracer is not None and info:
            self.tracer.join(info[0].get("snap"))  # job-end → barrier edge

    def close(self) -> None:
        """Join the worker (completing any in-flight drain). Idempotent;
        re-raises a pending drain exception exactly once."""
        if self._closed:
            return
        self._closed = True
        try:
            self.wait()
        finally:
            if self._pool is not None:
                self._pool.shutdown(wait=True)


# ---------------------------------------------------------------------------
# the sealed front: one seal/settle/poison lifecycle for every backend
# ---------------------------------------------------------------------------
class SealedFront:
    """The double-buffered H_R lifecycle (DESIGN.md §9/§11), written
    once. Before ISSUE 7 each backend (`BatchedWriteEngine`,
    `SimBackend`, `ShardedBackend`) reimplemented the same machine:

    * **fold** — (token, Δ) pairs accumulate in the *active* buffer of
      their partition (one partition for single-table fronts, one per
      owner shard for the sharded store);
    * **settle** — wait out the in-flight drain; a sealed chunk still
      present *after* the barrier means its drain died (the worker
      clears delivered slots), so the front is **poisoned**: writes
      fail loudly rather than silently dropping the chunk, reads keep
      overlaying it, and ``FlashStore.restore()`` is the way back;
    * **seal** — post-settle, the active buffer swaps for a fresh one
      and becomes the read-only *in-flight* overlay; the sealed
      ``(keys, Δs)`` arrays (sorted, deterministic dispatch order) go
      to the caller for dispatch. With a WAL attached, every sealed
      part is appended and fsync'd here — **before** the drain is
      submitted — so a crash mid-drain loses nothing that was sealed;
    * **mark_drained** — worker side, under the dispatcher lock: the
      delivered parts' overlays clear (atomically with the device
      state rebind) and drain completions are logged.

    Owning the lifecycle here means the WAL hook is written once, and
    the flashlint FL006 lock discipline audits one class instead of
    three."""

    # shared with the drain worker; flashlint FL006 holds every access
    # to the state lock (or an audited under-lock/quiescent method)
    _fl_guarded = ("_inflight",)

    def __init__(self, dispatcher: Optional[FlushDispatcher] = None,
                 parts: int = 1, wal=None):
        self.dispatcher = dispatcher
        self.parts = int(parts)
        self.wal = wal
        self._buf: List[Dict[int, int]] = [dict() for _ in range(self.parts)]
        # sealed-but-draining chunks: the worker clears a part's slot
        # (under the dispatcher lock) once its entries are on device
        self._inflight: List[Optional[Dict[int, int]]] = [None] * self.parts
        self._wal_seqs: List[Optional[int]] = [None] * self.parts
        self.seals = 0

    def _trace(self, kind: str, resource=None, rw=None, **meta) -> None:
        d = self.dispatcher
        if d is not None and getattr(d, "tracer", None) is not None:
            d.tracer.record(kind, resource=resource, rw=rw, **meta)

    def _res(self, part: int) -> str:
        return ("hr:inflight" if self.parts == 1
                else f"hr:inflight[{part}]")

    # -- ingest side ---------------------------------------------------------
    def fold(self, uniq: np.ndarray, sums: np.ndarray,
             owners: Optional[np.ndarray] = None) -> Tuple[int, int]:
        """Fold pre-deduped (token, Δ-sum) pairs into the active buffers
        (partitioned by ``owners`` when given). Returns
        ``(n_new_slots, n_cancelled)`` for the caller's ledger."""
        from .write_engine import fold_entry
        n_new = cancelled = 0
        if owners is None:
            buf = self._buf[0]
            for k, s in zip(uniq.tolist(), sums.tolist()):
                opened = fold_entry(buf, k, s)
                if opened > 0:
                    n_new += 1
                elif opened < 0:
                    cancelled += 1
        else:
            bufs = self._buf
            for k, s, o in zip(uniq.tolist(), sums.tolist(),
                               owners.tolist()):
                opened = fold_entry(bufs[o], k, s)
                if opened > 0:
                    n_new += 1
                elif opened < 0:
                    cancelled += 1
        self._trace("hr_write", "hr:active", "w")
        return n_new, cancelled

    def part_len(self, part: int = 0) -> int:
        """Active-buffer size of one partition (threshold decisions)."""
        return len(self._buf[part])

    def part_lens(self) -> List[int]:
        return [len(b) for b in self._buf]

    # -- lifecycle -----------------------------------------------------------
    def settle(self) -> None:
        """Barrier the in-flight drain, then fail loudly if it died.

        The pre-barrier probes are benign unlocked reads: worst case a
        redundant barrier. A sealed chunk still present *after* the
        barrier is the poison state — its drain failed (the worker
        clears delivered slots, and the barrier re-raised the worker's
        exception exactly once already): the entries are undelivered
        and the donated state is suspect."""
        d = self.dispatcher
        if (any(b is not None
                for b in self._inflight)      # flashlint: disable=FL006
                or (d is not None and d.pending)):
            if d is not None:
                d.wait()
        if any(b is not None
               for b in self._inflight):      # flashlint: disable=FL006
            raise RuntimeError(
                "store is poisoned: a drain failed and its sealed H_R "
                "chunk was never delivered — reopen from the last durable "
                "state (FlashStore.restore() clears the poison and "
                "replays the WAL)")

    # flashlint: quiescent (callers settle first; see the class docstring)
    def seal(self, parts: Optional[List[int]] = None
             ) -> Optional[Dict[int, Tuple[np.ndarray, np.ndarray]]]:
        """Swap the selected partitions' active buffers into the
        in-flight overlay; returns ``{part: (sorted keys, deltas)}`` or
        ``None`` when nothing is buffered. With a WAL, every sealed
        part is logged and one fsync lands before this returns."""
        sel = [p for p in (range(self.parts) if parts is None else parts)
               if self._buf[p]]
        if not sel:
            return None
        out: Dict[int, Tuple[np.ndarray, np.ndarray]] = {}
        for p in sel:
            if self._inflight[p] is not None:
                # never clobber a sealed chunk (a failed drain leaves
                # its entries here — they are still the read overlay)
                raise RuntimeError(
                    f"sealed H_R part {p} over an in-flight chunk; wait "
                    f"out the previous drain first")
            b = self._buf[p]
            keys = np.fromiter(b.keys(), np.int64, len(b))
            dels = np.fromiter(b.values(), np.int64, len(b))
            order = np.argsort(keys, kind="stable")  # deterministic
            keys, dels = keys[order], dels[order]
            out[p] = (keys, dels)
            self._inflight[p] = b
            self._buf[p] = dict()
            self._trace("swap", "hr:active", "w")
            self._trace("seal", self._res(p), "w", entries=keys.size)
            if self.wal is not None:
                self._wal_seqs[p] = self.wal.append_seal(p, keys, dels)
        self.seals += 1
        if self.wal is not None:
            self.wal.sync()           # durable before the drain dispatches
        return out

    def mark_drained(self, parts=None) -> None:  # flashlint: under-lock
        """Worker side, under the dispatcher lock: the sealed chunks are
        really on device — clear their overlays (atomically with the
        state rebind the worker just traced) and log the completions."""
        for p in (range(self.parts) if parts is None else parts):
            self._inflight[p] = None
            self._trace("inflight_clear", self._res(p), "w")
            if self.wal is not None and self._wal_seqs[p] is not None:
                self.wal.append_commit(p, self._wal_seqs[p])
                self._wal_seqs[p] = None

    # -- read-your-writes ----------------------------------------------------
    def pending(self, flat: np.ndarray,
                owners: Optional[np.ndarray] = None) -> np.ndarray:
        # flashlint: under-lock
        """Not-yet-durable Δ per key: active + in-flight buffers of each
        key's partition. Call under the dispatcher lock (the worker
        clears in-flight slots under it)."""
        self._trace("hr_read", "hr:active", "r")
        inf = self._inflight
        for p, b in enumerate(inf):
            if b:
                self._trace("hr_read", self._res(p), "r")
        if owners is None:
            buf, i0 = self._buf[0], inf[0]
            if not buf and not i0:
                return np.zeros(flat.size, np.int64)
            if i0:
                return np.fromiter(
                    (buf.get(int(k), 0) + i0.get(int(k), 0) for k in flat),
                    np.int64, flat.size)
            return np.fromiter((buf.get(int(k), 0) for k in flat),
                               np.int64, flat.size)
        if not any(self._buf) and not any(inf):
            return np.zeros(flat.size, np.int64)
        bufs = self._buf
        return np.fromiter(
            (bufs[o].get(int(k), 0)
             + (inf[o].get(int(k), 0) if inf[o] else 0)
             for k, o in zip(flat, owners)), np.int64, flat.size)

    def entries(self) -> int:
        # benign unlocked snapshot (monitoring only, may be momentarily
        # stale); never used for control flow
        return (sum(len(b) for b in self._buf)
                + sum(len(b)
                      for b in self._inflight if b))  # flashlint: disable=FL006

    @property
    def poisoned(self) -> bool:
        """An undelivered sealed chunk survives the barrier (benign
        unlocked probe: only consulted on quiesced paths)."""
        return any(b is not None
                   for b in self._inflight)           # flashlint: disable=FL006

    def clear(self) -> None:  # flashlint: quiescent (restore path, re-armed)
        """Drop every buffer — active and in-flight — clearing any
        poison. Only the restore path calls this, after re-arming the
        dispatcher: the dropped entries are exactly what the WAL replay
        re-delivers."""
        self._buf = [dict() for _ in range(self.parts)]
        self._inflight = [None] * self.parts
        self._wal_seqs = [None] * self.parts


# ---------------------------------------------------------------------------
# sim backend: the event-level SSD simulation
# ---------------------------------------------------------------------------
class SimBackend:
    """`table_sim` behind the store protocol, with the store-level
    double-buffered H_R in front (DESIGN.md §9): updates fold into an
    active host dict; sealed chunks replay into the simulator —
    ``update_batch`` is the engine-chunk-compatible ±Δ twin — on the
    drain worker, so the async lifecycle is identical across backends.
    The sim's own RAM buffer keeps playing the *costed* H_R inside the
    cost model; `query_batch` already consolidates
    data/change/overflow + buffer, and the front buffers overlay on
    top."""

    name = "sim"
    # shared with the drain worker; flashlint FL006 holds every access
    # to the state lock (or an audited under-lock/quiescent method). The
    # double-buffer itself now lives in the SealedFront.
    _fl_guarded = ("_dirty",)

    def __init__(self, geom=None, scheme: str = "MDB-L",
                 ram_buffer_pct: float = 5.0,
                 change_segment_pct: float = 12.5,
                 flush_threshold: Optional[int] = None,
                 async_flush: bool = True, wal=None, **table_kw):
        from .flash_model import TableGeometry
        from .table_sim import make_table
        from .write_engine import WriteEngineStats
        self.geom = geom if geom is not None else TableGeometry(
            num_blocks=16, pages_per_block=64, entries_per_page=64)
        self.scheme = scheme
        # ctor args kept for restore-from-scratch (no snapshot on disk)
        self._ram_pct = ram_buffer_pct
        self._cs_pct = change_segment_pct
        self._table_kw = dict(table_kw)
        self.table = make_table(scheme, self.geom, ram_buffer_pct,
                                change_segment_pct, **table_kw)
        # the front H_R seals at the costed RAM buffer's own capacity by
        # default, so threshold behaviour tracks the paper's H_R size
        self.flush_threshold = int(self.table.ram.capacity
                                   if flush_threshold is None
                                   else flush_threshold)
        self._disp = FlushDispatcher(enabled=async_flush)
        self.front = SealedFront(dispatcher=self._disp, parts=1, wal=wal)
        self._dirty = False          # sim holds undrained/unmerged entries
        self.stats_ledger = WriteEngineStats()
        self._disp.ledger = self.stats_ledger

    # -- the buffered write path -------------------------------------------
    def update(self, tokens, deltas=None) -> None:
        from .write_engine import dedup_batch
        led = self.stats_ledger
        led.updates += 1
        uniq, sums, n_valid = dedup_batch(tokens, deltas, EMPTY)
        if n_valid == 0:
            return
        led.entries += n_valid
        n_new, cancelled = self.front.fold(uniq, sums)
        led.cancelled += cancelled
        led.buffered += n_new
        led.deduped += n_valid - n_new
        if self.front.part_len() >= self.flush_threshold:
            led.auto_flushes += 1
            self.drain(wait=False)

    def _seal(self) -> Optional[tuple]:  # flashlint: quiescent (post-settle)
        out = self.front.seal()
        return None if out is None else out[0]

    def _replay(self, keys, dels, merge: bool) -> None:  # flashlint: under-lock
        # worker side, under the dispatcher lock
        led = self.stats_ledger
        if keys is not None:
            self.table.update_batch(keys, dels)
            led.dispatches += 1
            led.dispatched_entries += keys.size
            self._dirty = True
            self.front.mark_drained()
            led.flushes += 1
        if merge:
            self.table.finalize()
            led.merges += 1
            self._dirty = False
        elif keys is not None:
            self.table.flush()       # stage, no forced merge

    def drain(self, wait: bool = True) -> None:
        self.front.settle()
        sealed = self._seal()
        if sealed is not None:
            k, d = sealed
            self._disp.submit(lambda: self._replay(k, d, merge=False),
                              label=f"sim-drain#{self.front.seals}:"
                                    f"{k.size}e")
        if wait:
            self._disp.wait()

    def flush(self, wait: bool = True) -> None:  # durability point
        self.front.settle()
        sealed = self._seal()
        # post-settle probe: no job in flight, the flag is stable
        if sealed is None and not self._dirty:  # flashlint: disable=FL006
            if wait:
                self._disp.wait()
            return                    # complete no-op
        k, d = sealed if sealed is not None else (None, None)
        n = 0 if k is None else k.size
        self._disp.submit(lambda: self._replay(k, d, merge=True),
                          label=f"sim-flush#{self.front.seals}:{n}e")
        if wait:
            self._disp.wait()

    # -- read-your-writes ---------------------------------------------------
    def pending(self, keys) -> np.ndarray:  # flashlint: under-lock
        return self.front.pending(_flat_i64(keys))

    def query_batch(self, keys) -> np.ndarray:
        with self._disp.lock:
            base = np.asarray(self.table.query_batch(keys), np.int64)
            pend = self.pending(keys)
        return base + pend

    def pending_entries(self) -> int:
        return self.front.entries() + len(self.table.ram.items)

    # -- durability (DESIGN.md §11) -----------------------------------------
    # flashlint: quiescent (facade snapshots post-flush; nothing in flight)
    def snapshot_state(self, path, step: int, meta: Dict,
                       manager=None) -> Path:
        """Capture the whole costed simulator (table + its own RAM buffer
        + ledgers) with the checkpoint layout's atomic tmp+rename, as a
        pickle — the sim is a plain NumPy/host object graph, so pickling
        round-trips it exactly. ``manager`` is accepted for signature
        parity with the device backends (unused: no arrays to shard)."""
        import json
        import pickle
        path = Path(path)
        final = path / f"step_{step:08d}"
        tmp = path / f"step_{step:08d}.tmp"
        if tmp.exists():
            import shutil
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        with self._disp.lock:
            blob = pickle.dumps(self.table)
        (tmp / "sim_table.pkl").write_bytes(blob)
        (tmp / "meta.json").write_text(json.dumps(meta))
        if final.exists():
            import shutil
            shutil.rmtree(final)
        tmp.rename(final)
        return final

    # flashlint: quiescent (restore path: dispatcher re-armed, no worker)
    def restore_state(self, path, step: Optional[int] = None):
        """Load the pickled simulator from ``path`` (latest ``step_*`` or
        an explicit ``step``); with no snapshot on disk, rebuild a fresh
        table so the WAL replay starts from zero. Returns
        ``(step | None, meta)``."""
        import json
        import pickle
        from .table_sim import make_table
        if path is not None and step is None:
            step = _latest_step(path)
        if path is None or step is None:
            self.table = make_table(self.scheme, self.geom, self._ram_pct,
                                    self._cs_pct, **self._table_kw)
            self._dirty = False
            return None, {}
        d = Path(path) / f"step_{step:08d}"
        self.table = pickle.loads((d / "sim_table.pkl").read_bytes())
        self._dirty = False
        meta = json.loads((d / "meta.json").read_text())
        return step, meta

    def rearm(self) -> None:
        """Replace a (possibly wedged/poisoned) dispatcher with a fresh
        worker of the same sync/async flavour; the restore path calls
        this before clearing the front."""
        old = self._disp
        self._disp = FlushDispatcher(enabled=old.enabled)
        self._disp.ledger = self.stats_ledger
        self._disp.tracer = old.tracer
        self.front.dispatcher = self._disp
        try:
            old.close()
        except Exception:
            pass                      # the poison already surfaced once

    def partition_heat(self, keys) -> np.ndarray:
        return np.zeros(_flat_i64(keys).size)     # no device wear feed

    def wear(self) -> Dict[str, int]:
        """The sim's wear counters: ``cleans`` *is* the paper's erase
        count (the device backends' ``tile_stores`` analogue)."""
        self._disp.wait()
        led = self.table.ledger
        return {"cleans": led.cleans, "block_ops": led.block_ops,
                "page_ops": led.page_ops, "merges": led.merges,
                "stages": led.stages}

    def stats(self) -> Dict[str, int]:
        self._disp.wait()             # quiesce: one consistent ledger
        led = self.table.ledger
        q = self.table.qstats
        out = {"backend": self.name, "scheme": self.scheme,
               "cleans": led.cleans, "block_ops": led.block_ops,
               "page_ops": led.page_ops, "merges": led.merges,
               "stages": led.stages, "queries": q.queries,
               "found": q.found,
               "buffered_entries": self.pending_entries()}
        out.update({f"write_{k}": v
                    for k, v in self.stats_ledger.as_dict().items()})
        return out

    def close(self) -> None:
        self._disp.close()


# ---------------------------------------------------------------------------
# device backend: single-table engine pair
# ---------------------------------------------------------------------------
class DeviceBackend:
    """The PR-2/PR-3 engine pair, auto-wired: one
    :class:`~.write_engine.BatchedWriteEngine` owning the table state,
    one paired :class:`~.query_engine.BatchedQueryEngine`, flush →
    invalidate enforced by construction. With ``track_wear=True`` the
    backend additionally attributes per-drain ``TableStats`` wear deltas
    (Δ``tile_stores``) to change-segment partitions — the feed for
    wear-aware eviction policies (`serving/prefix_cache`)."""

    name = "device"
    # the wear ledger is mutated by _on_drain on the drain worker; FL006
    # holds every access to the state lock or an audited method
    _fl_guarded = ("_wear",)

    def __init__(self, cfg=None, state=None, chunk: int = 4096,
                 query_chunk: int = 1024,
                 flush_threshold: Optional[int] = None,
                 hot_capacity: int = 4096, track_wear: bool = False,
                 record: Optional[list] = None, async_flush: bool = True,
                 wal=None, **table_kw):
        from . import table_jax as tj
        from .query_engine import BatchedQueryEngine
        from .write_engine import BatchedWriteEngine
        self.cfg = cfg if cfg is not None else tj.FlashTableConfig(**table_kw)
        self.scheme = self.cfg.scheme
        self.query_engine = BatchedQueryEngine(
            self.cfg, chunk=query_chunk, hot_capacity=hot_capacity,
            filter_fn=((lambda state, q: tj.filter_probe(self.cfg, state, q))
                       if self.cfg.filters else None))
        self._track_wear = bool(track_wear)
        self._disp = FlushDispatcher(enabled=async_flush)
        self.writer = BatchedWriteEngine(
            self.cfg, state=state, chunk=chunk,
            flush_threshold=flush_threshold, query_engine=self.query_engine,
            record=record, on_flush=self._on_drain if track_wear else None,
            dispatcher=self._disp, wal=wal)
        # wear attribution: partition -> accumulated Δtile_stores share,
        # plus the staged-since-last-merge histogram merges are charged to
        # (the ledger is shared with the sharded backend — ISSUE 10)
        from .write_engine import PartitionHeatLedger
        self._wear = PartitionHeatLedger()

    # -- wear attribution ---------------------------------------------------
    def _partition_of(self, keys: np.ndarray) -> np.ndarray:
        """Host-side partition id: MDB's change-segment partition when the
        scheme has one, else the data block itself (finest granularity)."""
        s = self.cfg.pair.s(np.asarray(keys, np.int64))
        if self.scheme == "MDB":
            return np.asarray(s) // self.cfg.blocks_per_partition
        return np.asarray(s)

    def _on_drain(self, keys, wear_delta: int) -> None:  # flashlint: under-lock
        # the ledger charges the measured Δtile_stores to the partitions
        # staged since the last forced merge, proportional to staged
        # volume, with a decayed history (recent merge pressure, not the
        # lifetime total); keys=None marks the forced merge that drains
        # the staged histogram
        parts_counts = None
        if keys is not None:                 # H_R drain: staged entries
            parts, counts = np.unique(self._partition_of(keys),
                                      return_counts=True)
            parts_counts = list(zip(parts.tolist(), counts.tolist()))
        self._wear.note(parts_counts, wear_delta)

    def partition_heat(self, keys) -> np.ndarray:
        """Write pressure of each key's partition: entries currently
        pending for it (H_R — both buffers — + staged-unmerged; it *will*
        be rewritten at the next merge no matter what) plus the decayed
        per-merge ``TableStats`` wear history. Hot partitions are being
        rewritten anyway — re-dirtying them is nearly free; dirtying a
        cold one costs a fresh block rewrite. Takes the dispatcher lock:
        ``_on_drain`` mutates the heat ledgers on the drain worker."""
        flat = _flat_i64(keys)
        if flat.size == 0:
            return np.zeros(0)
        with self._disp.lock:
            pending, heat = self._wear.snapshot()
            for b in (self.writer.front._buf[0],
                      self.writer.front._inflight[0]):
                if not b:
                    continue
                bk = np.fromiter(b.keys(), np.int64, len(b))
                parts, counts = np.unique(self._partition_of(bk),
                                          return_counts=True)
                for p, c in zip(parts.tolist(), counts.tolist()):
                    pending[p] = pending.get(p, 0) + c
        if not pending and not heat:
            return np.zeros(flat.size)
        parts = self._partition_of(flat)
        return np.asarray([pending.get(int(p), 0)
                           + heat.get(int(p), 0.0) for p in parts])

    # -- protocol -----------------------------------------------------------
    @property
    def state(self):
        return self.writer.state

    @property
    def front(self) -> SealedFront:
        """The engine's sealed front (the store facade's lifecycle
        handle: quiesce / poison probe / WAL)."""
        return self.writer.front

    def update(self, tokens, deltas=None) -> None:
        self.writer.update(tokens, deltas)

    def query_batch(self, keys) -> np.ndarray:
        return self.writer.query_batch(keys)

    def drain(self, wait: bool = True) -> None:
        self.writer.flush(wait=wait)

    def flush(self, wait: bool = True) -> None:
        self.writer.merge(wait=wait)

    def pending_entries(self) -> int:
        return self.writer.buffered_entries

    def wear(self) -> Dict[str, int]:
        self._disp.wait()             # quiesce: device counters settled
        s = self.state.stats
        return {f: int(getattr(s, f)) for f in s._fields}

    def stats(self) -> Dict[str, int]:
        out = {"backend": self.name, "scheme": self.scheme}
        out.update(self.wear())       # barriers the in-flight drain
        out.update({f"write_{k}": v
                    for k, v in self.writer.stats.as_dict().items()})
        out.update({f"query_{k}": v
                    for k, v in self.query_engine.stats.as_dict().items()})
        out["buffered_entries"] = self.pending_entries()
        return out

    # -- durability (DESIGN.md §11) -----------------------------------------
    # flashlint: quiescent (facade snapshots post-flush; nothing in flight)
    def snapshot_state(self, path, step: int, meta: Dict,
                       manager=None) -> Path:
        """Capture the device table state through the checkpoint layout
        (atomic tmp+rename ``step_<N>/{meta.json,arrays.npz}``)."""
        from ..checkpoint.checkpoint import CheckpointManager
        if manager is None:
            # keep=huge: snapshot GC policy belongs to the caller, not
            # the durability path
            manager = CheckpointManager(path, every_steps=1, keep=1_000_000)
        manager.save(step, self.state, blocking=True, extra_meta=meta)
        return Path(path) / f"step_{step:08d}"

    # flashlint: quiescent (restore path: dispatcher re-armed, no worker)
    def restore_state(self, path, step: Optional[int] = None):
        """Load the device state from the latest (or given) snapshot
        under ``path``; with no snapshot, re-init a fresh table so the
        WAL replay starts from zero. Returns ``(step | None, meta)``."""
        import jax
        import jax.numpy as jnp

        from . import table_jax as tj
        if path is not None and step is None:
            step = _latest_step(path)
        if path is None or step is None:
            self.writer.state = tj.init(self.cfg)
            meta = {}
            step = None
        else:
            from ..checkpoint.checkpoint import restore_checkpoint
            restored, meta = restore_checkpoint(path, tj.init(self.cfg),
                                                step=step)
            # npz leaves come back as numpy; the donated update programs
            # (and assert_live) need real jax arrays
            self.writer.state = jax.tree.map(jnp.asarray, restored)
        self.writer._staged_dirty = True  # snapshot may hold staged segments
        self._wear.clear()
        self.query_engine.invalidate()
        return step, meta

    def rearm(self) -> None:
        """Replace a (possibly wedged/poisoned) dispatcher with a fresh
        worker; restore calls this before clearing the front."""
        old = self._disp
        self._disp = FlushDispatcher(enabled=old.enabled)
        self._disp.ledger = self.writer.stats
        self._disp.tracer = old.tracer
        self.writer.dispatcher = self._disp
        self.writer.front.dispatcher = self._disp
        try:
            old.close()
        except Exception:
            pass                      # the poison already surfaced once

    def close(self) -> None:
        self._disp.close()


# ---------------------------------------------------------------------------
# sharded backend: per-shard H_R partitions over the distributed table
# ---------------------------------------------------------------------------
class ShardedBackend:
    """The distributed table (:mod:`.distributed`) brought to engine
    parity — the ROADMAP "distributed sharded table at scale" item.

    * **per-shard H_R partitions** — the host buffer is split by
      ``owner(x)`` (the same two-level hash that shards the data
      segment), so dedup/cancellation state is per-shard and a drain can
      target one shard's traffic;
    * **shard-local flush thresholds** — a partition drains when *it*
      fills; the other shards' buffers stay warm (their entries keep
      absorbing duplicates) instead of being forced out by a global
      count. Because the collective is fixed-shape anyway, partitions at
      least ``piggyback_frac`` full ride along for free;
    * **owner-aligned dispatch** — drained entries are placed directly in
      their owner shard's slice of the update batch, so the ``all_to_all``
      routes every entry shard-locally (src == dst: zero cross-shard
      payload movement) and the per-(src,dst) ``bucket_cap`` can never
      overflow (``shard_chunk <= bucket_cap`` entries, all self-owned);
    * **consolidated lookups** — one shard_map'd lookup per EMPTY-padded
      query chunk serves the whole deduped batch (every shard probes its
      blocks, one psum combines), fronted by the standard
      :class:`~.query_engine.BatchedQueryEngine` hot cache + H_R overlay.

    All three schemes shard (ISSUE 10): MDB's per-change-segment-partition
    log pointers tile to a per-shard leading dim like every other leaf
    (:func:`distributed._squeeze` is scheme-aware).

    **Multi-process meshes** (ISSUE 10, DESIGN.md §14). When the process
    was brought up under ``jax.distributed.initialize`` the same backend
    runs the *cluster* edition: the mesh spans every process's devices,
    each host folds its own ingest into its host-local per-shard H_R
    partitions, and the cross-host ``all_to_all`` inside the update
    program routes drained entries to their owner's blocks. Because
    collective programs are SPMD, three rules change vs. single-host:

    * drains/flushes/queries are **collective** — every process must call
      them at the same logical point (threshold auto-flush is disabled;
      the caller drives the drain cadence);
    * hosts first **agree on the number of drain waves** (and whether a
      device merge is pending anywhere) via a tiny caller-thread
      collective run post-settle, so the worker-side collectives stay in
      global program order (``agree_k < waves_k < agree_{k+1}``) while
      still being hidden behind each host's local ingest;
    * each host packs its sealed entries into its **local device slices**
      only (``<= shard_chunk`` entries per slice, so the per-(src,dst)
      bucket can never overflow: ``write_carried == 0`` stays structural
      even though the a2a now does real cross-host routing).
    """

    name = "sharded"
    # shared with the drain worker; flashlint FL006 holds every access
    # to the state lock (or an audited under-lock/quiescent method). The
    # per-shard H_R double-buffer itself lives in the SealedFront.
    _fl_guarded = ("state", "_staged_dirty", "_wear")

    def __init__(self, cfg=None, mesh=None, axis: str = "table",
                 num_shards: Optional[int] = None,
                 shard_chunk: Optional[int] = None,
                 flush_threshold: Optional[int] = None,
                 query_chunk: int = 1024, hot_capacity: int = 4096,
                 piggyback_frac: float = 0.5, async_flush: bool = True,
                 track_wear: bool = True, wal=None, **table_kw):
        import jax
        from jax.sharding import NamedSharding

        from . import distributed as D
        from . import table_jax as tj
        from .query_engine import BatchedQueryEngine
        from .write_engine import PartitionHeatLedger, WriteEngineStats

        if cfg is None or isinstance(cfg, tj.FlashTableConfig):
            n = int(num_shards if num_shards is not None
                    else jax.device_count())
            local = cfg if cfg is not None else tj.FlashTableConfig(
                **table_kw)
            cfg = D.ShardedTableConfig(local=local, num_shards=n)
        self.cfg = cfg
        n = cfg.num_shards
        if n & (n - 1):
            raise ValueError(f"num_shards={n} must be a power of two")
        self.scheme = cfg.local.scheme
        self.mesh = mesh if mesh is not None else jax.make_mesh((n,), (axis,))
        self.axis = axis
        # multi-process mesh? (jax.distributed.initialize before open)
        self.num_processes = int(jax.process_count())
        self.process_index = int(jax.process_index())
        self.multihost = self.num_processes > 1
        # mesh positions whose device this process owns == the slices this
        # host may pack drained entries into (all of them, single-host)
        self._local_shards = (D.host_shards(self.mesh, axis)
                              if self.multihost else list(range(n)))
        self.shard_chunk = int(min(cfg.bucket_cap, shard_chunk or 1024))
        self.flush_threshold = int(2 * self.shard_chunk
                                   if flush_threshold is None
                                   else flush_threshold)
        self.piggyback_frac = float(piggyback_frac)
        self._jnp = jax.numpy
        self._upd = D.make_update_fn(cfg, self.mesh, axis,
                                     with_deltas=True, donate=True)
        self._mrg = D.make_flush_fn(cfg, self.mesh, axis, donate=True)
        self._sync = (D.make_sync_fn(cfg, self.mesh, axis)
                      if self.multihost else None)
        look = D.make_lookup_fn(cfg, self.mesh, axis, with_dist=True,
                                with_tiles=True)
        filt = (D.make_filter_fn(cfg, self.mesh, axis)
                if cfg.local.filters else None)
        if self.multihost:
            # query batches must be *global* (replicated) arrays — a
            # process-local jnp array is not addressable mesh-wide
            mesh_ = self.mesh
            lookup_fn = lambda state, q: look(
                state, D.make_replicated(mesh_, np.asarray(q)))
            filter_fn = (None if filt is None else lambda state, q: filt(
                state, D.make_replicated(mesh_, np.asarray(q))))
        else:
            lookup_fn = lambda state, q: look(state, q)
            filter_fn = (None if filt is None
                         else lambda state, q: filt(state, q))
        self.query_engine = BatchedQueryEngine(
            cfg.local, chunk=query_chunk, hot_capacity=hot_capacity,
            lookup_fn=lookup_fn, filter_fn=filter_fn)
        spec = jax.tree.map(lambda s: NamedSharding(self.mesh, s),
                            D.state_pspec(axis, cfg.local),
                            is_leaf=lambda s: type(s).__name__
                            == "PartitionSpec")
        self._spec = spec             # restore reshard target
        self.state = (D.place_global(cfg, self.mesh, axis) if self.multihost
                      else jax.device_put(D.init_global(cfg), spec))
        self._shard_bits = cfg.local.q_log2 - cfg.local.r_log2
        self._staged_dirty = False    # staged entries since last merge
        self._disp = FlushDispatcher(enabled=async_flush)
        # per-shard H_R partitions behind the one sealed-front lifecycle
        self.front = SealedFront(dispatcher=self._disp, parts=n, wal=wal)
        self.stats_ledger = WriteEngineStats()
        self._disp.ledger = self.stats_ledger
        self.piggybacked = 0
        self.carried = 0
        # per-shard wear/heat (ISSUE 10): keyed by *global* block id so
        # heat is a function of the trace, not of the mesh topology; the
        # merge charge is the trace-derived staged volume (the sharded
        # TableStats deltas are not per-host-readable). track_wear is
        # accepted for DeviceBackend signature parity — the proxy feed is
        # cheap enough to keep on unconditionally.
        self._track_wear = bool(track_wear)
        self._wear = PartitionHeatLedger()

    @property
    def _inflight(self) -> List[Optional[Dict[int, int]]]:
        """Read-only view of the sealed per-shard overlays (tests probe
        it; the front owns the real slots)."""
        return self.front._inflight

    # -- owner routing ------------------------------------------------------
    def owner_of(self, keys) -> np.ndarray:
        """Owner shard per key: the global block id's top (shard) bits."""
        s = np.asarray(self.cfg.global_pair.s(_flat_i64(keys)))
        return s >> self._shard_bits

    # -- the buffered write path -------------------------------------------
    def update(self, tokens, deltas=None) -> None:
        from .write_engine import dedup_batch
        led = self.stats_ledger
        led.updates += 1
        uniq, sums, n_valid = dedup_batch(tokens, deltas, EMPTY)
        if n_valid == 0:
            return
        led.entries += n_valid
        owners = self.owner_of(uniq)
        n_new, cancelled = self.front.fold(uniq, sums, owners)
        led.cancelled += cancelled
        led.buffered += n_new
        led.deduped += n_valid - n_new
        if self.multihost:
            # drains are collective: a host-local threshold must not
            # launch a collective program the other hosts don't know
            # about. The caller drives the drain cadence (DESIGN.md §14).
            return
        lens = self.front.part_lens()
        hot = [i for i, ln in enumerate(lens)
               if ln >= self.flush_threshold]
        if hot:
            led.auto_flushes += 1
            ride = [i for i, ln in enumerate(lens)
                    if i not in hot
                    and ln >= self.piggyback_frac * self.flush_threshold]
            self.piggybacked += len(ride)
            self.drain(shards=hot + ride, wait=False)

    def _seal(self, shards=None) -> Optional[Dict]:  # flashlint: quiescent
        """Seal the selected shards' H_R partitions via the front (each
        sealed dict becomes that shard's in-flight overlay). Returns
        {shard: (sorted keys, deltas)} or None. Callers run it
        post-settle (no drain in flight)."""
        return self.front.seal(parts=shards)

    # flashlint: under-lock (drain-worker body, submitted via dispatcher)
    def _drain_sealed(self, per_shard: Dict) -> None:
        """Dispatch sealed shard partitions to their owners' change
        segments (no forced merge) — worker side, under the dispatcher
        lock. One fixed-shape collective per ``shard_chunk``-entry wave;
        every drained entry rides in its owner's slice, so the a2a is
        shard-local by construction."""
        from .distributed import assert_live
        jnp = self._jnp
        n = self.cfg.num_shards
        step = self.shard_chunk
        led = self.stats_ledger
        assert_live(self.state)       # off-thread donation guard (§9)
        waves = max(-(-ks.size // step) for ks, _ in per_shard.values())
        for w in range(waves):
            toks = np.full(n * step, EMPTY, np.int64)
            dels = np.zeros(n * step, np.int64)
            for s, (ks, vs) in per_shard.items():
                part_k = ks[w * step:(w + 1) * step]
                part_v = vs[w * step:(w + 1) * step]
                toks[s * step:s * step + part_k.size] = part_k
                dels[s * step:s * step + part_v.size] = part_v
            self.state, n_carry = self._upd(self.state,
                                            jnp.asarray(toks, jnp.int32),
                                            jnp.asarray(dels, jnp.int32))
            led.dispatches += 1
            # owner-aligned placement keeps every (src,dst) bucket within
            # bucket_cap, so the collective can never carry entries over
            self.carried += int(np.asarray(n_carry).sum())
        import jax
        jax.block_until_ready(self.state)   # durable, not merely queued (§9)
        self._disp.trace("state_rebind", "state", "w")
        self._staged_dirty = True
        for _s, (ks, _vs) in per_shard.items():
            led.dispatched_entries += ks.size
        self._note_staged(per_shard)
        self.front.mark_drained(sorted(per_shard))
        led.flushes += 1
        self.query_engine.invalidate()
        led.invalidations += 1

    def _note_staged(self, per_shard: Dict) -> None:  # flashlint: under-lock
        """Feed the wear ledger with the drained entries, keyed by
        *global* block id — the trace-derived proxy for per-shard
        ``partition_heat`` (identical no matter how the mesh splits the
        trace across processes). Worker side, under the dispatcher lock."""
        if not self._track_wear or not per_shard:
            return
        blocks = np.concatenate(
            [np.asarray(self.cfg.global_pair.s(ks))
             for ks, _vs in per_shard.values()])
        parts, counts = np.unique(blocks, return_counts=True)
        self._wear.note(list(zip(parts.tolist(), counts.tolist())), 0)

    # flashlint: under-lock (drain-worker body, submitted via dispatcher)
    def _merge_device(self) -> None:
        """Force the device merge of all staged change segments — worker
        side, under the dispatcher lock."""
        import jax

        from .distributed import assert_live
        assert_live(self.state)
        self.state = self._mrg(self.state)
        jax.block_until_ready(self.state)
        self._disp.trace("state_rebind", "state", "w")
        self.stats_ledger.merges += 1
        self._staged_dirty = False
        if self._track_wear:
            # merge charge = staged volume since the last merge (the
            # trace-derived twin of DeviceBackend's Δtile_stores feed)
            self._wear.note(None, float(sum(self._wear.staged.values())))
        self.query_engine.invalidate()
        self.stats_ledger.invalidations += 1

    def _stall_if_inflight(self) -> None:
        """Wait out in-flight work before sealing or a no-op decision
        (the double-buffer stall + poison check live in
        :meth:`SealedFront.settle`); a running job whose merge phase has
        yet to settle ``_staged_dirty`` also barriers here."""
        self.front.settle()

    # -- multi-process drains (ISSUE 10, DESIGN.md §14) ----------------------
    def _agree(self, waves: int, dirty: int) -> Tuple[int, int]:
        """Caller-thread agreement collective: element-wise max over
        shards of ``(waves, dirty)``. Each process fills only its own
        shards' rows (the placement callback never asks for the others),
        so the result is the max over hosts. Runs post-settle — no worker
        collective can be in flight — keeping the global collective order
        strict: ``agree_k < waves_k < agree_{k+1}`` on every host."""
        from . import distributed as D
        v = np.zeros((self.cfg.num_shards, 2), np.int32)
        v[self._local_shards, 0] = waves
        v[self._local_shards, 1] = dirty
        got = np.asarray(self._sync(
            D.make_global_batch(self.mesh, self.axis, v)))
        return int(got[0]), int(got[1])

    def _drain_collective(self, merge: bool, wait: bool) -> None:
        """Multihost drain/flush body: seal all host-local partitions,
        agree with the other hosts on the number of fixed-shape drain
        waves (and, for a flush, whether any host still has staged
        segments), then submit ONE worker job that runs exactly the
        agreed program sequence — identical on every host (SPMD
        lockstep), with the collectives themselves hidden behind the
        next buffer's local ingest (the overlap_us ledger)."""
        per_shard = self._seal(None)
        total = (sum(ks.size for ks, _vs in per_shard.values())
                 if per_shard else 0)
        budget = len(self._local_shards) * self.shard_chunk
        waves = -(-total // budget) if total else 0
        # post-settle probe: no job in flight, the flag is stable
        dirty = 1 if (merge and
                      self._staged_dirty) else 0  # flashlint: disable=FL006
        g_waves, g_dirty = self._agree(waves, dirty)
        if g_waves == 0 and not (merge and g_dirty):
            if wait:
                self._disp.wait()
            return

        def job():
            self._drain_sealed_multihost(per_shard, g_waves)
            if merge and g_dirty:
                self._merge_device()

        kind = "flush" if merge else "drain"
        mine = sorted(per_shard) if per_shard else []
        self._disp.submit(job, label=f"mh-{kind}#{self.front.seals}:"
                                     f"waves{g_waves}:shards{mine}")
        if wait:
            self._disp.wait()

    # flashlint: under-lock (drain-worker body, submitted via dispatcher)
    def _drain_sealed_multihost(self, per_shard: Optional[Dict],
                                waves: int) -> None:
        """Run the agreed number of collective update waves, packing this
        host's sealed entries into its *local* device slices only (the
        a2a routes them to their owners across hosts). Each slice holds
        at most ``shard_chunk <= bucket_cap`` entries, so no (src, dst)
        bucket can overflow — ``write_carried == 0`` stays structural. A
        host with nothing sealed still runs its share of the waves with
        EMPTY slices (SPMD lockstep)."""
        from . import distributed as D
        from .distributed import assert_live
        n = self.cfg.num_shards
        step = self.shard_chunk
        budget = len(self._local_shards) * step
        led = self.stats_ledger
        assert_live(self.state)
        if per_shard:
            order = sorted(per_shard)
            ks = np.concatenate([per_shard[s][0] for s in order])
            vs = np.concatenate([per_shard[s][1] for s in order])
        else:
            ks = np.zeros(0, np.int64)
            vs = np.zeros(0, np.int64)
        for w in range(waves):
            toks = np.full(n * step, EMPTY, np.int64)
            dels = np.zeros(n * step, np.int64)
            ck = ks[w * budget:(w + 1) * budget]
            cv = vs[w * budget:(w + 1) * budget]
            for j, s in enumerate(self._local_shards):
                pk = ck[j * step:(j + 1) * step]
                pv = cv[j * step:(j + 1) * step]
                toks[s * step:s * step + pk.size] = pk
                dels[s * step:s * step + pv.size] = pv
            gt = D.make_global_batch(self.mesh, self.axis,
                                     toks.astype(np.int32))
            gd = D.make_global_batch(self.mesh, self.axis,
                                     dels.astype(np.int32))
            self.state, n_carry = self._upd(self.state, gt, gd)
            led.dispatches += 1
            self.carried += int(np.asarray(n_carry))
        import jax
        jax.block_until_ready(self.state)   # durable, not merely queued
        self._disp.trace("state_rebind", "state", "w")
        if waves:
            # other hosts' entries may have landed in our local shards'
            # change segments even when we sealed nothing
            self._staged_dirty = True
        if per_shard:
            for _s, (pks, _pvs) in per_shard.items():
                led.dispatched_entries += pks.size
            self._note_staged(per_shard)
            self.front.mark_drained(sorted(per_shard))
            led.flushes += 1
        self.query_engine.invalidate()
        led.invalidations += 1

    def drain(self, shards: Optional[List[int]] = None,
              wait: bool = True) -> None:
        """Seal the selected shards' H_R partitions and drain them on
        the worker (no forced merge). On a multi-process mesh this is a
        collective call: every process seals *all* its local partitions
        (``shards`` selection is host-local and therefore ignored) and
        the hosts agree on the wave count before the worker dispatches."""
        self._stall_if_inflight()
        if self.multihost:
            self._drain_collective(merge=False, wait=wait)
            return
        per_shard = self._seal(shards)
        if per_shard is not None:
            self._disp.submit(lambda: self._drain_sealed(per_shard),
                              label=f"shard-drain#{self.front.seals}:"
                                    f"shards{sorted(per_shard)}")
        if wait:
            self._disp.wait()

    def flush(self, wait: bool = True) -> None:
        """Durability point: drain every H_R partition, then force the
        device merge of all staged change segments. A complete no-op —
        nothing buffered, in flight or staged — touches neither the
        device nor the hot cache. Collective on a multi-process mesh
        (the merge runs on every host when *any* host has staged
        segments; the no-op decision is agreed, not local)."""
        self._stall_if_inflight()
        if self.multihost:
            self._drain_collective(merge=True, wait=wait)
            return
        per_shard = self._seal(None)
        # post-settle probe: no job is in flight here, so the flag is
        # stable until we submit below
        if (per_shard is None
                and not self._staged_dirty):  # flashlint: disable=FL006
            if wait:
                self._disp.wait()
            return

        def job():
            if per_shard is not None:
                self._drain_sealed(per_shard)
            self._merge_device()

        shards = sorted(per_shard) if per_shard else []
        self._disp.submit(job, label=f"shard-flush#{self.front.seals}:"
                                     f"shards{shards}")
        if wait:
            self._disp.wait()

    # -- read-your-writes ---------------------------------------------------
    def pending_entries(self) -> int:
        # benign unlocked snapshot (monitoring only, may be momentarily
        # stale); never used for control flow
        return self.front.entries()

    def pending(self, keys) -> np.ndarray:  # flashlint: under-lock
        """Not-yet-durable Δ per key: active + in-flight partition of the
        key's owner shard. Call under the dispatcher lock (the worker
        clears in-flight slots under it, atomically with the state
        rebind)."""
        flat = _flat_i64(keys)
        return self.front.pending(flat, self.owner_of(flat))

    def query_batch(self, keys) -> np.ndarray:
        if self.multihost:
            # lookups are collective programs: barrier the in-flight
            # drain first so every host issues them at the same point in
            # the global program order. Every process must call
            # query_batch with identical keys (DESIGN.md §14).
            self._disp.wait()
        with self._disp.lock:
            base = self.query_engine.query_batch(self.state, keys)
            pend = self.pending(keys)
        return base + pend

    def partition_heat(self, keys) -> np.ndarray:
        """Write pressure of each key's *global* block (ISSUE 10): H_R
        entries pending for it (active + in-flight, this host's view of
        the trace) plus the decayed per-merge heat history from the
        trace-derived wear proxy. Topology-invariant by construction —
        the ledger keys are global block ids, so the same trace produces
        the same heat on a 1-host-8-shard and a 2-process-4-shard mesh."""
        flat = _flat_i64(keys)
        if flat.size == 0:
            return np.zeros(0)
        with self._disp.lock:
            pending, heat = self._wear.snapshot()
            for bufs in (self.front._buf, self.front._inflight):
                for b in bufs:
                    if not b:
                        continue
                    bk = np.fromiter(b.keys(), np.int64, len(b))
                    parts, counts = np.unique(
                        np.asarray(self.cfg.global_pair.s(bk)),
                        return_counts=True)
                    for p, c in zip(parts.tolist(), counts.tolist()):
                        pending[p] = pending.get(p, 0) + c
        if not pending and not heat:
            return np.zeros(flat.size)
        parts = np.asarray(self.cfg.global_pair.s(flat))
        return np.asarray([pending.get(int(p), 0)
                           + heat.get(int(p), 0.0) for p in parts])

    def wear(self) -> Dict[str, int]:  # flashlint: quiescent
        """Device wear counters summed across shards. On a multi-process
        mesh a host can only read its addressable shards, so the counters
        are the *local* shards' sums — the per-host wear view (the drain
        routed every entry to its owner, so summing across hosts'
        reports recovers the global figure)."""
        self._disp.wait()             # quiesce: device counters settled
        s = self.state.stats

        def tot(x) -> int:
            if self.multihost:
                return int(sum(int(np.asarray(sh.data).sum())
                               for sh in x.addressable_shards))
            return int(np.asarray(x).sum())

        return {f: tot(getattr(s, f)) for f in s._fields}

    def stats(self) -> Dict[str, int]:
        out = {"backend": self.name, "scheme": self.scheme,
               "shards": self.cfg.num_shards}
        out.update(self.wear())       # barriers the in-flight drain
        out.update({f"write_{k}": v
                    for k, v in self.stats_ledger.as_dict().items()})
        out.update({f"query_{k}": v
                    for k, v in self.query_engine.stats.as_dict().items()})
        out["buffered_entries"] = self.pending_entries()
        out["write_piggybacked"] = self.piggybacked
        out["write_carried"] = self.carried
        out["buffered_per_shard_max"] = max(
            self.front.part_lens(), default=0)
        return out

    # -- durability (DESIGN.md §11) -----------------------------------------
    # flashlint: quiescent (facade snapshots post-flush; nothing in flight)
    def snapshot_state(self, path, step: int, meta: Dict,
                       manager=None) -> Path:
        """Capture the global sharded state through the checkpoint layout
        (full arrays per the single-process writer; restore reshards
        against the current mesh). Multi-process meshes recover through
        their per-host WALs instead (DESIGN.md §14): serializing a
        non-addressable global array would need a gather collective the
        checkpoint layer doesn't speak yet."""
        if self.multihost:
            raise NotImplementedError(
                "multihost sharded stores snapshot via per-host WALs "
                "(FlashStore.restore replays them); global-array "
                "snapshots need a gather the checkpoint layer lacks")
        from ..checkpoint.checkpoint import CheckpointManager
        if manager is None:
            manager = CheckpointManager(path, every_steps=1, keep=1_000_000)
        manager.save(step, self.state, blocking=True, extra_meta=meta)
        return Path(path) / f"step_{step:08d}"

    # flashlint: quiescent (restore path: dispatcher re-armed, no worker)
    def restore_state(self, path, step: Optional[int] = None):
        """Load the global state from the latest (or given) snapshot and
        device_put it against the current mesh's shardings (the elastic
        reshard); with no snapshot, re-init fresh. Returns
        ``(step | None, meta)``."""
        import jax

        from . import distributed as D
        if path is not None and step is None:
            step = _latest_step(path)
        if path is None or step is None:
            self.state = (D.place_global(self.cfg, self.mesh, self.axis)
                          if self.multihost
                          else jax.device_put(D.init_global(self.cfg),
                                              self._spec))
            meta = {}
            step = None
        else:
            if self.multihost:
                raise NotImplementedError(
                    "multihost sharded stores restore from per-host "
                    "WALs over a fresh init (path=None)")
            from ..checkpoint.checkpoint import restore_checkpoint
            restored, meta = restore_checkpoint(
                path, D.init_global(self.cfg), step=step,
                shardings=self._spec)
            self.state = restored
        self._staged_dirty = True     # snapshot may hold staged segments
        self._wear.clear()
        self.query_engine.invalidate()
        return step, meta

    def rearm(self) -> None:
        """Replace a (possibly wedged/poisoned) dispatcher with a fresh
        worker; restore calls this before clearing the front."""
        old = self._disp
        self._disp = FlushDispatcher(enabled=old.enabled)
        self._disp.ledger = self.stats_ledger
        self._disp.tracer = old.tracer
        self.front.dispatcher = self._disp
        try:
            old.close()
        except Exception:
            pass                      # the poison already surfaced once

    def close(self) -> None:
        self._disp.close()


_BACKENDS = {"sim": SimBackend, "device": DeviceBackend,
             "sharded": ShardedBackend}


# ---------------------------------------------------------------------------
# the facade
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class RestoreReport:
    """What :meth:`FlashStore.restore` actually did — the recovery
    audit trail (tests assert on it; operators log it)."""

    snapshot_step: Optional[int]  # step restored from (None: fresh init)
    base_seq: int                 # WAL seqs <= this were pre-rotation
    records_replayed: int         # sealed WAL chunks re-applied
    entries_replayed: int         # (token, Δ) pairs re-applied
    tail_discarded_bytes: int     # torn WAL tail dropped (warned loudly)
    poison_cleared: bool          # the store was poisoned going in
    meta: Dict                    # snapshot meta.json (includes extras)


class FlashStore:
    """Backend-agnostic counting hash table with the paper's deferred-
    update discipline built in. Construct with :meth:`open`; use as a
    context manager for automatic flush-on-exit. See the module docstring
    for the backend landscape."""

    def __init__(self, backend_impl):
        self._b = backend_impl
        self._closed = False

    @classmethod
    def open(cls, config=None, backend: str = "device", **kw) -> "FlashStore":
        """One constructor for every backend.

        ``config`` is backend-shaped — a ``TableGeometry`` for ``sim``, a
        ``FlashTableConfig`` for ``device``, a ``ShardedTableConfig`` (or
        the local ``FlashTableConfig``) for ``sharded`` — or ``None`` to
        build one from ``**kw`` (``scheme=``, ``q_log2=``, ...). Engine
        knobs (``chunk``, ``flush_threshold``, ``query_chunk``,
        ``hot_capacity``, ``async_flush``, ...) pass through as keywords;
        ``async_flush=False`` opts out of the background drain worker
        (DESIGN.md §9) for a synchronous store.

        ``wal=`` (a path, or a :class:`~.wal.WriteAheadLog`) attaches a
        chunk-granular write-ahead log: every sealed H_R chunk is
        appended and fsync'd *before* its drain dispatches, so a crash
        mid-drain loses nothing that was sealed — :meth:`restore` replays
        the log (DESIGN.md §11). Default off: the paper's numbers carry
        no WAL cost unless asked for.
        """
        try:
            impl = _BACKENDS[backend]
        except KeyError:
            raise ValueError(f"unknown backend {backend!r}; expected one "
                             f"of {tuple(_BACKENDS)}") from None
        wal = kw.pop("wal", None)
        if wal is not None and not hasattr(wal, "append_seal"):
            from .wal import WriteAheadLog
            wal = WriteAheadLog(wal)
        kw["wal"] = wal
        if config is None:
            return cls(impl(**kw))
        if backend == "sim":
            return cls(impl(geom=config, **kw))
        return cls(impl(cfg=config, **kw))

    # -- lifecycle ----------------------------------------------------------
    def _check_open(self) -> None:
        if self._closed:
            raise ValueError("store is closed")

    def close(self) -> None:
        """Flush (durability point) and release the store: any in-flight
        drain completes, the buffers empty, the drain worker joins.
        Idempotent — a second close (or ``__exit__`` after an explicit
        close) does nothing. If the final flush fails (e.g. the store
        was poisoned by an earlier drain failure), the error propagates
        but the worker is still joined and the store still ends closed —
        no thread leak, no close() loop."""
        if self._closed:
            return
        try:
            self._b.flush(wait=True)
        finally:
            self._b.close()
            if self._b.front.wal is not None:
                self._b.front.wal.close()
            self._closed = True

    def __enter__(self) -> "FlashStore":
        self._check_open()
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        # an exception mid-stream still drains H_R: buffered counts are
        # the caller's data, not scratch
        self.close()

    # -- writes -------------------------------------------------------------
    def update(self, tokens, deltas=None) -> None:
        """Accumulate a (token[, Δ]) batch into H_R. Duplicates fold,
        zero-sum Δs cancel (§2.6), EMPTY tokens are padding; the device
        sees traffic only at flush thresholds."""
        self._check_open()
        self._b.update(tokens, deltas)

    def increment(self, key: int, delta: int = 1) -> None:
        """Single-key counter bump; ``delta=-1`` is the paper's
        deletion-by-decrement."""
        self.update(np.asarray([key], np.int64),
                    np.asarray([delta], np.int64))

    def flush(self, wait: bool = True) -> None:
        """Durability point: drain H_R and force the device merge of any
        staged change segment (end-of-stream / checkpoint).

        ``wait=True`` (default) is the durability **barrier**: when it
        returns, every buffered entry is on device and any drain error
        has been re-raised here. ``wait=False`` schedules the drain+merge
        on the background worker and returns immediately — ingest can
        continue; a later ``flush()``/``stats()``/``close()`` barriers.
        A flush with nothing buffered, in flight or staged is a complete
        no-op (in particular, it does not invalidate the hot-key cache)."""
        self._check_open()
        self._b.flush(wait=wait)

    def drain(self, wait: bool = True) -> None:
        """Stage H_R to the device change segment without forcing the
        merge (the cheap half of :meth:`flush`): sealed entries reach
        flash as sequential change-segment writes, data blocks are not
        rewritten. Same ``wait`` semantics as :meth:`flush`."""
        self._check_open()
        self._b.drain(wait=wait)

    # -- reads --------------------------------------------------------------
    def query(self, keys):
        """Counts for ``keys`` — scalar in, ``int`` out; array-like in,
        ``int64`` array out (aligned with the flattened input). Reads are
        read-your-writes: buffered H_R deltas overlay device counts."""
        self._check_open()
        if np.isscalar(keys) or (isinstance(keys, np.ndarray)
                                 and keys.ndim == 0):
            return int(self._b.query_batch(np.asarray([keys]))[0])
        return self._b.query_batch(keys)

    def query_batch(self, keys) -> np.ndarray:
        """Alias of :meth:`query` for unambiguously-batched call sites."""
        self._check_open()
        return self._b.query_batch(keys)

    # -- introspection ------------------------------------------------------
    @property
    def backend(self) -> str:
        return self._b.name

    @property
    def scheme(self) -> str:
        return self._b.scheme

    @property
    def cfg(self):
        return getattr(self._b, "cfg", None)

    @property
    def state(self):
        """Device table state (device/sharded backends)."""
        return getattr(self._b, "state", None)

    @property
    def buffered_entries(self) -> int:
        return self._b.pending_entries()

    def stats(self) -> Dict[str, int]:
        """One flat ledger: device wear (``tile_stores`` = paper cleans)
        or sim I/O counters, plus ``write_*`` (H_R, including the async
        ``write_overlap_us``/``write_stall_us`` flush ledgers) and
        ``query_*`` (batched read path) counters. Barriers any in-flight
        drain first, so the ledger is a consistent snapshot."""
        return self._b.stats()

    def wear(self) -> Dict[str, int]:
        """The backend's wear counters: device/sharded ``TableStats``
        fields (``tile_stores`` = paper cleans), sim ledger counters
        (``cleans`` itself)."""
        return self._b.wear()

    def partition_heat(self, keys) -> np.ndarray:
        """Per-key wear heat of the key's change-segment partition (device
        backend with ``track_wear=True``; zeros elsewhere). Feed for
        wear-aware eviction: re-dirtying a hot partition is nearly free."""
        return self._b.partition_heat(keys)

    # -- durability: snapshot / restore (DESIGN.md §11) ----------------------
    @property
    def wal(self):
        """The attached :class:`~.wal.WriteAheadLog` (None without one)."""
        return self._b.front.wal

    def quiesce(self) -> None:
        """Join any in-flight drain without forcing new device traffic —
        the barrier ``CheckpointManager`` takes before serializing, so a
        checkpoint never captures a mid-donation state. Raises if the
        store is poisoned (the snapshot would be missing a sealed
        chunk)."""
        self._check_open()
        self._b.front.settle()

    def snapshot(self, path, step: Optional[int] = None,
                 extra_meta: Optional[Dict] = None, manager=None) -> Path:
        """Durability capture: flush everything (drain + device merge,
        the barrier), write the device/sim state through the checkpoint
        layout under ``path``, then **rotate** the WAL — every logged
        chunk is now redundant with the snapshot. Returns the snapshot
        directory.

        ``step`` defaults to one past the latest snapshot under ``path``
        (0 for the first). ``extra_meta`` rides along in ``meta.json``
        (e.g. ``CorpusStats`` counters)."""
        self._check_open()
        self._b.flush(wait=True)
        wal = self._b.front.wal
        base = wal.last_seq if wal is not None else 0
        if step is None:
            latest = _latest_step(path)
            step = 0 if latest is None else latest + 1
        meta = {"wal_base_seq": base, "store_backend": self.backend,
                "store_scheme": self.scheme}
        meta.update(extra_meta or {})
        out = self._b.snapshot_state(path, step, meta, manager=manager)
        if wal is not None:
            wal.rotate()
        return out

    def restore(self, path=None, step: Optional[int] = None
                ) -> RestoreReport:
        """Recover to the last durable state: drop every buffer (clearing
        any poison), re-arm the drain worker, load the latest snapshot
        under ``path`` (fresh-init when ``path`` is None or holds no
        snapshot), then replay sealed-but-uncovered WAL records — seqs
        after the snapshot's ``wal_base_seq`` — through the normal update
        path (appends suppressed, so restoring twice is idempotent).

        The recovery contract (DESIGN.md §11): after ``restore()``, the
        store holds exactly the deltas that were sealed before the crash
        — no lost chunks (seal fsyncs before dispatch), no double-applied
        chunks (the snapshot rotates the log; replay reapplies onto the
        snapshot, or onto a fresh table covering seq 0). Entries that
        were still in the *active* buffer (never sealed) are the one
        permissible loss — exactly the paper's H_R volatility window."""
        b = self._b
        try:
            b._disp.wait()            # settle what can settle; poison is
        except Exception:
            pass                      # cleared below, not re-raised here
        poisoned = b.front.poisoned
        b.rearm()
        b.front.clear()
        self._closed = False          # restore reopens a closed store
        snap_step, snap_meta = b.restore_state(path, step)
        base = int(snap_meta.get("wal_base_seq", 0))
        records_replayed = entries_replayed = 0
        discarded = 0
        wal = b.front.wal
        if wal is not None:
            from .wal import SEAL, WriteAheadLog, read_wal
            if wal._f.closed:         # restoring a closed store: reopen
                wal = WriteAheadLog(wal.path, fsync=wal._do_fsync)
                b.front.wal = wal
            records, discarded = read_wal(wal.path)
            seals = sorted((r for r in records
                            if r.kind == SEAL and r.seq > base),
                           key=lambda r: r.seq)
            with wal.suppressed():
                for r in seals:
                    b.update(r.keys, r.deltas)
                    records_replayed += 1
                    entries_replayed += int(r.keys.size)
                # multihost: drain() is collective — every host must call
                # it even with zero seal records of its own (per-host WALs
                # recover independently but drain in lockstep, §14)
                if seals or getattr(b, "multihost", False):
                    b.drain(wait=True)
        return RestoreReport(
            snapshot_step=snap_step, base_seq=base,
            records_replayed=records_replayed,
            entries_replayed=entries_replayed,
            tail_discarded_bytes=discarded, poison_cleared=poisoned,
            meta=snap_meta)


__all__ = ["FlashStore", "FlushDispatcher", "DrainError", "SealedFront",
           "RestoreReport", "SimBackend", "DeviceBackend", "ShardedBackend",
           "EMPTY"]
