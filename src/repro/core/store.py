"""One `FlashStore` facade over every flash-hash table backend (DESIGN.md §8).

The paper's central claim is that one deferred-update discipline — RAM
buffer H_R in front, semi-random block-local merges behind — serves every
scheme variant (§2, Fig 4). Before this module, the public surface leaked
the plumbing: every consumer manually constructed and paired a
:class:`~.write_engine.BatchedWriteEngine` with a
:class:`~.query_engine.BatchedQueryEngine`, while the sharded table
(:mod:`.distributed`) exposed a third, engine-less API with none of the
H_R dedup, donation or read-your-writes semantics. `FlashStore` is the
single entry point:

    with FlashStore.open(backend="device", scheme="MDB-L") as store:
        store.update(tokens)            # buffered in H_R
        store.increment(key, -1)        # deletion-by-decrement (§2.6)
        counts = store.query(keys)      # read-your-writes, batched
        store.flush()                   # durability point: drain + merge
        print(store.stats())

Three backends plug in behind the identical lifecycle via a small
``TableBackend`` protocol (duck-typed — ``update`` / ``query_batch`` /
``drain`` / ``flush`` / ``stats`` / ``pending_entries``):

* ``sim``     — the event-level NumPy simulator (exact SSD cost ledger;
  the paper's measurement harness). Its RAM buffer *is* H_R.
* ``device``  — the single-table JAX/Pallas path: the store owns the
  engine pair, and the flush → invalidate contract is enforced here,
  never by callers.
* ``sharded`` — the multi-device table: per-shard H_R partitions keyed
  by ``owner(x)``, shard-local flush thresholds (one hot shard drains
  its own partition without forcing every shard's buffer out), and
  cross-shard consolidated batched lookups (one psum per query chunk).

Engine pairing happens *only* in this module: constructing a write/query
engine by hand elsewhere is the deprecated pre-PR4 surface.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

from .table_sim import EMPTY


def _flat_i64(x) -> np.ndarray:
    return np.asarray(x).reshape(-1).astype(np.int64)


# ---------------------------------------------------------------------------
# sim backend: the event-level SSD simulation
# ---------------------------------------------------------------------------
class SimBackend:
    """`table_sim` behind the store protocol. The sim's own RAM buffer
    plays H_R; `update_batch` is the engine-chunk-compatible ±Δ twin and
    `query_batch` already consolidates data/change/overflow + buffer."""

    name = "sim"

    def __init__(self, geom=None, scheme: str = "MDB-L",
                 ram_buffer_pct: float = 5.0,
                 change_segment_pct: float = 12.5, **table_kw):
        from .flash_model import TableGeometry
        from .table_sim import make_table
        self.geom = geom if geom is not None else TableGeometry(
            num_blocks=16, pages_per_block=64, entries_per_page=64)
        self.scheme = scheme
        self.table = make_table(scheme, self.geom, ram_buffer_pct,
                                change_segment_pct, **table_kw)

    def update(self, tokens, deltas=None) -> None:
        self.table.update_batch(tokens, deltas)

    def query_batch(self, keys) -> np.ndarray:
        return np.asarray(self.table.query_batch(keys), np.int64)

    def drain(self) -> None:          # stage H_R without a forced merge
        self.table.flush()

    def flush(self) -> None:          # durability point
        self.table.finalize()

    def pending_entries(self) -> int:
        return len(self.table.ram.items)

    def partition_heat(self, keys) -> np.ndarray:
        return np.zeros(_flat_i64(keys).size)     # no device wear feed

    def wear(self) -> Dict[str, int]:
        """The sim's wear counters: ``cleans`` *is* the paper's erase
        count (the device backends' ``tile_stores`` analogue)."""
        led = self.table.ledger
        return {"cleans": led.cleans, "block_ops": led.block_ops,
                "page_ops": led.page_ops, "merges": led.merges,
                "stages": led.stages}

    def stats(self) -> Dict[str, int]:
        led = self.table.ledger
        q = self.table.qstats
        out = {"backend": self.name, "scheme": self.scheme,
               "cleans": led.cleans, "block_ops": led.block_ops,
               "page_ops": led.page_ops, "merges": led.merges,
               "stages": led.stages, "queries": q.queries,
               "found": q.found,
               "buffered_entries": self.pending_entries()}
        return out

    def close(self) -> None:
        pass


# ---------------------------------------------------------------------------
# device backend: single-table engine pair
# ---------------------------------------------------------------------------
class DeviceBackend:
    """The PR-2/PR-3 engine pair, auto-wired: one
    :class:`~.write_engine.BatchedWriteEngine` owning the table state,
    one paired :class:`~.query_engine.BatchedQueryEngine`, flush →
    invalidate enforced by construction. With ``track_wear=True`` the
    backend additionally attributes per-drain ``TableStats`` wear deltas
    (Δ``tile_stores``) to change-segment partitions — the feed for
    wear-aware eviction policies (`serving/prefix_cache`)."""

    name = "device"

    def __init__(self, cfg=None, state=None, chunk: int = 4096,
                 query_chunk: int = 1024,
                 flush_threshold: Optional[int] = None,
                 hot_capacity: int = 4096, track_wear: bool = False,
                 record: Optional[list] = None, **table_kw):
        from . import table_jax as tj
        from .query_engine import BatchedQueryEngine
        from .write_engine import BatchedWriteEngine
        self.cfg = cfg if cfg is not None else tj.FlashTableConfig(**table_kw)
        self.scheme = self.cfg.scheme
        self.query_engine = BatchedQueryEngine(
            self.cfg, chunk=query_chunk, hot_capacity=hot_capacity)
        self._track_wear = bool(track_wear)
        self.writer = BatchedWriteEngine(
            self.cfg, state=state, chunk=chunk,
            flush_threshold=flush_threshold, query_engine=self.query_engine,
            record=record, on_flush=self._on_drain if track_wear else None)
        # wear attribution: partition -> accumulated Δtile_stores share,
        # plus the staged-since-last-merge histogram merges are charged to
        self._heat: Dict[int, float] = {}
        self._staged_parts: Dict[int, int] = {}

    # -- wear attribution ---------------------------------------------------
    def _partition_of(self, keys: np.ndarray) -> np.ndarray:
        """Host-side partition id: MDB's change-segment partition when the
        scheme has one, else the data block itself (finest granularity)."""
        s = self.cfg.pair.s(np.asarray(keys, np.int64))
        if self.scheme == "MDB":
            return np.asarray(s) // self.cfg.blocks_per_partition
        return np.asarray(s)

    def _on_drain(self, keys: Optional[np.ndarray], wear_delta: int) -> None:
        if keys is not None:                 # H_R drain: staged entries
            parts, counts = np.unique(self._partition_of(keys),
                                      return_counts=True)
            for p, c in zip(parts.tolist(), counts.tolist()):
                self._staged_parts[p] = self._staged_parts.get(p, 0) + c
        # charge the measured Δtile_stores to the partitions staged since
        # the last forced merge, proportional to their staged volume; the
        # history decays so heat tracks *recent* merge pressure, not the
        # lifetime total (an old burst must not pin a partition hot)
        if wear_delta > 0 and self._staged_parts:
            self._heat = {p: 0.5 * v for p, v in self._heat.items()}
            total = sum(self._staged_parts.values())
            for p, c in self._staged_parts.items():
                self._heat[p] = self._heat.get(p, 0.0) + wear_delta * c / total
        if keys is None:                     # forced merge drained the lot
            self._staged_parts.clear()

    def partition_heat(self, keys) -> np.ndarray:
        """Write pressure of each key's partition: entries currently
        pending for it (H_R + staged-unmerged — it *will* be rewritten at
        the next merge no matter what) plus the decayed per-merge
        ``TableStats`` wear history. Hot partitions are being rewritten
        anyway — re-dirtying them is nearly free; dirtying a cold one
        costs a fresh block rewrite."""
        flat = _flat_i64(keys)
        if flat.size == 0:
            return np.zeros(0)
        pending = dict(self._staged_parts)
        if self.writer.buffered_entries:
            bk = np.fromiter(self.writer._buf.keys(), np.int64,
                             self.writer.buffered_entries)
            parts, counts = np.unique(self._partition_of(bk),
                                      return_counts=True)
            for p, c in zip(parts.tolist(), counts.tolist()):
                pending[p] = pending.get(p, 0) + c
        if not pending and not self._heat:
            return np.zeros(flat.size)
        parts = self._partition_of(flat)
        return np.asarray([pending.get(int(p), 0)
                           + self._heat.get(int(p), 0.0) for p in parts])

    # -- protocol -----------------------------------------------------------
    @property
    def state(self):
        return self.writer.state

    def update(self, tokens, deltas=None) -> None:
        self.writer.update(tokens, deltas)

    def query_batch(self, keys) -> np.ndarray:
        return self.writer.query_batch(keys)

    def drain(self) -> None:
        self.writer.flush()

    def flush(self) -> None:
        self.writer.merge()

    def pending_entries(self) -> int:
        return self.writer.buffered_entries

    def wear(self) -> Dict[str, int]:
        s = self.state.stats
        return {f: int(getattr(s, f)) for f in s._fields}

    def stats(self) -> Dict[str, int]:
        out = {"backend": self.name, "scheme": self.scheme}
        out.update(self.wear())
        out.update({f"write_{k}": v
                    for k, v in self.writer.stats.as_dict().items()})
        out.update({f"query_{k}": v
                    for k, v in self.query_engine.stats.as_dict().items()})
        out["buffered_entries"] = self.pending_entries()
        return out

    def close(self) -> None:
        pass


# ---------------------------------------------------------------------------
# sharded backend: per-shard H_R partitions over the distributed table
# ---------------------------------------------------------------------------
class ShardedBackend:
    """The distributed table (:mod:`.distributed`) brought to engine
    parity — the ROADMAP "distributed sharded table at scale" item.

    * **per-shard H_R partitions** — the host buffer is split by
      ``owner(x)`` (the same two-level hash that shards the data
      segment), so dedup/cancellation state is per-shard and a drain can
      target one shard's traffic;
    * **shard-local flush thresholds** — a partition drains when *it*
      fills; the other shards' buffers stay warm (their entries keep
      absorbing duplicates) instead of being forced out by a global
      count. Because the collective is fixed-shape anyway, partitions at
      least ``piggyback_frac`` full ride along for free;
    * **owner-aligned dispatch** — drained entries are placed directly in
      their owner shard's slice of the update batch, so the ``all_to_all``
      routes every entry shard-locally (src == dst: zero cross-shard
      payload movement) and the per-(src,dst) ``bucket_cap`` can never
      overflow (``shard_chunk <= bucket_cap`` entries, all self-owned);
    * **consolidated lookups** — one shard_map'd lookup per EMPTY-padded
      query chunk serves the whole deduped batch (every shard probes its
      blocks, one psum combines), fronted by the standard
      :class:`~.query_engine.BatchedQueryEngine` hot cache + H_R overlay.

    The local scheme must be MB or MDB-L (MDB's partitioned change
    segment and the shard axis would partition the same dimension twice).
    """

    name = "sharded"

    def __init__(self, cfg=None, mesh=None, axis: str = "table",
                 num_shards: Optional[int] = None,
                 shard_chunk: Optional[int] = None,
                 flush_threshold: Optional[int] = None,
                 query_chunk: int = 1024, hot_capacity: int = 4096,
                 piggyback_frac: float = 0.5, **table_kw):
        import jax
        from jax.sharding import NamedSharding

        from . import distributed as D
        from . import table_jax as tj
        from .query_engine import BatchedQueryEngine
        from .write_engine import WriteEngineStats

        if cfg is None or isinstance(cfg, tj.FlashTableConfig):
            n = int(num_shards if num_shards is not None
                    else jax.device_count())
            local = cfg if cfg is not None else tj.FlashTableConfig(
                **table_kw)
            cfg = D.ShardedTableConfig(local=local, num_shards=n)
        self.cfg = cfg
        n = cfg.num_shards
        if n & (n - 1):
            raise ValueError(f"num_shards={n} must be a power of two")
        if cfg.local.scheme not in ("MB", "MDB-L"):
            raise ValueError(
                f"sharded backend requires an MB or MDB-L local scheme, "
                f"got {cfg.local.scheme!r} (MDB partitions the change "
                f"segment over the same axis the mesh shards)")
        self.scheme = cfg.local.scheme
        self.mesh = mesh if mesh is not None else jax.make_mesh((n,), (axis,))
        self.axis = axis
        self.shard_chunk = int(min(cfg.bucket_cap, shard_chunk or 1024))
        self.flush_threshold = int(2 * self.shard_chunk
                                   if flush_threshold is None
                                   else flush_threshold)
        self.piggyback_frac = float(piggyback_frac)
        self._jnp = jax.numpy
        self._upd = D.make_update_fn(cfg, self.mesh, axis,
                                     with_deltas=True, donate=True)
        self._mrg = D.make_flush_fn(cfg, self.mesh, axis, donate=True)
        look = D.make_lookup_fn(cfg, self.mesh, axis, with_dist=True)
        self.query_engine = BatchedQueryEngine(
            cfg.local, chunk=query_chunk, hot_capacity=hot_capacity,
            lookup_fn=lambda state, q: look(state, q))
        spec = jax.tree.map(lambda s: NamedSharding(self.mesh, s),
                            D.state_pspec(axis),
                            is_leaf=lambda s: type(s).__name__
                            == "PartitionSpec")
        self.state = jax.device_put(D.init_global(cfg), spec)
        self._shard_bits = cfg.local.q_log2 - cfg.local.r_log2
        self._buf: List[Dict[int, int]] = [dict() for _ in range(n)]
        self.stats_ledger = WriteEngineStats()
        self.piggybacked = 0
        self.carried = 0

    # -- owner routing ------------------------------------------------------
    def owner_of(self, keys) -> np.ndarray:
        """Owner shard per key: the global block id's top (shard) bits."""
        s = np.asarray(self.cfg.global_pair.s(_flat_i64(keys)))
        return s >> self._shard_bits

    # -- the buffered write path -------------------------------------------
    def update(self, tokens, deltas=None) -> None:
        from .write_engine import dedup_batch, fold_entry
        led = self.stats_ledger
        led.updates += 1
        uniq, sums, n_valid = dedup_batch(tokens, deltas, EMPTY)
        if n_valid == 0:
            return
        led.entries += n_valid
        owners = self.owner_of(uniq)
        n_new = 0
        for k, s, o in zip(uniq.tolist(), sums.tolist(), owners.tolist()):
            opened = fold_entry(self._buf[o], k, s)
            if opened > 0:
                n_new += 1
            elif opened < 0:
                led.cancelled += 1
        led.buffered += n_new
        led.deduped += n_valid - n_new
        hot = [i for i, b in enumerate(self._buf)
               if len(b) >= self.flush_threshold]
        if hot:
            led.auto_flushes += 1
            ride = [i for i, b in enumerate(self._buf)
                    if i not in hot
                    and len(b) >= self.piggyback_frac * self.flush_threshold]
            self.piggybacked += len(ride)
            self.drain(shards=hot + ride)

    def drain(self, shards: Optional[List[int]] = None) -> None:
        """Drain the selected shards' H_R partitions to their owners'
        change segments (no forced merge). One fixed-shape collective per
        ``shard_chunk``-entry wave; every drained entry rides in its
        owner's slice, so the a2a is shard-local by construction."""
        jnp = self._jnp
        n = self.cfg.num_shards
        step = self.shard_chunk
        sel = [s for s in (range(n) if shards is None else shards)
               if self._buf[s]]
        if not sel:
            return
        led = self.stats_ledger
        per_shard = {}
        waves = 0
        for s in sel:
            ks = np.fromiter(self._buf[s].keys(), np.int64, len(self._buf[s]))
            vs = np.fromiter(self._buf[s].values(), np.int64,
                             len(self._buf[s]))
            order = np.argsort(ks, kind="stable")   # deterministic dispatch
            per_shard[s] = (ks[order], vs[order])
            waves = max(waves, -(-ks.size // step))
        for w in range(waves):
            toks = np.full(n * step, EMPTY, np.int64)
            dels = np.zeros(n * step, np.int64)
            for s, (ks, vs) in per_shard.items():
                part_k = ks[w * step:(w + 1) * step]
                part_v = vs[w * step:(w + 1) * step]
                toks[s * step:s * step + part_k.size] = part_k
                dels[s * step:s * step + part_v.size] = part_v
            self.state, n_carry = self._upd(self.state,
                                            jnp.asarray(toks, jnp.int32),
                                            jnp.asarray(dels, jnp.int32))
            led.dispatches += 1
            # owner-aligned placement keeps every (src,dst) bucket within
            # bucket_cap, so the collective can never carry entries over
            self.carried += int(np.asarray(n_carry).sum())
        for s in sel:
            led.dispatched_entries += per_shard[s][0].size
            self._buf[s].clear()
        led.flushes += 1
        self.query_engine.invalidate()
        led.invalidations += 1

    def flush(self) -> None:
        """Durability point: drain every H_R partition, then force the
        device merge of all staged change segments."""
        self.drain()
        self.state = self._mrg(self.state)
        self.stats_ledger.merges += 1
        self.query_engine.invalidate()
        self.stats_ledger.invalidations += 1

    # -- read-your-writes ---------------------------------------------------
    def pending_entries(self) -> int:
        return sum(len(b) for b in self._buf)

    def pending(self, keys) -> np.ndarray:
        flat = _flat_i64(keys)
        if not any(self._buf):
            return np.zeros(flat.size, np.int64)
        owners = self.owner_of(flat)
        return np.fromiter(
            (self._buf[o].get(int(k), 0) for k, o in zip(flat, owners)),
            np.int64, flat.size)

    def query_batch(self, keys) -> np.ndarray:
        base = self.query_engine.query_batch(self.state, keys)
        if any(self._buf):
            base = base + self.pending(keys)
        return base

    def partition_heat(self, keys) -> np.ndarray:
        return np.zeros(_flat_i64(keys).size)     # not tracked per shard yet

    def wear(self) -> Dict[str, int]:
        """Device wear counters summed across shards."""
        s = self.state.stats
        return {f: int(np.asarray(getattr(s, f)).sum()) for f in s._fields}

    def stats(self) -> Dict[str, int]:
        out = {"backend": self.name, "scheme": self.scheme,
               "shards": self.cfg.num_shards}
        out.update(self.wear())
        out.update({f"write_{k}": v
                    for k, v in self.stats_ledger.as_dict().items()})
        out.update({f"query_{k}": v
                    for k, v in self.query_engine.stats.as_dict().items()})
        out["buffered_entries"] = self.pending_entries()
        out["write_piggybacked"] = self.piggybacked
        out["write_carried"] = self.carried
        out["buffered_per_shard_max"] = max(
            (len(b) for b in self._buf), default=0)
        return out

    def close(self) -> None:
        pass


_BACKENDS = {"sim": SimBackend, "device": DeviceBackend,
             "sharded": ShardedBackend}


# ---------------------------------------------------------------------------
# the facade
# ---------------------------------------------------------------------------
class FlashStore:
    """Backend-agnostic counting hash table with the paper's deferred-
    update discipline built in. Construct with :meth:`open`; use as a
    context manager for automatic flush-on-exit. See the module docstring
    for the backend landscape."""

    def __init__(self, backend_impl):
        self._b = backend_impl
        self._closed = False

    @classmethod
    def open(cls, config=None, backend: str = "device", **kw) -> "FlashStore":
        """One constructor for every backend.

        ``config`` is backend-shaped — a ``TableGeometry`` for ``sim``, a
        ``FlashTableConfig`` for ``device``, a ``ShardedTableConfig`` (or
        the local ``FlashTableConfig``) for ``sharded`` — or ``None`` to
        build one from ``**kw`` (``scheme=``, ``q_log2=``, ...). Engine
        knobs (``chunk``, ``flush_threshold``, ``query_chunk``,
        ``hot_capacity``, ...) pass through as keywords.
        """
        try:
            impl = _BACKENDS[backend]
        except KeyError:
            raise ValueError(f"unknown backend {backend!r}; expected one "
                             f"of {tuple(_BACKENDS)}") from None
        if config is None:
            return cls(impl(**kw))
        if backend == "sim":
            return cls(impl(geom=config, **kw))
        return cls(impl(cfg=config, **kw))

    # -- lifecycle ----------------------------------------------------------
    def _check_open(self) -> None:
        if self._closed:
            raise ValueError("store is closed")

    def close(self) -> None:
        """Flush (durability point) and release the store. Idempotent."""
        if self._closed:
            return
        self._b.flush()
        self._b.close()
        self._closed = True

    def __enter__(self) -> "FlashStore":
        self._check_open()
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        # an exception mid-stream still drains H_R: buffered counts are
        # the caller's data, not scratch
        self.close()

    # -- writes -------------------------------------------------------------
    def update(self, tokens, deltas=None) -> None:
        """Accumulate a (token[, Δ]) batch into H_R. Duplicates fold,
        zero-sum Δs cancel (§2.6), EMPTY tokens are padding; the device
        sees traffic only at flush thresholds."""
        self._check_open()
        self._b.update(tokens, deltas)

    def increment(self, key: int, delta: int = 1) -> None:
        """Single-key counter bump; ``delta=-1`` is the paper's
        deletion-by-decrement."""
        self.update(np.asarray([key], np.int64),
                    np.asarray([delta], np.int64))

    def flush(self) -> None:
        """Durability point: drain H_R and force the device merge of any
        staged change segment (end-of-stream / checkpoint)."""
        self._check_open()
        self._b.flush()

    # -- reads --------------------------------------------------------------
    def query(self, keys):
        """Counts for ``keys`` — scalar in, ``int`` out; array-like in,
        ``int64`` array out (aligned with the flattened input). Reads are
        read-your-writes: buffered H_R deltas overlay device counts."""
        self._check_open()
        if np.isscalar(keys) or (isinstance(keys, np.ndarray)
                                 and keys.ndim == 0):
            return int(self._b.query_batch(np.asarray([keys]))[0])
        return self._b.query_batch(keys)

    def query_batch(self, keys) -> np.ndarray:
        """Alias of :meth:`query` for unambiguously-batched call sites."""
        self._check_open()
        return self._b.query_batch(keys)

    # -- introspection ------------------------------------------------------
    @property
    def backend(self) -> str:
        return self._b.name

    @property
    def scheme(self) -> str:
        return self._b.scheme

    @property
    def cfg(self):
        return getattr(self._b, "cfg", None)

    @property
    def state(self):
        """Device table state (device/sharded backends)."""
        return getattr(self._b, "state", None)

    @property
    def buffered_entries(self) -> int:
        return self._b.pending_entries()

    def stats(self) -> Dict[str, int]:
        """One flat ledger: device wear (``tile_stores`` = paper cleans)
        or sim I/O counters, plus ``write_*`` (H_R) and ``query_*``
        (batched read path) counters."""
        return self._b.stats()

    def wear(self) -> Dict[str, int]:
        """The backend's wear counters: device/sharded ``TableStats``
        fields (``tile_stores`` = paper cleans), sim ledger counters
        (``cleans`` itself)."""
        return self._b.wear()

    def partition_heat(self, keys) -> np.ndarray:
        """Per-key wear heat of the key's change-segment partition (device
        backend with ``track_wear=True``; zeros elsewhere). Feed for
        wear-aware eviction: re-dirtying a hot partition is nearly free."""
        return self._b.partition_heat(keys)


__all__ = ["FlashStore", "SimBackend", "DeviceBackend", "ShardedBackend",
           "EMPTY"]
