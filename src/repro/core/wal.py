"""Chunk-granular write-ahead log for the store's H_R front (DESIGN.md §11).

The paper's H_R buffer (§2.2) makes writes fast precisely by keeping
recent deltas out of flash — so a crash mid-drain loses exactly the data
the design worked hardest to batch. FAWN-style log-structured stores
treat the RAM front as recoverable-by-construction: append the sealed
chunk to a log *before* dispatching it, and replay the log after a
crash. The :class:`~.store.SealedFront` lifecycle gives the log a
natural granularity — one record per sealed H_R chunk, appended and
fsync'd at seal time (before the drain is even submitted), plus one
commit record when the drain worker delivers it.

Record format (binary, little-endian, after an 8-byte ``FLWAL001``
magic)::

    <u32 crc32> <u8 type> <i32 part> <u64 seq> <u32 n>
    n × <i64 key> , n × <i64 delta>          (SEAL records only)

``crc32`` covers everything after itself (type..payload), so a torn
final write — header or payload cut short by a crash — is detected and
discarded loudly instead of replayed as garbage. ``part`` is the H_R
partition (0 for single-table fronts, the owner shard for the sharded
store), which is what lets :mod:`repro.runtime.elastic` re-own a
departing shard's partition by filtering the log. ``seq`` is monotonic
per file and never reused: a snapshot records the last sealed ``seq``
it covers (``wal_base_seq``) and replay applies only records after it.

Durability points:

* **seal** — every sealed part appends one SEAL record; one ``fsync``
  per seal *event* (covering all parts sealed together) lands before
  the drain is submitted. A chunk the caller saw sealed is recoverable.
* **commit** — the drain worker appends a COMMIT for each delivered
  part (no fsync: losing a commit only means idempotent replay work).
* **rotate** — ``FlashStore.snapshot()`` quiesces, captures the device
  state, then truncates the log: every record is now redundant with the
  snapshot. Plain merges do *not* rotate — device state is volatile
  until a snapshot captures it.
"""
from __future__ import annotations

import contextlib
import dataclasses
import os
import struct
# the log is appended from both the caller (seal) and the drain worker
# (commit); it carries its own lock rather than borrowing the
# dispatcher's so a WAL append can never extend the state lock's hold
# time. flashlint FL004 allows this module explicitly.
import threading
import warnings
import zlib
from pathlib import Path
from typing import List, Optional, Tuple

import numpy as np

MAGIC = b"FLWAL001"
SEAL = 1      # a sealed H_R chunk: payload = keys + deltas
COMMIT = 2    # drain completion for an earlier SEAL's seq (no payload)

_HDR = struct.Struct("<BiQI")      # type, part, seq, n  (crc32 prepended)
_CRC = struct.Struct("<I")


@dataclasses.dataclass
class WalRecord:
    """One decoded log record (``keys``/``deltas`` are None for COMMIT)."""

    kind: int
    part: int
    seq: int
    keys: Optional[np.ndarray]
    deltas: Optional[np.ndarray]


def _encode(kind: int, part: int, seq: int,
            keys: Optional[np.ndarray],
            deltas: Optional[np.ndarray]) -> bytes:
    n = 0 if keys is None else int(keys.size)
    body = _HDR.pack(kind, part, seq, n)
    if n:
        body += np.ascontiguousarray(keys, "<i8").tobytes()
        body += np.ascontiguousarray(deltas, "<i8").tobytes()
    return _CRC.pack(zlib.crc32(body)) + body


def read_wal(path) -> Tuple[List[WalRecord], int]:
    """Decode every intact record of ``path``; returns
    ``(records, discarded_tail_bytes)``.

    A non-record-aligned tail (torn final write: short header, short
    payload, or CRC mismatch) is discarded **loudly** — a ``UserWarning``
    names the file and byte count — and everything before it is kept:
    records are appended strictly in order, so the first bad byte ends
    the recoverable prefix. A missing file reads as empty."""
    path = Path(path)
    if not path.exists():
        return [], 0
    blob = path.read_bytes()
    if blob[:len(MAGIC)] != MAGIC:
        raise ValueError(f"{path}: not a FlashStore WAL "
                         f"(bad magic {blob[:8]!r})")
    out: List[WalRecord] = []
    off = len(MAGIC)
    while off < len(blob):
        start = off
        hdr_end = off + _CRC.size + _HDR.size
        if hdr_end > len(blob):
            break                     # torn header
        (crc,) = _CRC.unpack_from(blob, off)
        kind, part, seq, n = _HDR.unpack_from(blob, off + _CRC.size)
        end = hdr_end + 16 * n        # two i64 arrays of n entries
        if kind not in (SEAL, COMMIT) or end > len(blob):
            break                     # torn/garbage payload
        if zlib.crc32(blob[off + _CRC.size:end]) != crc:
            break                     # corrupt record
        keys = deltas = None
        if n:
            keys = np.frombuffer(blob, "<i8", n, hdr_end).astype(np.int64)
            deltas = np.frombuffer(blob, "<i8", n,
                                   hdr_end + 8 * n).astype(np.int64)
        out.append(WalRecord(kind, part, seq, keys, deltas))
        off = end
    discarded = len(blob) - off
    if discarded:
        warnings.warn(
            f"{path}: discarding {discarded} bytes of torn WAL tail after "
            f"{len(out)} intact records (record at offset {start} is "
            "truncated or corrupt — its seal never completed and is not "
            "recoverable)", stacklevel=2)
    return out, discarded


class WriteAheadLog:
    """Append-side handle: sequenced seal/commit records, one fsync per
    seal event, replay suppression, and snapshot rotation.

    Opening an existing file resumes sequencing after its last intact
    record; a torn tail is truncated (with the :func:`read_wal` warning)
    so new appends land on a clean record boundary."""

    def __init__(self, path, fsync: bool = True):
        self.path = Path(path)
        self.path.parent.mkdir(parents=True, exist_ok=True)
        self._lock = threading.Lock()
        self._do_fsync = bool(fsync)
        self._suppress = 0
        self._next_seq = 1
        self._sealed: set = set()
        self._commits: set = set()
        self._pending_seals = 0
        #: fsync'd seal events so far (a multi-part seal counts once)
        self.seal_events = 0
        #: test/chaos hook: called with ``seal_events`` after each seal
        #: fsync lands — the point "between seal and settle" the chaos
        #: harness SIGKILLs at (tests/helpers/chaos_store_main.py)
        self.after_sync = None
        if self.path.exists() and self.path.stat().st_size > 0:
            records, discarded = read_wal(self.path)
            good = len(MAGIC) + sum(
                _CRC.size + _HDR.size + 16 * (r.keys.size if r.keys
                                              is not None else 0)
                for r in records)
            self._f = open(self.path, "r+b")
            if discarded:
                self._f.truncate(good)   # re-align appends; warned above
            self._f.seek(good)
            for r in records:
                if r.kind == SEAL:
                    self._sealed.add(r.seq)
                else:
                    self._commits.add(r.seq)
                self._next_seq = max(self._next_seq, r.seq + 1)
        else:
            self._f = open(self.path, "w+b")
            self._f.write(MAGIC)
            self._f.flush()

    # -- watermarks ----------------------------------------------------------
    @property
    def last_seq(self) -> int:
        """Highest seal seq appended (0 when the log is empty)."""
        return self._next_seq - 1

    @property
    def committed_seq(self) -> int:
        """Highest seq with every seal at or below it drain-committed."""
        hi = 0
        for s in sorted(self._sealed):
            if s not in self._commits:
                break
            hi = s
        return hi

    # -- append side ---------------------------------------------------------
    @contextlib.contextmanager
    def suppressed(self):
        """No-op all appends inside the block — the replay path drives
        recovered entries through the normal update/seal machinery, and
        this is what keeps it from re-logging (and therefore makes
        ``restore()`` idempotent)."""
        with self._lock:
            self._suppress += 1
        try:
            yield
        finally:
            with self._lock:
                self._suppress -= 1

    def append_seal(self, part: int, keys: np.ndarray,
                    deltas: np.ndarray) -> Optional[int]:
        """Log one sealed chunk; returns its seq (None when suppressed
        or closed). The caller finishes the seal event with :meth:`sync`
        before dispatching the drain."""
        with self._lock:
            if self._suppress or self._f.closed:
                return None
            seq = self._next_seq
            self._next_seq += 1
            self._f.write(_encode(SEAL, int(part), seq, keys, deltas))
            self._f.flush()           # visible to readers even if killed
            self._sealed.add(seq)
            self._pending_seals += 1
            return seq

    def append_commit(self, part: int, seq: int) -> None:
        """Log a drain completion for seal ``seq`` (worker side). Not
        fsync'd — a lost commit only costs idempotent replay work."""
        with self._lock:
            if self._suppress or self._f.closed or seq is None:
                return
            self._f.write(_encode(COMMIT, int(part), int(seq), None, None))
            self._f.flush()
            self._commits.add(int(seq))

    def sync(self) -> None:
        """Make the current seal event durable: one fsync covering every
        part sealed since the last sync, *before* the drain dispatch."""
        hook = None
        with self._lock:
            if self._f.closed:
                return
            self._f.flush()
            if self._do_fsync:
                os.fsync(self._f.fileno())
            if self._pending_seals:
                self._pending_seals = 0
                self.seal_events += 1
                hook = self.after_sync
        if hook is not None:
            hook(self.seal_events)

    # -- lifecycle -----------------------------------------------------------
    def rotate(self) -> None:
        """Truncate the log to empty (snapshot taken: every record is
        redundant with the captured device state). Sequencing continues
        monotonically — seqs are never reused across rotations."""
        with self._lock:
            if self._f.closed:
                return
            self._f.truncate(len(MAGIC))
            self._f.seek(len(MAGIC))
            self._f.flush()
            if self._do_fsync:
                os.fsync(self._f.fileno())
            self._sealed.clear()
            self._commits.clear()
            self._pending_seals = 0

    def close(self) -> None:
        with self._lock:
            if not self._f.closed:
                self._f.flush()
                self._f.close()


__all__ = ["WriteAheadLog", "WalRecord", "read_wal", "SEAL", "COMMIT",
           "MAGIC"]
