"""Core: the paper's counting hash table for two-tier memories.

Event-level SSD simulation (paper-faithful benchmarks) plus the TPU-native
JAX twin used by the framework's data/statistics and serving layers.
"""
from .flash_model import (CostLedger, FlashDevice, TableGeometry, DEVICES,
                          MLC1, MLC2, SLC)
from .hashing import HashPair, Pow2Hash, hash_pair_for
from .table_sim import (EMPTY, MBTable, MDBTable, MDBLTable, NaiveTable,
                        SCHEMES, make_table)
from .store import FlashStore
from .tfidf import TfIdfPipeline, token_id, tokenize

__all__ = [
    "CostLedger", "FlashDevice", "TableGeometry", "DEVICES", "MLC1", "MLC2",
    "SLC", "HashPair", "Pow2Hash", "hash_pair_for", "EMPTY", "MBTable",
    "MDBTable", "MDBLTable", "NaiveTable", "SCHEMES", "make_table",
    "FlashStore", "TfIdfPipeline", "token_id", "tokenize",
]
