"""AdamW vs a straight-line numpy reference + schedule/compression tests."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         cosine_schedule)
from repro.optim.compress import _quantize, _dequantize


def _np_adamw(cfg, g, m, v, p, lr, t):
    gn = np.sqrt(sum((x.astype(np.float64) ** 2).sum() for x in
                     jax.tree.leaves(g)))
    scale = min(1.0, cfg.clip_norm / max(gn, 1e-9))
    out_p, out_m, out_v = {}, {}, {}
    for k in g:
        gg = g[k] * scale
        m2 = cfg.b1 * m[k] + (1 - cfg.b1) * gg
        v2 = cfg.b2 * v[k] + (1 - cfg.b2) * gg ** 2
        mh = m2 / (1 - cfg.b1 ** t)
        vh = v2 / (1 - cfg.b2 ** t)
        step = mh / (np.sqrt(vh) + cfg.eps)
        if p[k].ndim >= 2:
            step = step + cfg.weight_decay * p[k]
        out_p[k] = p[k] - lr * step
        out_m[k], out_v[k] = m2, v2
    return out_p, out_m, out_v


def test_adamw_matches_reference():
    cfg = AdamWConfig()
    rng = np.random.default_rng(0)
    p_np = {"w": rng.standard_normal((4, 3)).astype(np.float32),
            "b": rng.standard_normal((3,)).astype(np.float32)}
    g_np = {"w": rng.standard_normal((4, 3)).astype(np.float32),
            "b": rng.standard_normal((3,)).astype(np.float32)}
    params = jax.tree.map(jnp.asarray, p_np)
    state = adamw_init(cfg, params)
    m = jax.tree.map(np.zeros_like, p_np)
    v = jax.tree.map(np.zeros_like, p_np)
    lr = 1e-2
    for t in range(1, 4):
        params, state, gnorm = adamw_update(
            cfg, jax.tree.map(jnp.asarray, g_np), state, params, lr)
        p_np, m, v = _np_adamw(cfg, g_np, m, v, p_np, lr, t)
    for k in p_np:
        np.testing.assert_allclose(np.asarray(params[k]), p_np[k],
                                   rtol=2e-5, atol=2e-5)


def test_clip_applies():
    cfg = AdamWConfig(clip_norm=0.1)
    params = {"w": jnp.zeros((8,))}
    state = adamw_init(cfg, params)
    big = {"w": jnp.full((8,), 100.0)}
    _, _, gnorm = adamw_update(cfg, big, state, params, 1e-3)
    assert float(gnorm) > 100  # reported pre-clip norm


def test_moment_dtypes():
    cfg = AdamWConfig(m_dtype="bfloat16", v_dtype="float32")
    params = {"w": jnp.zeros((4, 4), jnp.bfloat16)}
    state = adamw_init(cfg, params)
    assert state.m["w"].dtype == jnp.bfloat16
    assert state.v["w"].dtype == jnp.float32
    new_p, new_s, _ = adamw_update(cfg, {"w": jnp.ones((4, 4))}, state,
                                   params, 1e-3)
    assert new_s.m["w"].dtype == jnp.bfloat16
    assert new_p["w"].dtype == jnp.bfloat16


def test_cosine_schedule():
    lr0 = float(cosine_schedule(jnp.int32(0), peak_lr=1.0, warmup_steps=10,
                                total_steps=100))
    lr_peak = float(cosine_schedule(jnp.int32(10), peak_lr=1.0,
                                    warmup_steps=10, total_steps=100))
    lr_end = float(cosine_schedule(jnp.int32(100), peak_lr=1.0,
                                   warmup_steps=10, total_steps=100))
    assert lr0 < 0.2 and abs(lr_peak - 1.0) < 0.01
    assert abs(lr_end - 0.1) < 0.01


def test_quantize_error_feedback_contract():
    """EF property: err carries exactly the quantization residual."""
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal(1000), jnp.float32)
    q, scale = _quantize(x)
    deq = _dequantize(q, scale)
    err = x - deq
    assert float(jnp.abs(err).max()) <= float(scale) * 0.5 + 1e-6
    # accumulated EF keeps the long-run mean unbiased
    acc = jnp.zeros_like(x)
    carried = jnp.zeros_like(x)
    for _ in range(50):
        g = x + carried
        q, s = _quantize(g)
        d = _dequantize(q, s)
        carried = g - d
        acc = acc + d
    np.testing.assert_allclose(np.asarray(acc / 50), np.asarray(x),
                               atol=1e-3)
