"""Event-level simulation: counting semantics vs collections.Counter
(hypothesis), §2.6 deletions, §2.5 overflow, ledger trend invariants."""
import numpy as np
import pytest
from collections import Counter

from helpers.hypothesis_shim import given, settings, strategies as st

from repro.core import MLC1, TableGeometry, make_table

GEOM = TableGeometry(num_blocks=8, pages_per_block=8, entries_per_page=16)


@pytest.mark.parametrize("scheme", ["MB", "MDB", "MDB-L", "naive"])
def test_counts_match_counter(scheme):
    rng = np.random.default_rng(0)
    keys = rng.integers(0, 400, size=5000)
    t = make_table(scheme, GEOM, ram_buffer_pct=3.0, change_segment_pct=25.0)
    t.insert_batch(keys)
    t.finalize()
    truth = Counter(keys.tolist())
    for k, c in truth.items():
        assert t.logical_count(int(k)) == c
    # query() agrees and accounts costs
    for k in list(truth)[:50]:
        assert t.query(int(k)) == truth[k]
    assert t.qstats.queries == 50


@given(st.lists(st.tuples(st.integers(0, 200), st.integers(-3, 5)),
                min_size=1, max_size=400))
@settings(max_examples=25, deadline=None)
def test_property_arbitrary_deltas(ops):
    """Any sequence of (key, Δ) updates must reproduce the exact counts
    (negative deltas = deletion-by-decrement, paper §2.6)."""
    t = make_table("MDB-L", GEOM, ram_buffer_pct=2.0,
                   change_segment_pct=25.0)
    truth = Counter()
    for k, d in ops:
        t.insert(k, d)
        truth[k] += d
    t.finalize()
    for k in truth:
        assert t.logical_count(k) == truth[k], (k, truth[k])


@pytest.mark.parametrize("scheme", ["MB", "MDB", "MDB-L"])
def test_full_removal(scheme):
    t = make_table(scheme, GEOM, ram_buffer_pct=2.0, change_segment_pct=25.0)
    rng = np.random.default_rng(1)
    keys = rng.integers(0, 300, size=3000)
    t.insert_batch(keys)
    t.finalize()
    victim = int(keys[0])
    assert t.logical_count(victim) > 0
    assert t.remove(victim)
    assert t.logical_count(victim) == 0
    # other keys unaffected; probes still terminate correctly
    truth = Counter(keys.tolist())
    for k in list(truth)[:30]:
        if k != victim:
            assert t.query(int(k)) == truth[k]


def test_overflow_region():
    """Force a block to overflow; counts must survive in the overflow
    region and queries must pay the chain-read cost."""
    geom = TableGeometry(num_blocks=2, pages_per_block=2, entries_per_page=8)
    t = make_table("MB", geom, ram_buffer_pct=95.0)
    # 2 blocks × 16 entries; insert 40 distinct keys → guaranteed spill
    keys = np.arange(40, dtype=np.int64)
    t.insert_batch(keys)
    t.finalize()
    assert len(t.ds.ov_keys) > 0
    for k in range(40):
        assert t.logical_count(k) == 1


def test_naive_is_much_worse():
    """§3.5: the bufferless table induces orders of magnitude more cleans."""
    rng = np.random.default_rng(2)
    keys = rng.integers(0, 600, size=20000)
    buffered = make_table("MDB-L", GEOM, ram_buffer_pct=5.0,
                          change_segment_pct=25.0)
    naive = make_table("naive", GEOM)
    buffered.insert_batch(keys)
    buffered.finalize()
    naive.insert_batch(keys)
    naive.finalize()
    # ratios compress at 1/1000 scale geometry (paper: 615× at 100MB
    # table / 128-page blocks); the full-scale ratio is reproduced in
    # benchmarks/bench_io_costs.py
    assert naive.ledger.cleans > 2.5 * max(buffered.ledger.cleans, 1)
    assert (naive.ledger.time_us(MLC1) >
            2 * buffered.ledger.time_us(MLC1))


def test_ram_buffer_size_reduces_io():
    """Table-2 trend 1: ops drop as RAM buffer grows."""
    rng = np.random.default_rng(3)
    keys = rng.integers(0, 600, size=30000)
    costs = []
    for pct in [2.0, 10.0, 40.0]:
        t = make_table("MB", GEOM, ram_buffer_pct=pct)
        t.insert_batch(keys)
        t.finalize()
        costs.append(t.ledger.time_us(MLC1))
    assert costs[0] > costs[1] > costs[2]


def test_mb_more_cleans_than_mdbl():
    """Fig 5 trend: MB ≫ MDB-L cleans under the same workload."""
    rng = np.random.default_rng(4)
    keys = rng.integers(0, 600, size=30000)
    mb = make_table("MB", GEOM, ram_buffer_pct=2.0)
    ml = make_table("MDB-L", GEOM, ram_buffer_pct=2.0,
                    change_segment_pct=50.0)
    mb.insert_batch(keys); mb.finalize()
    ml.insert_batch(keys); ml.finalize()
    assert mb.ledger.cleans > ml.ledger.cleans


def test_load_factor_sane():
    t = make_table("MB", GEOM, ram_buffer_pct=5.0)
    t.insert_batch(np.arange(500, dtype=np.int64))
    t.finalize()
    assert 0.4 < t.ds.load_factor < 0.55
