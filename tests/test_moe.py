"""MoE: gather-only dispatch vs dense-routing oracle; capacity drops."""
import dataclasses
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import moe as Mo


def _setup(cf=8.0, arch="granite_moe_1b"):
    cfg = dataclasses.replace(get_config(arch, tiny=True),
                              capacity_factor=cf)
    params = Mo.init_moe(jax.random.key(1), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(2), (3, 16, cfg.d_model),
                          jnp.float32)
    return cfg, params, x


def _dense_ref(cfg, params, x):
    logits = jnp.einsum("bsd,de->bse", x, params["router"])
    probs = jax.nn.softmax(logits, -1)
    tp, ti = Mo._topk(probs, cfg.experts_per_token)
    tp = tp / tp.sum(-1, keepdims=True)

    def ffn_e(e, xx):
        if "w_gate" in params:
            g = xx @ params["w_gate"][e]
            u = xx @ params["w_up"][e]
            return (jax.nn.silu(g) * u) @ params["w_down"][e]
        h = xx @ params["w_in"][e]
        return jax.nn.gelu(h) @ params["w_down"][e]

    all_out = jnp.stack([ffn_e(e, x) for e in range(cfg.num_experts)])
    ref = jnp.zeros_like(x)
    for i in range(cfg.experts_per_token):
        sel = jnp.take_along_axis(all_out.transpose(1, 2, 0, 3),
                                  ti[..., i:i + 1, None], axis=2)[:, :, 0, :]
        ref = ref + tp[..., i:i + 1] * sel
    return ref


def test_no_drop_equals_dense():
    cfg, params, x = _setup(cf=8.0)
    y, aux, counts = Mo.moe_apply(params, cfg, x)
    ref = _dense_ref(cfg, params, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
    assert float(aux) > 0
    assert int(counts.sum()) == 3 * 16 * cfg.experts_per_token


def test_capacity_drop_reduces_output():
    cfg_lo, params, x = _setup(cf=0.10)
    y_lo, _, _ = Mo.moe_apply(params, cfg_lo, x)
    cfg_hi = dataclasses.replace(cfg_lo, capacity_factor=8.0)
    y_hi, _, _ = Mo.moe_apply(params, cfg_hi, x)
    # low capacity must differ (tokens dropped), not explode
    assert not np.allclose(np.asarray(y_lo), np.asarray(y_hi))
    assert np.isfinite(np.asarray(y_lo)).all()


def test_topk_matches_lax():
    p = jax.random.uniform(jax.random.key(3), (5, 7, 16))
    v1, i1 = Mo._topk(p, 4)
    v2, i2 = jax.lax.top_k(p, 4)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))


def test_grad_flows():
    cfg, params, x = _setup(cf=2.0)

    def f(p):
        y, aux, _ = Mo.moe_apply(p, cfg, x)
        return (y ** 2).mean() + 0.01 * aux

    g = jax.grad(f)(params)
    gn = sum(float(jnp.abs(v).sum()) for v in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0
