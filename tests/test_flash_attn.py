"""Pallas flash-attention kernel vs dense oracle: shape/dtype sweeps."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.flash_attn import ops, ref


@pytest.mark.parametrize("b,s,h,kvh,d,bq,bk", [
    (2, 128, 4, 4, 32, 32, 32),     # MHA
    (1, 256, 8, 2, 64, 64, 64),     # GQA 4:1
    (2, 128, 6, 2, 16, 64, 32),     # GQA 3:1, odd dims
    (1, 128, 4, 1, 32, 128, 128),   # MQA, single tile
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matches_oracle(b, s, h, kvh, d, bq, bk, dtype):
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (b, s, h, d), dtype)
    k = jax.random.normal(ks[1], (b, s, kvh, d), dtype)
    v = jax.random.normal(ks[2], (b, s, kvh, d), dtype)
    got = ops.flash_attention(q, k, v, block_q=bq, block_k=bk)
    want = ref.sdpa_ref(q, k, v)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_non_causal():
    ks = jax.random.split(jax.random.key(1), 3)
    q = jax.random.normal(ks[0], (1, 64, 2, 16), jnp.float32)
    k = jax.random.normal(ks[1], (1, 64, 2, 16), jnp.float32)
    v = jax.random.normal(ks[2], (1, 64, 2, 16), jnp.float32)
    got = ops.flash_attention(q, k, v, causal=False, block_q=32, block_k=32)
    want = ref.sdpa_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_causality_enforced():
    """Changing future tokens must not change earlier outputs."""
    ks = jax.random.split(jax.random.key(2), 3)
    q = jax.random.normal(ks[0], (1, 64, 2, 16), jnp.float32)
    k = jax.random.normal(ks[1], (1, 64, 2, 16), jnp.float32)
    v = jax.random.normal(ks[2], (1, 64, 2, 16), jnp.float32)
    o1 = ops.flash_attention(q, k, v, block_q=32, block_k=32)
    k2 = k.at[:, 40:].set(123.0)
    v2 = v.at[:, 40:].set(-7.0)
    o2 = ops.flash_attention(q, k2, v2, block_q=32, block_k=32)
    np.testing.assert_array_equal(np.asarray(o1[:, :40]),
                                  np.asarray(o2[:, :40]))
