"""TF-IDF pipeline vs an independent numpy oracle."""
import math
import numpy as np
import pytest

from repro.core import TableGeometry
from repro.core.tfidf import TfIdfPipeline, tokenize

DOCS = [
    "the cat sat on the mat",
    "the dog sat on the log",
    "macintosh apple computers and the apple fruit",
    "the the the the stopword heavy document",
    "quantum flash storage devices on solid state drives",
]


def _oracle():
    toks = [tokenize(d) for d in DOCS]
    tf_total = {}
    df = {}
    for dt in toks:
        for t in dt:
            tf_total[t] = tf_total.get(t, 0) + 1
        for t in set(dt):
            df[t] = df.get(t, 0) + 1
    return toks, tf_total, df


@pytest.fixture()
def pipe():
    geom = TableGeometry(num_blocks=4, pages_per_block=8, entries_per_page=16)
    p = TfIdfPipeline(geom, scheme="MDB-L", ram_buffer_pct=10.0,
                      change_segment_pct=25.0)
    for d in DOCS:
        p.add_document(tokenize(d))
    p.finalize()
    return p


def test_term_frequencies(pipe):
    _, tf_total, _ = _oracle()
    for t, c in tf_total.items():
        assert pipe.term_frequency(t) == c
    assert pipe.term_frequency("nonexistent") == 0


def test_idf(pipe):
    toks, _, df = _oracle()
    for t, d in df.items():
        assert abs(pipe.idf(t) - math.log(len(DOCS) / d)) < 1e-9


def test_tfidf_scores_and_keywords(pipe):
    toks, _, df = _oracle()
    doc = toks[2]
    scores = pipe.tfidf(doc)
    # oracle
    n = len(doc)
    for t in set(doc):
        tf = doc.count(t) / n
        expect = tf * math.log(len(DOCS) / df[t])
        assert abs(scores[t] - expect) < 1e-9
    # 'the' is a near-stop-word (4/5 docs): lowest idf → lowest score of
    # this doc's words; a moderate threshold keeps content words only
    assert scores["the"] == min(scores.values())
    kws = pipe.keywords(doc, threshold=scores["the"] * 1.01)
    assert "apple" in kws and "the" not in kws


def test_stopwords_rank_below_rare_words(pipe):
    assert pipe.idf("the") < pipe.idf("quantum")


def test_idf_many_matches_scalar(pipe):
    toks = ["the", "apple", "quantum", "nonexistent", "the"]
    many = pipe.idf_many(toks)
    want = np.asarray([pipe.idf(t) for t in toks])
    np.testing.assert_allclose(many, want, atol=1e-12)
    assert many[3] == 0.0  # absent tokens score 0, not -inf


@pytest.mark.parametrize("scheme", ["MB", "MDB", "MDB-L"])
def test_device_backend_matches_sim(scheme):
    """Sim-vs-device: the same workload through table_sim and table_jax
    must produce identical logical answers under every scheme."""
    geom = TableGeometry(num_blocks=4, pages_per_block=8, entries_per_page=16)
    sim = TfIdfPipeline(geom, scheme=scheme, ram_buffer_pct=10.0,
                        change_segment_pct=25.0)
    dev = TfIdfPipeline(geom, scheme=scheme, backend="device",
                        q_log2=12, r_log2=8)
    for d in DOCS:
        sim.add_document(tokenize(d))
        dev.add_document(tokenize(d))
    sim.finalize()
    dev.finalize()
    _, tf_total, df = _oracle()
    for t, c in tf_total.items():
        assert dev.term_frequency(t) == c == sim.term_frequency(t)
    for t, d in df.items():
        assert abs(dev.idf(t) - math.log(len(DOCS) / d)) < 1e-9
    wear = dev.term_table.wear()
    assert wear["dropped"] == 0
    assert wear["tile_stores"] > 0
