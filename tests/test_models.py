"""Per-arch smoke tests (reduced configs, CPU) + decode/prefill consistency.

The decode-vs-full check is the strongest correctness test in the suite:
prefilling S tokens then decoding one-by-one must reproduce the logits the
full (training-path) forward computes at those positions, for every token
mixer family (GQA/MLA/SSD/hybrid) and cache type.
"""
import dataclasses
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import model as M

KEY = jax.random.key(0)


def _batch(cfg, b, s, seed=0):
    rng = np.random.default_rng(seed)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)),
                              jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)),
                              jnp.int32),
    }
    if cfg.frontend != "none":
        batch["frontend_embeds"] = jnp.asarray(
            rng.standard_normal((b, cfg.num_patches, cfg.d_model)) * 0.02,
            jnp.dtype(cfg.dtype))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    """One forward + one grad step on a reduced config: shapes + finite."""
    cfg = get_config(arch, tiny=True)
    params = M.init_params(KEY, cfg)
    batch = _batch(cfg, 2, 64)
    logits, aux, counts = M.forward_train(params, cfg, batch)
    assert logits.shape == (2, 64, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    loss, metrics = M.loss_fn(params, cfg, batch)
    assert bool(jnp.isfinite(loss))
    grads = jax.grad(lambda p: M.loss_fn(p, cfg, batch)[0])(params)
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.isfinite(g.astype(jnp.float32)).all())
               for g in flat)
    if cfg.num_experts:
        assert counts is not None
        assert int(counts.sum()) == 2 * 64 * cfg.experts_per_token * sum(
            1 for f in cfg.ffn_pattern if f == "moe") * cfg.num_groups


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_full_forward(arch):
    """prefill(S) + step-by-step decode == full forward logits."""
    # capacity_factor high enough that the full pass drops no tokens
    # (drops are a train-time artifact; decode (s=1) never drops, so the
    # comparison is only meaningful drop-free)
    cfg = dataclasses.replace(get_config(arch, tiny=True), dtype="float32",
                              capacity_factor=8.0)
    params = M.init_params(KEY, cfg)
    b, s_pre, n_dec = 2, 32, 4
    s_all = s_pre + n_dec
    rng = np.random.default_rng(1)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s_all)),
                         jnp.int32)
    batch_all = {"tokens": tokens, "labels": tokens}
    if cfg.frontend != "none":
        fe = jnp.asarray(rng.standard_normal(
            (b, cfg.num_patches, cfg.d_model)) * 0.02, jnp.float32)
        batch_all["frontend_embeds"] = fe
    # full forward over all positions — ssd chunking needs divisibility
    if s_all % max(cfg.ssm_chunk, 1) and "ssm" in cfg.layer_pattern:
        pytest.skip("chunk divisibility")
    full_logits, _, _ = M.forward_train(params, cfg, batch_all, remat=False)

    batch_pre = {"tokens": tokens[:, :s_pre]}
    if cfg.frontend != "none":
        batch_pre["frontend_embeds"] = batch_all["frontend_embeds"]
    logits_pre, caches = M.prefill(params, cfg, batch_pre)
    np.testing.assert_allclose(
        np.asarray(logits_pre[:, 0]), np.asarray(full_logits[:, s_pre - 1]),
        rtol=2e-4, atol=2e-4)
    caches = M.pad_caches(cfg, caches, s_all)
    for i in range(n_dec):
        idx = jnp.int32(s_pre + i)
        logits_i, caches = M.decode_step(params, cfg,
                                         tokens[:, s_pre + i:s_pre + i + 1],
                                         caches, idx)
        np.testing.assert_allclose(
            np.asarray(logits_i[:, 0]),
            np.asarray(full_logits[:, s_pre + i]),
            rtol=2e-4, atol=2e-4, err_msg=f"{arch} decode step {i}")


def test_vocab_padding_masked():
    cfg = get_config("granite_moe_1b", tiny=True)
    assert cfg.padded_vocab % 256 == 0 and cfg.padded_vocab >= cfg.vocab_size
    params = M.init_params(KEY, cfg)
    logits, _, _ = M.forward_train(params, cfg, _batch(cfg, 1, 32))
    pad = np.asarray(logits[..., cfg.vocab_size:])
    assert (pad <= -1e29).all()


def test_label_masking():
    cfg = get_config("llama32_3b", tiny=True)
    params = M.init_params(KEY, cfg)
    batch = _batch(cfg, 2, 32)
    l1, _ = M.loss_fn(params, cfg, batch)
    batch2 = dict(batch, labels=batch["labels"].at[:, :16].set(-1))
    l2, m2 = M.loss_fn(params, cfg, batch2)
    assert float(m2["tokens"]) == 2 * 16
    assert not np.isclose(float(l1), float(l2))


@pytest.mark.parametrize("arch", ["llama32_3b", "nemotron4_340b",
                                  "jamba15_large_398b"])
def test_chunked_attention_matches_dense(arch):
    """§Perf opt: online-softmax chunked attention == dense softmax."""
    cfg = dataclasses.replace(get_config(arch, tiny=True), dtype="float32",
                              attn_q_chunk=16, attn_kv_chunk=8)
    cfg_c = dataclasses.replace(cfg, attn_impl="chunked")
    params = M.init_params(KEY, cfg)
    batch = _batch(cfg, 2, 64)
    l1, _, _ = M.forward_train(params, cfg, batch, remat=False)
    l2, _, _ = M.forward_train(params, cfg_c, batch, remat=False)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               rtol=1e-3, atol=1e-3)
