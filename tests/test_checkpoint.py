"""Checkpointing: roundtrip, atomicity, retention, async, emergency."""
import json
import numpy as np
import jax.numpy as jnp
import pytest

from repro.checkpoint import (CheckpointManager, latest_step,
                              restore_checkpoint, save_checkpoint)


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {"params": {"w": jnp.asarray(rng.standard_normal((4, 4)),
                                        jnp.float32),
                       "b": jnp.asarray(rng.standard_normal(4), jnp.float32)},
            "opt": {"m": jnp.zeros((4, 4)), "count": jnp.int32(17)}}


def test_roundtrip(tmp_path):
    t = _tree()
    save_checkpoint(tmp_path, 5, t)
    restored, meta = restore_checkpoint(tmp_path, t)
    assert meta["step"] == 5
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(t["params"]["w"]))
    assert int(restored["opt"]["count"]) == 17


def test_latest_and_retention(tmp_path):
    mgr = CheckpointManager(tmp_path, every_steps=2, keep=2)
    t = _tree()
    for step in range(8):
        mgr.maybe_save(step, t, blocking=True)
    assert latest_step(tmp_path) == 6
    kept = sorted(p.name for p in tmp_path.glob("step_*"))
    assert len(kept) == 2  # retention


def test_async_save(tmp_path):
    mgr = CheckpointManager(tmp_path, every_steps=1, keep=3)
    t = _tree()
    mgr.save(3, t, blocking=False)
    mgr.wait()
    assert latest_step(tmp_path) == 3


def test_atomic_publish(tmp_path):
    """A .tmp dir never counts as a checkpoint."""
    (tmp_path / "step_00000009.tmp").mkdir(parents=True)
    assert latest_step(tmp_path) is None
    save_checkpoint(tmp_path, 2, _tree())
    assert latest_step(tmp_path) == 2


def test_restore_mismatch_raises(tmp_path):
    save_checkpoint(tmp_path, 1, {"a": jnp.zeros(3)})
    with pytest.raises(KeyError):
        restore_checkpoint(tmp_path, {"b": jnp.zeros(3)})
    with pytest.raises(ValueError):
        restore_checkpoint(tmp_path, {"a": jnp.zeros(4)})


def test_emergency(tmp_path):
    mgr = CheckpointManager(tmp_path, every_steps=100)
    mgr.emergency(42, _tree())
    restored, meta = restore_checkpoint(tmp_path, _tree())
    assert meta.get("emergency") is True and meta["step"] == 42


def test_data_state_in_meta(tmp_path):
    save_checkpoint(tmp_path, 11, _tree())
    meta = json.loads((tmp_path / "step_00000011" / "meta.json").read_text())
    assert meta["data_state"]["step"] == 11
