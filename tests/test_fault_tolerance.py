"""Fault tolerance: restart-from-checkpoint, NaN rollback, stragglers,
elastic replanning."""
import math
import jax.numpy as jnp
import pytest

from repro.checkpoint import CheckpointManager
from repro.runtime import (NaNGuard, ResilientTrainer, StepWatchdog,
                           plan_mesh_shape)


def _quadratic_step(poison_at=None):
    """Toy trainable state: minimize (w-3)^2 by GD; optionally poison one
    step with NaN."""
    def step_fn(state, step):
        w = state["w"]
        if poison_at is not None and step == poison_at:
            loss = jnp.float32(float("nan"))
            return state, {"loss": loss}
        g = 2 * (w - 3.0)
        w = w - 0.1 * g
        return {"w": w}, {"loss": (w - 3.0) ** 2}
    return step_fn


def test_runs_to_completion(tmp_path):
    tr = ResilientTrainer(_quadratic_step(),
                          CheckpointManager(tmp_path, every_steps=5))
    state, report = tr.run({"w": jnp.float32(0.0)}, num_steps=40)
    assert report.steps_done == 40
    assert report.final_loss < 1e-3
    assert report.restarts == 0


def test_restart_from_checkpoint(tmp_path):
    tr = ResilientTrainer(_quadratic_step(),
                          CheckpointManager(tmp_path, every_steps=5),
                          inject_failure_at=17)
    state, report = tr.run({"w": jnp.float32(0.0)}, num_steps=40)
    assert report.restarts == 1
    assert report.steps_done >= 38  # resumed from step 15's checkpoint
    assert report.final_loss < 1e-3


def test_nan_rollback(tmp_path):
    tr = ResilientTrainer(_quadratic_step(poison_at=12),
                          CheckpointManager(tmp_path, every_steps=5))
    state, report = tr.run({"w": jnp.float32(0.0)}, num_steps=30)
    assert report.rollbacks == 1
    assert math.isfinite(report.final_loss)
    assert report.final_loss < 1e-2


def test_nan_guard_spike():
    g = NaNGuard(spike_factor=5.0, window=8)
    for _ in range(8):
        assert g.check(1.0)
    assert not g.check(100.0)   # spike
    assert g.check(1.1)


def test_watchdog():
    events = []
    w = StepWatchdog(factor=3.0, min_samples=3,
                     on_straggler=lambda s, t, m: events.append(s))
    for i in range(5):
        w.observe(i, 0.1)
    assert w.observe(5, 1.0)     # 10× median
    assert events == [5]
    assert not w.observe(6, 0.12)


def test_elastic_plan():
    assert plan_mesh_shape(256, 16, 256) == (16, 16)
    # lose a node group: 240 devices → 15 data rows? 256 % 15 != 0 → 8
    d, m = plan_mesh_shape(240, 16, 256)
    assert d * m <= 240 and 256 % d == 0
    with pytest.raises(ValueError):
        plan_mesh_shape(8, 16, 256)
