"""Batched write engine: engine-buffered ≡ direct tj.update ≡ table_sim.

The PR-3 acceptance property (ISSUE 3): updates routed through the
host-side H_R buffer (``BatchedWriteEngine``) must be *bit-identical* —
table contents and wear counters — to dispatching the same chunks
through direct ``tj.update`` calls, and logically identical to the
event-level ``table_sim`` oracle, under every scheme, including
flush-threshold boundaries, Δ-cancellation, and state reuse across
donated dispatches. Plus the donation-aliasing contract (no stale host
references survive a donated update) and the automatic-invalidation
regression (no stale read after an unflushed writer).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import table_jax as tj
from repro.core.flash_model import TableGeometry
from repro.core.query_engine import BatchedQueryEngine
from repro.core.table_sim import make_table
from repro.core.write_engine import BatchedWriteEngine
from repro.data import CorpusStats

SCHEMES = ["MB", "MDB", "MDB-L"]
GEOM = TableGeometry(num_blocks=16, pages_per_block=2, entries_per_page=8)


def _cfg(scheme, **kw):
    base = dict(q_log2=8, r_log2=4, scheme=scheme, log_capacity=64,
                cs_partitions=4, max_updates_per_block=32,
                overflow_capacity=128)
    base.update(kw)
    return tj.FlashTableConfig(**base)


def _assert_states_bitidentical(a, b):
    """Every leaf — data/change/overflow segments AND TableStats wear
    counters — must match exactly."""
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@pytest.mark.parametrize("scheme", SCHEMES)
def test_engine_equals_direct_equals_sim(scheme):
    cfg = _cfg(scheme)
    rec = []
    eng = BatchedWriteEngine(cfg, chunk=32, flush_threshold=48, record=rec)
    sim = make_table(scheme, GEOM, ram_buffer_pct=10.0,
                     change_segment_pct=25.0)
    rng = np.random.default_rng(0)
    seen = []
    # several writer batches: duplicates, skew, explicit ±Δ
    for i in range(6):
        toks = rng.integers(0, 300, size=40)
        eng.update(toks)
        sim.update_batch(toks)
        seen.append(toks)
    negs = np.asarray([5, 9, 13])
    eng.update(negs, np.full(3, -1, np.int64))
    sim.update_batch(negs, np.full(3, -1, np.int64))
    # the threshold really triggered mid-stream, and the engine kept
    # updating through the donated post-flush state
    assert eng.stats.auto_flushes >= 1
    assert eng.stats.dispatches >= 1
    eng.merge()
    sim.finalize()
    # 1) logical oracle: engine counts == sim counts for the union of
    #    touched keys + absent keys (reads through a fresh query engine,
    #    so nothing is served from a cache)
    keys = np.concatenate([np.unique(np.concatenate(seen)),
                           np.asarray([7777, 8888])])
    qe = BatchedQueryEngine(cfg, hot_capacity=0)
    got = qe.query_batch(eng.state, keys)
    want = sim.query_batch(keys)
    np.testing.assert_array_equal(got, want)
    # 2) bit-identity: replaying the exact recorded dispatch chunks
    #    through direct per-call tj.update produces the same final state
    #    — contents and wear counters — as the engine path
    st = tj.init(cfg)
    for pk, pd in rec:
        st = tj.update(cfg, st, jnp.asarray(pk, jnp.int32),
                       jnp.asarray(pd, jnp.int32))
    st = tj.flush(cfg, st)
    _assert_states_bitidentical(st, eng.state)
    assert int(eng.state.stats.dropped) == 0


@pytest.mark.parametrize("scheme", SCHEMES)
def test_flush_threshold_boundary(scheme):
    """Exactly `flush_threshold` unique entries must trigger the auto
    flush; one fewer must not."""
    cfg = _cfg(scheme)
    eng = BatchedWriteEngine(cfg, chunk=16, flush_threshold=20)
    eng.update(np.arange(19))
    assert eng.stats.auto_flushes == 0 and eng.buffered_entries == 19
    eng.update(np.asarray([19]))          # hits the boundary exactly
    assert eng.stats.auto_flushes == 1 and eng.buffered_entries == 0
    assert eng.stats.dispatches == 2      # 20 entries / chunk 16
    # post-flush, the engine keeps accepting updates on the donated state
    eng.update(np.arange(5))
    eng.merge()
    qe = BatchedQueryEngine(cfg, hot_capacity=0)
    got = qe.query_batch(eng.state, np.arange(20))
    np.testing.assert_array_equal(got, [2] * 5 + [1] * 15)


def test_delta_cancellation_never_reaches_device():
    """+Δ/−Δ pairs cancel inside H_R (paper §2.6): no device traffic."""
    cfg = _cfg("MDB-L")
    eng = BatchedWriteEngine(cfg, chunk=16, flush_threshold=1000)
    eng.update(np.asarray([42, 42, 43]))
    eng.update(np.asarray([42, 42, 43]), np.asarray([-1, -1, -1]))
    assert eng.buffered_entries == 0
    assert eng.stats.cancelled == 2
    eng.flush()                            # empty H_R: no dispatch at all
    assert eng.stats.dispatches == 0 and eng.stats.dispatched_entries == 0


def test_write_stats_ledger_identities():
    cfg = _cfg("MDB-L")
    eng = BatchedWriteEngine(cfg, chunk=16, flush_threshold=1000)
    eng.update(np.asarray([1, 2, 3, 1, 2, tj.EMPTY]))   # EMPTY = padding
    eng.update(np.asarray([3, 4]))
    s = eng.stats
    assert s.updates == 2
    assert s.entries == 7                  # EMPTY never counted
    assert s.buffered == 4                 # tokens 1..4 opened slots
    assert s.deduped == 3                  # 1, 2 (in-batch) + 3 (cross)
    assert s.entries == s.buffered + s.deduped
    eng.flush()
    assert s.dispatched_entries == 4 and s.flushes == 1
    # a brand-new token whose batch-internal Δs cancel opens no slot:
    # absorbed (deduped + cancelled), never counted as buffered
    eng.update(np.asarray([99, 99]), np.asarray([1, -1]))
    assert eng.buffered_entries == 0
    assert s.buffered == 4 and s.cancelled == 1
    assert s.entries == s.buffered + s.deduped   # identity still holds


def test_donated_update_invalidates_old_state():
    """Donation aliasing: after a donated update/flush, the old state's
    buffers are spent — no stale host reference survives — and the
    returned state is fully usable."""
    cfg = _cfg("MDB-L")
    st0 = tj.init(cfg)
    st1 = tj.update(cfg, st0, jnp.asarray([1, 2, 3], jnp.int32))
    # flashlint: disable=FL002 — reading st0 after donation IS the test
    assert all(leaf.is_deleted() for leaf in jax.tree.leaves(st0))
    with pytest.raises(RuntimeError):
        np.asarray(st0.keys)             # flashlint: disable=FL002
    cnt, _ = tj.lookup(cfg, st1, jnp.asarray([1, 2, 3, 4], jnp.int32))
    assert list(map(int, cnt)) == [1, 1, 1, 0]
    st2 = tj.flush(cfg, st1)
    # flashlint: disable=FL002 — same: the donated flush must spend st1
    assert all(leaf.is_deleted() for leaf in jax.tree.leaves(st1))
    cnt, _ = tj.lookup(cfg, st2, jnp.asarray([1, 2, 3, 4], jnp.int32))
    assert list(map(int, cnt)) == [1, 1, 1, 0]
    # lookup is a read: it must NOT donate
    assert not any(leaf.is_deleted() for leaf in jax.tree.leaves(st2))


def test_no_stale_reads_after_unflushed_writer():
    """Regression (ISSUE 3 satellite): a writer mutation that has not
    reached the device yet must still be visible to readers — previously
    each caller had to remember a manual engine.invalidate() after every
    write; now the write engine owns the contract."""
    st = CorpusStats.create(q_log2=10, r_log2=6, scheme="MDB-L",
                            log_capacity=1 << 8, overflow_capacity=1 << 8,
                            max_updates_per_block=1 << 6)
    toks = np.arange(100, 130)
    st.ingest(toks)
    st.flush()
    first = st.counts(toks)                # populates the hot-key cache
    np.testing.assert_array_equal(first, np.ones(30))
    st.ingest(toks[:10])                   # buffered in H_R, no dispatch
    assert st.store.buffered_entries > 0
    got = st.counts(toks)                  # must not serve stale counts
    np.testing.assert_array_equal(got, [2] * 10 + [1] * 20)
    # after the device flush the same counts come from the table itself
    st.flush()
    assert st.store.buffered_entries == 0
    np.testing.assert_array_equal(st.counts(toks), got)
    # MoE accounting rides the same engine: deltas visible pre-flush
    st.ingest_expert_counts(layer=2, counts=np.asarray([4, 0, 1]))
    np.testing.assert_array_equal(st.expert_counts(2, 3), [4, 0, 1])


def test_sim_update_batch_is_engine_chunk_compatible():
    """The sim twin accepts EMPTY-padded fixed-shape (keys, Δ) chunks:
    padding is ignored at no cost, deltas keep counting semantics."""
    sim = make_table("MDB-L", GEOM, ram_buffer_pct=10.0,
                     change_segment_pct=25.0)
    chunk = np.asarray([5, 5, 9, -1, -1, -1], np.int64)
    sim.update_batch(chunk)
    sim.update_batch(np.asarray([9, -1], np.int64),
                     np.asarray([-1, 7], np.int64))
    sim.finalize()
    assert sim.logical_count(5) == 2
    assert sim.logical_count(9) == 0       # 1 − 1: decremented away
    # a padded chunk of only EMPTY keys is a free no-op
    before = dict(sim.ledger.__dict__)
    sim.update_batch(np.full(8, -1, np.int64))
    assert dict(sim.ledger.__dict__) == before
