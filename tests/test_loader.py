"""Loader: determinism, resume-from-step, masking, host slicing, filter."""
import numpy as np

from repro.data import LoaderConfig, SyntheticCorpus, make_batch
from repro.data.loader import host_slice


def _cfg(**kw):
    corpus = SyntheticCorpus(num_docs=50, mean_doc_len=64, vocab_size=1000,
                             seed=3)
    base = dict(corpus=corpus, seq_len=128, global_batch=8, microbatches=2,
                vocab_size=1000)
    base.update(kw)
    return LoaderConfig(**base)


def test_shapes_and_ranges():
    cfg = _cfg()
    b = make_batch(cfg, step=0)
    assert b["tokens"].shape == (2, 4, 128)
    assert b["labels"].shape == (2, 4, 128)
    assert b["tokens"].min() >= 0 and b["tokens"].max() < 1000
    # separators produce masked label positions
    assert (b["labels"] == -1).sum() > 0


def test_determinism_and_resume():
    cfg = _cfg()
    b1 = make_batch(cfg, step=7)
    b2 = make_batch(cfg, step=7)
    b3 = make_batch(cfg, step=8)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(b1["tokens"], b3["tokens"])


def test_host_slice():
    cfg = _cfg()
    b = make_batch(cfg, step=0)
    s0 = host_slice(b, 0, 2)
    s1 = host_slice(b, 1, 2)
    assert s0["tokens"].shape == (2, 2, 128)
    np.testing.assert_array_equal(
        np.concatenate([s0["tokens"], s1["tokens"]], axis=1), b["tokens"])


def test_doc_filter_drops_docs():
    corpus = SyntheticCorpus(num_docs=50, mean_doc_len=64, vocab_size=1000,
                             seed=3)
    seen = []

    def flt(toks):
        seen.append(len(toks))
        return len(toks) % 2 == 0  # arbitrary deterministic filter

    cfg = _cfg(doc_filter=flt)
    b = make_batch(cfg, step=0)
    assert len(seen) > 0
    assert b["tokens"].shape == (2, 4, 128)


def test_frontend_stub():
    cfg = _cfg(num_patches=4, d_model=16)
    b = make_batch(cfg, step=0)
    assert b["frontend_embeds"].shape == (2, 4, 4, 16)
