"""FlashStore facade: one API, three backends, identical semantics.

The PR-4 acceptance property (ISSUE 4): the same token stream driven
through ``FlashStore.open(backend=...)`` for ``sim``, ``device`` and
``sharded`` must produce identical counts — before a flush
(read-your-writes through the H_R overlay), after increments/decrements
(Δ-cancellation), and after the durability flush. Since PR 5 every
backend drains through the async double-buffered dispatcher by default
(DESIGN.md §9), so these properties now also prove the async path; the
deprecated pre-PR4 engine shims are deleted (`test_engine_shims_are_gone`).
"""
import os
import subprocess
import sys
from collections import Counter
from pathlib import Path

import numpy as np
import pytest

from repro.core import table_jax as tj
from repro.core.store import FlashStore

HELPERS = Path(__file__).parent / "helpers"

SCHEMES = ["MB", "MDB", "MDB-L"]


def _cfg(scheme, **kw):
    base = dict(q_log2=10, r_log2=6, scheme=scheme, log_capacity=1 << 9,
                cs_partitions=4, max_updates_per_block=1 << 6,
                overflow_capacity=1 << 9)
    base.update(kw)
    return tj.FlashTableConfig(**base)


def _shard_count() -> int:
    """All local devices when that is a power of two (the dedicated CI
    job forces 8), else 1 — the facade must behave identically."""
    import jax
    n = jax.device_count()
    return n if n & (n - 1) == 0 else 1


def _open_all(scheme="MDB-L"):
    stores = {
        "sim": FlashStore.open(backend="sim", scheme=scheme),
        "device": FlashStore.open(_cfg(scheme), backend="device",
                                  chunk=256, flush_threshold=512),
    }
    if scheme in ("MB", "MDB-L"):
        stores["sharded"] = FlashStore.open(
            _cfg(scheme), backend="sharded", num_shards=_shard_count(),
            shard_chunk=256, flush_threshold=300)
    return stores


def test_cross_backend_equivalence_one_stream():
    """sim ≡ device ≡ sharded on one skewed stream with ±Δ batches,
    visibility checked at every lifecycle point."""
    rng = np.random.default_rng(0)
    toks = rng.integers(0, 500, size=4096).astype(np.int64)
    truth = Counter(toks.tolist())
    keys = np.array(sorted(truth))
    want = np.array([truth[int(k)] for k in keys])
    dec = keys[::7]                      # decrement a spread of keys
    stores = _open_all("MDB-L")
    results = {}
    for name, st in stores.items():
        for i in range(0, toks.size, 512):
            st.update(toks[i:i + 512])
        # read-your-writes: H_R + staged entries visible pre-flush
        np.testing.assert_array_equal(st.query(keys), want,
                                      err_msg=f"{name}: pre-flush")
        st.update(dec, np.full(dec.size, -1, np.int64))
        np.testing.assert_array_equal(
            st.query(dec), want[::7] - 1, err_msg=f"{name}: post-decrement")
        st.update(dec)                   # +1: cancels inside H_R
        st.flush()
        got = st.query(keys)
        np.testing.assert_array_equal(got, want,
                                      err_msg=f"{name}: post-flush")
        assert st.query(999_999) == 0    # absent key, scalar path
        results[name] = got
        s = st.stats()
        assert s["backend"] == name and s["buffered_entries"] == 0
        st.close()
    for name, got in results.items():
        np.testing.assert_array_equal(got, results["sim"], err_msg=name)


@pytest.mark.parametrize("scheme", SCHEMES)
def test_sim_equals_device_per_scheme(scheme):
    """Every scheme answers the same counts through the facade."""
    rng = np.random.default_rng(1)
    toks = rng.integers(0, 300, size=1500).astype(np.int64)
    keys = np.unique(toks)
    got = {}
    sim = FlashStore.open(backend="sim", scheme=scheme)
    dev = FlashStore.open(_cfg(scheme), backend="device", chunk=128,
                          flush_threshold=256)
    for st in (sim, dev):
        st.update(toks)
        st.flush()
        got[st.backend] = st.query(keys)
        st.close()
    np.testing.assert_array_equal(got["sim"], got["device"])


def test_increment_and_context_manager():
    with FlashStore.open(_cfg("MDB-L"), backend="device") as st:
        st.increment(42)
        st.increment(42, 2)
        st.increment(42, -1)
        assert st.query(42) == 2         # buffered Δs, no flush yet
        assert st.buffered_entries == 1
    assert st._closed
    with pytest.raises(ValueError):
        st.update(np.asarray([1]))
    st.close()                           # idempotent


def test_sharded_shard_local_thresholds():
    """One hot shard drains alone: the other shards' H_R partitions keep
    buffering (no global drain), and the collective carries nothing."""
    n = _shard_count()
    if n == 1:
        pytest.skip("needs a multi-device mesh (dedicated CI job)")
    st = FlashStore.open(_cfg("MDB-L"), backend="sharded", num_shards=n,
                         shard_chunk=64, flush_threshold=64,
                         piggyback_frac=2.0)    # piggyback off: isolate
    b = st._b
    # craft keys owned by shard 0 vs the rest
    keys = np.arange(20_000)
    owners = b.owner_of(keys)
    hot = keys[owners == 0][:64]          # exactly the threshold
    cold = keys[owners != 0][:32]
    st.update(cold)
    assert st.buffered_entries == 32      # below threshold: all buffered
    st.update(hot)                        # shard 0 hits its threshold
    s = st.stats()
    assert s["write_auto_flushes"] == 1
    assert st.buffered_entries == 32      # cold shards kept their H_R
    assert s["write_carried"] == 0
    # reads still consolidate across drained + buffered shards
    np.testing.assert_array_equal(st.query(hot), np.ones(hot.size))
    np.testing.assert_array_equal(st.query(cold), np.ones(cold.size))
    st.close()


def test_engine_shims_are_gone():
    """ROADMAP "Engine shim removal": the deprecated pre-PR4 surfaces
    (`DeviceTableAdapter`, `make_device_table`, `CorpusStats(engine=/
    writer=)`) were deleted in PR 5 — the store is the only way in.
    flashlint rule FL005 (CI's lint-contracts job) keeps them deleted —
    import-aware, so aliased reintroductions are caught too."""
    import inspect

    from repro.core import tfidf
    from repro.data import CorpusStats
    assert not hasattr(tfidf, "DeviceTableAdapter")
    assert not hasattr(tfidf, "make_device_table")
    params = inspect.signature(CorpusStats).parameters
    assert "engine" not in params and "writer" not in params


def test_state_adoption_still_works():
    """Adopting a prebuilt device state (the surviving, non-shim half of
    the old writer-adoption path) seeds the store's table."""
    import jax.numpy as jnp

    from repro.data import CorpusStats
    cfg = _cfg("MDB-L")
    state = tj.update(cfg, tj.init(cfg), jnp.asarray([1, 1, 2], jnp.int32))
    cs = CorpusStats(cfg, state=state)
    np.testing.assert_array_equal(cs.counts(np.asarray([1, 2])), [2, 1])


def test_sim_backend_implements_wear():
    """Generic cross-backend code may call wear() everywhere: the sim
    reports its ledger (cleans = the paper's erase count)."""
    st = FlashStore.open(backend="sim", scheme="MDB-L")
    st.update(np.arange(100))
    st.flush()
    w = st.wear()
    assert w["cleans"] > 0 and "block_ops" in w
    st.close()


def test_corpus_stats_sharded_backend():
    """CorpusStats scales to the sharded store with zero caller changes."""
    from repro.data import CorpusStats
    st = CorpusStats.create(q_log2=10, r_log2=6, scheme="MDB-L",
                            log_capacity=1 << 9,
                            max_updates_per_block=1 << 6,
                            overflow_capacity=1 << 9, backend="sharded")
    toks = np.arange(50, 90)
    st.ingest(toks)
    np.testing.assert_array_equal(st.counts(toks), np.ones(40))
    st.flush()
    np.testing.assert_array_equal(st.counts(toks), np.ones(40))
    assert st.wear()["dropped"] == 0
    assert st.query_stats()["batches"] > 0  # consolidated read path


def test_engine_pairing_lives_only_in_store():
    """Acceptance guard: no consumer module constructs the engine pair
    by hand anymore — the store is the only wiring point. The AST walk
    that used to live here is now flashlint rule FL001 (ISSUE 6); this
    thin check keeps the property pinned to this suite."""
    from repro.analysis import flashlint
    src = Path(__file__).resolve().parent.parent / "src"
    violations, n_files = flashlint.lint_paths([src], select=["FL001"])
    assert n_files > 0
    assert violations == [], "manual engine wiring:\n" + "\n".join(
        v.format() for v in violations)


def _run(script, *args, timeout=1200):
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    return subprocess.run(
        [sys.executable, str(HELPERS / script), *args],
        capture_output=True, text=True, timeout=timeout, env=env)


@pytest.mark.slow
def test_sharded_store_eight_devices():
    """The full 8-shard facade property, in a subprocess with its own
    8-virtual-device XLA view (mirrors tests/test_distributed.py)."""
    r = _run("dist_store_main.py")
    assert "DIST_STORE_OK" in r.stdout, r.stdout + r.stderr
