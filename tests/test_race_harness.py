"""Race harness: vector-clock tracing + replay checking (ISSUE 6).

Three *seeded* logical races — each a realistic one-line regression in
the store's concurrency discipline — must be flagged by
``Tracer.check``, and clean executions (including a 3-seed randomized
stress interleaving, the CI lane) must produce zero findings. The
seeds are injected through monkeypatched hooks on a live store, so the
harness is judged against real dispatcher/worker executions, not
synthetic logs."""
import threading

import numpy as np
import pytest

from repro.analysis import race_harness
from repro.core.store import FlashStore


def _open_device(**kw):
    base = dict(backend="device", scheme="MDB-L", q_log2=8, r_log2=4,
                log_capacity=64, cs_partitions=4, max_updates_per_block=32,
                overflow_capacity=128, flush_threshold=10_000)
    base.update(kw)
    return FlashStore.open(**base)


# -- clean executions -------------------------------------------------------
def test_clean_run_device_zero_findings():
    st = _open_device()
    tr = race_harness.attach(st)
    st.update(np.arange(100))
    st.drain(wait=False)                 # overlapped drain
    st.update(np.arange(50, 150))
    assert st.query(7) == 1              # read-your-writes mid-flight
    st.flush()
    np.testing.assert_array_equal(st.query(np.arange(50, 60)),
                                  np.full(10, 2))
    assert st.query(55) == 2             # warm-cache path after a flush
    st.close()
    findings = tr.check()
    assert findings == [], "\n".join(f.describe() for f in findings)
    kinds = {e.kind for e in tr.events}
    assert {"hr_write", "seal", "state_rebind", "invalidate",
            "cache_insert", "job_start", "job_end"} <= kinds


def test_clean_run_sim_zero_findings():
    st = FlashStore.open(backend="sim", scheme="MDB-L")
    tr = race_harness.attach(st)
    st.update(np.arange(64))
    st.drain(wait=False)
    st.update(np.arange(32, 96))
    assert st.query(40) == 2
    st.flush()
    st.close()
    findings = tr.check()
    assert findings == [], "\n".join(f.describe() for f in findings)
    assert {"seal", "inflight_clear"} <= {e.kind for e in tr.events}


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_clean_stress_interleaving_zero_findings(seed):
    """The CI stress lane: a randomized update/drain/query/flush mix
    (auto-flush threshold deliberately low so drains overlap ingest)
    must yield a race-free log on every seed."""
    rng = np.random.default_rng(seed)
    st = _open_device(flush_threshold=64)
    tr = race_harness.attach(st)
    for _ in range(40):
        op = int(rng.integers(0, 4))
        if op == 0:
            st.update(rng.integers(0, 256, size=48))
        elif op == 1:
            st.drain(wait=False)
        elif op == 2:
            st.query(rng.integers(0, 256, size=16))
        else:
            st.flush(wait=bool(rng.integers(0, 2)))
    st.close()
    findings = tr.check()
    assert findings == [], "\n".join(f.describe() for f in findings)


# -- seeded interleaving 1: invalidate *before* rebind ----------------------
def test_seeded_invalidate_before_rebind_is_flagged():
    """The fence on the wrong side: a drain that invalidates first and
    rebinds after leaves a window where the cache repopulates from the
    pre-drain state. Physically this run may be harmless — the checker
    must flag the *ordering*, not the luck."""
    st = _open_device()
    eng = st._b.writer
    tr = race_harness.attach(st)
    st.update(np.arange(64))

    orig = eng._dispatch

    def bad_dispatch(keys, dels):
        eng._invalidate()                      # fence first (the bug)
        qe, eng.query_engine = eng.query_engine, None
        try:
            orig(keys, dels)                   # ...rebind after, unfenced
        finally:
            eng.query_engine = qe

    eng._dispatch = bad_dispatch
    st.drain(wait=True)
    findings = tr.check()
    assert {f.kind for f in findings} == {"unfenced-rebind"}
    assert "rebound" in findings[0].message
    eng._dispatch = orig
    st.close()


# -- seeded interleaving 2: double seal without settling --------------------
def test_seeded_double_seal_without_settle_is_flagged():
    """Sealing H_R while the previous sealed chunk is still draining:
    the worker's in-flight clear and the caller's re-seal write the same
    slot with no happens-before edge (the second chunk is silently
    dropped). A gate holds the worker so the bad interleaving is
    deterministic — the vector clocks flag it regardless of timing."""
    st = _open_device()
    eng = st._b.writer
    tr = race_harness.attach(st)
    gate = threading.Event()
    orig = eng._dispatch

    eng.update(np.arange(32))
    sealed = eng.seal()

    def gated_drain():
        gate.wait(timeout=30)
        orig(*sealed)

    eng.dispatcher.submit(gated_drain, label="gated-drain#1")
    eng.update(np.arange(100, 140))
    # the seeded bug: re-seal without settling the in-flight drain
    # (defeating the clobber guard the way a broken refactor would)
    eng._inflight = None
    eng.seal()
    gate.set()
    eng.dispatcher.wait()
    findings = tr.check()
    assert {f.kind for f in findings} == {"data-race"}
    assert len(findings) == 1
    assert "hr:inflight" in findings[0].message
    assert {e.resource for e in findings[0].events} == {"hr:inflight"}
    st.close()


# -- seeded interleaving 3: cache insert across an un-fenced clear ----------
def test_seeded_stale_cache_insert_is_flagged():
    """An invalidation that clears the hot cache but forgets the epoch
    bump: a lookup already in flight passes the fence and re-caches
    counts probed against the pre-clear state — stale forever. The
    epoch-vs-happened-before invalidation count catches it."""
    st = _open_device()
    qe = st._b.query_engine
    tr = race_harness.attach(st)
    st.update(np.arange(64))
    st.flush()                           # 2 invalidations, epoch == 2

    orig_lookup = qe._lookup
    fired = []

    def bad_lookup(state, q):
        out = orig_lookup(state, q)
        if not fired:                    # mid-lookup, exactly once
            fired.append(1)
            # the seeded bug: clear without bumping the epoch fence
            qe._trace("invalidate", "cache", "w", epoch=qe._epoch)
            qe._hot.clear()
        return out

    qe._lookup = bad_lookup
    st.query(np.arange(16))
    findings = tr.check()
    assert {f.kind for f in findings} == {"stale-cache-insert"}
    assert "epoch" in findings[0].message
    qe._lookup = orig_lookup
    st.close()


# -- harness plumbing -------------------------------------------------------
def test_attach_rejects_dispatcherless_objects():
    with pytest.raises(ValueError, match="no FlushDispatcher"):
        race_harness.attach(object())


def test_vector_clock_orderings():
    a, b = {1: 2, 2: 1}, {1: 3, 2: 1}
    assert race_harness._leq(a, b) and not race_harness._leq(b, a)
    assert not race_harness._concurrent(a, b)
    assert race_harness._concurrent({1: 1}, {2: 1})
