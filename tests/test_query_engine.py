"""Batched query engine: query_batch ≡ per-key query ≡ table_sim oracle.

The PR-2 acceptance property (ISSUE 2): batched queries must be
bit-identical to the per-key path under every scheme, for keys
deliberately resident in each of the paper's three regions — data
segment, change segment/log (staged, unflushed), and overflow — plus
absent keys, duplicates and EMPTY padding. The event-level ``table_sim``
tables answer the same workload as the independent oracle (logical
counts are placement-independent, so the differing sim hash pair does
not matter). Since PR 5 these tests drive the engine through its only
public surface, the :class:`~repro.core.store.FlashStore` facade
(``store.drain()`` = stage without merge, ``store.stats()["query_*"]`` =
the engine ledger); the engine shims they used to ride are gone.
"""
import numpy as np
import pytest

from repro.core import table_jax as tj
from repro.core.flash_model import TableGeometry
from repro.core.query_engine import BatchedQueryEngine
from repro.core.store import FlashStore
from repro.core.table_sim import make_table

SCHEMES = ["MB", "MDB", "MDB-L"]
GEOM = TableGeometry(num_blocks=16, pages_per_block=2, entries_per_page=8)


def _same_block_keys(pair, block, n, lo=0):
    out = []
    x = lo
    while len(out) < n:
        if int(pair.s(x)) == block:
            out.append(x)
        x += 1
    return np.asarray(out, dtype=np.int64)


def _dev(scheme, query_chunk=64, hot_capacity=4096, **kw):
    cfg = dict(q_log2=8, r_log2=4, log_capacity=64, cs_partitions=4,
               max_updates_per_block=32, overflow_capacity=128)
    cfg.update(kw)
    # small fixed shapes: keep dispatch chunks within the tiny test logs
    # (oversized chunks unroll statically) and compiles fast; the large
    # flush threshold keeps writes buffered until an explicit drain/flush
    return FlashStore.open(tj.FlashTableConfig(scheme=scheme, **cfg),
                           backend="device", chunk=32,
                           query_chunk=query_chunk,
                           hot_capacity=hot_capacity, flush_threshold=8192)


def _qstats(store):
    """The engine's query-path ledger, through the store surface."""
    s = store.stats()
    return {k[len("query_"):]: v for k, v in s.items()
            if k.startswith("query_")}


@pytest.mark.parametrize("scheme", SCHEMES)
def test_query_batch_equals_per_key_equals_sim(scheme):
    dev = _dev(scheme)
    sim = make_table(scheme, GEOM, ram_buffer_pct=10.0,
                     change_segment_pct=25.0)
    rng = np.random.default_rng(0)
    # data segment + overflow: overfill one device block (r=16) so the
    # excess spills to the overflow region after the merge
    hot = _same_block_keys(dev.cfg.pair, 3, 24)
    bulk = rng.integers(0, 400, size=256)
    merged = np.concatenate([hot, hot[:8], bulk])        # some counts of 2
    dev.update(merged)
    dev.flush()
    assert dev.wear()["dropped"] == 0
    ov_resident = int(np.asarray(dev.state.ov_keys != -1).sum())
    assert ov_resident >= 8                               # spill really hit
    sim.insert_batch(merged)
    sim.finalize()
    # change segment / log: staged on device, never merged (MB merges at
    # once, which is that scheme's contract — no change segment to stage
    # into). store.drain() stages H_R on device *without* a merge.
    staged = np.arange(1000, 1020)
    dev.update(staged)
    dev.drain()
    sim.insert_batch(staged)
    if scheme != "MB":
        assert int(np.ravel(dev.state.log_ptr).sum()) > 0
    # RAM buffer H_R: buffered in the write engine, never dispatched
    buffered = np.arange(5000, 5012)
    dev.update(buffered)
    assert dev.buffered_entries == len(buffered)
    sim.insert_batch(buffered)
    # the query set crosses every region + absent keys + duplicates
    absent = np.asarray([777777, 888888])
    q = np.concatenate([hot, staged, buffered, bulk[:64], absent, hot[:5]])
    per_key = np.asarray([dev.query(int(k)) for k in q])
    batched = dev.query_batch(q)
    oracle = np.asarray([sim.query(int(k)) for k in q])
    np.testing.assert_array_equal(batched, per_key)
    np.testing.assert_array_equal(batched, oracle)
    # dedup happened: the duplicated hot[:5] keys cost no extra probes
    st = _qstats(dev)
    assert st["unique_keys"] < st["keys"]
    dev.close()


@pytest.mark.parametrize("scheme", SCHEMES)
def test_empty_padding_keys_return_zero(scheme):
    dev = _dev(scheme)
    dev.update(np.asarray([5, 5, 9]))
    got = dev.query_batch(np.asarray([5, -1, 9, -1]))
    assert list(got) == [2, 0, 1, 0]


def test_hot_cache_serves_repeats_and_invalidates_on_update():
    dev = _dev("MDB-L")
    keys = np.arange(50, 80)
    dev.update(keys)
    dev.flush()
    first = dev.query_batch(keys)
    st = _qstats(dev)
    assert st["cache_hits"] == 0 and st["device_queries"] == len(keys)
    dispatches = st["device_dispatches"]
    second = dev.query_batch(keys)                 # all from the hot cache
    np.testing.assert_array_equal(first, second)
    st = _qstats(dev)
    assert st["cache_hits"] == len(keys)
    assert st["device_dispatches"] == dispatches   # no device traffic
    # a buffered (unflushed) write must be visible immediately: the H_R
    # overlay serves it on top of the still-valid hot cache, with no new
    # device traffic
    dev.update(np.asarray([50]))
    inval_before = st["invalidations"]
    assert dev.query(50) == 2
    assert _qstats(dev)["device_dispatches"] == dispatches
    # the store-driven drain invalidates the hot cache; the re-probe
    # then sees the device-resident count
    dev.drain()
    assert _qstats(dev)["invalidations"] > inval_before
    assert dev.query(50) == 2
    assert _qstats(dev)["device_queries"] > len(keys)  # really went back


def test_probe_distance_batch_aggregation():
    dev = _dev("MDB-L")
    keys = np.arange(200, 232)
    dev.update(keys)
    dev.flush()
    dev.query_batch(keys)
    st = _qstats(dev)
    # every resident key probes at least 1 slot (home, inclusive)
    assert st["probe_total"] >= st["device_queries"] >= len(keys)
    assert 1 <= st["probe_max"] <= dev.cfg.block_entries
    # cache hits add nothing to the probe ledger
    dev.query_batch(keys)
    assert _qstats(dev)["probe_total"] == st["probe_total"]


def test_engine_chunking_single_compiled_shape():
    dev = _dev("MDB-L", query_chunk=16)   # force multi-chunk dispatch
    keys = np.arange(3000, 3100)          # 100 unique keys -> 7 chunks
    dev.update(keys)
    dev.flush()
    got = dev.query_batch(keys)
    np.testing.assert_array_equal(got, np.ones(len(keys), np.int64))
    assert _qstats(dev)["device_dispatches"] == -(-len(keys) // 16)


def test_engine_hot_capacity_zero_disables_cache():
    """hot_capacity=0 must mean 'no caching', not a crash on first miss."""
    dev = _dev("MDB-L", hot_capacity=0)
    dev.update(np.arange(8))
    for _ in range(2):
        np.testing.assert_array_equal(dev.query_batch(np.arange(8)),
                                      np.ones(8, np.int64))
    assert _qstats(dev)["cache_hits"] == 0


def test_engine_state_free_reads():
    """query_batch must not mutate table state (reads are functional)."""
    dev = _dev("MDB")
    dev.update(np.arange(10))
    dev.drain()                 # stage H_R so the device has the counts
    stats_before = dev.wear()
    eng = BatchedQueryEngine(dev.cfg, chunk=8)
    out = eng.query_batch(dev.state, np.arange(10))
    np.testing.assert_array_equal(out, np.ones(10, np.int64))
    assert dev.wear() == stats_before


def test_sim_query_batch_matches_engine_empty_semantics():
    """The sim's API twin must agree on EMPTY padding: count 0, no cost."""
    sim = make_table("MDB-L", GEOM, ram_buffer_pct=10.0,
                     change_segment_pct=25.0)
    sim.insert_batch(np.asarray([5, 5, 9]))
    before = sim.qstats.queries
    got = sim.query_batch(np.asarray([5, -1, 9, -1]))
    assert list(got) == [2, 0, 1, 0]
    assert sim.qstats.queries == before + 2   # EMPTY keys never costed


def test_prefix_cache_refcounts_through_engine():
    """Serving path: acquire/insert/release refcounts stay exact through
    the engine's hot cache (every _bump invalidates), and eviction only
    frees zero-refcount blocks while pinned blocks survive."""
    from repro.serving.prefix_cache import PrefixKVCache

    cache = PrefixKVCache(block_tokens=2, capacity_blocks=4, q_log2=10,
                          r_log2=6, scheme="MDB-L")
    toks_a = [1, 2, 3, 4]                      # two whole blocks
    n, _, pinned_a = cache.acquire(toks_a)
    assert n == 0 and pinned_a == []           # cold cache: nothing to pin
    ins_a = cache.insert(toks_a, value="A", slicer=lambda v, n: v)
    assert len(ins_a) == 2
    keys_a = cache.block_keys(toks_a)
    assert list(cache._count(keys_a)) == [1, 1]
    # a second request over the same prefix bumps the refcounts
    n, val, pinned_a2 = cache.acquire(toks_a)
    assert n == 4 and val == "A"
    assert list(cache._count(keys_a)) == [2, 2]   # stale cache would say 1
    # fill the store with one released (zero-ref) block and one pinned
    # one; eviction must take the zero-ref block and spare the pinned
    cache.release(ins_a)                       # A held only by the acquire
    p10 = cache.insert([10, 11], value="v10", slicer=lambda v, n: v)
    cache.release(p10)                         # v10 refcount -> 0
    cache.insert([12, 13], value="v12", slicer=lambda v, n: v)  # store: 4
    cache.insert([14, 15], value="v14", slicer=lambda v, n: v)  # evicts
    assert cache.evictions >= 1
    assert cache.block_keys([10, 11])[0] not in cache.store  # zero-ref gone
    assert set(keys_a) <= set(cache.store)     # pinned blocks survived
    cache.release(pinned_a2)
    assert list(cache._count(keys_a)) == [0, 0]
    s = cache.stats()
    assert s["dropped"] == 0 and s["query_batches"] > 0
