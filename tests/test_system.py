"""End-to-end behaviour: tiny LM pretrain run through the resilient
runtime (loss ↓, checkpoints land, resume works) and the paper's TF-IDF
workload through the full pipeline."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import CheckpointManager, latest_step
from repro.configs import get_config
from repro.core import TableGeometry
from repro.core.tfidf import TfIdfPipeline
from repro.data import CorpusStats, LoaderConfig, SyntheticCorpus, make_batch
from repro.models import model as M
from repro.optim import AdamWConfig, adamw_init
from repro.runtime import ResilientTrainer
from repro.launch import steps as steps_mod


@pytest.mark.slow
def test_end_to_end_training_with_failure(tmp_path):
    cfg = get_config("llama32_3b", tiny=True)
    corpus = SyntheticCorpus(num_docs=64, mean_doc_len=48,
                             vocab_size=cfg.vocab_size, seed=5)
    lcfg = LoaderConfig(corpus=corpus, seq_len=32, global_batch=4,
                        microbatches=2, vocab_size=cfg.vocab_size)
    opt_cfg = AdamWConfig()
    train_step = jax.jit(steps_mod.make_train_step(
        cfg, opt_cfg, steps_mod.TrainHyper(peak_lr=3e-3, warmup_steps=5,
                                           total_steps=60)))
    params = M.init_params(jax.random.key(0), cfg)
    opt = adamw_init(opt_cfg, params)

    losses = []

    def step_fn(state, step):
        batch = jax.tree.map(jnp.asarray, make_batch(lcfg, step))
        params, opt = state["params"], state["opt"]
        params, opt, metrics = train_step(params, opt, batch)
        losses.append(float(metrics["loss"]))
        return {"params": params, "opt": opt}, metrics

    trainer = ResilientTrainer(
        step_fn, CheckpointManager(tmp_path, every_steps=10),
        inject_failure_at=23)
    state, report = trainer.run({"params": params, "opt": opt},
                                num_steps=50)
    assert report.restarts == 1
    assert latest_step(tmp_path) is not None
    first = np.mean(losses[:5])
    last = np.mean(losses[-5:])
    assert last < first, (first, last)


def test_tfidf_end_to_end_all_schemes():
    """The paper's workload: stream a corpus, interleave queries, compare
    schemes' answers (identical counts, different I/O profiles)."""
    geom = TableGeometry(num_blocks=8, pages_per_block=8, entries_per_page=32)
    corpus = SyntheticCorpus(num_docs=40, mean_doc_len=120, vocab_size=3000,
                             seed=9)
    pipes = {s: TfIdfPipeline(geom, scheme=s, ram_buffer_pct=2.0,
                              change_segment_pct=25.0, track_df=False)
             for s in ("MB", "MDB", "MDB-L")}
    for doc in corpus:
        for p in pipes.values():
            p.add_document_ids(doc)
    for p in pipes.values():
        p.finalize()
    # identical logical answers
    probe = corpus.doc_tokens(0)[:20]
    answers = {s: [p.term_table.query(int(t)) for t in probe]
               for s, p in pipes.items()}
    assert answers["MB"] == answers["MDB"] == answers["MDB-L"]
    # different I/O profiles, same ordering as the paper
    cleans = {s: p.term_table.stats()["cleans"] for s, p in pipes.items()}
    assert cleans["MB"] >= cleans["MDB"] >= cleans["MDB-L"]


def test_corpus_stats_filter_plugs_into_loader():
    st = CorpusStats.create(q_log2=14, r_log2=9)
    corpus = SyntheticCorpus(num_docs=32, mean_doc_len=64, vocab_size=4000,
                             seed=2)
    for d in corpus:
        st.ingest(d)
    st.flush()
    lcfg = LoaderConfig(corpus=corpus, seq_len=64, global_batch=4,
                        microbatches=1, vocab_size=4000,
                        doc_filter=st.doc_filter(0.0))
    batch = make_batch(lcfg, 0)
    assert batch["tokens"].shape == (1, 4, 64)
