"""flashlint: the contract checker (ISSUE 6 tentpole).

Acceptance: every seeded fixture violation (one file per rule, under
``tests/lint_fixtures/src``) is flagged with its rule id and file:line,
the real tree lints clean, the CLI fails closed on empty input, and
suppression comments work."""
import re
from pathlib import Path

import pytest

from repro.analysis import flashlint

REPO = Path(__file__).resolve().parents[1]
FIXTURES = REPO / "tests" / "lint_fixtures" / "src"
TREE = [REPO / "src", REPO / "tests", REPO / "benchmarks",
        REPO / "examples"]


@pytest.mark.parametrize("rule", ["FL001", "FL002", "FL003", "FL004",
                                  "FL005", "FL006"])
def test_each_fixture_trips_exactly_its_rule(rule):
    fixture = FIXTURES / f"{rule.lower()}_bad.py"
    vs = flashlint.lint_file(fixture)
    assert vs, f"{fixture.name} should trip {rule}"
    assert {v.rule for v in vs} == {rule}
    assert all(v.line > 0 for v in vs)
    # the formatted line carries file:line:col + the rule id
    assert re.match(rf".*{rule.lower()}_bad\.py:\d+:\d+: {rule} ",
                    vs[0].format())


def test_fl004_serving_scope():
    """The scheduler (trace-replay feeder threads) is allow-listed; every
    other serving file still trips FL004 (PR 9 satellite)."""
    fixture = FIXTURES / "serving" / "trace_bad.py"
    vs = flashlint.lint_file(fixture)
    assert {v.rule for v in vs} == {"FL004"}
    sched = REPO / "src" / "repro" / "serving" / "scheduler.py"
    assert "threading" in sched.read_text()
    assert [v for v in flashlint.lint_file(sched) if v.rule == "FL004"] == []


def test_cli_nonzero_on_fixtures_zero_on_tree(capsys):
    rc = flashlint.main([str(FIXTURES)])
    out = capsys.readouterr()
    assert rc == 1
    for rule in ["FL001", "FL002", "FL003", "FL004", "FL005", "FL006"]:
        assert rule in out.out, f"{rule} missing from CLI output"
    assert re.search(r"fl001_bad\.py:\d+:\d+: FL001", out.out)


def test_tree_is_clean():
    """The ISSUE-6 acceptance gate, callable from pytest as well as the
    CI lint-contracts job."""
    violations, n_files = flashlint.lint_paths(TREE)
    assert n_files > 50
    assert violations == [], "\n".join(v.format() for v in violations)


def test_recursive_walk_skips_fixture_trees():
    files = list(flashlint.iter_py_files([REPO / "tests"]))
    assert files, "walk found no test files"
    assert not [f for f in files if "lint_fixtures" in f.parts]


def test_fail_closed_on_empty_input(tmp_path, capsys):
    assert flashlint.main([str(tmp_path)]) == 2
    assert "no Python files" in capsys.readouterr().err


def test_unknown_rule_select_rejected():
    with pytest.raises(ValueError, match="FL999"):
        flashlint.lint_file(FIXTURES / "fl001_bad.py", select=["FL999"])


def test_line_and_file_suppressions(tmp_path):
    mod = tmp_path / "src" / "mod.py"
    mod.parent.mkdir()
    mod.write_text(
        "import threading  # flashlint: disable=FL004\n"
        "import _thread\n")
    vs = flashlint.lint_file(mod)
    assert [v.rule for v in vs] == ["FL004"]
    assert vs[0].line == 2                    # only the unsuppressed one
    mod.write_text(
        "# flashlint: disable-file=FL004\n"
        "import threading\n"
        "import _thread\n")
    assert flashlint.lint_file(mod) == []


def test_src_scoping(tmp_path):
    """src-scoped rules stay quiet outside a src tree (tests and
    benchmarks legitimately construct engines and threads)."""
    mod = tmp_path / "helpers.py"
    mod.write_text("import threading\n")
    assert flashlint.lint_file(mod) == []


def test_syntax_error_is_a_violation(tmp_path):
    bad = tmp_path / "src" / "broken.py"
    bad.parent.mkdir()
    bad.write_text("def oops(:\n")
    vs = flashlint.lint_file(bad)
    assert [v.rule for v in vs] == ["FL000"]
