"""Mamba-2 SSD: chunked algorithm vs naive sequential recurrence oracle."""
import dataclasses
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import ssm as S


def _naive_ssd(params, cfg, u):
    """Token-by-token recurrence (the SSM definition, fp32)."""
    b, l, _ = u.shape
    cache = S.init_ssm_cache(cfg, b, u.dtype)
    outs = []
    for t in range(l):
        y, cache = S.ssd_decode(params, cfg, u[:, t:t + 1, :], cache)
        outs.append(y)
    return jnp.concatenate(outs, axis=1)


def test_chunked_equals_recurrent():
    cfg = dataclasses.replace(get_config("mamba2_2p7b", tiny=True),
                              dtype="float32")
    key = jax.random.key(0)
    params = S.init_ssm(key, cfg, jnp.float32)
    u = jax.random.normal(jax.random.key(1), (2, 64, cfg.d_model),
                          jnp.float32) * 0.5
    y_chunk = S.ssd_full(params, cfg, u)
    y_naive = _naive_ssd(params, cfg, u)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_naive),
                               rtol=2e-4, atol=2e-4)


def test_prefill_state_continues_decode():
    cfg = dataclasses.replace(get_config("mamba2_2p7b", tiny=True),
                              dtype="float32")
    params = S.init_ssm(jax.random.key(0), cfg, jnp.float32)
    u = jax.random.normal(jax.random.key(2), (1, 96, cfg.d_model),
                          jnp.float32) * 0.5
    y_full = S.ssd_full(params, cfg, u)
    y_pre, cache = S.ssd_full(params, cfg, u[:, :64, :], return_cache=True)
    np.testing.assert_allclose(np.asarray(y_pre), np.asarray(y_full[:, :64]),
                               rtol=1e-5, atol=1e-5)
    y = y_pre
    for t in range(64, 96):
        yt, cache = S.ssd_decode(params, cfg, u[:, t:t + 1, :], cache)
        np.testing.assert_allclose(np.asarray(yt[:, 0]),
                                   np.asarray(y_full[:, t]),
                                   rtol=3e-4, atol=3e-4,
                                   err_msg=f"t={t}")


def test_state_decay_bounded():
    """Stability: with A<0 the state norm must stay bounded."""
    cfg = dataclasses.replace(get_config("mamba2_2p7b", tiny=True),
                              dtype="float32")
    params = S.init_ssm(jax.random.key(0), cfg, jnp.float32)
    cache = S.init_ssm_cache(cfg, 1, jnp.float32)
    u = jax.random.normal(jax.random.key(3), (1, 1, cfg.d_model))
    norms = []
    for _ in range(200):
        _, cache = S.ssd_decode(params, cfg, u, cache)
        norms.append(float(jnp.linalg.norm(cache.state)))
    assert norms[-1] < 10 * max(norms[:20])


def test_conv_split_identical():
    """§Perf opt: per-stream convs == fused concat conv."""
    import dataclasses as dc
    cfg = dc.replace(get_config("mamba2_2p7b", tiny=True), dtype="float32")
    cfg_split = dc.replace(cfg, opt_conv_split=True)
    params = S.init_ssm(jax.random.key(0), cfg, jnp.float32)
    u = jax.random.normal(jax.random.key(4), (2, 64, cfg.d_model),
                          jnp.float32)
    y1 = S.ssd_full(params, cfg, u)
    y2 = S.ssd_full(params, cfg_split, u)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-6, atol=1e-6)
