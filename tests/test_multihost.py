"""Multi-process sharded FlashStore tests (ISSUE 10, DESIGN.md §14).

Each test launches ``helpers/multihost_main.py`` as the *parent* role,
which spawns two ``jax.distributed``-joined worker processes (4 virtual
CPU devices each → one 8-device mesh over a localhost coordinator) plus,
where a reference exists, the single-host 8-virtual-device store on the
same stream. The parent compares dumped query results against the sim
oracle / Counter truth and prints ``MULTIHOST_OK``.

Runs inside tier-1 and in the dedicated ``tests-multihost`` CI lane
(2 processes × 4 devices, faulthandler armed against collective hangs).
"""
import os
import subprocess
import sys
from pathlib import Path

import pytest

HELPERS = Path(__file__).parent / "helpers"


def _run(scenario, scheme="MDB-L", timeout=1200, tmp_path=None):
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)       # children pin their own device count
    return subprocess.run(
        [sys.executable, str(HELPERS / "multihost_main.py"),
         "--role", "parent", "--scenario", scenario, "--scheme", scheme,
         "--tmp", str(tmp_path)],
        capture_output=True, text=True, timeout=timeout, env=env)


@pytest.mark.slow
@pytest.mark.parametrize("scheme", ["MB", "MDB", "MDB-L"])
def test_multihost_matches_single_host_and_oracle(scheme, tmp_path):
    """2-process × 4-device mesh produces bit-identical final contents
    (universe-wide query results) vs the single-host sharded store and
    the sim oracle on the same ±Δ stream; owner-aligned waves carry
    nothing on either host."""
    r = _run("equivalence", scheme=scheme, tmp_path=tmp_path)
    assert "MULTIHOST_OK" in r.stdout, r.stdout[-4000:] + r.stderr[-4000:]


@pytest.mark.slow
def test_partition_heat_is_topology_invariant(tmp_path):
    """The same skewed trace yields identical per-block heat — and
    therefore the same eviction victims — on 1-host-8-shard and
    2-process-4-shard meshes."""
    r = _run("heat", tmp_path=tmp_path)
    assert "MULTIHOST_OK" in r.stdout, r.stdout[-4000:] + r.stderr[-4000:]
    assert "HEAT_MATCH" in r.stdout


@pytest.mark.slow
def test_per_host_wals_restore_independently(tmp_path):
    """Each process replays its own WAL after a crash; the collective
    replay drain reassembles the exact pre-crash global contents."""
    r = _run("wal_restore", tmp_path=tmp_path)
    assert "MULTIHOST_OK" in r.stdout, r.stdout[-4000:] + r.stderr[-4000:]


@pytest.mark.slow
def test_handoff_is_process_count_aware(tmp_path):
    """A departed store's WAL replayed by two surviving processes lands
    exactly once: disjoint round-robin slices, totals match truth."""
    r = _run("handoff", tmp_path=tmp_path)
    assert "MULTIHOST_OK" in r.stdout, r.stdout[-4000:] + r.stderr[-4000:]
    assert "HANDOFF0" in r.stdout and "HANDOFF1" in r.stdout
