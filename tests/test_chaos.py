"""Chaos lane: SIGKILL mid-drain, restore, assert nothing lost or
double-applied.

A subprocess (tests/helpers/chaos_store_main.py) ingests a seeded stream
and is SIGKILLed by the WAL's ``after_sync`` hook at a chosen seal event
— after the sealed chunk's records are durable, before its drain runs.
The parent then opens a fresh store over the same WAL, ``restore()``s,
and checks the recovered contents three ways:

* against the recomputed truth (per-key delta sums of batches
  1..kill_after) — zero *lost* deltas,
* the full keyspace, so keys the victim never wrote read 0 — zero
  *double-applied* or phantom deltas,
* against a sim-oracle store fed the same batches — backend-independent
  bit-equality.

The snapshot variant rotates the WAL mid-stream so restore must stitch
snapshot + replayed tail.
"""
import importlib.util
import os
import subprocess
import sys
from collections import Counter
from pathlib import Path

import numpy as np
import pytest

HELPER = Path(__file__).resolve().parent / "helpers" / "chaos_store_main.py"

_spec = importlib.util.spec_from_file_location("chaos_store_main", HELPER)
chaos = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(chaos)

# sharded runs MB / MDB-L only (no per-shard MDB build; DESIGN.md §8)
CASES = ([("sim", s) for s in ("MB", "MDB", "MDB-L")]
         + [("device", s) for s in ("MB", "MDB", "MDB-L")]
         + [("sharded", s) for s in ("MB", "MDB-L")])

# seeded kill points: vary where in the stream the crash lands so the
# lane covers early / mid / late WAL tails, deterministically per scheme
KILL_AFTER = {"MB": 2, "MDB": 3, "MDB-L": 4}


def _run_victim(wal_path, backend, scheme, kill_after, extra=()):
    env = dict(os.environ, JAX_PLATFORMS="cpu")
    env.pop("XLA_FLAGS", None)  # victim always runs single-device
    return subprocess.run(
        [sys.executable, str(HELPER), backend, scheme, str(wal_path),
         str(kill_after), *map(str, extra)],
        capture_output=True, text=True, timeout=600, env=env)


def _truth(n_batches):
    """Per-key delta sums of batches 1..n_batches over the full keyspace."""
    sums = Counter()
    for toks, dels in chaos.make_batches()[:n_batches]:
        for t, d in zip(toks.tolist(), dels.tolist()):
            sums[t] += d
    keys = np.arange(chaos.KEYSPACE, dtype=np.int64)
    return keys, np.array([sums[int(k)] for k in keys], np.int64)


def _oracle(scheme, n_batches, keys):
    """Sim store fed the same stream: the backend-independent reference."""
    st = chaos.open_store("sim", scheme, None)
    try:
        for toks, dels in chaos.make_batches()[:n_batches]:
            st.update(toks, dels)
        st.flush(wait=True)
        return np.asarray(st.query_batch(keys), np.int64)
    finally:
        st.close()


@pytest.mark.parametrize("backend,scheme", CASES,
                         ids=[f"{b}-{s}" for b, s in CASES])
def test_sigkill_between_seal_and_drain(tmp_path, backend, scheme):
    kill_after = KILL_AFTER[scheme]
    wal = tmp_path / "chaos.wal"
    proc = _run_victim(wal, backend, scheme, kill_after)
    assert proc.returncode == -9, (
        f"victim survived (rc={proc.returncode})\n"
        f"stdout: {proc.stdout}\nstderr: {proc.stderr}")
    assert "NEVER_KILLED" not in proc.stdout
    assert wal.exists() and wal.stat().st_size > 8  # magic + records

    st = chaos.open_store(backend, scheme, wal)
    try:
        rep = st.restore()
        assert rep.snapshot_step is None            # no snapshot was taken
        assert rep.tail_discarded_bytes == 0        # kill was post-fsync
        assert rep.records_replayed >= kill_after
        keys, want = _truth(kill_after)
        got = np.asarray(st.query_batch(keys), np.int64)
        np.testing.assert_array_equal(got, want)
        np.testing.assert_array_equal(got, _oracle(scheme, kill_after, keys))
        # the store is live after restore: it can keep ingesting
        st.update(np.array([7, 7], np.int64))
        st.flush(wait=True)
        assert int(st.query_batch(np.array([7], np.int64))[0]) == want[7] + 2
    finally:
        if not st._closed:
            st.close()


@pytest.mark.parametrize("backend", ["device", "sharded"])
def test_sigkill_after_midstream_snapshot(tmp_path, backend):
    """Snapshot rotates the WAL mid-stream; the crash lands two batches
    later, so recovery = snapshot(1..2) + WAL replay(3..4)."""
    scheme, snap_after, kill_after = "MDB-L", 2, 4
    wal = tmp_path / "chaos.wal"
    snap = tmp_path / "snap"
    proc = _run_victim(wal, backend, scheme, kill_after,
                       extra=(snap, snap_after))
    assert proc.returncode == -9, (
        f"victim survived (rc={proc.returncode})\n"
        f"stdout: {proc.stdout}\nstderr: {proc.stderr}")

    st = chaos.open_store(backend, scheme, wal)
    try:
        rep = st.restore(snap)
        assert rep.snapshot_step is not None
        assert rep.records_replayed > 0             # batches 3..4 tail
        keys, want = _truth(kill_after)
        got = np.asarray(st.query_batch(keys), np.int64)
        np.testing.assert_array_equal(got, want)
        np.testing.assert_array_equal(got, _oracle(scheme, kill_after, keys))
    finally:
        if not st._closed:
            st.close()
