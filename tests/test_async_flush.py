"""Async double-buffered flush (DESIGN.md §9) — the PR-5 tentpole.

Every backend drains H_R on a background worker while ingest keeps
filling the fresh active buffer. These tests pin the contract:

* equivalence — the async path answers exactly what the synchronous
  path (and the sim oracle) answers, at every lifecycle point;
* read-your-writes *during* a drain — queries overlay the sealed
  in-flight chunk (proved deterministically by parking the worker);
* ``flush(wait=True)`` is a durability barrier; ``wait=False`` returns
  with the drain in flight;
* ``close()`` / ``__exit__`` with a drain in flight join the worker,
  complete the barrier and stay idempotent (ISSUE-5 satellite);
* a no-op flush — nothing buffered, in flight or staged — never
  invalidates the hot-key cache (ISSUE-5 satellite regression);
* the ``overlap_us``/``stall_us`` ledgers and the epoch fence in the
  query engine;
* a mixed-op concurrency stress stream per backend×scheme — the CI
  ``tests-stress`` lane runs this file 3× under distinct
  ``PYTHONHASHSEED``s with a faulthandler timeout, so flush/invalidate
  races surface as dumps, not silent flakes.
"""
import threading
from collections import Counter

import numpy as np
import pytest

from repro.core import table_jax as tj
from repro.core.store import FlashStore, FlushDispatcher

SCHEMES = ["MB", "MDB", "MDB-L"]


def _cfg(scheme, **kw):
    base = dict(q_log2=10, r_log2=6, scheme=scheme, log_capacity=1 << 9,
                cs_partitions=4, max_updates_per_block=1 << 6,
                overflow_capacity=1 << 9)
    base.update(kw)
    return tj.FlashTableConfig(**base)


def _shard_count() -> int:
    import jax
    n = jax.device_count()
    return n if n & (n - 1) == 0 else 1


def _open(backend, scheme="MDB-L", **kw):
    if backend == "sim":
        return FlashStore.open(backend="sim", scheme=scheme, **kw)
    if backend == "device":
        kw.setdefault("chunk", 128)
        kw.setdefault("flush_threshold", 256)
        return FlashStore.open(_cfg(scheme), backend="device", **kw)
    kw.setdefault("shard_chunk", 128)
    kw.setdefault("flush_threshold", 200)
    return FlashStore.open(_cfg(scheme), backend="sharded",
                           num_shards=_shard_count(), **kw)


def _park_worker(store):
    """Deterministically hold the store's drain worker busy: the next
    sealed drain queues behind the returned event. Single worker, so
    nothing drains until the event is set."""
    ev = threading.Event()
    store._b._disp._pool.submit(ev.wait)
    return ev


# ---------------------------------------------------------------------------
# equivalence: async ≡ sync ≡ sim oracle
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend", ["sim", "device", "sharded"])
def test_async_equals_sync_equals_oracle(backend):
    """One skewed ±Δ stream with interleaved reads and wait=False
    flushes: the async store must answer exactly what the synchronous
    store answers, at every probe point and at the end."""
    rng = np.random.default_rng(3)
    toks = rng.integers(0, 400, size=3000).astype(np.int64)
    probes = np.arange(0, 450)           # resident + absent keys
    stores = {"async": _open(backend, async_flush=True),
              "sync": _open(backend, async_flush=False)}
    answers = {name: [] for name in stores}
    for name, st in stores.items():
        for i in range(0, toks.size, 250):
            st.update(toks[i:i + 250])
            if i % 500 == 0:
                answers[name].append(st.query(probes))   # mid-stream RYW
            if i == 1000:
                st.flush(wait=False)     # merge while ingest continues
        dec = np.unique(toks)[::5]
        st.update(dec, np.full(dec.size, -1, np.int64))
        answers[name].append(st.query(probes))
        st.flush()
        answers[name].append(st.query(probes))
        assert st.buffered_entries == 0
        st.close()
    for a, b in zip(answers["async"], answers["sync"]):
        np.testing.assert_array_equal(a, b)
    # independent truth for the final state
    truth = Counter(toks.tolist())
    for k in dec.tolist():
        truth[k] -= 1
    want = np.array([truth.get(int(k), 0) for k in probes])
    np.testing.assert_array_equal(answers["async"][-1], want)


@pytest.mark.parametrize("scheme", SCHEMES)
def test_async_per_scheme_final_contents(scheme):
    """Every scheme survives threshold-triggered async drains."""
    rng = np.random.default_rng(11)
    toks = rng.integers(0, 300, size=2000).astype(np.int64)
    truth = Counter(toks.tolist())
    keys = np.array(sorted(truth))
    want = np.array([truth[int(k)] for k in keys])
    with _open("device", scheme=scheme) as st:
        for i in range(0, toks.size, 100):
            st.update(toks[i:i + 100])
        np.testing.assert_array_equal(st.query(keys), want)
        st.flush()
        np.testing.assert_array_equal(st.query(keys), want)


# ---------------------------------------------------------------------------
# read-your-writes while a drain is in flight
# ---------------------------------------------------------------------------
def test_query_overlays_inflight_chunk():
    """Park the worker, seal a buffer, query: the sealed (in-flight,
    undrained) entries must still be visible — the overlay covers both
    buffers. After release + barrier the same counts come from device."""
    st = _open("device", flush_threshold=10_000)
    st.update(np.arange(100))            # active H_R
    ev = _park_worker(st)
    try:
        st.drain(wait=False)             # seals; drain queued behind ev
        assert st._b.writer._inflight is not None
        st.update(np.arange(50, 150))    # refills the fresh active buffer
        got = st.query(np.arange(150))   # overlay: active + in-flight
        want = np.concatenate([np.ones(50), 2 * np.ones(50), np.ones(50)])
        np.testing.assert_array_equal(got, want)
    finally:
        ev.set()
    st.flush()                           # barrier: everything on device
    assert st._b.writer._inflight is None
    np.testing.assert_array_equal(st.query(np.arange(150)), want)
    st.close()


def test_sharded_query_overlays_inflight_partitions():
    st = _open("sharded", flush_threshold=10_000)
    keys = np.arange(200)
    st.update(keys)
    ev = _park_worker(st)
    try:
        st.drain(wait=False)
        assert any(b is not None for b in st._b._inflight)
        np.testing.assert_array_equal(st.query(keys), np.ones(keys.size))
    finally:
        ev.set()
    st.flush()
    np.testing.assert_array_equal(st.query(keys), np.ones(keys.size))
    st.close()


def test_flush_wait_false_then_barrier():
    st = _open("device", flush_threshold=10_000)
    st.update(np.arange(500))
    ev = _park_worker(st)
    try:
        st.flush(wait=False)             # returns with the drain queued
        assert st.buffered_entries == 500   # sealed, not yet durable
    finally:
        ev.set()
    st.flush(wait=True)                  # the durability barrier
    assert st.buffered_entries == 0
    s = st.stats()
    assert s["write_flushes"] == 1 and s["write_merges"] >= 1
    st.close()


# ---------------------------------------------------------------------------
# close()/__exit__ with a drain in flight (ISSUE-5 satellite)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("how", ["close", "exit"])
def test_close_joins_inflight_drain(how):
    """close()/__exit__ during a drain must join the worker, complete
    the durability barrier, and stay idempotent."""
    st = _open("device", flush_threshold=10_000)
    st.update(np.arange(300))
    ev = _park_worker(st)
    done = threading.Event()

    def closer():
        if how == "close":
            st.close()
        else:
            st.__exit__(None, None, None)
        done.set()

    st.drain(wait=False)                 # in-flight, parked behind ev
    t = threading.Thread(target=closer)
    t.start()
    assert not done.wait(0.2)            # close really blocks on the drain
    ev.set()
    t.join(timeout=30)
    assert done.is_set() and st._closed
    w = st._b.writer
    assert w._inflight is None and w.buffered_entries == 0
    assert w.stats.flushes == 1 and w.stats.merges >= 1
    st.close()                           # idempotent
    st.__exit__(None, None, None)        # also idempotent post-close
    with pytest.raises(ValueError):
        st.update(np.asarray([1]))


def test_drain_error_surfaces_at_barrier_and_poisons():
    """A drain that dies on the worker re-raises at the next barrier,
    after which the store is poisoned: the undelivered sealed chunk is
    never silently dropped (reads keep overlaying it, writes fail
    loudly), and close() still joins the worker and ends closed."""
    from repro.core.store import DrainError
    st = _open("device", flush_threshold=10_000)
    st.update(np.arange(10))
    # poison the dispatch: donate the state out from under the engine
    tj.flush(st.cfg, st.state)
    st.drain(wait=False)
    with pytest.raises(DrainError, match="donated") as ei:
        st.flush(wait=True)
    # the barrier error names the sealed chunk (regression: it used to
    # re-raise the bare worker exception, losing which drain died) and
    # chains the worker-side traceback
    assert "hr-drain#1:10e" in str(ei.value)
    assert isinstance(ei.value.__cause__, RuntimeError)
    # the sealed chunk is still the read overlay, not silently dropped
    assert st.buffered_entries == 10
    with pytest.raises(RuntimeError, match="poisoned"):
        st.flush()
    # close releases the worker despite the poison, and stays idempotent
    with pytest.raises(RuntimeError, match="poisoned"):
        st.close()
    assert st._closed and st._b._disp._closed
    st.close()
    with pytest.raises(ValueError):
        st.update(np.asarray([1]))


def test_assert_live_guard_rejects_stale_state():
    """The segments.assert_live donation guard fails loudly (not as an
    opaque XLA deleted-buffer error) when a drain would start from an
    already-donated state."""
    cfg = _cfg("MDB-L")
    state = tj.init(cfg)
    state2 = tj.update(cfg, state, np.arange(8, dtype=np.int32))
    with pytest.raises(RuntimeError, match="donated"):
        tj.assert_live(state)            # flashlint: disable=FL002 (the point)
    tj.assert_live(state2)               # live state passes


# ---------------------------------------------------------------------------
# no-op flush must not invalidate (ISSUE-5 satellite regression)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend", ["device", "sharded"])
def test_noop_flush_skips_invalidation(backend):
    """flush() with nothing buffered, in flight or staged must leave the
    hot-key cache alone: previously some backends invalidated anyway,
    evicting every hot key for no reason."""
    st = _open(backend, flush_threshold=10_000)
    keys = np.arange(40)
    st.update(keys)
    st.flush()                           # real flush: drains + merges
    st.query(keys)                       # warm the hot cache
    s0 = st.stats()
    st.flush()                           # H_R empty, nothing staged
    st.flush()                           # and again
    s1 = st.stats()
    assert s1["query_invalidations"] == s0["query_invalidations"]
    assert s1["write_merges"] == s0["write_merges"]   # no device merge
    st.query(keys)                       # served from the still-warm cache
    s2 = st.stats()
    assert s2["query_cache_hits"] > s1["query_cache_hits"]
    assert s2["query_device_queries"] == s1["query_device_queries"]
    st.close()


def test_adopted_staged_state_still_merges():
    """An adopted state may arrive with a staged (unmerged) change
    segment: the first flush must really merge it — the no-op path is
    only for provably-clean engines (regression: _staged_dirty used to
    initialize False for state= adoption, silently skipping the
    pre-PR5 unconditional merge)."""
    cfg = _cfg("MDB-L")
    staged = tj.update(cfg, tj.init(cfg), np.arange(40, dtype=np.int32))
    assert int(np.ravel(staged.log_ptr).sum()) > 0    # really staged
    st = FlashStore.open(cfg, backend="device", state=staged)
    st.flush()
    assert int(np.ravel(st.state.log_ptr).sum()) == 0  # log compacted
    assert st.stats()["write_merges"] == 1
    st.flush()                                         # now provably clean
    assert st.stats()["write_merges"] == 1             # no-op path again
    np.testing.assert_array_equal(st.query(np.arange(40)), np.ones(40))
    st.close()


def test_background_merge_not_duplicated():
    """flush(wait=False) followed by flush() must not schedule a second
    device merge: the no-op decision settles the pending job first
    instead of reading a stale _staged_dirty mid-merge."""
    st = _open("device", flush_threshold=10_000)
    st.update(np.arange(64))
    st.flush(wait=False)
    st.flush()
    st.flush()
    assert st.stats()["write_merges"] == 1
    st.close()


def test_sim_noop_flush_is_free():
    st = _open("sim")
    st.update(np.arange(20))
    st.flush()
    before = st.stats()
    st.flush()
    after = st.stats()
    for k in ("cleans", "block_ops", "page_ops", "merges", "stages"):
        assert after[k] == before[k]
    st.close()


# ---------------------------------------------------------------------------
# ledgers and fencing
# ---------------------------------------------------------------------------
def test_overlap_and_stall_ledgers():
    """Synchronous drains charge their full duration to stall_us and
    never to overlap_us; async drains run on the worker (overlap_us) and
    only residual barrier waits stall."""
    toks = np.random.default_rng(0).integers(0, 5000, 6000)
    sync = _open("device", async_flush=False, flush_threshold=512)
    for i in range(0, toks.size, 200):
        sync.update(toks[i:i + 200])
    sync.flush()
    ss = sync.stats()
    assert ss["write_stall_us"] > 0 and ss["write_overlap_us"] == 0
    sync.close()
    a = _open("device", async_flush=True, flush_threshold=512)
    for i in range(0, toks.size, 200):
        a.update(toks[i:i + 200])
    a.flush()
    sa = a.stats()
    assert sa["write_overlap_us"] > 0
    a.close()


def test_dispatcher_serializes_and_propagates():
    """FlushDispatcher unit contract: jobs run in order on one worker,
    wait() re-raises as a DrainError naming the failing chunk and
    chaining the worker's original exception, close() is idempotent."""
    from repro.core.store import DrainError
    d = FlushDispatcher(enabled=True)
    order = []
    d.submit(lambda: order.append(1))
    d.submit(lambda: order.append(2))    # waits job 1 out first
    d.wait()
    assert order == [1, 2]

    def boom():
        raise ValueError("drain died")

    d.submit(boom, label="hr-drain#3:17e")
    with pytest.raises(DrainError, match="drain died") as ei:
        d.wait()
    # the barrier error names the job and its sealed chunk, and chains
    # the worker's original exception with its traceback (raise ... from)
    assert "job #2" in str(ei.value)
    assert "hr-drain#3:17e" in str(ei.value)
    assert type(ei.value.__cause__) is ValueError
    assert ei.value.__cause__.__traceback__ is not None
    d.close()
    d.close()
    with pytest.raises(ValueError):
        d.submit(lambda: None)


def test_query_engine_epoch_fence():
    """An invalidation landing mid-lookup drops that lookup's cache
    inserts (they may predate the drain) — the fence the async store
    relies on (DESIGN.md §9)."""
    from repro.core.query_engine import BatchedQueryEngine
    cfg = _cfg("MDB-L")
    state = tj.update(cfg, tj.init(cfg), np.arange(8, dtype=np.int32))
    eng = BatchedQueryEngine(cfg, chunk=8)
    lookup = eng._lookup

    def racing_lookup(st, q):
        out = lookup(st, q)
        eng.invalidate()                 # a drain lands mid-lookup
        return out

    eng._lookup = racing_lookup
    out = eng.query_batch(state, np.arange(8))
    np.testing.assert_array_equal(out, np.ones(8))
    assert eng._hot == {}                # fenced: nothing cached
    assert eng.stats.fenced == 8
    eng._lookup = lookup
    eng.query_batch(state, np.arange(8))
    assert len(eng._hot) == 8            # un-raced lookups cache again
    assert eng.stats.fenced == 8


# ---------------------------------------------------------------------------
# the stress lane (CI tests-stress: 3 × PYTHONHASHSEED, faulthandler)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend,scheme", [("sim", "MDB-L"),
                                            ("device", "MB"),
                                            ("device", "MDB"),
                                            ("device", "MDB-L"),
                                            ("sharded", "MDB-L")])
def test_concurrency_stress(backend, scheme):
    """Hammer the store with a mixed op stream — tiny thresholds so
    drains are constantly in flight, queries and wait=False flushes
    interleaved, ±Δ churn — and verify read-your-writes at every probe
    plus exact final contents. Any flush/invalidate race shows up as a
    wrong count or (under the CI faulthandler lane) a hang dump."""
    rng = np.random.default_rng(29)
    kw = (dict(flush_threshold=64, chunk=64) if backend == "device" else
          dict(flush_threshold=48, shard_chunk=64) if backend == "sharded"
          else dict(flush_threshold=64))
    st = _open(backend, scheme=scheme, **kw)
    truth = Counter()
    probes = np.arange(0, 220)
    for step in range(60):
        toks = rng.integers(0, 200, size=rng.integers(1, 120))
        st.update(toks)
        truth.update(toks.tolist())
        op = step % 6
        if op == 0:
            alive = np.array([k for k, v in truth.items() if v > 0])
            dec = rng.choice(alive, size=min(5, alive.size), replace=False)
            st.update(dec, np.full(dec.size, -1, np.int64))
            truth.subtract(dec.tolist())
        elif op == 1:
            st.flush(wait=False)
        elif op == 2:
            st.drain(wait=False)
        elif op == 3:
            want = np.array([truth.get(int(k), 0) for k in probes])
            np.testing.assert_array_equal(st.query(probes), want,
                                          err_msg=f"step {step}")
    st.flush()
    want = np.array([truth.get(int(k), 0) for k in probes])
    np.testing.assert_array_equal(st.query(probes), want)
    assert st.buffered_entries == 0
    if backend != "sim":
        assert st.wear()["dropped"] == 0
    st.close()
