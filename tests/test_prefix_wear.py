"""Wear-aware prefix-cache eviction (ROADMAP item, ISSUE-4 satellite).

The policy: among zero-refcount blocks, evict the one whose refcount key
lives in the *hottest* change-segment partition (per-merge ``TableStats``
wear deltas + pending write pressure, tracked by the store). Its eventual
re-insertion then dirties a partition that is merged anyway; first-fit
instead keeps re-dirtying cold partitions, buying extra block rewrites.

The trace models a serving loop: a stream of fresh prefixes keeps one
partition hot (prefill pins are held across the periodic checkpoint
flush, so their ±1 pairs split and reach the device), while a small cold
working set in another partition is re-acquired in short hit windows
(±1 cancels in H_R — a resident cold block costs zero device traffic).
Identical traces, the only degree of freedom is the eviction choice.
"""
import numpy as np
import pytest

from repro.serving.prefix_cache import PrefixKVCache

IDENT = lambda v, n: v
ROUNDS = 12


def _prefixes_in_partition(cache, part, n, start=0):
    """Token blocks whose chain-hash key maps to change partition ``part``."""
    out, t = [], start
    bpp = cache.cfg.blocks_per_partition
    while len(out) < n:
        toks = [t, t + 1]
        key = cache.block_keys(toks)[0]
        if int(cache.cfg.pair.s(key)) // bpp == part:
            out.append(toks)
        t += 2
    return out


def _run_trace(policy):
    cache = PrefixKVCache(block_tokens=2, capacity_blocks=4,
                          q_log2=10, r_log2=6, scheme="MDB",
                          cs_partitions=4, eviction=policy)
    cold = _prefixes_in_partition(cache, part=1, n=3)
    fresh = _prefixes_in_partition(cache, part=0, n=ROUNDS + 1, start=10_000)
    # setup: the cold working set becomes resident, zero-ref
    pins = []
    for toks in cold:
        pins += cache.insert(toks, tuple(toks), slicer=IDENT)
    cache._refs.flush()
    cache.release(pins)
    prev = []
    for r in range(ROUNDS):
        cache.release(prev)               # previous prefill finished
        # a fresh hot-partition prefix per round: capacity is full, so
        # each insert forces exactly the policy's eviction choice
        cur = list(cache.insert(fresh[r], tuple(fresh[r]), slicer=IDENT))
        n, _v, p = cache.acquire(cold[r % 3])
        if n:
            cache.release(p)              # hit: short pin, cancels in H_R
        else:                             # miss: re-prefill, long pin
            cur += cache.insert(cold[r % 3], tuple(cold[r % 3]),
                                slicer=IDENT)
        cache._refs.flush()               # serving checkpoint
        prev = cur
    return cache


@pytest.mark.parametrize("policy", ["wear", "first_fit"])
def test_refcounts_stay_exact_under_either_policy(policy):
    cache = _run_trace(policy)
    s = cache.stats()
    assert s["dropped"] == 0
    # every block still resident is zero-ref (all pins released or held
    # exactly once by `prev`, which the trace left holding one round)
    keys = list(cache.store.keys())
    counts = cache._count(keys)
    assert set(np.asarray(counts).tolist()) <= {0, 1}


def test_wear_aware_eviction_beats_first_fit_on_skewed_trace():
    """The ROADMAP acceptance: identical skewed traces, strictly lower
    accounted wear (tile_stores = the paper's cleans analogue) and fewer
    cold-set misses under the wear-aware policy."""
    wear = _run_trace("wear").stats()
    fifo = _run_trace("first_fit").stats()
    assert wear["tile_stores"] < fifo["tile_stores"], (wear, fifo)
    # the mechanism: first-fit keeps evicting the cold working set, so it
    # pays re-insertions (misses) that re-dirty the cold partition
    assert wear["misses"] < fifo["misses"]
    assert wear["evictions"] <= fifo["evictions"]


def test_partition_heat_reflects_pending_pressure():
    """The heat feed itself: a partition with buffered H_R traffic is
    hotter than an untouched one."""
    cache = PrefixKVCache(block_tokens=2, capacity_blocks=8,
                          q_log2=10, r_log2=6, scheme="MDB",
                          cs_partitions=4, eviction="wear")
    hot = _prefixes_in_partition(cache, part=2, n=1)[0]
    coldkey = _prefixes_in_partition(cache, part=3, n=1)[0]
    cache.insert(hot, "h", slicer=IDENT)          # +1 buffered
    k_hot = cache.block_keys(hot)[0]
    k_cold = cache.block_keys(coldkey)[0]
    heat = cache._refs.partition_heat(np.asarray([k_hot, k_cold]))
    assert heat[0] > heat[1] == 0.0


def test_first_fit_policy_still_available_and_validated():
    with pytest.raises(ValueError):
        PrefixKVCache(eviction="lru")
    c = PrefixKVCache(block_tokens=2, capacity_blocks=2, q_log2=10,
                      r_log2=6, eviction="first_fit")
    assert c.stats()["eviction"] == "first_fit"
