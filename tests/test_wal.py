"""Crash-recoverable FlashStore (ISSUE 7): WAL format + replay,
snapshot/restore across every backend, poison recovery, and the unified
snapshot surfaces (CorpusStats, PrefixKVCache, CheckpointManager
quiesce, elastic WAL handoff).

The recovery contract under test (DESIGN.md §11): everything sealed
before a crash is recoverable — seal records are fsync'd before the
drain dispatches — and replay is idempotent (restore twice, restore
after a clean close, restore over a snapshot all agree)."""
import os

import numpy as np
import pytest

from repro.core import table_jax as tj
from repro.core.store import FlashStore
from repro.core.wal import MAGIC, SEAL, WriteAheadLog, read_wal

SCHEMES = {"sim": ["MB", "MDB", "MDB-L"],
           "device": ["MB", "MDB", "MDB-L"],
           "sharded": ["MB", "MDB-L"]}


def _cfg(scheme, **kw):
    base = dict(q_log2=10, r_log2=6, scheme=scheme, log_capacity=1 << 9,
                cs_partitions=4, max_updates_per_block=1 << 6,
                overflow_capacity=1 << 9)
    base.update(kw)
    return tj.FlashTableConfig(**base)


def _shard_count() -> int:
    import jax
    n = jax.device_count()
    return n if n & (n - 1) == 0 else 1


def _open(backend, scheme="MDB-L", **kw):
    kw.setdefault("flush_threshold", 10_000)   # no surprise auto-drains
    if backend == "sim":
        return FlashStore.open(backend="sim", scheme=scheme, **kw)
    if backend == "device":
        kw.setdefault("chunk", 128)
        return FlashStore.open(_cfg(scheme), backend="device", **kw)
    kw.setdefault("shard_chunk", 128)
    return FlashStore.open(_cfg(scheme), backend="sharded",
                           num_shards=_shard_count(), **kw)


# ---------------------------------------------------------------------------
# the log itself
# ---------------------------------------------------------------------------
def test_wal_roundtrip_and_watermarks(tmp_path):
    p = tmp_path / "w.wal"
    w = WriteAheadLog(p)
    s1 = w.append_seal(0, np.array([3, 1, 2]), np.array([1, 1, -1]))
    s2 = w.append_seal(1, np.array([9]), np.array([5]))
    w.sync()
    assert (s1, s2) == (1, 2)
    assert w.last_seq == 2 and w.committed_seq == 0
    w.append_commit(0, s1)
    assert w.committed_seq == 1          # s2 uncommitted blocks the prefix
    w.append_commit(1, s2)
    assert w.committed_seq == 2
    w.close()

    records, discarded = read_wal(p)
    assert discarded == 0
    kinds = [r.kind for r in records]
    assert kinds == [SEAL, SEAL, 2, 2]
    np.testing.assert_array_equal(records[0].keys, [3, 1, 2])
    np.testing.assert_array_equal(records[0].deltas, [1, 1, -1])
    assert records[1].part == 1

    # reopen resumes sequencing after the last intact record
    w2 = WriteAheadLog(p)
    assert w2.last_seq == 2 and w2.committed_seq == 2
    assert w2.append_seal(0, np.array([7]), np.array([1])) == 3
    w2.close()


def test_wal_missing_file_reads_empty_and_bad_magic_raises(tmp_path):
    assert read_wal(tmp_path / "nope.wal") == ([], 0)
    bad = tmp_path / "bad.wal"
    bad.write_bytes(b"NOTAWAL!" + b"\x00" * 32)
    with pytest.raises(ValueError, match="magic"):
        read_wal(bad)


def test_wal_torn_tail_discarded_loudly(tmp_path):
    """A crash mid-append leaves a non-record-aligned tail: the intact
    prefix survives, the tail is dropped with a warning, and reopening
    truncates so new appends land on a clean boundary."""
    p = tmp_path / "torn.wal"
    w = WriteAheadLog(p)
    w.append_seal(0, np.array([1, 2]), np.array([1, 1]))
    w.append_seal(0, np.array([3, 4, 5]), np.array([1, 1, 1]))
    w.sync()
    w.close()
    whole = p.read_bytes()
    p.write_bytes(whole[:-7])            # tear the last record's payload

    with pytest.warns(UserWarning, match="torn WAL tail"):
        records, discarded = read_wal(p)
    assert discarded > 0
    assert [r.seq for r in records] == [1]

    with pytest.warns(UserWarning, match="torn WAL tail"):
        w2 = WriteAheadLog(p)
    assert w2.last_seq == 1
    assert w2.append_seal(0, np.array([9]), np.array([1])) == 2
    w2.close()
    records, discarded = read_wal(p)     # clean again after truncation
    assert discarded == 0 and [r.seq for r in records] == [1, 2]


# ---------------------------------------------------------------------------
# restore: replay semantics + idempotence (ISSUE-7 satellite)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend", ["sim", "device", "sharded"])
def test_restore_without_snapshot_replays_wal(tmp_path, backend):
    wal = tmp_path / "s.wal"
    st = _open(backend, wal=wal)
    st.update(np.arange(100), np.ones(100, np.int64))
    st.drain(wait=True)
    st.update(np.arange(50), np.full(50, 2, np.int64))
    st.drain(wait=True)
    assert st.wal.last_seq >= 2
    st.close()                           # WAL survives a clean close

    st2 = _open(backend, wal=wal)
    rep = st2.restore()                  # no snapshot: fresh init + replay
    assert rep.snapshot_step is None
    assert rep.records_replayed >= 2
    assert rep.entries_replayed == 150
    assert int(st2.query(5)) == 3 and int(st2.query(75)) == 1

    rep2 = st2.restore()                 # idempotent: replays the same log
    assert rep2.entries_replayed == rep.entries_replayed
    assert int(st2.query(5)) == 3 and int(st2.query(75)) == 1
    st2.close()


@pytest.mark.parametrize("backend,scheme",
                         [(b, s) for b in SCHEMES for s in SCHEMES[b]])
def test_snapshot_restore_with_post_snapshot_wal(tmp_path, backend, scheme):
    """Snapshot rotates the WAL; deltas sealed afterwards replay on top
    of the restored snapshot — no lost and no double-applied chunks."""
    wal = tmp_path / "s.wal"
    snap = tmp_path / "snap"
    st = _open(backend, scheme=scheme, wal=wal)
    st.update(np.arange(100), np.ones(100, np.int64))
    st.drain(wait=True)
    st.snapshot(snap)
    assert os.path.getsize(wal) == len(MAGIC)    # rotated
    st.update(np.arange(30), np.full(30, 4, np.int64))
    st.drain(wait=True)
    st.close()

    st2 = _open(backend, scheme=scheme, wal=wal)
    rep = st2.restore(snap)
    assert rep.snapshot_step == 0
    assert rep.records_replayed >= 1 and rep.entries_replayed == 30
    assert int(st2.query(5)) == 5        # 1 from snapshot + 4 replayed
    assert int(st2.query(60)) == 1       # snapshot only
    st2.close()


def test_restore_after_clean_close_is_noop_replay(tmp_path):
    """snapshot() then close(): the WAL is empty, restore is a pure
    snapshot load — zero records replayed."""
    wal = tmp_path / "s.wal"
    snap = tmp_path / "snap"
    st = _open("sim", wal=wal)
    st.update(np.arange(40))
    st.snapshot(snap)
    st.close()

    st2 = _open("sim", wal=wal)
    rep = st2.restore(snap)
    assert rep.records_replayed == 0 and rep.entries_replayed == 0
    assert int(st2.query(7)) == 1
    st2.close()


def test_restore_clears_poison_and_rearms(tmp_path):
    """ISSUE-7 fix: a poisoned store (worker DrainError) used to stay
    wedged — every flush/close re-raised. restore() clears the poison,
    re-arms the dispatcher, and recovers the sealed chunk from the WAL
    (zero lost deltas), leaving the store fully usable."""
    from repro.core.store import DrainError
    wal = tmp_path / "s.wal"
    st = _open("device", wal=wal)
    st.update(np.arange(10))
    tj.flush(st.cfg, st.state)           # donate the state out: drain dies
    st.drain(wait=False)
    with pytest.raises(DrainError, match="donated"):
        st.flush(wait=True)
    with pytest.raises(RuntimeError, match="poisoned"):
        st.flush()                       # wedged: every drain path raises
    assert st._b.front.poisoned

    rep = st.restore()                   # same store object, in place
    assert rep.poison_cleared
    assert rep.entries_replayed == 10    # the poisoned chunk was logged
    assert not st._b.front.poisoned
    np.testing.assert_array_equal(st.query(np.arange(10)), np.ones(10))
    st.update(np.asarray([3]))           # usable again
    st.flush(wait=True)                  # fresh worker drains fine
    assert int(st.query(3)) == 2
    st.close()                           # clean close, no re-raise


def test_restore_reopens_a_closed_store(tmp_path):
    wal = tmp_path / "s.wal"
    st = _open("sim", wal=wal)
    st.update(np.arange(20))
    st.drain(wait=True)
    st.close()
    with pytest.raises(ValueError):
        st.update(np.asarray([1]))
    st.restore()                         # reopen + replay in place
    assert int(st.query(3)) == 1
    st.update(np.asarray([3]))           # WAL reopened: new seals log again
    st.drain(wait=True)
    assert st.wal.last_seq >= 2
    st.close()


# ---------------------------------------------------------------------------
# unified snapshot surface (ISSUE-7 satellite)
# ---------------------------------------------------------------------------
def test_corpus_stats_snapshot_roundtrip(tmp_path):
    from repro.data.stats import CorpusStats
    cs = CorpusStats(_cfg("MDB-L"), wal=tmp_path / "cs.wal")
    cs.ingest(np.arange(64))
    cs.ingest(np.arange(32))
    cs.snapshot(tmp_path / "snap")
    cs.store.close()

    cs2 = CorpusStats(_cfg("MDB-L"), wal=tmp_path / "cs.wal")
    rep = cs2.restore(tmp_path / "snap")
    assert (cs2.docs_seen, cs2.tokens_seen) == (2, 96)
    assert rep.meta["docs_seen"] == 2
    np.testing.assert_array_equal(cs2.counts(np.arange(32)), np.full(32, 2))
    np.testing.assert_array_equal(cs2.counts(np.arange(32, 64)), np.ones(32))
    cs2.store.close()


def test_prefix_cache_snapshot_roundtrip(tmp_path):
    from repro.serving.prefix_cache import PrefixKVCache
    c = PrefixKVCache(block_tokens=4, capacity_blocks=16)
    toks = list(range(12))
    keys = c.insert(toks, value={"kv": np.arange(3)},
                    slicer=lambda v, n: {"kv": v["kv"][: n // 4]})
    n, _val, pinned = c.acquire(toks)
    assert n == 12
    c.snapshot(tmp_path)
    c._refs.close()

    c2 = PrefixKVCache(block_tokens=4, capacity_blocks=16)
    c2.restore(tmp_path)
    assert set(c2.store) == set(keys)
    assert (c2.hits, c2.misses) == (c.hits, c.misses)
    n2, val2, _ = c2.acquire(toks)       # refcounts restored through store
    assert n2 == 12
    np.testing.assert_array_equal(val2["kv"], np.arange(3))
    counts = c2._refs.query_batch(np.asarray(keys, np.int64))
    assert (counts >= 1).all()           # insert+acquire pins survived
    c2._refs.close()


def test_checkpoint_manager_quiesce_joins_inflight_drain(tmp_path):
    """A registered store quiesce barrier means save()/emergency() never
    serialize while a background drain is mid-donation."""
    from repro.checkpoint.checkpoint import CheckpointManager
    st = _open("device")
    train_state = {"w": np.zeros(3)}     # the trainer's own pytree
    ck = CheckpointManager(tmp_path / "ck", every_steps=1, keep=2)
    ck.register_quiesce(st.quiesce)
    ck.register_quiesce(st.quiesce)      # idempotent registration
    assert len(ck._quiesce) == 1
    st.update(np.arange(200))
    st.drain(wait=False)                 # in flight on the worker
    ck.save(0, train_state, blocking=True)
    assert not st._b._disp.pending       # the save joined the drain
    st.update(np.arange(50))
    st.drain(wait=False)
    ck.emergency(1, train_state)         # best-effort path joins too
    assert not st._b._disp.pending
    assert (tmp_path / "ck" / "step_00000001").exists()
    st.close()


def test_resilient_trainer_registers_store_quiesce(tmp_path):
    from repro.checkpoint.checkpoint import CheckpointManager
    from repro.runtime.fault_tolerance import ResilientTrainer
    st = _open("sim")
    ck = CheckpointManager(tmp_path / "ck", every_steps=1)
    tr = ResilientTrainer(lambda s, i: (s, {"loss": 1.0}), ck, stores=(st,))
    assert st.quiesce in ck._quiesce and tr.stores == (st,)
    st.close()


# ---------------------------------------------------------------------------
# elastic WAL handoff (ISSUE-7 tentpole: departing shard re-owned)
# ---------------------------------------------------------------------------
def test_elastic_wal_handoff_reowns_departing_partitions(tmp_path):
    from repro.runtime.elastic import handoff_hr_partitions
    wal = tmp_path / "depart.wal"
    a = _open("sharded", wal=wal)
    toks = np.arange(200)
    a.update(toks, np.ones(200, np.int64))
    a.drain(wait=True)                   # sealed (logged) + drained
    a.close()                            # node "departs"; its WAL survives

    b = _open("sharded")                 # survivor: no snapshot of A
    n_rec, n_ent = handoff_hr_partitions(wal, b)
    assert n_rec >= 1 and n_ent == 200
    np.testing.assert_array_equal(b.query(toks), np.ones(200))

    # partition filter: replaying only shard 0's records yields exactly
    # the keys shard 0 owned in A's front
    c = _open("sharded")
    n_rec0, n_ent0 = handoff_hr_partitions(wal, c, shards=[0])
    owned0 = int((a._b.owner_of(toks) == 0).sum())
    assert n_ent0 == owned0
    assert int(c.query_batch(toks).sum()) == owned0
    b.close()
    c.close()
