"""Multi-device tests (subprocess with 8 virtual CPU devices, so the main
pytest process keeps its single-device view)."""
import os
import subprocess
import sys
from pathlib import Path

import pytest

HELPERS = Path(__file__).parent / "helpers"


def _run(script, *args, timeout=1200):
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    return subprocess.run(
        [sys.executable, str(HELPERS / script), *args],
        capture_output=True, text=True, timeout=timeout, env=env)


@pytest.mark.slow
def test_distributed_flash_table():
    r = _run("dist_table_main.py")
    assert "DIST_TABLE_OK" in r.stdout, r.stdout + r.stderr


@pytest.mark.slow
def test_elastic_reshard():
    r = _run("dist_train_main.py", "elastic")
    assert "ELASTIC_OK" in r.stdout, r.stdout + r.stderr


@pytest.mark.slow
@pytest.mark.parametrize("arch", [
    "granite_moe_1b", "phi35_moe_42b", "minicpm3_4b", "starcoder2_7b",
    "llama32_3b", "nemotron4_340b", "llava_next_mistral_7b", "mamba2_2p7b",
    "musicgen_large", "jamba15_large_398b"])
def test_sharded_train_and_decode(arch):
    r = _run("dist_train_main.py", arch)
    assert f"ARCH_OK {arch}" in r.stdout, r.stdout[-2000:] + r.stderr[-3000:]
