"""Unit + property tests for the two-level hash pair (paper §2, eq. 1-3)."""
import numpy as np
import pytest

from helpers.hypothesis_shim import given, settings, strategies as st

from repro.core.hashing import HashPair, Pow2Hash, hash_pair_for


def test_basic_ranges():
    p = hash_pair_for(num_blocks=7, block_entries=64)
    xs = np.arange(10_000, dtype=np.int64)
    g = p.g(xs)
    s = p.s(xs)
    assert g.min() >= 0 and g.max() < p.q
    assert s.min() >= 0 and s.max() < p.num_slots


@given(st.integers(0, 2**31 - 1), st.integers(1, 64), st.integers(1, 512))
@settings(max_examples=200, deadline=None)
def test_placement_property(x, nb, r):
    """Eq. (3): s(x) = g(x) div r — the slot's keys land in one block."""
    p = HashPair(q=nb * r, r=r)
    assert p.s(x) == p.g(x) // r
    assert r * p.s(x) <= p.g(x) < r * (p.s(x) + 1)


@given(st.integers(0, 2**31 - 1), st.integers(3, 12), st.integers(1, 8))
@settings(max_examples=200, deadline=None)
def test_pow2_placement_property(x, qlog, rlog):
    rlog = min(rlog, qlog)
    p = Pow2Hash(q_log2=qlog, r_log2=rlog)
    g, s = p.g(x), p.s(x)
    assert 0 <= g < p.q
    assert s == g >> rlog
    assert p.home_within_block(x) == g & (p.r - 1)


def test_pow2_matches_numpy_vectorized():
    p = Pow2Hash(q_log2=14, r_log2=8)
    xs = np.arange(5000, dtype=np.int32)
    g_vec = np.asarray(p.g(xs))
    for x in [0, 1, 17, 4999]:
        assert g_vec[x] == p.g(int(x))


def test_uniformity():
    """Hash should spread a contiguous key range over blocks evenly-ish."""
    p = Pow2Hash(q_log2=16, r_log2=10)
    xs = np.arange(100_000, dtype=np.int32)
    blocks = np.asarray(p.s(xs))
    counts = np.bincount(blocks, minlength=p.num_slots)
    mean = counts.mean()
    assert counts.max() < 2.0 * mean
    assert counts.min() > 0.3 * mean


def test_invalid_geometry():
    with pytest.raises(ValueError):
        HashPair(q=100, r=33)
    with pytest.raises(ValueError):
        Pow2Hash(q_log2=4, r_log2=6)
