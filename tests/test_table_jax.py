"""Device-resident JAX table: policies vs Counter, deltas, wear stats."""
import numpy as np
import jax.numpy as jnp
import pytest
from collections import Counter

from repro.core import table_jax as tj
from repro.core.hashing import Pow2Hash, filter_words_for
from repro.kernels.flash_hash import ops, ref

SCHEMES = ["MB", "MDB", "MDB-L"]


def _cfg(scheme, **overrides):
    kw = dict(q_log2=12, r_log2=8, scheme=scheme,
              log_capacity=1 << 12, cs_partitions=4,
              max_updates_per_block=1 << 8,
              overflow_capacity=1 << 10)
    kw.update(overrides)
    return tj.FlashTableConfig(**kw)


def _pad(arr, n, fill):
    out = np.full(n, fill, dtype=np.int64)
    out[:len(arr)] = arr
    return jnp.asarray(out, jnp.int32)


def _same_block_keys(pair, block, n, lo=0):
    """n distinct keys whose secondary hash lands in ``block``."""
    out = []
    x = lo
    while len(out) < n:
        if int(pair.s(x)) == block:
            out.append(x)
        x += 1
    return np.asarray(out, dtype=np.int64)


@pytest.mark.parametrize("scheme", SCHEMES)
def test_counts_vs_counter(scheme):
    cfg = _cfg(scheme)
    st = tj.init(cfg)
    rng = np.random.default_rng(0)
    toks = rng.integers(0, 1500, size=8192)
    truth = Counter(toks.tolist())
    for i in range(0, len(toks), 2048):
        st = tj.update(cfg, st, jnp.asarray(toks[i:i + 2048], jnp.int32))
    st = tj.flush(cfg, st)
    q = _pad(np.array(sorted(truth)), 2048, 0)
    cnt, _ = tj.lookup(cfg, st, q)
    got = dict(zip(map(int, q), map(int, cnt)))
    for k, c in truth.items():
        assert got[k] == c
    assert int(st.stats.dropped) == 0


@pytest.mark.parametrize("scheme", ["MDB", "MDB-L"])
def test_deletion_by_decrement(scheme):
    cfg = _cfg(scheme)
    st = tj.init(cfg)
    toks = jnp.asarray([10, 10, 10, 20], jnp.int32)
    st = tj.update(cfg, st, toks)
    st = tj.update(cfg, st, jnp.asarray([10, 20], jnp.int32),
                   deltas=jnp.asarray([-1, -1], jnp.int32))
    st = tj.flush(cfg, st)
    cnt, _ = tj.lookup(cfg, st, jnp.asarray([10, 20, 30, 10], jnp.int32))
    assert list(map(int, cnt)) == [2, 0, 0, 2]


@pytest.mark.parametrize("scheme", ["MDB", "MDB-L"])
def test_query_sees_staged_log(scheme):
    """Paper §2.7: queries consolidate data segment + change segment."""
    cfg = _cfg(scheme)
    st = tj.init(cfg)
    st = tj.update(cfg, st, jnp.asarray([7, 7, 8], jnp.int32))
    # no flush: counts still in the change segment
    assert int(st.stats.merges) == 0
    cnt, _ = tj.lookup(cfg, st, jnp.asarray([7, 8, 9, 7], jnp.int32))
    assert list(map(int, cnt)) == [2, 1, 0, 2]


def test_buffered_schemes_fewer_tile_rewrites_than_mb():
    """The paper's headline clean-count claim, on-device: on a skewed
    (zipf) workload both change-segment schemes rewrite data-segment tiles
    far less often than MB, which merges on every update."""
    rng = np.random.default_rng(1)
    toks = (rng.zipf(1.3, size=16384) % 1500).astype(np.int64)
    stores = {}
    for scheme in SCHEMES:
        cfg = _cfg(scheme)
        st = tj.init(cfg)
        for i in range(0, len(toks), 1024):
            st = tj.update(cfg, st, jnp.asarray(toks[i:i + 1024], jnp.int32))
        st = tj.flush(cfg, st)
        stores[scheme] = int(st.stats.tile_stores)
        assert int(st.stats.dropped) == 0
    assert stores["MDB"] < stores["MB"]
    assert stores["MDB-L"] < stores["MB"]
    assert stores["MB"] > 2 * stores["MDB-L"]


def test_merge_records_only_dirty_tiles():
    """A merge whose staged keys hit one block must not charge
    ``num_blocks`` tile stores (the dirty-block path, not full-grid)."""
    cfg = _cfg("MDB-L")
    pair = cfg.pair
    keys = _same_block_keys(pair, 3, 20)
    st = tj.init(cfg)
    st = tj.update(cfg, st, jnp.asarray(keys, jnp.int32))
    st = tj.flush(cfg, st)
    assert int(st.stats.merges) == 1
    assert int(st.stats.tile_stores) == 1          # one dirty block
    assert int(st.stats.tile_loads) == 1
    cnt, _ = tj.lookup(cfg, st, jnp.asarray(keys[:16], jnp.int32))
    assert all(int(c) == 1 for c in cnt)


def test_mdb_partition_merge_stores_exactly_k():
    """Acceptance: filling one CS partition drains only its k blocks."""
    cfg = _cfg("MDB", q_log2=10, r_log2=6, log_capacity=256,
               cs_partitions=4, max_updates_per_block=64,
               overflow_capacity=256)
    k = cfg.blocks_per_partition
    part_cap = cfg.partition_capacity
    keys = _same_block_keys(cfg.pair, 1, part_cap + 8)  # block 1 → partition 0
    st = tj.init(cfg)
    st = tj.update(cfg, st, jnp.asarray(keys[:part_cap - 4], jnp.int32))
    assert int(st.stats.merges) == 0
    before = int(st.stats.tile_stores)
    st = tj.update(cfg, st, jnp.asarray(keys[part_cap - 4:], jnp.int32))
    assert int(st.stats.merges) == 1
    assert int(st.stats.tile_stores) - before == k
    cnt, _ = tj.lookup(cfg, st, jnp.asarray(keys, jnp.int32))
    assert all(int(c) == 1 for c in cnt)
    assert int(st.stats.dropped) == 0


def test_merge_dirty_matches_ref():
    """ops.merge_dirty over a dirty-first block permutation must agree
    with the pure-jnp oracle's full-grid merge."""
    pair = Pow2Hash(q_log2=10, r_log2=7)
    n_b, r = pair.num_slots, pair.r
    rng = np.random.default_rng(2)
    tk = jnp.full((n_b, r), ref.EMPTY, jnp.int32)
    tc = jnp.zeros((n_b, r), jnp.int32)
    toks = jnp.asarray(rng.integers(0, 500, size=512), jnp.int32)
    keys, cnts = ops.accumulate(toks)
    # oracle path: bucket by block, full-grid reference merge
    uk, uc, _, _, _ = ops.bucket_updates(pair, keys, cnts, 64)
    want_k, want_c, _, _ = ref.merge_ref(pair, tk, tc, uk, uc)
    # dirty path: dirty-first permutation grid, rows in grid order
    valid = keys != ref.EMPTY
    blk = jnp.where(valid, pair.s(keys), 0).astype(jnp.int32)
    dirty = jnp.zeros((n_b,), jnp.int32).at[blk].add(
        valid.astype(jnp.int32)) > 0
    perm = jnp.argsort(jnp.where(dirty, 0, 1), stable=True).astype(jnp.int32)
    inv = jnp.zeros((n_b,), jnp.int32).at[perm].set(
        jnp.arange(n_b, dtype=jnp.int32))
    rows = jnp.where(valid, inv[blk], n_b).astype(jnp.int32)
    duk, duc, _, _, _ = ops.bucket_rows(rows, keys, cnts, n_b, 64)
    tf = jnp.zeros((n_b, filter_words_for(r)), jnp.uint32)
    got_k, got_c, _, _, _ = ops.merge_dirty(pair, tk, tc, tf, perm, duk, duc)
    np.testing.assert_array_equal(np.asarray(want_k), np.asarray(got_k))
    np.testing.assert_array_equal(np.asarray(want_c), np.asarray(got_c))


def test_stage_oversized_chunk_keeps_carry():
    """Regression (log corruption): after a forced merge leaves n_carry
    entries at the log head, a chunk with ``chunk > log_capacity -
    n_carry`` used to be written through a clamped dynamic_update_slice,
    silently overwriting the carried entries. The stage path must instead
    merge repeatedly until the chunk fits."""
    cfg = _cfg("MDB-L", q_log2=8, r_log2=4, log_capacity=32,
               max_updates_per_block=4, overflow_capacity=64)
    pair = cfg.pair
    keys = _same_block_keys(pair, 0, 44)  # all hash to block 0 → heavy carry
    st = tj.init(cfg)
    st = tj.update(cfg, st, jnp.asarray(keys[:28], jnp.int32))
    assert int(st.stats.merges) == 0 and int(st.log_ptr) == 28
    # 16 more: forces a merge; max_u=4 leaves n_carry=24 > 32-16, so the
    # old single-merge path would clamp and clobber 8 carried entries.
    st = tj.update(cfg, st, jnp.asarray(keys[28:44], jnp.int32))
    assert int(st.stats.merges) >= 2  # merged repeatedly until it fit
    st = tj.flush(cfg, st)
    cnt, _ = tj.lookup(cfg, st, jnp.asarray(keys, jnp.int32))
    assert list(map(int, cnt)) == [1] * 44
    assert int(st.stats.dropped) == 0


def test_mdb_hot_block_pressure_drains_without_loss():
    """Regression: under hot-block pressure a partition drain can leave
    carry such that a chunk still does not fit; the stage path must keep
    draining (like MDB-L's loop-until-fits), not drop counts after one
    bounded retry."""
    cfg = _cfg("MDB", q_log2=8, r_log2=4, log_capacity=32,
               cs_partitions=4, max_updates_per_block=2,
               overflow_capacity=512)
    keys = _same_block_keys(cfg.pair, 0, 40)  # all → partition 0
    st = tj.init(cfg)
    for i in range(0, 40, 8):
        st = tj.update(cfg, st, jnp.asarray(keys[i:i + 8], jnp.int32))
    st = tj.flush(cfg, st)
    cnt, _ = tj.lookup(cfg, st, jnp.asarray(keys, jnp.int32))
    assert list(map(int, cnt)) == [1] * 40
    assert int(st.stats.dropped) == 0


def test_empty_flush_is_free():
    """flush() with nothing staged must not run (or count) a merge."""
    for scheme in SCHEMES:
        cfg = _cfg(scheme)
        st = tj.flush(cfg, tj.init(cfg))
        assert int(st.stats.merges) == 0, scheme
        assert int(st.stats.tile_stores) == 0, scheme


def test_mb_carry_is_merged_not_dropped():
    """Updates beyond a tile's max_u used to be silently discarded on the
    MB path; they must be merged (and surfaced in stats.carried)."""
    cfg = _cfg("MB", q_log2=8, r_log2=4, max_updates_per_block=4,
               overflow_capacity=64)
    keys = _same_block_keys(cfg.pair, 2, 12)
    st = tj.init(cfg)
    st = tj.update(cfg, st, jnp.asarray(keys, jnp.int32))
    assert int(st.stats.carried) > 0      # capacity pressure is observable
    cnt, _ = tj.lookup(cfg, st, jnp.asarray(keys, jnp.int32))
    assert list(map(int, cnt)) == [1] * 12
    assert int(st.stats.dropped) == 0


def test_scan_segment_tail_not_double_counted():
    """Regression: with a segment capacity that is not a multiple of the
    scan chunk, ``dynamic_slice`` clamps the last chunk's start, so the
    tail scan used to re-read (and double-count) the overlap with the
    previous chunk. The segment must be padded/masked instead."""
    seg_k = jnp.arange(10, dtype=jnp.int32)
    seg_c = jnp.ones(10, jnp.int32)
    got = tj._scan_segment(seg_k, seg_c, jnp.arange(10, dtype=jnp.int32),
                           chunk=4)
    assert list(map(int, got)) == [1] * 10


def test_lookup_non_power_of_two_overflow_capacity():
    """End-to-end regression: overflow entries past the clamped-chunk
    boundary (capacity 1100, chunk 1024 → overlap [76, 1024)) must count
    once. 156 same-block keys → 140 overflow residents on a 16-entry
    block, positions 0..139 spanning the old double-count window."""
    cfg = _cfg("MB", q_log2=8, r_log2=4, max_updates_per_block=512,
               overflow_capacity=1100)
    keys = _same_block_keys(cfg.pair, 0, 156)
    st = tj.init(cfg)
    st = tj.update(cfg, st, jnp.asarray(keys, jnp.int32))
    assert int(st.ov_ptr) == 156 - cfg.block_entries  # 140 in overflow
    cnt, _ = tj.lookup(cfg, st, jnp.asarray(keys, jnp.int32))
    assert list(map(int, cnt)) == [1] * 156
    assert int(st.stats.dropped) == 0


def test_lookup_non_multiple_log_capacity():
    """Same regression on the change segment: a staged (unflushed) MDB-L
    log with capacity 1500 puts entries in the clamped overlap window
    [476, 1024); every staged key must count exactly once."""
    cfg = _cfg("MDB-L", log_capacity=1500)
    st = tj.init(cfg)
    keys = jnp.arange(1, 601, dtype=jnp.int32)
    st = tj.update(cfg, st, keys)
    assert int(st.stats.merges) == 0 and int(st.log_ptr) == 600
    cnt, _ = tj.lookup(cfg, st, keys)
    assert list(map(int, cnt)) == [1] * 600


def test_lookup_empty_padding_returns_zero():
    """EMPTY query lanes are padding: (0, 0), under every scheme."""
    for scheme in SCHEMES:
        cfg = _cfg(scheme)
        st = tj.update(cfg, tj.init(cfg), jnp.asarray([3, 3, 4], jnp.int32))
        cnt, dist = tj.lookup(cfg, st,
                              jnp.asarray([3, tj.EMPTY, 4], jnp.int32))
        assert list(map(int, cnt)) == [2, 0, 1], scheme
        assert int(dist[1]) == 0, scheme


def test_invalid_scheme_rejected():
    with pytest.raises(ValueError):
        tj.FlashTableConfig(scheme="MDB-X")
    with pytest.raises(ValueError):
        tj.FlashTableConfig(scheme="MDB", cs_partitions=7)  # 7 ∤ 64


def test_load_factor():
    cfg = _cfg("MB")
    st = tj.init(cfg)
    st = tj.update(cfg, st, jnp.asarray(np.arange(2048), jnp.int32))
    lf = float(tj.load_factor(cfg, st))
    assert 0.45 < lf < 0.55
