"""Device-resident JAX table: policies vs Counter, deltas, wear stats."""
import numpy as np
import jax.numpy as jnp
import pytest
from collections import Counter

from repro.core import table_jax as tj


def _cfg(scheme):
    return tj.FlashTableConfig(q_log2=12, r_log2=8, scheme=scheme,
                               log_capacity=1 << 12,
                               max_updates_per_block=1 << 8,
                               overflow_capacity=1 << 10)


def _pad(arr, n, fill):
    out = np.full(n, fill, dtype=np.int64)
    out[:len(arr)] = arr
    return jnp.asarray(out, jnp.int32)


@pytest.mark.parametrize("scheme", ["MB", "MDB-L"])
def test_counts_vs_counter(scheme):
    cfg = _cfg(scheme)
    st = tj.init(cfg)
    rng = np.random.default_rng(0)
    toks = rng.integers(0, 1500, size=8192)
    truth = Counter(toks.tolist())
    for i in range(0, len(toks), 2048):
        st = tj.update(cfg, st, jnp.asarray(toks[i:i + 2048], jnp.int32))
    st = tj.flush(cfg, st)
    q = _pad(np.array(sorted(truth)), 2048, 0)
    cnt, _ = tj.lookup(cfg, st, q)
    got = dict(zip(map(int, q), map(int, cnt)))
    for k, c in truth.items():
        assert got[k] == c
    assert int(st.stats.dropped) == 0


def test_deletion_by_decrement():
    cfg = _cfg("MDB-L")
    st = tj.init(cfg)
    toks = jnp.asarray([10, 10, 10, 20], jnp.int32)
    st = tj.update(cfg, st, toks)
    st = tj.update(cfg, st, jnp.asarray([10, 20], jnp.int32),
                   deltas=jnp.asarray([-1, -1], jnp.int32))
    st = tj.flush(cfg, st)
    cnt, _ = tj.lookup(cfg, st, jnp.asarray([10, 20, 30, 10], jnp.int32))
    assert list(map(int, cnt)) == [2, 0, 0, 2]


def test_query_sees_staged_log():
    """Paper §2.7: queries consolidate data segment + change segment."""
    cfg = _cfg("MDB-L")
    st = tj.init(cfg)
    st = tj.update(cfg, st, jnp.asarray([7, 7, 8], jnp.int32))
    # no flush: counts still in the log
    assert int(st.stats.merges) == 0
    cnt, _ = tj.lookup(cfg, st, jnp.asarray([7, 8, 9, 7], jnp.int32))
    assert list(map(int, cnt)) == [2, 1, 0, 2]


def test_mdbl_fewer_tile_rewrites_than_mb():
    """The paper's clean-count result, on-device: MDB-L buffers in the log
    so the data segment is rewritten ~log_cap/flush_size× less often."""
    rng = np.random.default_rng(1)
    toks = rng.integers(0, 1000, size=16384)
    stores = {}
    for scheme in ["MB", "MDB-L"]:
        cfg = _cfg(scheme)
        st = tj.init(cfg)
        for i in range(0, len(toks), 1024):
            st = tj.update(cfg, st, jnp.asarray(toks[i:i + 1024], jnp.int32))
        st = tj.flush(cfg, st)
        stores[scheme] = int(st.stats.tile_stores)
    assert stores["MB"] > 2 * stores["MDB-L"]


def test_load_factor():
    cfg = _cfg("MB")
    st = tj.init(cfg)
    st = tj.update(cfg, st, jnp.asarray(np.arange(2048), jnp.int32))
    lf = float(tj.load_factor(cfg, st))
    assert 0.45 < lf < 0.55
