"""CorpusStats: the flash-hash table as the data layer's stats engine."""
import numpy as np
from collections import Counter

from repro.data import CorpusStats, SyntheticCorpus


def _stats():
    return CorpusStats.create(q_log2=14, r_log2=9)


def test_counts_after_ingest():
    st = _stats()
    rng = np.random.default_rng(0)
    all_toks = []
    for _ in range(4):
        toks = rng.integers(0, 800, size=1024)
        all_toks.extend(toks.tolist())
        st.ingest(toks)
    st.flush()
    truth = Counter(all_toks)
    keys = np.array(sorted(truth))
    got = st.counts(keys)
    for k, c in zip(keys, got):
        assert truth[int(k)] == int(c)


def test_tfidf_weights_ordering():
    st = _stats()
    toks = np.array([1] * 500 + [2] * 5)
    st.ingest(toks)
    st.flush()
    w = st.tfidf_weights(np.array([1, 2]))
    assert w[0] < w[1]  # frequent token → lower IDF


def test_doc_filter():
    st = _stats()
    corpus = SyntheticCorpus(num_docs=30, mean_doc_len=128,
                             vocab_size=2000, seed=1)
    for d in corpus:
        st.ingest(d)
    st.flush()
    scores = [st.doc_score(corpus.doc_tokens(i)) for i in range(10)]
    thr = sorted(scores)[5]
    flt = st.doc_filter(thr)
    kept = [flt(corpus.doc_tokens(i)) for i in range(10)]
    assert 3 <= sum(kept) <= 7  # threshold splits the docs


def test_expert_counting():
    st = _stats()
    st.ingest_expert_counts(layer=3, counts=np.array([5, 0, 2, 1]))
    st.ingest_expert_counts(layer=3, counts=np.array([1, 1, 0, 0]))
    st.ingest_expert_counts(layer=7, counts=np.array([9, 9, 9, 9]))
    st.flush()
    got3 = st.expert_counts(3, 4)
    got7 = st.expert_counts(7, 4)
    np.testing.assert_array_equal(got3, [6, 1, 2, 1])
    np.testing.assert_array_equal(got7, [9, 9, 9, 9])
