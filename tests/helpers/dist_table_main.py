"""Subprocess helper: distributed flash-hash table on 8 virtual devices."""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))
import sys
from collections import Counter
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[2] / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import distributed as D
from repro.core import table_jax as tj


def main():
    mesh = jax.make_mesh((8,), ("model",))
    # log must absorb one full a2a delivery: num_shards × bucket_cap
    cfg = D.ShardedTableConfig(
        local=tj.FlashTableConfig(q_log2=10, r_log2=7, scheme="MDB-L",
                                  log_capacity=1 << 14,
                                  max_updates_per_block=1 << 7,
                                  overflow_capacity=1 << 9),
        num_shards=8, bucket_cap=1 << 9)
    state = D.init_global(cfg)
    from repro.core.distributed import state_pspec
    from jax.sharding import NamedSharding
    sharded = jax.device_put(
        state, jax.tree.map(
            lambda s: NamedSharding(mesh, s), state_pspec("model"),
            is_leaf=lambda s: hasattr(s, "_normalized_spec")
            or type(s).__name__ == "PartitionSpec"))
    upd = D.make_update_fn(cfg, mesh, "model")
    look = D.make_lookup_fn(cfg, mesh, "model")

    rng = np.random.default_rng(0)
    toks = rng.integers(0, 5000, size=8 * 2048)
    truth = Counter(toks.tolist())
    with mesh:
        state2, ncarry = upd(sharded, jnp.asarray(toks, jnp.int32))
        q = np.array(sorted(truth))[:1024]
        q = np.pad(q, (0, 1024 - len(q) % 1024 if len(q) % 1024 else 0))
        cnt = look(state2, jnp.asarray(q, jnp.int32))
    got = dict(zip(map(int, q), map(int, np.asarray(cnt))))
    bad = sum(1 for k in got if truth.get(k, 0) != got[k] and k != -1)
    # duplicate padded keys map to the same count — tolerate none wrong
    print("BAD", bad, "CARRY", int(ncarry.sum()) if hasattr(ncarry, "sum")
          else int(ncarry))
    assert bad == 0, f"{bad} mismatches"
    print("DIST_TABLE_OK")


if __name__ == "__main__":
    main()
