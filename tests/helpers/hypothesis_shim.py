"""Hypothesis, or a deterministic stand-in when it is not installed.

The tier-1 environment does not guarantee ``hypothesis``; property tests
must still collect and run. Import ``given/settings/strategies`` from this
module instead of ``hypothesis`` — when the real library is present it is
used verbatim, otherwise a tiny deterministic fallback generates a fixed
set of examples per strategy (boundary values first, then seeded pseudo-
random draws). The fallback covers exactly the strategy surface the test
suite uses: ``integers``, ``tuples``, ``lists``.
"""
from __future__ import annotations

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings, strategies  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    import functools
    import inspect
    import random

    HAVE_HYPOTHESIS = False
    _FALLBACK_EXAMPLES = 50

    class _Strategy:
        def example(self, rng: random.Random, i: int):
            raise NotImplementedError

    class _Integers(_Strategy):
        def __init__(self, lo: int, hi: int):
            self.lo, self.hi = lo, hi

        def example(self, rng, i):
            if i == 0:
                return self.lo
            if i == 1:
                return self.hi
            return rng.randint(self.lo, self.hi)

    class _Tuples(_Strategy):
        def __init__(self, *elems):
            self.elems = elems

        def example(self, rng, i):
            return tuple(e.example(rng, i) for e in self.elems)

    class _Lists(_Strategy):
        def __init__(self, elem, min_size=0, max_size=10):
            self.elem = elem
            self.min_size = min_size
            self.max_size = max_size

        def example(self, rng, i):
            if i == 0:
                n = self.min_size
            elif i == 1:
                n = self.max_size
            else:
                n = rng.randint(self.min_size, self.max_size)
            return [self.elem.example(rng, i) for _ in range(n)]

    class _StrategiesModule:
        @staticmethod
        def integers(min_value: int, max_value: int) -> _Strategy:
            return _Integers(min_value, max_value)

        @staticmethod
        def tuples(*elems: _Strategy) -> _Strategy:
            return _Tuples(*elems)

        @staticmethod
        def lists(elem: _Strategy, min_size: int = 0,
                  max_size: int = 10) -> _Strategy:
            return _Lists(elem, min_size, max_size)

    strategies = _StrategiesModule()

    def given(*strats: _Strategy):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                rng = random.Random(0)
                for i in range(_FALLBACK_EXAMPLES):
                    fn(*args, *(s.example(rng, i) for s in strats), **kwargs)

            # strategy-bound params must not look like pytest fixtures
            sig = inspect.signature(fn)
            params = list(sig.parameters.values())
            n_bound = len(strats)
            wrapper.__signature__ = sig.replace(
                parameters=params[:len(params) - n_bound])
            return wrapper
        return deco

    def settings(**_kwargs):
        def deco(fn):
            return fn
        return deco
