"""Subprocess helper: FlashStore(backend="sharded") on 8 virtual devices.

The sharded facade must match the event-level sim oracle on one skewed
±Δ stream — read-your-writes before any flush, Δ-cancellation, and the
post-merge device contents — while the owner-aligned collective carries
nothing and drops nothing.
"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))
import sys
from collections import Counter
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[2] / "src"))

import jax
import numpy as np

from repro.core import table_jax as tj
from repro.core.distributed import ShardedTableConfig
from repro.core.store import FlashStore


def main():
    assert jax.device_count() == 8, jax.devices()
    cfg = ShardedTableConfig(
        local=tj.FlashTableConfig(q_log2=10, r_log2=7, scheme="MDB-L",
                                  log_capacity=1 << 14,
                                  max_updates_per_block=1 << 7,
                                  overflow_capacity=1 << 9),
        num_shards=8, bucket_cap=1 << 9)
    store = FlashStore.open(cfg, backend="sharded", shard_chunk=512,
                            flush_threshold=400)
    sim = FlashStore.open(backend="sim", scheme="MDB-L")

    rng = np.random.default_rng(0)
    toks = rng.integers(0, 5000, size=8 * 2048).astype(np.int64)
    truth = Counter(toks.tolist())
    for i in range(0, toks.size, 2048):
        store.update(toks[i:i + 2048])
        sim.update(toks[i:i + 2048])
    keys = np.array(sorted(truth))
    want = np.array([truth[int(k)] for k in keys])

    # read-your-writes before any forced merge: H_R overlay + staged
    np.testing.assert_array_equal(store.query(keys), want)
    # deletion-by-decrement crosses shards too
    dec = keys[::5]
    for st in (store, sim):
        st.update(dec, np.full(dec.size, -1, np.int64))
    np.testing.assert_array_equal(store.query(dec), want[::5] - 1)
    np.testing.assert_array_equal(store.query(dec), sim.query(dec))

    store.flush()
    sim.flush()
    np.testing.assert_array_equal(store.query(keys), sim.query(keys))

    s = store.stats()
    assert s["shards"] == 8
    assert s["write_carried"] == 0, s       # owner-aligned a2a never carries
    assert s["dropped"] == 0, s
    assert s["write_auto_flushes"] >= 1, s  # shard-local thresholds fired
    print("SHARD_STATS", {k: s[k] for k in
                          ("tile_stores", "write_flushes", "write_dispatches",
                           "write_auto_flushes", "write_piggybacked",
                           "write_deduped", "buffered_entries")})
    print("DIST_STORE_OK")


if __name__ == "__main__":
    main()
