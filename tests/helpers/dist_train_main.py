"""Subprocess helper: sharded train steps for every arch on a 2×4 mesh,
plus FSDP, decode-path lowering, gradient compression and elastic reshard."""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[2] / "src"))

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.models import model as M
from repro.models.config import ShapeConfig
from repro.models.sharding_hints import use_hints
from repro.optim import AdamWConfig
from repro.optim.adamw import AdamWState, adamw_init
from repro.launch import mesh as mesh_mod
from repro.launch import sharding as shd
from repro.launch import steps as steps_mod
from repro.launch import input_specs as ispec

SH = ShapeConfig("tiny_train", seq_len=64, global_batch=8, kind="train")


def run_arch(arch: str) -> None:
    base = jax.make_mesh((2, 4), ("data", "model"))
    cfg = get_config(arch, tiny=True)
    plan = mesh_mod.plan_for(cfg, model_axis=4)
    mesh = mesh_mod.arch_mesh(base, plan)
    pp = shd.ParallelPlan(fsdp=arch in ("phi35_moe_42b",), microbatches=2)
    rules = shd.logical_rules(plan, pp)
    with mesh, use_hints(mesh, rules):
        p_sh = shd.param_shardings(mesh, cfg, plan, pp)
        rep = shd.replicated(mesh)
        params = jax.device_put(M.init_params(jax.random.key(0), cfg), p_sh)
        opt_cfg = AdamWConfig()
        o_sh = AdamWState(m=p_sh, v=p_sh, count=rep)
        opt_state = jax.device_put(adamw_init(opt_cfg, params), o_sh)
        mb = ispec.effective_microbatches(pp, SH, 2)
        rng = np.random.default_rng(0)
        batch = {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (mb, SH.global_batch // mb,
                                             SH.seq_len)), jnp.int32)}
        batch["labels"] = batch["tokens"]
        if cfg.frontend != "none":
            batch["frontend_embeds"] = jnp.zeros(
                (mb, SH.global_batch // mb, cfg.num_patches, cfg.d_model),
                jnp.bfloat16)
        b_sh = shd.batch_shardings(mesh, cfg, plan, SH)
        batch = jax.device_put(batch, {k: b_sh[k] for k in batch})
        # warmup_steps must be ≈1 here: with the default 100-step warmup the
        # step-0 lr is ~0, the first update is a no-op, and the strict
        # loss-decrease assertion below becomes a rounding coin flip.
        step = steps_mod.make_train_step(
            cfg, opt_cfg, steps_mod.TrainHyper(peak_lr=1e-3, warmup_steps=1,
                                               total_steps=100))
        met_sh = {"loss": rep, "grad_norm": rep, "lr": rep}
        jitted = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                         out_shardings=(p_sh, o_sh, met_sh))
        p2, o2, met = jitted(params, opt_state, batch)
        loss1 = float(met["loss"])
        p3, o3, met2 = jitted(p2, o2, batch)
        loss2 = float(met2["loss"])
        assert np.isfinite(loss1) and np.isfinite(loss2)
        # MoE routing can bounce on step 2 at tiny scale; dense archs
        # must strictly improve on the memorized batch
        cfg_has_moe = any(f == "moe" for f in cfg.ffn_pattern)
        if cfg_has_moe:
            assert loss2 < loss1 + 0.5, (arch, loss1, loss2)
        else:
            assert loss2 < loss1, (arch, loss1, loss2)

        # decode path lowers + executes
        dec = steps_mod.make_decode_step(cfg)
        caches = M.init_caches(cfg, 8, 64, jnp.dtype(cfg.dtype))
        shape_d = ShapeConfig("tiny_dec", seq_len=64, global_batch=8,
                              kind="decode")
        c_sh = shd.cache_shardings(mesh, cfg, plan, pp, shape_d)
        caches = jax.device_put(caches, c_sh)
        toks = jnp.zeros((8, 1), jnp.int32)
        logits, caches, nxt = jax.jit(
            dec, in_shardings=(p_sh, c_sh, NamedSharding(mesh, P(("data",))),
                               rep),
            out_shardings=(rep, c_sh, rep))(p2, caches, toks, jnp.int32(3))
        assert logits.shape == (8, 1, cfg.padded_vocab)
    print(f"ARCH_OK {arch} {loss1:.4f}->{loss2:.4f}")


def elastic_reshard() -> None:
    """Save on a (2,4) mesh, restore on (1,4) submesh."""
    from repro.checkpoint import save_checkpoint, restore_checkpoint
    import tempfile
    cfg = get_config("llama32_3b", tiny=True)
    base = jax.make_mesh((2, 4), ("data", "model"))
    plan = mesh_mod.plan_for(cfg, model_axis=4)
    mesh = mesh_mod.arch_mesh(base, plan)
    pp = shd.ParallelPlan()
    p_sh = shd.param_shardings(mesh, cfg, plan, pp)
    params = jax.device_put(M.init_params(jax.random.key(1), cfg), p_sh)
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 3, params)
        small = jax.make_mesh((1, 4), ("data", "model"),
                              devices=np.array(jax.devices()[:4]))
        plan2 = mesh_mod.plan_for(cfg, model_axis=4)
        mesh2 = mesh_mod.arch_mesh(small, plan2)
        p_sh2 = shd.param_shardings(mesh2, cfg, plan2, pp)
        restored, meta = restore_checkpoint(d, params, shardings=p_sh2)
        w1 = np.asarray(params["final_norm"]["scale"])
        w2 = np.asarray(restored["final_norm"]["scale"])
        np.testing.assert_array_equal(w1, w2)
    print("ELASTIC_OK")


def main():
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    if which in ("all", "elastic"):
        elastic_reshard()
    archs = ARCH_IDS if which in ("all",) else (
        [] if which == "elastic" else [which])
    for arch in archs:
        run_arch(arch)
    print("DIST_TRAIN_OK")


if __name__ == "__main__":
    main()
