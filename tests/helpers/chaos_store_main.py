"""Chaos subprocess: SIGKILL a FlashStore between seal and settle.

Usage: ``python chaos_store_main.py <backend> <scheme> <wal_path>
<kill_after> [snapshot_path <snap_after>]``

Ingests a fixed seeded stream of ±Δ batches, draining after each with
``wait=False`` (the async path). The WAL's ``after_sync`` hook — which
fires once per seal *event*, immediately after the seal records are
fsync'd and strictly before the drain dispatches — SIGKILLs this process
at seal event ``kill_after``. The parent (tests/test_chaos.py) then
knows the log holds exactly batches 1..kill_after: batch ``kill_after``
was sealed and logged but its drain never ran, the harshest recoverable
point. With ``snapshot_path``, a snapshot is taken after batch
``snap_after`` (rotating the WAL mid-stream) so restore must combine
snapshot + replay.

The parent imports this module for ``make_batches``/``open_store`` so
the truth it computes is bit-identical to what the killed process saw.
"""
import os
import signal
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[2] / "src"))

import numpy as np  # noqa: E402

BATCHES = 6
BATCH = 200
KEYSPACE = 300


def make_batches():
    """The seeded stream, identical in child and parent: skewed tokens,
    ±Δ deltas (so cancellation is exercised inside the sealed chunks)."""
    rng = np.random.default_rng(7)
    out = []
    for _ in range(BATCHES):
        toks = rng.integers(0, KEYSPACE, size=BATCH).astype(np.int64)
        dels = rng.choice(np.array([1, 1, 2, -1], np.int64), size=BATCH)
        out.append((toks, dels))
    return out


def open_store(backend, scheme, wal_path):
    from repro.core import table_jax as tj
    from repro.core.store import FlashStore
    # threshold high enough that only the explicit per-batch drains seal:
    # the kill-point accounting is 1 seal event per batch
    if backend == "sim":
        return FlashStore.open(backend="sim", scheme=scheme, wal=wal_path,
                               flush_threshold=10_000)
    cfg = tj.FlashTableConfig(q_log2=10, r_log2=6, scheme=scheme,
                              log_capacity=1 << 9, cs_partitions=4,
                              max_updates_per_block=1 << 6,
                              overflow_capacity=1 << 9)
    if backend == "device":
        return FlashStore.open(cfg, backend="device", chunk=128,
                               wal=wal_path, flush_threshold=10_000)
    import jax
    n = jax.device_count()
    n = n if n & (n - 1) == 0 else 1
    return FlashStore.open(cfg, backend="sharded", num_shards=n,
                           shard_chunk=128, wal=wal_path,
                           flush_threshold=10_000)


def main():
    backend, scheme, wal_path, kill_after = sys.argv[1:5]
    kill_after = int(kill_after)
    snap_path = sys.argv[5] if len(sys.argv) > 5 else None
    snap_after = int(sys.argv[6]) if len(sys.argv) > 6 else 0
    st = open_store(backend, scheme, wal_path)

    def maybe_kill(seal_events):
        if seal_events == kill_after:
            # no atexit, no cleanup, no flush: the real failure mode
            os.kill(os.getpid(), signal.SIGKILL)

    st.wal.after_sync = maybe_kill
    for i, (toks, dels) in enumerate(make_batches(), start=1):
        st.update(toks, dels)
        st.drain(wait=False)             # seals (fsync, hook) then drains
        if snap_path is not None and i == snap_after:
            st.snapshot(snap_path)       # rotates the WAL mid-stream
    print("NEVER_KILLED", flush=True)    # parent asserts we died instead


if __name__ == "__main__":
    main()
