"""Multi-process sharded FlashStore launcher (ISSUE 10, DESIGN.md §14).

Parent process (no jax configured) spawns:

* two ``--role worker`` children — one JAX *process* each, joined into a
  single 8-device mesh via ``jax.distributed.initialize`` over a local
  TCP coordinator, 4 virtual CPU devices per process
  (``xla_force_host_platform_device_count=4``), gloo CPU collectives;
* optionally one ``--role single`` child — the single-host 8-virtual-
  device sharded reference on the *same* stream.

and compares their dumped query results against each other and the sim
oracle (computed in-parent). Scenarios:

``equivalence``  2-process store vs single-host sharded vs sim oracle:
                 bit-identical final contents per scheme (MB/MDB/MDB-L),
                 ``write_carried == 0`` on every host.
``heat``         identical skewed trace on 1-host-8-shard vs
                 2-process-4-shard meshes yields identical per-shard
                 ``partition_heat`` (and therefore eviction victims):
                 heat is a function of the trace, not the topology.
``wal_restore``  per-host WALs recover independently: each process seals
                 through its own log, the stores are abandoned
                 un-closed, fresh stores replay their own logs (drains
                 in lockstep) and reproduce the truth.
``handoff``      2-process departure: a departed store's WAL is re-owned
                 by both surviving processes via
                 ``elastic.handoff_hr_partitions`` — disjoint
                 round-robin record slices, exactly-once totals.

The child env (XLA flags, gloo collectives config *before*
``jax.distributed.initialize``) is the load-bearing part: CPU
multiprocess collectives need ``jax_cpu_collectives_implementation`` set
via ``jax.config.update`` in-process.
"""
import argparse
import os
import socket
import subprocess
import sys
from pathlib import Path

import numpy as np

ROOT = Path(__file__).resolve().parents[2]
UNIVERSE = 5000          # key space for every stream below
N_BATCHES = 16
BATCH = 1024
NUM_PROCS = 2


# ---------------------------------------------------------------------------
# shared deterministic inputs (every role regenerates from the seed)
# ---------------------------------------------------------------------------
def make_batches(seed: int = 0, deltas: bool = False):
    """N_BATCHES (tokens, deltas|None) batches over a skewed key space.

    With ``deltas``, the final batch decrements every 3rd key the stream
    actually touched (deletion-by-decrement, §2.6) — net counts stay
    non-negative so a plain Counter is the truth."""
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(N_BATCHES - (1 if deltas else 0)):
        toks = (rng.zipf(1.3, size=BATCH) % UNIVERSE).astype(np.int64)
        d = rng.choice(np.array([1, 1, 2], np.int64), BATCH) if deltas \
            else None
        out.append((toks, d))
    if deltas:
        seen = np.unique(np.concatenate([t for t, _ in out]))[::3]
        dec = seen[:BATCH]
        out.append((dec.astype(np.int64),
                    np.full(dec.size, -1, np.int64)))
    return out


def truth_of(batches):
    from collections import Counter
    c: Counter = Counter()
    for toks, d in batches:
        if d is None:
            c.update(toks.tolist())
        else:
            for k, v in zip(toks.tolist(), d.tolist()):
                c[k] += v
    return c


def store_kwargs(scheme: str) -> dict:
    kw = dict(q_log2=10, r_log2=7, scheme=scheme,
              log_capacity=1 << 14, max_updates_per_block=1 << 7,
              overflow_capacity=1 << 9)
    if scheme == "MDB":
        kw["cs_partitions"] = 4          # divides 2^(10-7) local blocks
    return kw


def open_sharded(scheme: str, wal=None):
    from repro.core import table_jax as tj
    from repro.core.distributed import ShardedTableConfig
    from repro.core.store import FlashStore
    cfg = ShardedTableConfig(
        local=tj.FlashTableConfig(**store_kwargs(scheme)),
        num_shards=8, bucket_cap=1 << 9)
    # flush_threshold is moot in multihost (auto-flush disabled) but keeps
    # the single-host reference on the same explicit-drain cadence
    return FlashStore.open(cfg, backend="sharded", shard_chunk=256,
                           flush_threshold=1 << 30, wal=wal)


def ingest(store, batches, mine=lambda i: True, drain_every: int = 4):
    """Drive the agreed drain cadence: every process walks the *global*
    batch index sequence, folds only its own batches, and hits the
    collective drain points together."""
    for i, (toks, d) in enumerate(batches):
        if mine(i):
            store.update(toks, d)
        if i % drain_every == drain_every - 1:
            store.drain(wait=True)


def query_universe(store) -> np.ndarray:
    return np.asarray(store.query_batch(np.arange(UNIVERSE, dtype=np.int64)))


# ---------------------------------------------------------------------------
# roles
# ---------------------------------------------------------------------------
def run_worker(a) -> None:
    import jax
    try:
        # must run after `import jax`, before distributed.initialize —
        # the env-var spelling does NOT work (spike-verified)
        jax.config.update("jax_cpu_collectives_implementation", "gloo")
    except Exception:
        pass          # newer jax: gloo is already the CPU default
    jax.distributed.initialize(
        coordinator_address=f"127.0.0.1:{a.port}",
        num_processes=NUM_PROCS, process_id=a.pid)
    assert jax.device_count() == 8 and jax.local_device_count() == 4
    tmp = Path(a.tmp)
    if a.scenario == "equivalence":
        batches = make_batches(deltas=True)
        store = open_sharded(a.scheme)
        ingest(store, batches, mine=lambda i: i % NUM_PROCS == a.pid)
        store.flush(wait=True)
        got = query_universe(store)
        s = store.stats()
        assert s["write_carried"] == 0, s    # owner-aligned waves: no carry
        assert s["dropped"] == 0, s
        if a.pid == 0:
            np.save(tmp / "mh_counts.npy", got)
        store.close()
    elif a.scenario == "heat":
        batches = make_batches(seed=3)       # counts-only, heavily skewed
        store = open_sharded(a.scheme)
        # the whole trace lands on host 0; host 1 participates in the
        # collectives with empty seals — heat must match single-host
        ingest(store, batches, mine=lambda i: a.pid == 0)
        store.flush(wait=True)
        heat = store.partition_heat(np.arange(UNIVERSE, dtype=np.int64))
        if a.pid == 0:
            np.save(tmp / "mh_heat.npy", np.asarray(heat))
        store.close()
    elif a.scenario == "wal_restore":
        batches = make_batches(seed=5)
        wal_path = tmp / f"wal_{a.pid}.log"
        store = open_sharded(a.scheme, wal=str(wal_path))
        ingest(store, batches, mine=lambda i: i % NUM_PROCS == a.pid)
        store.drain(wait=True)               # seal + drain everything
        # crash: abandon the store un-closed (device state discarded);
        # the per-host WAL is the only survivor
        store._b._disp.close()
        store._b.front.wal.close()
        store2 = open_sharded(a.scheme, wal=str(wal_path))
        rep = store2.restore(path=None)
        assert rep.records_replayed > 0, rep
        store2.flush(wait=True)
        got = query_universe(store2)
        if a.pid == 0:
            np.save(tmp / "mh_counts.npy", got)
        store2.close()
    elif a.scenario == "handoff":
        from repro.runtime.elastic import handoff_hr_partitions
        batches = make_batches(seed=7)
        store = open_sharded(a.scheme)
        ingest(store, batches, mine=lambda i: i % NUM_PROCS == a.pid)
        store.drain(wait=True)
        n_rec, n_ent = handoff_hr_partitions(str(tmp / "depart.log"), store)
        print(f"HANDOFF{a.pid} records={n_rec} entries={n_ent}", flush=True)
        assert n_rec > 0                     # the slice split left us some
        store.flush(wait=True)
        got = query_universe(store)
        if a.pid == 0:
            np.save(tmp / "mh_counts.npy", got)
        store.close()
    else:
        raise SystemExit(f"unknown scenario {a.scenario}")
    print(f"MH{a.pid}_OK", flush=True)


def run_single(a) -> None:
    import jax
    assert jax.device_count() == 8, jax.devices()
    tmp = Path(a.tmp)
    if a.scenario == "equivalence":
        batches = make_batches(deltas=True)
        store = open_sharded(a.scheme)
        ingest(store, batches)
        store.flush(wait=True)
        np.save(tmp / "single_counts.npy", query_universe(store))
        assert store.stats()["write_carried"] == 0
        store.close()
    elif a.scenario == "heat":
        batches = make_batches(seed=3)
        store = open_sharded(a.scheme)
        ingest(store, batches)
        store.flush(wait=True)
        heat = store.partition_heat(np.arange(UNIVERSE, dtype=np.int64))
        np.save(tmp / "single_heat.npy", np.asarray(heat))
        store.close()
    else:
        raise SystemExit(f"no single-host reference for {a.scenario}")
    print("SINGLE_OK", flush=True)


# ---------------------------------------------------------------------------
# parent: spawn + compare
# ---------------------------------------------------------------------------
def _free_port() -> int:
    s = socket.socket()
    s.bind(("127.0.0.1", 0))
    port = s.getsockname()[1]
    s.close()
    return port


def _spawn(role: str, a, port: int, pid: int = 0) -> subprocess.Popen:
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    devs = 4 if role == "worker" else 8
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devs}"
    env["JAX_PLATFORMS"] = "cpu"
    cmd = [sys.executable, str(Path(__file__).resolve()), "--role", role,
           "--scenario", a.scenario, "--scheme", a.scheme,
           "--tmp", a.tmp, "--port", str(port), "--pid", str(pid)]
    return subprocess.Popen(cmd, env=env, cwd=str(ROOT),
                            stdout=subprocess.PIPE,
                            stderr=subprocess.STDOUT, text=True)


def _wait_ok(proc: subprocess.Popen, marker: str, timeout: int = 600) -> str:
    out, _ = proc.communicate(timeout=timeout)
    assert proc.returncode == 0, f"{marker} rc={proc.returncode}\n{out}"
    assert marker in out, f"missing {marker}\n{out}"
    return out


def run_parent(a) -> None:
    tmp = Path(a.tmp)
    tmp.mkdir(parents=True, exist_ok=True)
    port = _free_port()

    if a.scenario == "handoff":
        # the departing node: a WAL'd store sealing one stream (the sim
        # backend keeps the parent jax-free; only its *log* matters)
        from repro.core.store import FlashStore
        depart = make_batches(seed=11)
        dstore = FlashStore.open(backend="sim", scheme=a.scheme,
                                 wal=str(tmp / "depart.log"))
        for toks, d in depart:
            dstore.update(toks, d)
            dstore.drain(wait=True)          # one sealed WAL record per
        dstore.close()                       # batch: both survivors get
                                             # a non-empty replay slice

    workers = [_spawn("worker", a, port, pid=p) for p in range(NUM_PROCS)]
    single = (None if a.scenario in ("wal_restore", "handoff")
              else _spawn("single", a, port))
    for p, w in enumerate(workers):
        out = _wait_ok(w, f"MH{p}_OK")
        if a.scenario == "handoff":
            print(out, flush=True)
    if single is not None:
        _wait_ok(single, "SINGLE_OK")

    keys = np.arange(UNIVERSE)
    if a.scenario == "heat":
        mh = np.load(tmp / "mh_heat.npy")
        sg = np.load(tmp / "single_heat.npy")
        assert mh.shape == sg.shape == keys.shape
        np.testing.assert_allclose(mh, sg, rtol=1e-9)
        assert mh.max() > 0, "skewed trace produced no heat"
        # same eviction victim ordering, not merely close values
        assert int(np.argmax(mh)) == int(np.argmax(sg))
        print("HEAT_MATCH victim", int(np.argmax(mh)), flush=True)
    else:
        got = np.load(tmp / "mh_counts.npy")
        batches = make_batches(deltas=True) if a.scenario == "equivalence" \
            else make_batches(seed={"wal_restore": 5, "handoff": 7}
                              [a.scenario])
        truth = truth_of(batches)
        if a.scenario == "handoff":
            for k, v in truth_of(make_batches(seed=11)).items():
                truth[k] += v
        want = np.array([truth.get(int(k), 0) for k in keys])
        np.testing.assert_array_equal(got, want)
        if a.scenario == "equivalence":
            sg = np.load(tmp / "single_counts.npy")
            np.testing.assert_array_equal(got, sg)
            # the sim oracle agrees too (computed right here)
            from repro.core.store import FlashStore
            sim = FlashStore.open(backend="sim", scheme=a.scheme)
            for toks, d in batches:
                sim.update(toks, d)
            sim.flush()
            np.testing.assert_array_equal(got, np.asarray(sim.query(keys)))
            sim.close()
    print("MULTIHOST_OK", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--role", default="parent",
                    choices=("parent", "worker", "single"))
    ap.add_argument("--scenario", default="equivalence",
                    choices=("equivalence", "heat", "wal_restore",
                             "handoff"))
    ap.add_argument("--scheme", default="MDB-L",
                    choices=("MB", "MDB", "MDB-L"))
    ap.add_argument("--tmp", required=True)
    ap.add_argument("--port", type=int, default=0)
    ap.add_argument("--pid", type=int, default=0)
    a = ap.parse_args()
    if a.role == "parent":
        run_parent(a)
    elif a.role == "worker":
        run_worker(a)
    else:
        run_single(a)


if __name__ == "__main__":
    # role != parent: the XLA device-count env was set by the spawner
    # *before* this interpreter started; sys.path for repro comes first
    sys.path.insert(0, str(ROOT / "src"))
    main()
