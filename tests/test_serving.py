"""Serving subsystem (ISSUE 9): paged block pool, block-granular prefix
cache, continuous-batching scheduler, trace replay.

Acceptance pins:
- scheduler outputs are token-identical to the serial ``serve()`` loop
  (dense, MLA, and SSM stacks; fp32 so argmax ties cannot flip);
- ``cached_tokens`` reports the true reused-prefix length (satellite);
- the exact-full-prompt-hit branch still yields first-token logits
  (satellite);
- SSM archs get ``slicer=None`` and never insert sliced recurrent state
  (satellite);
- multi-worker trace replay is deterministic under a fixed seed.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model as M
from repro.serving import (BlockPool, ContinuousBatchingScheduler,
                           PrefixKVCache, Request, SchedRequest,
                           ServeEngine, make_trace, replay_trace)


def _f32(arch):
    return dataclasses.replace(get_config(arch, tiny=True), dtype="float32")


def _params(cfg):
    return M.init_params(jax.random.PRNGKey(0), cfg)


def _prompts(cfg, shared_tokens=16, suffixes=(5, 9, 0, 16), seed=2):
    rng = np.random.default_rng(seed)
    shared = [int(t) for t in rng.integers(1, cfg.vocab_size,
                                           size=shared_tokens)]
    return [shared + [int(t) for t in rng.integers(1, cfg.vocab_size,
                                                   size=n)]
            for n in suffixes]


def _sim_cache(**kw):
    kw.setdefault("block_tokens", 16)
    kw.setdefault("capacity_blocks", 32)
    return PrefixKVCache(backend="sim", **kw)


# ---------------------------------------------------------------------------
# block pool
# ---------------------------------------------------------------------------
class TestBlockPool:
    def test_alloc_free_roundtrip(self):
        pool = BlockPool(4)
        bids = [pool.alloc(f"v{i}") for i in range(4)]
        assert sorted(bids) == [0, 1, 2, 3]
        assert pool.alloc("overflow") is None          # exhausted, not an error
        assert [pool.get(b) for b in bids] == ["v0", "v1", "v2", "v3"]
        pool.free(bids[1])
        assert pool.num_free == 1 and pool.in_use == 3
        assert pool.alloc("again") == bids[1]          # LIFO reuse
        assert pool.high_water == 4

    def test_double_free_rejected(self):
        pool = BlockPool(2)
        b = pool.alloc("x")
        pool.free(b)
        with pytest.raises(ValueError, match="double free"):
            pool.free(b)

    def test_stats_and_validation(self):
        with pytest.raises(ValueError):
            BlockPool(0)
        pool = BlockPool(3)
        pool.alloc("a")
        s = pool.stats()
        assert s["pool_capacity"] == 3 and s["pool_in_use"] == 1
        assert s["pool_allocs"] == 1 and s["pool_high_water"] == 1


# ---------------------------------------------------------------------------
# block-granular prefix cache (refcounts as the page table)
# ---------------------------------------------------------------------------
class TestPagedPrefixCache:
    def test_insert_block_acquire_blocks(self):
        c = _sim_cache(block_tokens=4, capacity_blocks=8)
        toks = list(range(1, 11))                       # 2 whole blocks + 2
        k0 = c.insert_block(toks, 0, "seg0")
        k1 = c.insert_block(toks, 1, "seg1")
        assert k0 is not None and k1 is not None and k0 != k1
        assert c.insert_block(toks, 0, "dup") is None   # already resident
        n, values, pinned = c.acquire_blocks(toks)
        assert n == 8
        assert values == ["seg0", "seg1"]               # per-block segments
        assert len(pinned) == 2
        # insert pinned each block once, acquire pinned again
        assert list(c._count(pinned)) == [2, 2]
        c.release(pinned)
        c.release([k0, k1])
        assert list(c._count(pinned)) == [0, 0]

    def test_lookup_does_not_pin(self):
        c = _sim_cache(block_tokens=4, capacity_blocks=8)
        toks = list(range(1, 9))
        c.insert_block(toks, 0, "s0")
        assert c.lookup(toks) == 4                      # only block 0 resident
        assert list(c._count(c.block_keys(toks)[:1])) == [1]

    def test_pool_backed_eviction_frees_slots(self):
        c = _sim_cache(block_tokens=4, capacity_blocks=2)
        keys = []
        for i in range(4):
            toks = [10 * i + j for j in range(1, 5)]
            keys.append(c.insert_block(toks, 0, f"s{i}"))
            c.release([keys[-1]])                       # unpin immediately
        assert len(c.store) == 2                        # capacity respected
        assert c.evictions == 2
        s = c.stats()
        assert s["pool_in_use"] == 2 and s["pool_capacity"] == 2
        assert s["pool_frees"] == 2                     # evictions freed slots

    def test_eviction_spares_pinned_blocks(self):
        c = _sim_cache(block_tokens=4, capacity_blocks=2)
        a = [1, 2, 3, 4]
        b = [5, 6, 7, 8]
        ka = c.insert_block(a, 0, "A")                  # stays pinned
        kb = c.insert_block(b, 0, "B")
        c.release([kb])
        c.insert_block([9, 10, 11, 12], 0, "C")         # forces eviction
        assert ka in c.store and kb not in c.store

    def test_legacy_and_paged_share_refcounts(self):
        """Legacy acquire/release and paged pins go through one counting
        table — the page table is shared state, not per-API."""
        c = _sim_cache(block_tokens=4, capacity_blocks=8)
        toks = [1, 2, 3, 4]
        k = c.insert_block(toks, 0, "seg")
        n, _value, pinned = c.acquire(toks)             # legacy pin
        assert n == 4 and pinned == [k]
        assert list(c._count([k])) == [2]
        c.release(pinned)
        c.release([k])
        assert list(c._count([k])) == [0]

    def test_sim_backend_stats_are_tolerant(self):
        c = _sim_cache()
        s = c.stats()
        assert s["backend"] == "sim"
        for field in ("tile_stores", "dropped", "query_batches",
                      "pool_capacity", "write_buffered"):
            assert field in s


# ---------------------------------------------------------------------------
# engine satellites
# ---------------------------------------------------------------------------
class TestEngineSatellites:
    def test_cached_tokens_reports_reused_prefix(self):
        """Regression (ISSUE 9): the old expression reduced to
        ``consumed`` — a cache hit on a 16-token prefix of a 21-token
        prompt must report 16, not 21."""
        cfg = _f32("llama32_3b")
        eng = ServeEngine(cfg, _params(cfg), prefix_cache=_sim_cache())
        p1, p2 = _prompts(cfg, suffixes=(5, 9))
        r1 = eng.generate(Request(prompt=list(p1), max_new_tokens=2))
        assert r1.cached_tokens == 0                    # cold miss
        r2 = eng.generate(Request(prompt=list(p2), max_new_tokens=2))
        assert r2.cached_tokens == 16                   # shared whole block

    def test_full_prompt_hit_branch(self):
        """Exact full-prompt hit (prompt length a block multiple, all
        blocks cached) must still produce first-token logits — and the
        same first token as the cold pass (satellite for the dead
        ``batch`` assignment removal)."""
        cfg = _f32("llama32_3b")
        eng = ServeEngine(cfg, _params(cfg), prefix_cache=_sim_cache())
        (prompt,) = _prompts(cfg, shared_tokens=32, suffixes=(0,))
        cold = eng.generate(Request(prompt=list(prompt), max_new_tokens=3))
        hot = eng.generate(Request(prompt=list(prompt), max_new_tokens=3))
        assert hot.cached_tokens == len(prompt) == 32
        assert hot.output == cold.output

    def test_ssm_slicer_is_none_and_unsliced_insert(self):
        """SSM archs: ``_slicer`` must be None (recurrent state is not
        seq-sliceable) and insert must register only the exact prefix,
        never intermediate sliced states."""
        cfg = _f32("mamba2_2p7b")
        cache = _sim_cache()
        eng = ServeEngine(cfg, _params(cfg), prefix_cache=cache)
        assert eng._slicer() is None
        (prompt,) = _prompts(cfg, shared_tokens=32, suffixes=(0,))
        eng.generate(Request(prompt=list(prompt), max_new_tokens=2))
        # one entry (the full 2-block prefix), not one per block
        assert len(cache.store) == 1
        blk = next(iter(cache.store.values()))
        assert blk.tokens == tuple(prompt)              # exact, unsliced

    def test_attention_slicer_registers_every_block(self):
        cfg = _f32("llama32_3b")
        cache = _sim_cache()
        eng = ServeEngine(cfg, _params(cfg), prefix_cache=cache)
        assert callable(eng._slicer())
        (prompt,) = _prompts(cfg, shared_tokens=32, suffixes=(0,))
        eng.generate(Request(prompt=list(prompt), max_new_tokens=2))
        assert len(cache.store) == 2                    # one per whole block


# ---------------------------------------------------------------------------
# continuous-batching scheduler
# ---------------------------------------------------------------------------
def _serial_vs_batched(arch, max_slots=2, use_cache=True):
    cfg = _f32(arch)
    params = _params(cfg)
    prompts = _prompts(cfg)
    serial = ServeEngine(cfg, params).serve(
        [Request(prompt=list(p), max_new_tokens=5) for p in prompts])
    cache = _sim_cache() if use_cache else None
    sched = ContinuousBatchingScheduler(cfg, params, prefix_cache=cache,
                                        max_slots=max_slots, max_context=64)
    done = sched.run([SchedRequest(prompt=list(p), max_new_tokens=5,
                                   request_id=i)
                      for i, p in enumerate(prompts)])
    by_id = {r.request_id: r for r in done}
    for i, s in enumerate(serial):
        assert by_id[i].output == s.output, f"req {i} diverged"
    return sched, by_id


class TestScheduler:
    def test_identical_outputs_dense(self):
        sched, by_id = _serial_vs_batched("llama32_3b")
        assert sched.decode_steps > 0 and sched.chunk_calls > 0
        # later requests rode the blocks the earlier ones inserted
        assert by_id[2].cached_tokens == 16             # exact-block prompt
        assert by_id[3].cached_tokens == 16

    @pytest.mark.slow
    def test_identical_outputs_mla(self):
        _serial_vs_batched("minicpm3_4b")

    def test_identical_outputs_ssm_fallback(self):
        """Hybrid/SSM stacks take whole-prompt prefill (no paging) but
        still decode packed — outputs must match the serial loop."""
        sched, by_id = _serial_vs_batched("mamba2_2p7b")
        assert sched.chunk_calls == 0                   # no chunked prefill
        assert all(by_id[i].cached_tokens == 0 for i in by_id)

    def test_no_cache_still_batches(self):
        sched, _ = _serial_vs_batched("llama32_3b", use_cache=False)
        assert sched.cache is None and sched.chunk_calls > 0

    def test_oversized_request_rejected(self):
        cfg = _f32("llama32_3b")
        sched = ContinuousBatchingScheduler(cfg, _params(cfg),
                                            max_slots=1, max_context=32)
        with pytest.raises(ValueError, match="max_context"):
            sched.submit(SchedRequest(prompt=[1] * 30, max_new_tokens=8))

    def test_pins_released_on_completion(self):
        cfg = _f32("llama32_3b")
        cache = _sim_cache()
        sched = ContinuousBatchingScheduler(cfg, _params(cfg),
                                            prefix_cache=cache,
                                            max_slots=2, max_context=64)
        prompts = _prompts(cfg)
        sched.run([SchedRequest(prompt=list(p), max_new_tokens=3,
                                request_id=i)
                   for i, p in enumerate(prompts)])
        keys = list(cache.store.keys())
        assert keys, "prefill should have inserted blocks"
        assert all(c == 0 for c in cache._count(keys))  # all unpinned
        assert sched._free_slots and all(r is None for r in sched._active)


# ---------------------------------------------------------------------------
# trace generation + multi-worker replay
# ---------------------------------------------------------------------------
class TestTraceReplay:
    def test_trace_is_deterministic_and_block_aligned(self):
        a = make_trace(num_requests=8, num_users=3, seed=7)
        b = make_trace(num_requests=8, num_users=3, seed=7)
        assert [t.prompt for t in a] == [t.prompt for t in b]
        assert [t.arrival_s for t in a] == [t.arrival_s for t in b]
        assert all(t.arrival_s >= 0 for t in a)
        assert all(0 not in t.prompt for t in a)        # pad token excluded
        # same user ⇒ identical block-aligned system prefix
        by_user = {}
        for t in a:
            by_user.setdefault(t.user, t.prompt[:32])
            assert t.prompt[:32] == by_user[t.user]

    def test_multi_worker_replay_smoke(self):
        """Fixed-seed, two feeder threads: every request completes, the
        report accounts all tokens, and the repeated-prefix trace hits
        the cache (the CI tests-serving smoke)."""
        cfg = _f32("llama32_3b")
        cache = _sim_cache(capacity_blocks=64)
        sched = ContinuousBatchingScheduler(cfg, _params(cfg),
                                            prefix_cache=cache,
                                            max_slots=4, max_context=96)
        trace = make_trace(num_requests=12, num_users=3, prefix_blocks=2,
                           block_tokens=16, max_new_tokens=4,
                           vocab_size=cfg.vocab_size, seed=3)
        rep = replay_trace(sched, trace, workers=2)
        assert rep.requests == 12
        assert rep.generated_tokens == 12 * 4
        assert rep.tokens_per_s > 0
        assert rep.p99_latency_s >= rep.p50_latency_s > 0
        assert rep.hit_rate >= 0.3                      # zipf prefix reuse
        assert "fig7dev" in rep.summary()

    def test_replay_outputs_match_serial(self):
        """Replay through threads + scheduler must equal the serial
        engine on the same trace (the fig7dev identical-outputs gate)."""
        cfg = _f32("llama32_3b")
        params = _params(cfg)
        trace = make_trace(num_requests=6, num_users=2, prefix_blocks=1,
                           block_tokens=16, max_new_tokens=3,
                           vocab_size=cfg.vocab_size, seed=5)
        serial = ServeEngine(cfg, params).serve(
            [Request(prompt=list(t.prompt), max_new_tokens=3)
             for t in trace])
        sched = ContinuousBatchingScheduler(
            cfg, params, prefix_cache=_sim_cache(capacity_blocks=64),
            max_slots=3, max_context=64)
        replay_trace(sched, trace, workers=2)
        by_id = {r.request_id: r for r in sched.completed}
        for i, s in enumerate(serial):
            assert by_id[i].output == s.output
