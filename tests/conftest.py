import os
import sys
from pathlib import Path

# NOTE: deliberately NO --xla_force_host_platform_device_count here — smoke
# tests and benches must see 1 device; only dryrun.py forces 512, and the
# multi-device tests spawn subprocesses with their own XLA_FLAGS.
SRC = str(Path(__file__).resolve().parent.parent / "src")
if SRC not in sys.path:
    sys.path.insert(0, SRC)
os.environ.setdefault("JAX_PLATFORMS", "cpu")
