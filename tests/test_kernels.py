"""Pallas flash-hash kernels vs the pure-jnp oracle: shape/dtype sweeps in
interpret mode (per-kernel allclose contract)."""
import numpy as np
import jax.numpy as jnp
import pytest
from collections import Counter

from repro.core.hashing import Pow2Hash, filter_words_for
from repro.kernels.flash_hash import ops, ref

EMPTY = ref.EMPTY


def _zf(pair):
    """Fresh (all-zero) per-block Bloom filter rows for a table."""
    return jnp.zeros((pair.num_slots, filter_words_for(pair.r)), jnp.uint32)


def _mk_updates(pair, n_keys, key_space, seed, max_u):
    rng = np.random.default_rng(seed)
    toks = jnp.asarray(rng.integers(0, key_space, size=n_keys), jnp.int32)
    keys, cnts = ops.accumulate(toks)
    uk, uc, ck, cc, nd = ops.bucket_updates(pair, keys, cnts, max_u)
    return toks, uk, uc, int(nd)


@pytest.mark.parametrize("q_log2,r_log2,max_u", [
    (8, 5, 16), (10, 7, 64), (12, 8, 512), (13, 10, 256), (11, 11, 128),
])
def test_merge_matches_ref_shapes(q_log2, r_log2, max_u):
    pair = Pow2Hash(q_log2=q_log2, r_log2=r_log2)
    n_b, r = pair.num_slots, pair.r
    tk = jnp.full((n_b, r), EMPTY, jnp.int32)
    tc = jnp.zeros((n_b, r), jnp.int32)
    _, uk, uc, _ = _mk_updates(pair, 4 * pair.q // 8, 1 << 20, q_log2, max_u)
    r1 = ref.merge_ref(pair, tk, tc, uk, uc)
    nk, nc, _, sk, sc = ops.merge(pair, tk, tc, _zf(pair), uk, uc)
    for a, b in zip(r1, (nk, nc, sk, sc)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("count_dtype", [jnp.int32])
def test_merge_repeated_batches_count_exact(count_dtype):
    pair = Pow2Hash(q_log2=10, r_log2=7)
    n_b, r = pair.num_slots, pair.r
    tk = jnp.full((n_b, r), EMPTY, jnp.int32)
    tc = jnp.zeros((n_b, r), count_dtype)
    tf = _zf(pair)
    truth = Counter()
    rng = np.random.default_rng(7)
    for i in range(5):
        toks = rng.integers(0, 600, size=512)
        truth.update(toks.tolist())
        keys, cnts = ops.accumulate(jnp.asarray(toks, jnp.int32))
        uk, uc, _, _, nd = ops.bucket_updates(pair, keys, cnts, 128)
        assert int(nd) == 0
        tk, tc, tf, sk, sc = ops.merge(pair, tk, tc, tf, uk, uc)
        assert int((sk != EMPTY).sum()) == 0  # no spills at this load
    q = jnp.asarray(sorted(truth), jnp.int32)
    cnt, dist = ops.query_sorted(pair, tk, tc, q)
    got = dict(zip(map(int, q), map(int, cnt)))
    assert got == dict(truth)


def test_spill_semantics():
    """A block fed more keys than capacity must spill the excess, exactly."""
    pair = Pow2Hash(q_log2=6, r_log2=3)  # tiny blocks of 8
    n_b, r = pair.num_slots, pair.r
    # craft 12 distinct keys that all land in block 0
    keys = []
    x = 0
    while len(keys) < 12:
        if int(pair.s(x)) == 0:
            keys.append(x)
        x += 1
    uk = jnp.full((n_b, 16), EMPTY, jnp.int32).at[0, :12].set(
        jnp.asarray(keys, jnp.int32))
    uc = jnp.zeros((n_b, 16), jnp.int32).at[0, :12].set(1)
    tk = jnp.full((n_b, r), EMPTY, jnp.int32)
    tc = jnp.zeros((n_b, r), jnp.int32)
    nk, nc, _, sk, sc = ops.merge(pair, tk, tc, _zf(pair), uk, uc)
    assert int((nk[0] != EMPTY).sum()) == r          # block full
    assert int((sk[0] != EMPTY).sum()) == 12 - r     # rest spilled
    rk, rc, rsk, rsc = ref.merge_ref(pair, tk, tc, uk, uc)
    np.testing.assert_array_equal(np.asarray(nk), np.asarray(rk))
    np.testing.assert_array_equal(np.asarray(sk), np.asarray(rsk))


def test_negative_deltas_and_zero():
    pair = Pow2Hash(q_log2=8, r_log2=5)
    n_b, r = pair.num_slots, pair.r
    tk = jnp.full((n_b, r), EMPTY, jnp.int32)
    tc = jnp.zeros((n_b, r), jnp.int32)
    keys = jnp.asarray([42, 43], jnp.int32)
    deltas = jnp.asarray([5, -2], jnp.int32)
    uk, uc, _, _, _ = ops.bucket_updates(pair, keys, deltas, 8)
    tk, tc, _, _, _ = ops.merge(pair, tk, tc, _zf(pair), uk, uc)
    q = jnp.asarray([42, 43, 44, 42], jnp.int32)
    cnt, _ = ops.query_sorted(pair, tk, tc, q)
    assert list(map(int, cnt)) == [5, -2, 0, 5]


def test_query_probe_distance_vs_ref():
    pair = Pow2Hash(q_log2=9, r_log2=6)
    n_b, r = pair.num_slots, pair.r
    tk = jnp.full((n_b, r), EMPTY, jnp.int32)
    tc = jnp.zeros((n_b, r), jnp.int32)
    toks, uk, uc, _ = _mk_updates(pair, 300, 1000, 3, 64)
    tk, tc, _, _, _ = ops.merge(pair, tk, tc, _zf(pair), uk, uc)
    q = jnp.asarray(np.random.default_rng(4).integers(0, 1500, 64), jnp.int32)
    c1, d1 = ref.query_ref(pair, tk, tc, q)
    c2, d2 = ops.query_sorted(pair, tk, tc, q)
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))
    np.testing.assert_array_equal(np.asarray(d1), np.asarray(d2))


def test_merge_dirty_equals_full_merge():
    pair = Pow2Hash(q_log2=10, r_log2=7)
    n_b, r = pair.num_slots, pair.r
    rng = np.random.default_rng(5)
    tk = jnp.full((n_b, r), EMPTY, jnp.int32)
    tc = jnp.zeros((n_b, r), jnp.int32)
    _, uk, uc, _ = _mk_updates(pair, 500, 4000, 6, 64)
    full_k, full_c, full_f, _, _ = ops.merge(pair, tk, tc, _zf(pair), uk, uc)
    dirty = jnp.asarray([b for b in range(n_b)
                         if int((uk[b] != EMPTY).sum())], jnp.int32)
    dk, dc, df, _, _ = ops.merge_dirty(pair, tk, tc, _zf(pair), dirty,
                                       uk[dirty], uc[dirty])
    np.testing.assert_array_equal(np.asarray(full_k), np.asarray(dk))
    np.testing.assert_array_equal(np.asarray(full_c), np.asarray(dc))
    np.testing.assert_array_equal(np.asarray(full_f), np.asarray(df))


@pytest.mark.parametrize("qcap", [1, 3, 16, 128])
def test_query_blocked_matches_ref(qcap):
    """Batched query entry vs the oracle, including the multi-wave path
    (qcap below the fullest block's query count), duplicate keys, absent
    keys and EMPTY padding."""
    pair = Pow2Hash(q_log2=9, r_log2=6)
    n_b, r = pair.num_slots, pair.r
    tk = jnp.full((n_b, r), EMPTY, jnp.int32)
    tc = jnp.zeros((n_b, r), jnp.int32)
    _, uk, uc, _ = _mk_updates(pair, 300, 1000, 11, 64)
    tk, tc, _, _, _ = ops.merge(pair, tk, tc, _zf(pair), uk, uc)
    rng = np.random.default_rng(12)
    q = np.concatenate([rng.integers(0, 1500, 90),     # present + absent
                        np.full(6, EMPTY),             # padding lanes
                        rng.integers(0, 40, 32)])      # heavy duplicates
    q = jnp.asarray(q, jnp.int32)
    want_c, want_d = ref.query_ref(pair, tk, tc, q)
    got_c, got_d = ops.query_blocked(pair, tk, tc, q, qcap)
    np.testing.assert_array_equal(np.asarray(want_c), np.asarray(got_c))
    np.testing.assert_array_equal(np.asarray(want_d), np.asarray(got_d))


def test_query_blocked_matches_query_sorted():
    """The two query entry points must agree bit-for-bit on valid keys."""
    pair = Pow2Hash(q_log2=10, r_log2=7)
    n_b, r = pair.num_slots, pair.r
    tk = jnp.full((n_b, r), EMPTY, jnp.int32)
    tc = jnp.zeros((n_b, r), jnp.int32)
    _, uk, uc, _ = _mk_updates(pair, 500, 4000, 13, 64)
    tk, tc, _, _, _ = ops.merge(pair, tk, tc, _zf(pair), uk, uc)
    q = jnp.asarray(np.random.default_rng(14).integers(0, 5000, 256),
                    jnp.int32)
    c1, d1 = ops.query_sorted(pair, tk, tc, q)
    c2, d2 = ops.query_blocked(pair, tk, tc, q)
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))
    np.testing.assert_array_equal(np.asarray(d1), np.asarray(d2))


def test_accumulate_dedup():
    toks = jnp.asarray([5, 5, 7, EMPTY, 5, 9, 7, EMPTY], jnp.int32)
    keys, cnts = ops.accumulate(toks)
    got = {int(k): int(c) for k, c in zip(keys, cnts) if int(k) != EMPTY}
    assert got == {5: 3, 7: 2, 9: 1}
    assert int(cnts.sum()) == 6
