"""Blocked-Bloom negative-lookup fast path (ISSUE 8, DESIGN.md §12).

The hard invariant under test: **no false negatives, ever** — a key
resident in any of the paper's regions (data segment, change
segment/log, overflow; before or after snapshot/restore and elastic
WAL handoff) must survive the filter pre-pass under every scheme and
backend. Its complement is the perf contract: a *true* negative (a key
the filter itself rules out) costs zero accounted ``tile_loads`` at the
ops level and zero lookup dispatches at the engine level, and the sim's
costed twin answers it with zero flash page reads.

"True negative" here is the filter's own verdict: tests rejection-sample
absent keys through ``filter_probe`` so the ~4% false-positive rate can
never flake an assertion — a false positive costs a probe, never
correctness, and is exercised separately.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import segments as seg
from repro.core import table_jax as tj
from repro.core.flash_model import TableGeometry
from repro.core.hashing import bloom_positions, filter_words_for
from repro.core.store import FlashStore
from repro.core.table_sim import make_table

SCHEMES = ["MB", "MDB", "MDB-L"]
GEOM = TableGeometry(num_blocks=32, pages_per_block=4, entries_per_page=8)


def _sim(scheme, **kw):
    kw.setdefault("overflow_blocks", 4)       # room for skewed spills
    return make_table(scheme, GEOM, ram_buffer_pct=10.0,
                      change_segment_pct=25.0, **kw)


def _cfg(scheme, **kw):
    base = dict(q_log2=10, r_log2=6, scheme=scheme, log_capacity=1 << 9,
                cs_partitions=4, max_updates_per_block=1 << 6,
                overflow_capacity=1 << 9)
    base.update(kw)
    return tj.FlashTableConfig(**base)


def _shard_count() -> int:
    import jax
    n = jax.device_count()
    return n if n & (n - 1) == 0 else 1


def _open(backend, scheme="MDB-L", **kw):
    kw.setdefault("flush_threshold", 10_000)   # no surprise auto-drains
    if backend == "sim":
        return FlashStore.open(backend="sim", scheme=scheme, **kw)
    if backend == "device":
        kw.setdefault("chunk", 128)
        return FlashStore.open(_cfg(scheme), backend="device", **kw)
    kw.setdefault("shard_chunk", 128)
    return FlashStore.open(_cfg(scheme), backend="sharded",
                           num_shards=_shard_count(), **kw)


def _same_block_keys(pair, block, n, lo=0):
    out = []
    x = lo
    while len(out) < n:
        if int(pair.s(x)) == block:
            out.append(x)
        x += 1
    return np.asarray(out, dtype=np.int64)


def _probe(store, keys) -> np.ndarray:
    """May-contain verdicts through the backend's own filter path (the
    exact function the engine consults): bool (Q,)."""
    fn = store._b.query_engine._filter
    assert fn is not None, "store opened without filters"
    m = np.asarray(fn(store.state, jnp.asarray(keys, jnp.int32)))
    return m.astype(bool)


def _true_negatives(store, n, avoid, start=1_000_000) -> np.ndarray:
    """Rejection-sample ``n`` absent keys the filter itself rules out."""
    out = []
    x = start
    avoid = set(int(a) for a in avoid)
    while len(out) < n:
        cands = np.asarray([k for k in range(x, x + 256)
                            if k not in avoid], np.int64)
        neg = cands[~_probe(store, cands)]
        out.extend(int(k) for k in neg[: n - len(out)])
        x += 256
        assert x < start + 1 << 22, "filter FPR implausibly high"
    return np.asarray(out, np.int64)


def _qstats(store):
    s = store.stats()
    return {k[len("query_"):]: v for k, v in s.items()
            if k.startswith("query_")}


# ---------------------------------------------------------------------------
# the invariant: no false negatives, across regions × schemes × backends
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("scheme", SCHEMES)
def test_no_false_negatives_across_regions(scheme):
    """Keys living in data / change / overflow all survive the filter,
    and the filtered batched path stays exact vs the sim oracle."""
    st = _open("device", scheme)
    sim = _sim(scheme)
    rng = np.random.default_rng(0)
    # data + overflow: overfill one block (r=64) so the excess spills
    hot = _same_block_keys(st.cfg.pair, 3, 80)
    bulk = rng.integers(0, 500, size=400)
    merged = np.concatenate([hot, hot[:8], bulk])
    st.update(merged)
    st.flush()
    # a second merge re-drains the carried keys into the now-full block,
    # spilling them to overflow (the kick key marks the engine dirty —
    # a bare flush() after a merge is a contractual no-op)
    kick = np.asarray([123_456])
    st.update(kick)
    st.flush()
    sim.insert_batch(kick)
    assert st.wear()["dropped"] == 0
    assert int(np.asarray(st.state.ov_keys != -1).sum()) >= 8  # real spill
    sim.insert_batch(merged)
    sim.finalize()
    # change segment / log: staged, never merged (MB merges immediately)
    staged = np.arange(10_000, 10_040)
    st.update(staged)
    st.drain()
    sim.insert_batch(staged)
    present = np.unique(np.concatenate([merged, staged, kick]))
    assert _probe(st, present).all()          # the invariant itself
    absent = np.arange(500_000, 500_064)
    q = np.concatenate([present, absent])
    got = st.query_batch(q)
    oracle = np.asarray([sim.query(int(k)) for k in q])
    np.testing.assert_array_equal(got, oracle)
    st.close()


@pytest.mark.parametrize("scheme", SCHEMES)
def test_true_negatives_cost_zero_tiles(scheme):
    """Ops level: a batch of filter-ruled-out keys fetches no tile at
    all; the same batch without filters pays per-block fetches."""
    cfg = _cfg(scheme)
    state = tj.init(cfg)
    state = tj.update(cfg, state, jnp.asarray(np.arange(0, 3000, 3)))
    state = tj.flush(cfg, state)
    may = np.asarray(tj.filter_probe(
        cfg, state, jnp.asarray(np.arange(7_000_000, 7_002_048), jnp.int32)))
    neg = np.arange(7_000_000, 7_002_048)[~may.astype(bool)][:256]
    assert neg.size == 256
    cnt, dist, tiles = tj.lookup_ex(cfg, state, jnp.asarray(neg, jnp.int32))
    assert int(tiles) == 0
    assert int(np.asarray(cnt).sum()) == 0
    assert int(np.asarray(dist).sum()) == 0   # filtered keys: distance 0
    off = _cfg(scheme, filters=False)
    _, _, tiles_off = tj.lookup_ex(off, state, jnp.asarray(neg, jnp.int32))
    assert int(tiles_off) > 0                 # the traffic the filter saves
    # mixed batch: filters only ever shrink the fetched-tile set
    mixed = jnp.asarray(np.concatenate([np.arange(0, 90, 3), neg[:90]]),
                        jnp.int32)
    c_on, _, t_on = tj.lookup_ex(cfg, state, mixed)
    c_off, _, t_off = tj.lookup_ex(off, state, mixed)
    np.testing.assert_array_equal(np.asarray(c_on), np.asarray(c_off))
    assert int(t_on) <= int(t_off)


def test_wave_skip_on_compacted_block_list():
    """Satellite: the wave loop is sized by the *post-filter* max_load —
    an overloaded block whose queries are mostly definite misses drops
    below the wave boundary, and an all-filtered batch runs zero waves
    (tiles == 0) while still answering exact zeros."""
    cfg = _cfg("MB")
    state = tj.init(cfg)
    present = _same_block_keys(cfg.pair, 5, 3)
    state = tj.update(cfg, state, jnp.asarray(present))
    # 200 same-block keys > qcap=128 → 2 waves unfiltered; after the
    # filter kills the absent ones the survivors fit one wave
    cands = _same_block_keys(cfg.pair, 5, 200)
    may = np.asarray(tj.filter_probe(cfg, state, jnp.asarray(cands,
                                                             jnp.int32)))
    q = np.concatenate([present,
                        cands[~may.astype(bool)][:197]])
    cnt, dist, tiles = tj.lookup_ex(cfg, state, jnp.asarray(q, jnp.int32))
    np.testing.assert_array_equal(np.asarray(cnt)[:3], np.ones(3))
    assert int(np.asarray(cnt)[3:].sum()) == 0
    assert int(tiles) == 1                    # one block survived
    # all-filtered: zero tiles, zero waves, all-zero answers
    allneg = q[3:]
    cnt0, _, tiles0 = tj.lookup_ex(cfg, state,
                                   jnp.asarray(allneg, jnp.int32))
    assert int(tiles0) == 0 and int(np.asarray(cnt0).sum()) == 0


# ---------------------------------------------------------------------------
# engine: negative verdicts, negative cache, epoch fence (satellite 1)
# ---------------------------------------------------------------------------
def test_engine_skips_dispatch_and_caches_negatives():
    st = _open("device", "MDB-L")
    st.update(np.arange(100))
    st.flush()
    neg = _true_negatives(st, 32, avoid=np.arange(100))
    base = _qstats(st)
    got = st.query_batch(neg)
    assert int(got.sum()) == 0
    s1 = _qstats(st)
    assert s1["filter_negatives"] - base["filter_negatives"] == 32
    # every key was ruled out before dispatch: no lookup ran at all
    assert s1["device_dispatches"] == base["device_dispatches"]
    assert s1["tile_loads"] == base["tile_loads"]
    # negative entries went into the hot cache: the repeat is all hits
    got2 = st.query_batch(neg)
    s2 = _qstats(st)
    assert int(got2.sum()) == 0
    assert s2["cache_hits"] - s1["cache_hits"] == 32
    assert s2["filter_negatives"] == s1["filter_negatives"]
    st.close()


def test_flush_invalidate_evicts_negative_entries():
    """Regression (satellite 1): a cached negative must die with the
    epoch like any positive entry — else the first write to a
    previously-absent key would be shadowed by a stale 0 forever."""
    st = _open("device", "MDB-L")
    st.update(np.arange(50))
    st.flush()
    neg = _true_negatives(st, 8, avoid=np.arange(50))
    assert int(st.query_batch(neg).sum()) == 0       # cached as zeros
    st.update(neg)                                    # the keys appear...
    st.flush()                                        # ...and invalidate()
    np.testing.assert_array_equal(st.query_batch(neg), np.ones(8))
    s = _qstats(st)
    assert s["invalidations"] >= 1
    st.close()


def test_present_keys_never_filtered():
    """Engine end-to-end twin of the ops-level invariant: present keys
    (merged, staged or still buffered in H_R) always answer exactly."""
    st = _open("device", "MDB")
    merged = np.arange(0, 600, 3)
    st.update(merged)
    st.flush()
    staged = np.arange(20_000, 20_030)
    st.update(staged)
    st.drain()
    buffered = np.arange(30_000, 30_010)              # H_R only
    st.update(buffered)
    q = np.concatenate([merged, staged, buffered])
    np.testing.assert_array_equal(st.query_batch(q), np.ones(q.size))
    st.close()


def test_filters_off_store_still_exact():
    """cfg.filters=False: no filter_fn is wired, every miss dispatches,
    and answers stay exact (the A/B baseline the benchmarks use)."""
    st = _open("device", "MDB-L")
    off = FlashStore.open(_cfg("MDB-L", filters=False), backend="device",
                          chunk=128, flush_threshold=10_000)
    assert off._b.query_engine._filter is None
    for s in (st, off):
        s.update(np.arange(64))
        s.flush()
    absent = np.arange(900_000, 900_032)
    q = np.concatenate([np.arange(64), absent])
    np.testing.assert_array_equal(st.query_batch(q), off.query_batch(q))
    so = _qstats(off)
    assert so["filter_negatives"] == 0
    assert so["device_dispatches"] >= 1
    st.close()
    off.close()


# ---------------------------------------------------------------------------
# durability surfaces: post-restore, post-handoff (satellite 3)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend", ["device", "sharded"])
def test_no_false_negatives_post_restore(tmp_path, backend):
    wal = tmp_path / "s.wal"
    snap = tmp_path / "snap"
    st = _open(backend, "MDB-L", wal=wal)
    st.update(np.arange(100), np.ones(100, np.int64))
    st.drain(wait=True)
    st.snapshot(snap)                         # filter rides the pytree
    st.update(np.arange(100, 130))
    st.drain(wait=True)                       # sealed + logged, not snap'd
    st.close()

    st2 = _open(backend, "MDB-L", wal=wal)
    st2.restore(snap)                         # snapshot + WAL tail replay
    present = np.arange(130)
    assert _probe(st2, present).all()
    np.testing.assert_array_equal(st2.query_batch(present), np.ones(130))
    neg = _true_negatives(st2, 16, avoid=present)
    base = _qstats(st2)
    assert int(st2.query_batch(neg).sum()) == 0
    s = _qstats(st2)
    assert s["filter_negatives"] - base["filter_negatives"] == 16
    assert s["tile_loads"] == base["tile_loads"]
    st2.close()


def test_no_false_negatives_post_handoff(tmp_path):
    from repro.runtime.elastic import handoff_hr_partitions
    wal = tmp_path / "depart.wal"
    a = _open("sharded", wal=wal)
    toks = np.arange(200)
    a.update(toks, np.ones(200, np.int64))
    a.drain(wait=True)
    a.close()                                 # node departs; WAL survives

    b = _open("sharded")
    handoff_hr_partitions(wal, b)             # replays through update path
    b.drain(wait=True)                        # staged → filter maintained
    assert _probe(b, toks).all()
    np.testing.assert_array_equal(b.query_batch(toks), np.ones(200))
    neg = _true_negatives(b, 8, avoid=toks)
    assert int(b.query_batch(neg).sum()) == 0
    assert _qstats(b)["filter_negatives"] >= 8
    b.close()


def test_sharded_filter_parity_with_sim():
    st = _open("sharded")
    sim = _sim("MDB-L")
    rng = np.random.default_rng(3)
    toks = rng.integers(0, 600, size=800)
    st.update(toks)
    st.flush()
    sim.insert_batch(toks)
    sim.finalize()
    q = np.concatenate([np.unique(toks), np.arange(40_000, 40_064)])
    got = st.query_batch(q)
    oracle = np.asarray([sim.query(int(k)) for k in q])
    np.testing.assert_array_equal(got, oracle)
    st.close()


# ---------------------------------------------------------------------------
# the sim's costed twin
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("scheme", SCHEMES)
def test_sim_twin_true_negative_is_free(scheme):
    t = _sim(scheme)
    t.insert_batch(np.arange(300))
    t.finalize()
    # rejection-sample through the sim's own filter
    neg = [k for k in range(100_000, 100_400)
           if not t.filters.may_contain(int(t.pair.s(k)), k)][:32]
    assert len(neg) == 32
    pages_before = (t.ledger.page_ops, t.qstats.ds_page_reads,
                    t.qstats.overflow_page_reads, t.qstats.cs_page_reads)
    for k in neg:
        assert t.query(k) == 0
    assert t.qstats.filter_negatives == 32
    after = (t.ledger.page_ops, t.qstats.ds_page_reads,
             t.qstats.overflow_page_reads, t.qstats.cs_page_reads)
    assert after == pages_before              # zero flash reads accrued
    # the filterless twin pays data-segment page reads for the same keys
    t_off = _sim(scheme, filters=False)
    assert t_off.filters is None
    t_off.insert_batch(np.arange(300))
    t_off.finalize()
    for k in neg:
        assert t_off.query(k) == 0
    assert t_off.qstats.filter_negatives == 0
    assert t_off.qstats.ds_page_reads > 0


@pytest.mark.parametrize("scheme", SCHEMES)
def test_sim_twin_no_false_negatives(scheme):
    """Filtered and filterless sims agree on every key — present keys
    are never short-circuited to 0 (RAM-buffered keys included: the
    buffer answers before flash, bits are OR'd at the drain boundary)."""
    t_on = _sim(scheme)
    t_off = _sim(scheme, filters=False)
    rng = np.random.default_rng(7)
    stream = rng.integers(0, 500, size=1200)
    for t in (t_on, t_off):
        t.insert_batch(stream)                # flushes mid-stream
    q = list(range(520)) + [9999, 12345]      # present + tail-absent
    got = [t_on.query(k) for k in q]
    want = [t_off.query(k) for k in q]
    assert got == want


# ---------------------------------------------------------------------------
# maintenance soundness: rebuild vs incremental OR
# ---------------------------------------------------------------------------
def test_rebuild_filters_covers_and_is_subset():
    """``rebuild_filters`` (fresh OR over data+log+overflow) covers every
    present key, and its bit set is a subset of the incrementally
    maintained one — the monotone-OR discipline only ever *adds* bits
    (e.g. for keys that later moved on a merge), so dirty-block
    maintenance can never lose coverage the rebuild would have."""
    cfg = _cfg("MDB-L")
    state = tj.init(cfg)
    rng = np.random.default_rng(11)
    for _ in range(4):
        state = tj.update(cfg, state,
                          jnp.asarray(rng.integers(0, 5000, size=600)))
    state = tj.flush(cfg, state)
    state = tj.update(cfg, state, jnp.asarray(np.arange(90_000, 90_050)))
    maintained = np.asarray(state.filter_words)
    rebuilt = np.asarray(
        seg.rebuild_filters(cfg.pair, state).filter_words)
    assert (rebuilt & ~maintained).sum() == 0          # subset
    fresh = state._replace(filter_words=jnp.asarray(rebuilt))
    present = np.unique(np.concatenate(
        [np.asarray(state.keys).ravel(),
         np.asarray(state.log_keys).ravel(),
         np.asarray(state.ov_keys).ravel()]))
    present = present[present != tj.EMPTY]
    may = np.asarray(tj.filter_probe(cfg, fresh,
                                     jnp.asarray(present, jnp.int32)))
    assert may.all()


def test_bloom_positions_disjoint_and_deterministic():
    """The murmur-finalizer probe pair: both positions in range, not
    degenerately equal across a dense key population (the correlation
    bug the finalizer exists to kill), numpy ≡ jax."""
    keys = np.arange(4096, dtype=np.int64)
    fw = filter_words_for(64)
    bits_log2 = (fw * 32).bit_length() - 1
    p1, p2 = bloom_positions(keys, bits_log2)
    assert int(p1.max()) < fw * 32 and int(p2.max()) < fw * 32
    assert (p1 == p2).mean() < 0.05           # probes are independent
    j1, j2 = bloom_positions(jnp.asarray(keys, jnp.int32), bits_log2)
    np.testing.assert_array_equal(np.asarray(j1), p1.astype(np.uint32))
    np.testing.assert_array_equal(np.asarray(j2), p2.astype(np.uint32))
