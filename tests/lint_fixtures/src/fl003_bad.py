"""flashlint fixture: FL003 — a .state rebind with no invalidation,
plus the two Bloom-filter contract breaks (DESIGN.md §12)."""


class ForgetfulBackend:
    def __init__(self, state, query_engine):
        self.state = state                    # first bind: exempt
        self.query_engine = query_engine

    def drain(self, new_state):
        self.state = new_state                # stale cache survives this


def rebuild_without_filters(old):
    # keyword rebuild that silently drops the filter arrays
    return DeviceTableState(
        keys=old.keys, counts=old.counts, log_keys=old.log_keys,
        log_counts=old.log_counts, log_ptr=old.log_ptr,
        ov_keys=old.ov_keys, ov_counts=old.ov_counts, ov_ptr=old.ov_ptr,
        stats=old.stats)


def merge_no_filter_maintenance(pair, old, perm, uk, uc):
    # device merge that skips the in-kernel filter maintenance
    return hops.merge_dirty(pair, old.keys, old.counts, perm, uk, uc)
