"""flashlint fixture: FL003 — a .state rebind with no invalidation."""


class ForgetfulBackend:
    def __init__(self, state, query_engine):
        self.state = state                    # first bind: exempt
        self.query_engine = query_engine

    def drain(self, new_state):
        self.state = new_state                # stale cache survives this
