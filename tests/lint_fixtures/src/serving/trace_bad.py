"""flashlint fixture: FL004 — threading in a serving file that is not
the scheduler (only ``serving/scheduler.py``'s trace-replay feeders may
spawn workers)."""
import threading


def rogue_feeder(fn):
    t = threading.Thread(target=fn)
    t.start()
    return t
