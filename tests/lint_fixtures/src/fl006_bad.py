"""flashlint fixture: FL006 — guarded field touched outside the lock."""


class LeakyEngine:
    _fl_guarded = ("state", "_inflight")

    def __init__(self, dispatcher, state):
        self.dispatcher = dispatcher
        self.state = state                    # __init__: exempt
        self._inflight = None

    def _lock(self):
        return self.dispatcher.lock

    def peek(self):
        return self.state                     # unlocked guarded read

    def snapshot(self):
        with self._lock():
            return self.state                 # correctly locked
