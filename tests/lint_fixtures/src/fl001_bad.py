"""flashlint fixture: FL001 — engine construction outside core/store.py.

Deliberately violating file; the recursive walk skips ``lint_fixtures``
directories, so only the flashlint tests ever lint this."""
from repro.core.query_engine import BatchedQueryEngine
from repro.core.write_engine import BatchedWriteEngine


def hand_wired_pair(cfg):
    qe = BatchedQueryEngine(cfg)
    return BatchedWriteEngine(cfg, query_engine=qe), qe
