"""flashlint fixture: FL002 — reading a binding after donating it."""
from repro.core import table_jax as tj


def drain_once(cfg, state, toks):
    new_state = tj.update(cfg, state, toks)   # donates ``state``
    stale = state.keys                        # read of the spent binding
    return new_state, stale
