"""flashlint fixture: FL005 — an *aliased* deprecated-shim import, the
case the old ``forbid-shims`` CI grep could not see through."""
from repro.core.tfidf import DeviceTableAdapter as DTA


def open_table(cfg):
    return DTA(cfg)
