"""flashlint fixture: FL004 — threading outside the store dispatcher."""
import threading
from concurrent.futures import ThreadPoolExecutor


def rogue_worker(fn):
    pool = ThreadPoolExecutor(max_workers=1)
    t = threading.Thread(target=fn)
    t.start()
    return pool, t
