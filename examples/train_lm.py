"""End-to-end driver: pretrain a (reduced) model for a few hundred steps
through the full stack — deterministic loader, TF-IDF data filter, AdamW,
checkpoint/restart runtime. Any of the 10 assigned architectures works
via --arch; default trains a ~tiny llama3.2 on CPU in a couple minutes.

Run: PYTHONPATH=src python examples/train_lm.py [--arch mamba2_2p7b]
     (full-size archs: omit --tiny on a real pod slice)
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.launch.train import main

if __name__ == "__main__":
    sys.argv += ["--tiny", "--steps", "200", "--ckpt-dir",
                 "/tmp/repro_ckpt"] if "--steps" not in sys.argv else []
    main()
