"""Serving example: batched greedy decoding with the flash-hash prefix
KV cache (counting refcounts — the paper's §1 refcounting use case).

Run: PYTHONPATH=src python examples/serve_lm.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.launch.serve import main

if __name__ == "__main__":
    if "--arch" not in sys.argv:
        sys.argv += ["--arch", "llama32_3b", "--tiny", "--requests", "8",
                     "--prompt-len", "32", "--shared-prefix", "24",
                     "--max-new", "8"]
    main()
