"""Quickstart: the paper's counting hash table in 60 seconds.

Builds all three schemes (MB / MDB / MDB-L), streams a zipf token corpus,
compares their I/O ledgers on the paper's three SSD configurations, and
shows the device-resident (JAX/Pallas) twin answering the same queries.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import numpy as np
import jax.numpy as jnp

from repro.core import DEVICES, TableGeometry, make_table
from repro.core import table_jax as tj

rng = np.random.default_rng(0)
tokens = (rng.zipf(1.4, size=200_000) % (1 << 20)).astype(np.int64)
geom = TableGeometry(num_blocks=16, pages_per_block=64, entries_per_page=64)

print("=== SSD simulation (paper §3) ===")
for scheme in ("MB", "MDB", "MDB-L", "naive"):
    t = make_table(scheme, geom, ram_buffer_pct=5.0, change_segment_pct=12.5)
    t.insert_batch(tokens)
    t.finalize()
    led = t.ledger
    ios = {name: led.time_us(dev) / 1e6 for name, dev in DEVICES.items()}
    print(f"{scheme:6s} cleans={led.cleans:6d} block_ops={led.block_ops:6d} "
          f"page_ops={led.page_ops:7d} "
          + " ".join(f"{n}={s:7.2f}s" for n, s in ios.items()))

print("\n=== device-resident twin (JAX + Pallas kernels) ===")
cfg = tj.FlashTableConfig(q_log2=16, r_log2=10, scheme="MDB-L")
state = tj.init(cfg)
for i in range(0, len(tokens), 16384):
    chunk = tokens[i:i + 16384]
    if len(chunk) < 16384:
        chunk = np.pad(chunk, (0, 16384 - len(chunk)),
                       constant_values=tj.EMPTY)
    state = tj.update(cfg, state, jnp.asarray(chunk, jnp.int32))
state = tj.flush(cfg, state)
probe = np.unique(tokens)[:512]
cnt, dist = tj.lookup(cfg, state, jnp.asarray(probe, jnp.int32))
from collections import Counter
truth = Counter(tokens.tolist())
ok = all(truth[int(k)] == int(c) for k, c in zip(probe, cnt))
print(f"512 point queries correct: {ok}; "
      f"mean probe distance {float(dist.mean()):.2f} slots; "
      f"tile rewrites (clean analogue): {int(state.stats.tile_stores)}")
