"""Quickstart: one `FlashStore`, three backends (SSD simulator, JAX/Pallas
device table, multi-device sharded table) — same API, same deferred-update
discipline (H_R buffer → block-local merges).

Run: PYTHONPATH=src python examples/quickstart.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))
import numpy as np

from repro.core import FlashStore

rng = np.random.default_rng(0)
tokens = (rng.zipf(1.4, size=200_000) % (1 << 20)).astype(np.int64)
uniq, cnt = np.unique(tokens, return_counts=True)
probe, truth = uniq[:512], dict(zip(uniq.tolist(), cnt.tolist()))

for backend in ("sim", "device", "sharded"):
    with FlashStore.open(backend=backend, scheme="MDB-L") as store:
        store.update(tokens)                    # buffered + deduped in H_R
        store.increment(int(probe[0]), -1)      # deletion-by-decrement §2.6
        store.increment(int(probe[0]), +1)
        counts = store.query(probe)             # batched, read-your-writes
        ok = all(truth[int(k)] == int(c) for k, c in zip(probe, counts))
        store.flush()                           # durability point: merge
        wear = store.stats().get("tile_stores", store.stats().get("cleans"))
        print(f"{backend:8s} 512 point queries correct: {ok}; "
              f"wear (cleans analogue): {wear}")
