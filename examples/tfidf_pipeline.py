"""The paper's TF-IDF application end-to-end (paper §3.2), plus its role in
this framework: flash-hash corpus statistics driving LM data filtering.

Run: PYTHONPATH=src python examples/tfidf_pipeline.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import numpy as np

from repro.core import TableGeometry
from repro.core.tfidf import TfIdfPipeline, tokenize
from repro.data import CorpusStats, LoaderConfig, SyntheticCorpus, make_batch

DOCS = [
    "flash devices have fast sequential writes and slow random writes",
    "hash tables rely on the randomness of the hash function",
    "the change segment buffers updates like a log structured file system",
    "counting hash tables keep a frequency per key and support deletion",
    "solid state drives wear out after too many erase write cycles",
] * 20

print("=== TF-IDF over the counting hash table (paper §3.2) ===")
# every table behind the pipeline is a FlashStore (DESIGN.md §8);
# backend="sim" | "device" | "sharded" swaps the engine with no other change
geom = TableGeometry(num_blocks=8, pages_per_block=16, entries_per_page=32)
pipe = TfIdfPipeline(geom, scheme="MDB-L", ram_buffer_pct=5.0, backend="sim")
for d in DOCS:
    pipe.add_document(tokenize(d))
pipe.finalize()
doc = tokenize(DOCS[0])
scores = pipe.tfidf(doc)
top = sorted(scores.items(), key=lambda kv: -kv[1])[:5]
print("top keywords of doc 0:", [t for t, _ in top])
print(f"'the' idf={pipe.idf('the'):.3f}  'sequential' idf="
      f"{pipe.idf('sequential'):.3f}")
s = pipe.term_table.stats()
print(f"I/O ledger: cleans={s['cleans']} block_ops={s['block_ops']} "
      f"page_ops={s['page_ops']}")

print("\n=== as the LM data layer (framework integration) ===")
corpus = SyntheticCorpus(num_docs=200, mean_doc_len=96, vocab_size=8000,
                         seed=7)
stats = CorpusStats.create(q_log2=15, r_log2=9)
for d in corpus:
    stats.ingest(d)
stats.flush()
scores = [stats.doc_score(corpus.doc_tokens(i)) for i in range(20)]
thr = float(np.median(scores))
lcfg = LoaderConfig(corpus=corpus, seq_len=128, global_batch=4,
                    microbatches=1, vocab_size=8000,
                    doc_filter=stats.doc_filter(thr))
batch = make_batch(lcfg, step=0)
print(f"filtered batch ready: tokens {batch['tokens'].shape}, "
      f"median doc score {thr:.3f}")
