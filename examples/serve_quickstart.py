"""Serving quickstart: continuous batching over the paged prefix-KV
block pool (counting flash-hash refcounts as the page table), driven by
a tiny Zipf user trace on the sim backend.

Run: PYTHONPATH=src python examples/serve_quickstart.py
"""
import dataclasses
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))
import jax

from repro.configs import get_config
from repro.models import model as M
from repro.serving import (ContinuousBatchingScheduler, PrefixKVCache,
                           make_trace, replay_trace)

cfg = dataclasses.replace(get_config("llama32_3b", tiny=True),
                          dtype="float32")
params = M.init_params(jax.random.PRNGKey(0), cfg)

cache = PrefixKVCache(block_tokens=16, capacity_blocks=64, backend="sim")
sched = ContinuousBatchingScheduler(cfg, params, prefix_cache=cache,
                                    max_slots=4, max_context=96)
trace = make_trace(num_requests=12, num_users=3, prefix_blocks=2,
                   max_new_tokens=8, vocab_size=cfg.vocab_size, seed=0)
report = replay_trace(sched, trace, workers=2)

print(report.summary())
s = cache.stats()
print(f"blocks resident={s['resident']} pool_high_water="
      f"{s['pool_high_water']} refcount_evictions={s['evictions']}")
